#!/usr/bin/env python3
"""Validate BENCH_results.json against the benchmark record schema.

Every record must be exactly

    {"name": str, "config": dict, "metrics": dict, "timestamp": int}

(`benchmarks/common.py` normalizes free-form emits into this shape; this
check keeps the stored file canonical so cross-PR tooling can rely on it).
`serve_engine_faults` records get an extra pass: each chaos scenario's
sub-dict must carry its recovery/goodput keys with sane types.
`serve_engine_precision` records likewise: every fleet must report both
cost models' served energy, and the adaptive scenario must carry its
vs-pinned energy wins and bit-identity flags.
`serve_engine_speculative` records: plain and speculative modes must both
report their decode-goodput metrics, the speculative mode its draft/accept
ledger, and the record its accept rate, vs-plain goodput win, greedy
bit-identity flag and sampled seed-determinism flag.
`serve_engine_fleet` records: in-process and subprocess serving modes must
both report their per-router-step wall time (the IPC overhead comparison),
and the chaos pass its kill->replay outcome flags.
`serve_engine_obs` records: the observability-attached fleet pass must
report its measured overhead vs detached serving, the merged cross-process
trace size, and the bit-identity (no-perturbation) flag.
Duplicate records — same ``(name, config, timestamp)`` — are rejected
file-wide: they are double-appends, not new measurements.
Stdlib-only — runs in the docs CI job without the jax toolchain.

    python tools/check_bench_schema.py [BENCH_results.json ...]
"""
from __future__ import annotations

import json
import sys

REQUIRED = {
    "name": str,
    "config": dict,
    "metrics": dict,
    "timestamp": (int, float),
}

# bench_faults records must carry one sub-dict per chaos scenario with its
# recovery/goodput metrics, so cross-PR tooling can chart them.
FAULT_SCENARIOS = {
    "wedge_reroute": ("reroutes", "recovery_steps", "bit_identical",
                      "router_steps", "goodput_ok_per_step"),
    "nan_poison": ("failed", "partials_intact", "clean_partial_tokens"),
    "overload": ("submitted", "ok", "rejected"),
}
FAULT_NUMERIC = ("reroutes", "recovery_steps", "router_steps",
                 "goodput_ok_per_step", "failed", "clean_partial_tokens",
                 "submitted", "ok", "rejected")
FAULT_BOOL = ("bit_identical", "partials_intact")


def check_faults_record(rec) -> list:
    problems = []
    metrics = rec.get("metrics")
    if not isinstance(metrics, dict):
        return problems                 # shape error already reported
    for scenario, keys in FAULT_SCENARIOS.items():
        sub = metrics.get(scenario)
        if not isinstance(sub, dict):
            problems.append(f"metrics.{scenario} missing or not an object")
            continue
        for k in keys:
            if k not in sub:
                problems.append(f"metrics.{scenario} missing '{k}'")
        for k in FAULT_NUMERIC:
            if k in sub and (isinstance(sub[k], bool)
                             or not isinstance(sub[k], (int, float))):
                problems.append(f"metrics.{scenario}.{k} must be numeric")
        for k in FAULT_BOOL:
            if k in sub and not isinstance(sub[k], bool):
                problems.append(f"metrics.{scenario}.{k} must be a bool")
    return problems


# bench_precision records: every fleet reports both cost models on the same
# served trace; the adaptive scenario carries its pinned-fleet comparison.
PRECISION_FLEET_KEYS = ("served_energy_j", "served_energy_analytical_j",
                        "precision_counts", "top1_agreement_vs_fp32",
                        "mean_abs_logit_delta")
PRECISION_ADAPTIVE_NUMERIC = ("energy_win_vs_fp32_eq3",
                              "energy_win_vs_fp32_analytical")
PRECISION_ADAPTIVE_BOOL = ("pinned_bit_identical",
                           "per_precision_bit_identical")


def check_precision_record(rec) -> list:
    problems = []
    metrics = rec.get("metrics")
    if not isinstance(metrics, dict):
        return problems                 # shape error already reported
    fleets = metrics.get("fleets")
    if not isinstance(fleets, dict):
        problems.append("metrics.fleets missing or not an object")
    else:
        for required in ("fp32", "adaptive"):
            if required not in fleets:
                problems.append(f"metrics.fleets missing '{required}' — need "
                                "at least one adaptive-vs-pinned scenario")
        for fleet, sub in fleets.items():
            if not isinstance(sub, dict):
                problems.append(f"metrics.fleets.{fleet} not an object")
                continue
            for k in PRECISION_FLEET_KEYS:
                if k not in sub:
                    problems.append(f"metrics.fleets.{fleet} missing '{k}'")
            for k in ("served_energy_j", "served_energy_analytical_j"):
                if k in sub and (isinstance(sub[k], bool)
                                 or not isinstance(sub[k], (int, float))):
                    problems.append(
                        f"metrics.fleets.{fleet}.{k} must be numeric")
    adaptive = metrics.get("adaptive")
    if not isinstance(adaptive, dict):
        problems.append("metrics.adaptive missing or not an object")
        return problems
    for k in PRECISION_ADAPTIVE_NUMERIC:
        if k not in adaptive:
            problems.append(f"metrics.adaptive missing '{k}'")
        elif isinstance(adaptive[k], bool) or not isinstance(
                adaptive[k], (int, float)):
            problems.append(f"metrics.adaptive.{k} must be numeric")
    for k in PRECISION_ADAPTIVE_BOOL:
        if k not in adaptive:
            problems.append(f"metrics.adaptive missing '{k}'")
        elif not isinstance(adaptive[k], bool):
            problems.append(f"metrics.adaptive.{k} must be a bool")
    return problems


# bench_speculative records: both decode modes' goodput on the same greedy
# trace, the speculative draft/accept ledger, and the correctness flags the
# CI smoke guard gates on.
SPECULATIVE_MODE_KEYS = ("steps_run", "decode_tokens",
                         "goodput_decode_tok_per_step")
SPECULATIVE_LEDGER_KEYS = ("drafted_tokens", "accepted_tokens",
                           "goodput_accepted_tok_per_step")
SPECULATIVE_NUMERIC = ("accept_rate", "goodput_win")
SPECULATIVE_BOOL = ("bit_identical",)


def check_speculative_record(rec) -> list:
    problems = []
    metrics = rec.get("metrics")
    if not isinstance(metrics, dict):
        return problems                 # shape error already reported
    for mode in ("plain", "speculative"):
        sub = metrics.get(mode)
        if not isinstance(sub, dict):
            problems.append(f"metrics.{mode} missing or not an object")
            continue
        keys = SPECULATIVE_MODE_KEYS
        if mode == "speculative":
            keys = keys + SPECULATIVE_LEDGER_KEYS
        for k in keys:
            if k not in sub:
                problems.append(f"metrics.{mode} missing '{k}'")
            elif isinstance(sub[k], bool) or not isinstance(
                    sub[k], (int, float)):
                problems.append(f"metrics.{mode}.{k} must be numeric")
    for k in SPECULATIVE_NUMERIC:
        if k not in metrics:
            problems.append(f"metrics missing '{k}'")
        elif isinstance(metrics[k], bool) or not isinstance(
                metrics[k], (int, float)):
            problems.append(f"metrics.{k} must be numeric")
    for k in SPECULATIVE_BOOL:
        if k not in metrics:
            problems.append(f"metrics missing '{k}'")
        elif not isinstance(metrics[k], bool):
            problems.append(f"metrics.{k} must be a bool")
    sampling = metrics.get("sampling")
    if not isinstance(sampling, dict):
        problems.append("metrics.sampling missing or not an object")
    elif not isinstance(sampling.get("seed_deterministic"), bool):
        problems.append("metrics.sampling.seed_deterministic must be a bool")
    return problems


# bench_fleet records: both serving modes' per-step wall time (the IPC
# overhead comparison) plus the chaos pass's replay outcome flags.
FLEET_MODE_KEYS = ("wall_s", "router_steps", "step_ms", "req_per_s")
FLEET_CHAOS_NUMERIC = ("drains", "rerouted", "router_steps")
FLEET_CHAOS_BOOL = ("all_ok", "bit_identical")


def check_fleet_record(rec) -> list:
    problems = []
    metrics = rec.get("metrics")
    if not isinstance(metrics, dict):
        return problems                 # shape error already reported
    for mode in ("inproc", "subprocess"):
        sub = metrics.get(mode)
        if not isinstance(sub, dict):
            problems.append(f"metrics.{mode} missing or not an object")
            continue
        keys = FLEET_MODE_KEYS + (("spawn_s",) if mode == "subprocess"
                                  else ())
        for k in keys:
            if k not in sub:
                problems.append(f"metrics.{mode} missing '{k}'")
            elif isinstance(sub[k], bool) or not isinstance(
                    sub[k], (int, float)):
                problems.append(f"metrics.{mode}.{k} must be numeric")
    if "ipc_overhead_x" not in metrics:
        problems.append("metrics missing 'ipc_overhead_x'")
    elif isinstance(metrics["ipc_overhead_x"], bool) or not isinstance(
            metrics["ipc_overhead_x"], (int, float)):
        problems.append("metrics.ipc_overhead_x must be numeric")
    if not isinstance(metrics.get("bit_identical"), bool):
        problems.append("metrics.bit_identical must be a bool")
    chaos = metrics.get("chaos")
    if not isinstance(chaos, dict):
        problems.append("metrics.chaos missing or not an object")
        return problems
    for k in FLEET_CHAOS_NUMERIC:
        if k not in chaos:
            problems.append(f"metrics.chaos missing '{k}'")
        elif isinstance(chaos[k], bool) or not isinstance(
                chaos[k], (int, float)):
            problems.append(f"metrics.chaos.{k} must be numeric")
    for k in FLEET_CHAOS_BOOL:
        if k not in chaos:
            problems.append(f"metrics.chaos missing '{k}'")
        elif not isinstance(chaos[k], bool):
            problems.append(f"metrics.chaos.{k} must be a bool")
    return problems


# bench_fleet's observability pass (serve_engine_obs records): the obs tax
# vs the detached subprocess fleet, the merged cross-process trace size, and
# the no-perturbation flag the CI smoke guard gates on.
OBS_NUMERIC = ("wall_s", "step_ms", "overhead_x", "merged_trace_spans",
               "engine_steps")
OBS_BOOL = ("bit_identical",)


def check_obs_record(rec) -> list:
    problems = []
    metrics = rec.get("metrics")
    if not isinstance(metrics, dict):
        return problems                 # shape error already reported
    obs = metrics.get("obs")
    if not isinstance(obs, dict):
        return ["metrics.obs missing or not an object"]
    for k in OBS_NUMERIC:
        if k not in obs:
            problems.append(f"metrics.obs missing '{k}'")
        elif isinstance(obs[k], bool) or not isinstance(obs[k], (int, float)):
            problems.append(f"metrics.obs.{k} must be numeric")
    for k in OBS_BOOL:
        if k not in obs:
            problems.append(f"metrics.obs missing '{k}'")
        elif not isinstance(obs[k], bool):
            problems.append(f"metrics.obs.{k} must be a bool")
    if not isinstance(obs.get("trace_replicas"), list):
        problems.append("metrics.obs.trace_replicas must be a list")
    return problems


def check_record(rec) -> list:
    problems = []
    if not isinstance(rec, dict):
        return [f"record is {type(rec).__name__}, not an object"]
    for key, typ in REQUIRED.items():
        if key not in rec:
            problems.append(f"missing required key '{key}'")
        elif not isinstance(rec[key], typ) or isinstance(rec[key], bool):
            problems.append(
                f"'{key}' is {type(rec[key]).__name__}, expected "
                f"{typ[0].__name__ if isinstance(typ, tuple) else typ.__name__}")
    for key in sorted(set(rec) - set(REQUIRED)):
        problems.append(f"unknown top-level key '{key}' "
                        "(file it under config/metrics)")
    if rec.get("name") == "serve_engine_faults":
        problems += check_faults_record(rec)
    if rec.get("name") == "serve_engine_precision":
        problems += check_precision_record(rec)
    if rec.get("name") == "serve_engine_speculative":
        problems += check_speculative_record(rec)
    if rec.get("name") == "serve_engine_fleet":
        problems += check_fleet_record(rec)
    if rec.get("name") == "serve_engine_obs":
        problems += check_obs_record(rec)
    return problems


def record_key(rec):
    """Measurement-event identity: a second record with the same name,
    config and timestamp adds no information — it is a double-append
    (`benchmarks.common.append_result` now drops these at write time)."""
    if not isinstance(rec, dict):
        return None
    return (rec.get("name"),
            json.dumps(rec.get("config", {}), sort_keys=True),
            rec.get("timestamp"))


def check_file(path: str) -> int:
    try:
        with open(path) as f:
            data = json.load(f)
    except FileNotFoundError:
        print(f"{path}: missing (nothing to check)")
        return 0
    except json.JSONDecodeError as e:
        print(f"{path}: invalid JSON: {e}")
        return 1
    if not isinstance(data, list):
        print(f"{path}: top level must be a JSON list of records")
        return 1
    errors = 0
    seen = {}
    for i, rec in enumerate(data):
        problems = check_record(rec)
        key = record_key(rec)
        if key is not None and key in seen:
            problems = problems + [
                f"duplicate of record [{seen[key]}] "
                "(same name, config and timestamp)"]
        elif key is not None:
            seen[key] = i
        if problems:
            errors += 1
            label = rec.get("name", "?") if isinstance(rec, dict) else "?"
            for p in problems:
                print(f"{path}[{i}] ({label}): {p}")
    print(f"{path}: {len(data)} records, {errors} invalid")
    return 1 if errors else 0


def main(argv) -> int:
    paths = argv or ["BENCH_results.json"]
    return max(check_file(p) for p in paths)


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
