#!/usr/bin/env python3
"""Validate BENCH_results.json against the benchmark record schema.

Every record must be exactly

    {"name": str, "config": dict, "metrics": dict, "timestamp": int}

(`benchmarks/common.py` normalizes free-form emits into this shape; this
check keeps the stored file canonical so cross-PR tooling can rely on it).
Stdlib-only — runs in the docs CI job without the jax toolchain.

    python tools/check_bench_schema.py [BENCH_results.json ...]
"""
from __future__ import annotations

import json
import sys

REQUIRED = {
    "name": str,
    "config": dict,
    "metrics": dict,
    "timestamp": (int, float),
}


def check_record(rec) -> list:
    problems = []
    if not isinstance(rec, dict):
        return [f"record is {type(rec).__name__}, not an object"]
    for key, typ in REQUIRED.items():
        if key not in rec:
            problems.append(f"missing required key '{key}'")
        elif not isinstance(rec[key], typ) or isinstance(rec[key], bool):
            problems.append(
                f"'{key}' is {type(rec[key]).__name__}, expected "
                f"{typ[0].__name__ if isinstance(typ, tuple) else typ.__name__}")
    for key in sorted(set(rec) - set(REQUIRED)):
        problems.append(f"unknown top-level key '{key}' "
                        "(file it under config/metrics)")
    return problems


def check_file(path: str) -> int:
    try:
        with open(path) as f:
            data = json.load(f)
    except FileNotFoundError:
        print(f"{path}: missing (nothing to check)")
        return 0
    except json.JSONDecodeError as e:
        print(f"{path}: invalid JSON: {e}")
        return 1
    if not isinstance(data, list):
        print(f"{path}: top level must be a JSON list of records")
        return 1
    errors = 0
    for i, rec in enumerate(data):
        problems = check_record(rec)
        if problems:
            errors += 1
            label = rec.get("name", "?") if isinstance(rec, dict) else "?"
            for p in problems:
                print(f"{path}[{i}] ({label}): {p}")
    print(f"{path}: {len(data)} records, {errors} invalid")
    return 1 if errors else 0


def main(argv) -> int:
    paths = argv or ["BENCH_results.json"]
    return max(check_file(p) for p in paths)


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
