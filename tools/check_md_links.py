#!/usr/bin/env python
"""Markdown link checker for the docs CI job (stdlib only).

Walks the given files/directories for ``*.md``, extracts inline links and
images ``[text](target)``, and verifies that every *relative* target exists
on disk (anchors are stripped; ``http(s)://`` / ``mailto:`` targets are
skipped — CI must not depend on the network). Exits non-zero listing every
broken link.

    python tools/check_md_links.py README.md docs src/repro/serve/README.md
"""
from __future__ import annotations

import pathlib
import re
import sys

# inline links/images; ignores fenced code via a line-level backtick heuristic
LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")


def md_files(paths):
    for raw in paths:
        p = pathlib.Path(raw)
        if p.is_dir():
            yield from sorted(p.rglob("*.md"))
        elif p.suffix == ".md":
            yield p
        else:
            sys.exit(f"not a markdown file or directory: {p}")


def check_file(path: pathlib.Path):
    broken = []
    in_fence = False
    for lineno, line in enumerate(path.read_text().splitlines(), 1):
        if line.lstrip().startswith("```"):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        for target in LINK_RE.findall(line):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            if not (path.parent / rel).exists():
                broken.append((lineno, target))
    return broken


def main(argv):
    paths = argv or ["README.md", "docs", "src/repro/serve/README.md"]
    failures = 0
    for f in md_files(paths):
        for lineno, target in check_file(f):
            print(f"{f}:{lineno}: broken link -> {target}")
            failures += 1
    if failures:
        sys.exit(f"{failures} broken markdown link(s)")
    print(f"checked {len(list(md_files(paths)))} file(s): all links resolve")


if __name__ == "__main__":
    main(sys.argv[1:])
