"""The paper's §III ablation as a runnable study: sweep weight precision and
measure the spike-count response (quantization-sparsity interplay) plus the
projected FPGA energy via the Eq. 3 workload model.

    PYTHONPATH=src python examples/quant_sparsity_study.py
"""
import dataclasses
import sys

sys.path.insert(0, "src")

import jax

from repro.configs import vgg9_snn
from repro.core.energy import energy_per_image
from repro.core.workload import balance_allocation, conv_workload
from repro.data.synthetic import image_batch
from repro.models.vgg9 import init_vgg9, vgg9_forward, vgg9_loss
from repro.train.optim import adamw
from repro.train.schedule import constant
from repro.train.train_step import init_train_state, make_train_step

BASE = dataclasses.replace(vgg9_snn.TINY, num_classes=4)


def train(cfg, steps=60):
    opt = adamw(weight_decay=0.0)
    step = jax.jit(make_train_step(lambda p, b: vgg9_loss(p, b, cfg), opt,
                                   constant(2e-3)))
    state = init_train_state(init_vgg9(jax.random.PRNGKey(0), cfg), opt)
    for i in range(steps):
        state, _ = step(state, image_batch(0, i, 32, num_classes=4, hw=cfg.img_hw))
    return state["params"]


print(f"{'precision':>10} {'accuracy':>9} {'spikes/img':>11} {'energy (model)':>15}")
for bits in (0, 8, 4, 3):
    cfg = dataclasses.replace(BASE, quant_bits=bits)
    params = train(cfg)
    test = image_batch(55, 0, 64, num_classes=4, hw=cfg.img_hw)
    logits, counts = vgg9_forward(params, test["images"], cfg)
    acc = float((logits.argmax(-1) == test["labels"]).mean())
    spikes = float(sum(float(v) for v in counts.values())) / 64

    # project onto the FPGA cost model (per-image, balanced allocation)
    convs = [c for c in counts if c.startswith("conv")][1:]
    ls = [conv_workload(c, 16, 9, float(counts[c]) / 64) for c in convs]
    alloc = balance_allocation(ls, 12)
    bytes_per = 4.0 if bits == 0 else bits / 8
    e = energy_per_image(ls, alloc, [9 * 16 * 12 * bytes_per] * len(ls),
                         "fp32" if bits == 0 else "int4")
    name = "fp32" if bits == 0 else f"int{bits}"
    print(f"{name:>10} {acc:9.3f} {spikes:11.0f} {e['energy_j']*1e6:12.2f} uJ")
