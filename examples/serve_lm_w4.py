"""Serve a (reduced) assigned-architecture LM with int4-weight numerics —
the paper's quantization pipeline generalized to LM serving (DESIGN.md §4).

    PYTHONPATH=src python examples/serve_lm_w4.py --arch qwen1.5-4b
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core.quant import quantize_int4
from repro.kernels.int4_matmul.ops import w4a16_linear
from repro.models import transformer as tf
from repro.serve.api import EngineConfig
from repro.serve.core import EngineCore
from repro.serve.runners.lm import LMRunner


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-4b")
    ap.add_argument("--tokens", type=int, default=12)
    args = ap.parse_args()

    cfg = get_arch(args.arch).with_(
        n_layers=2 * len(get_arch(args.arch).pattern), tail=(),
        d_model=64, head_dim=16, d_ff=128, vocab=257, dtype="float32",
        remat="none", q_chunk=16, kv_chunk=16, frontend="",
        n_experts=8 if get_arch(args.arch).n_experts else 0,
        n_experts_padded=0, top_k=min(get_arch(args.arch).top_k, 2),
        moe_d_ff=32 if get_arch(args.arch).moe_d_ff else 0,
        d_rnn=64 if get_arch(args.arch).d_rnn else 0, fsdp_experts=False)

    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    print(f"arch={cfg.name} (reduced), serving fp32 vs int4-weight numerics")
    for bits in (0, 4):
        runner = LMRunner(cfg, params, max_seq=64, quant_bits=bits)
        core = EngineCore(runner, EngineConfig(slots=4))
        ids = [core.submit(p, max_new_tokens=args.tokens)
               for p in ([1, 2, 3], [9, 8], [5], [12, 13, 14])]
        results = core.run_until_complete()
        out = [results[i].outputs for i in ids]
        print(f"  w{bits or 16}: {[o[-args.tokens:] for o in out]}")

    # the production-path kernel: packed int4 weights, dequant in VMEM
    w = np.random.default_rng(0).normal(size=(cfg.d_model, cfg.vocab - 1)).astype("float32")
    qt = quantize_int4(jnp.asarray(w[:, :256]))
    x = jnp.asarray(np.random.default_rng(1).normal(size=(4, cfg.d_model)).astype("float32"))
    y = w4a16_linear(x, qt, interpret=True)
    print(f"int4_matmul kernel: x{tuple(x.shape)} @ packed{tuple(qt.packed.shape)} "
          f"-> {tuple(y.shape)}; HBM weight bytes = {qt.nbytes_logical} "
          f"(4x less than bf16)")


if __name__ == "__main__":
    main()
