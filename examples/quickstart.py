"""Quickstart: train a tiny direct-coded spiking VGG9 and inspect the
quantization-sparsity interplay — the paper's core loop in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses
import sys

sys.path.insert(0, "src")

import jax

from repro.configs import vgg9_snn
from repro.data.synthetic import image_batch
from repro.models.vgg9 import init_vgg9, vgg9_forward, vgg9_loss
from repro.train.optim import adamw
from repro.train.schedule import constant
from repro.train.train_step import init_train_state, make_train_step

cfg = dataclasses.replace(vgg9_snn.TINY, num_classes=4)

opt = adamw(weight_decay=0.0)
step = jax.jit(make_train_step(lambda p, b: vgg9_loss(p, b, cfg), opt, constant(2e-3)))
state = init_train_state(init_vgg9(jax.random.PRNGKey(0), cfg), opt)

print("training tiny spiking VGG9 (direct coding, T=2, surrogate gradients)...")
for i in range(50):
    batch = image_batch(0, i, 32, num_classes=4, hw=cfg.img_hw)
    state, metrics = step(state, batch)
    if i % 10 == 0:
        print(f"  step {i:3d}  loss={float(metrics['loss']):.4f}")

# quantization-sparsity interplay (paper Fig. 1)
test = image_batch(9, 0, 64, num_classes=4, hw=cfg.img_hw)
for name, c in (("fp32", cfg), ("int4", dataclasses.replace(cfg, quant_bits=4))):
    logits, counts = vgg9_forward(state["params"], test["images"], c)
    acc = float((logits.argmax(-1) == test["labels"]).mean())
    print(f"{name}: accuracy={acc:.3f} total_spikes={int(sum(counts.values()))} "
          f"per-layer={ {k: int(v) for k, v in counts.items()} }")
