"""End-to-end driver: train the spiking VGG9 with QAT, checkpoint/restart,
hybrid-kernel validation, and the Eq. 3 workload -> energy report.

    PYTHONPATH=src python examples/train_vgg9_snn.py --steps 200
"""
import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import vgg9_snn
from repro.core.energy import energy_per_image
from repro.core.hybrid import plan_hybrid
from repro.data.synthetic import image_batch
from repro.models.vgg9 import init_vgg9, vgg9_forward, vgg9_infer_hybrid, vgg9_loss
from repro.train.loop import TrainLoop
from repro.train.optim import adamw
from repro.train.schedule import warmup_cosine
from repro.train.train_step import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--int4", action="store_true", help="train with int4 QAT")
    ap.add_argument("--ckpt-dir", default="/tmp/vgg9_ckpt")
    args = ap.parse_args()

    cfg = dataclasses.replace(vgg9_snn.TINY, num_classes=4,
                              quant_bits=4 if args.int4 else 0)
    opt = adamw(weight_decay=0.0)
    step = jax.jit(make_train_step(lambda p, b: vgg9_loss(p, b, cfg), opt,
                                   warmup_cosine(3e-3, 20, args.steps)))
    state = init_train_state(init_vgg9(jax.random.PRNGKey(0), cfg), opt)

    loop = TrainLoop(step,
                     lambda i: image_batch(0, i, 32, num_classes=4, hw=cfg.img_hw),
                     ckpt_dir=args.ckpt_dir, ckpt_every=50, log_every=20)
    restored, start = loop.maybe_restore(jax.eval_shape(lambda: state))
    if restored is not None:
        state = restored
        print(f"resumed from checkpoint at step {start}")
    state = loop.run(state, args.steps, start_step=start)

    # evaluate + spike statistics
    test = image_batch(77, 0, 64, num_classes=4, hw=cfg.img_hw)
    logits, counts = vgg9_forward(state["params"], test["images"], cfg)
    acc = float((logits.argmax(-1) == test["labels"]).mean())
    print(f"\naccuracy={acc:.3f}, per-layer spikes:",
          {k: int(v) for k, v in counts.items()})

    # hybrid kernel path cross-check (dense core + sparse cores)
    hyb_logits, _ = vgg9_infer_hybrid(state["params"], test["images"][:8], cfg)
    ref_logits, _ = vgg9_forward(state["params"], test["images"][:8], cfg)
    print("hybrid kernels match reference:",
          bool(jnp.array_equal(hyb_logits, ref_logits)))

    # Eq. 3 workload model -> balanced core allocation -> energy estimate
    per_img = {k: float(v) / 64 for k, v in counts.items()}
    specs = [{"name": "conv0", "kind": "dense_input", "h_out": cfg.img_hw,
              "w_out": cfg.img_hw, "c_out": 8, "timesteps": cfg.timesteps}]
    for i, c in enumerate([12, 16, 16]):
        specs.append({"name": f"conv{i+1}", "kind": "conv", "c_out": c,
                      "filter_coeffs": 9})
    specs += [{"name": "fc0", "kind": "fc", "n_out": cfg.fc_dim},
              {"name": "fc1", "kind": "fc", "n_out": cfg.population}]
    plan = plan_hybrid(specs, per_img, budget=24)
    print("\nhybrid plan (layer, path, cores, latency share):")
    for l, ov in zip(plan.layers, plan.overheads):
        print(f"  {l.name:6s} {l.path:6s} cores={l.cores:2d} share={ov:.1%}")


if __name__ == "__main__":
    main()
