"""Paper Table I: area/power by layer, int4 vs fp32 hardware.

On TPU the FPGA LUT/BRAM columns map to weight-storage bytes and the power
column to the calibrated FPGA power model (core.energy). We reproduce the
paper's per-layer table for the full VGG9-CIFAR100 config (perf^2 allocation
(1,28,12,54,16,72,70,19,4)) and check the two headline ratios:
int4 uses ~8x fewer LUT-bytes (fp32 LUTRAM -> int4), and fp32 burns ~2.8x
more dynamic power.
"""
from repro.core.energy import power_model
from repro.configs.vgg9_snn import PERF2_CIFAR100

# full VGG9 (paper §V-A): 64C3-112C3-MP-192C3-216C3-MP-480C3-504C3-560C3-MP-1064-5000
LAYERS = [
    ("CONV_1_1", 3 * 64 * 9),       # weights (counts)
    ("CONV_1_2", 64 * 112 * 9),
    ("CONV_2_1", 112 * 192 * 9),
    ("CONV_2_2", 192 * 216 * 9),
    ("CONV_3_1", 216 * 480 * 9),
    ("CONV_3_2", 480 * 504 * 9),
    ("CONV_3_3", 504 * 560 * 9),
    ("FC", 4 * 4 * 560 * 1064 + 1064 * 5000),
]

from .common import emit


def run():
    total = {"int4": 0.0, "fp32": 0.0}
    power = {"int4": 0.0, "fp32": 0.0}
    for (name, n_weights), nc in zip(LAYERS, PERF2_CIFAR100[1:]):
        for prec, bytes_per in (("int4", 0.5), ("fp32", 4.0)):
            wb = n_weights * bytes_per
            pm = power_model(prec)
            p = pm.layer_power(nc, wb)
            total[prec] += wb
            power[prec] += p
            if prec == "int4":
                emit(f"table1/{name}", 0.0,
                     f"int4_bytes={wb:.0f};fp32_bytes={n_weights*4:.0f};"
                     f"int4_power_w={p:.3f}")
    mem_ratio = total["fp32"] / total["int4"]
    pow_ratio = power["fp32"] / power["int4"]
    emit("table1/memory_ratio", 0.0, f"fp32_over_int4={mem_ratio:.1f};paper=8x_LUT_3.4x_BRAM")
    emit("table1/power_ratio", 0.0, f"fp32_over_int4={pow_ratio:.2f};paper=2.82")


if __name__ == "__main__":
    run()
