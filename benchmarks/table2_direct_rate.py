"""Paper Table II: direct vs rate coding (CIFAR10, quantized LW config).

Paper: rate T=25: 107K spikes, 77.4% acc, 340 ms, 201 mJ;
       direct T=2: 41K spikes, 87.0% acc, 11.7 ms, 7.6 mJ  (26.4x energy).
We reproduce the energy/latency side with the calibrated cost model fed by
the paper's spike counts (the hardware-model reproduction), and the accuracy/
spike direction with tiny trained SNNs on synthetic data.
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import vgg9_snn
from repro.configs.vgg9_snn import LW_ALLOCATIONS
from repro.core.energy import energy_per_image
from repro.core.workload import conv_workload, dense_input_workload, fc_workload

from .common import emit
from .fig4_energy import weight_bytes


def hardware_model_side():
    """Energy model fed with the paper's Table II spike counts.

    Key modeling point (paper §V-D): the rate-coded network receives binary
    input spike trains, so its INPUT layer runs on the sparse cores with a
    very large event count (32x32x3 pixels x rate x 25 steps ~ 35% of all
    spikes), while the direct-coded network computes the input layer on the
    dense core (H*W*C_out*T systolic cycles). That asymmetry, plus 2 vs 25
    timesteps, is where the paper's 26.4x comes from.
    """
    alloc = list(LW_ALLOCATIONS["cifar10"])
    from .fig4_energy import spike_profile
    conv_s, fc_s = spike_profile("cifar10")
    base_total = sum(conv_s) + sum(fc_s)

    def hidden(ls, total_spikes):
        k = total_spikes / base_total
        ls += [conv_workload(f"conv{i+1}", c, 9, s * k)
               for i, (c, s) in enumerate(zip([112, 192, 216, 480, 504, 560], conv_s))]
        ls += [fc_workload("fc0", 1064, fc_s[0] * k),
               fc_workload("fc1", 1000, fc_s[1] * k)]
        return ls

    # rate T=25: input spike train ~ 32*32*3*0.45*25 = 35% of 107K events,
    # processed event-driven by conv0's sparse core
    s_in = 37_500
    wl_rate = hidden([conv_workload("conv0", 64, 9, s_in)], 107_000 - s_in)
    # direct T=2: input layer on the dense core, hidden layers see 41K spikes
    wl_direct = hidden([dense_input_workload("conv0", 32, 32, 64, 2)], 41_000)

    e_rate = energy_per_image(wl_rate, alloc, weight_bytes(0.5), "int4")
    e_direct = energy_per_image(wl_direct, alloc, weight_bytes(0.5), "int4")
    # paper Table II reports the steady-state pipelined interval (1/FPS) as
    # "latency" and energy = avg power x interval (cross-checks against the
    # 0.73 W / 120 FPS of Table III)
    int_rate = 1.0 / e_rate["throughput_fps"]
    int_direct = 1.0 / e_direct["throughput_fps"]
    en_rate = e_rate["energy_pipelined_j"]
    en_direct = e_direct["energy_pipelined_j"]
    ratio = en_rate / en_direct
    emit("table2/rate_T25", int_rate * 1e6,
         f"energy_mj={en_rate*1e3:.1f};paper_mj=201;interval_ms={int_rate*1e3:.0f};paper_ms=340")
    emit("table2/direct_T2", int_direct * 1e6,
         f"energy_mj={en_direct*1e3:.2f};paper_mj=7.6;interval_ms={int_direct*1e3:.1f};paper_ms=11.7")
    emit("table2/energy_improvement", 0.0,
         f"ratio={ratio:.1f};paper=26.4;interval_ratio={int_rate/int_direct:.1f};paper_lat_ratio=29")


def run():
    hardware_model_side()


if __name__ == "__main__":
    run()
