"""Unified serving engine benchmark: both runners through one EngineCore.

Measures end-to-end serving throughput (requests/sec through
submit -> schedule -> run -> poll) and the per-request stats surface for
both workloads:

* LM: ragged greedy generation — requests/sec, tokens/sec, slot occupancy.
* SNN: batched spiking-VGG9 inference — requests/sec, mean per-request
  tile-skip rate per layer, paper-model energy per request, dense-core and
  sparse-core kernel launches per batch.

Shapes are CPU/interpret friendly (`--smoke` shrinks them further for CI);
as with the other interpret-mode benchmarks, absolute wall-clock is a
correctness harness, not a TPU perf signal — the portable signals are the
skip rates, launch counts and slot occupancy. Emits via `common.emit` into
``BENCH_results.json``.
"""
import argparse
import json
import time

import jax
import numpy as np

from repro.configs import vgg9_snn
from repro.configs.base import ArchConfig
from repro.kernels.dense_conv_lif import ops as dense_ops
from repro.kernels.spike_conv import ops as sc_ops
from repro.models import transformer as tf
from repro.models.vgg9 import init_vgg9
from repro.serve.api import EngineConfig
from repro.serve.core import EngineCore
from repro.serve.runners.lm import LMRunner
from repro.serve.runners.snn import SNNRunner

from .common import append_result, emit


def _drain(core, payloads, **options):
    """Submit everything, drain the queue, return (results, seconds)."""
    ids = [core.submit(p, **options) for p in payloads]
    t0 = time.perf_counter()
    results = core.run_until_complete()
    dt = time.perf_counter() - t0
    return [results[i] for i in ids], dt


def bench_lm(smoke: bool) -> dict:
    cfg = ArchConfig(name="bench-serve", family="dense", n_layers=2, d_model=32,
                     n_heads=4, n_kv_heads=2, head_dim=8, d_ff=64, vocab=61,
                     dtype="float32", remat="none", q_chunk=8, kv_chunk=8)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    slots, tokens = (2, 4) if smoke else (4, 8)
    runner = LMRunner(cfg, params, max_seq=64)

    rng = np.random.default_rng(0)
    n_req = slots if smoke else 2 * slots + 1          # forces a partial batch
    prompts = [list(rng.integers(1, cfg.vocab, size=rng.integers(1, 6)))
               for _ in range(n_req)]
    # warm the jit caches on a throwaway core so the measured core's
    # occupancy/batch stats cover only the timed drain
    _drain(EngineCore(runner, EngineConfig(slots=slots)), prompts[:1],
           max_new_tokens=tokens)
    core = EngineCore(runner, EngineConfig(slots=slots))
    results, dt = _drain(core, prompts, max_new_tokens=tokens)

    stats = core.stats()
    rec = {
        "name": "serve_engine_lm",
        "requests": len(prompts),
        "req_per_s": round(len(prompts) / dt, 2),
        "tok_per_s": round(len(prompts) * tokens / dt, 1),
        "slot_occupancy": round(stats["slot_occupancy"], 3),
        "batches_run": stats["batches_run"],
    }
    assert all(len(r.outputs) == r.stats["prompt_len"] + tokens for r in results)
    emit("serve_engine_lm", dt / len(prompts) * 1e6,
         f"req/s={rec['req_per_s']} occ={rec['slot_occupancy']}",
         **{k: v for k, v in rec.items() if k != "name"})
    return rec


def bench_snn(smoke: bool) -> dict:
    import dataclasses
    cfg = vgg9_snn.TINY if smoke else dataclasses.replace(
        vgg9_snn.TINY, img_hw=32, stages=(16, 24, "MP", 32, 32, "MP"), fc_dim=64)
    params = init_vgg9(jax.random.PRNGKey(0), cfg)
    slots = 2 if smoke else 4
    runner = SNNRunner(cfg, params, interpret=True)

    n_req = slots if smoke else 2 * slots + 1
    keys = jax.random.split(jax.random.PRNGKey(1), n_req)
    imgs = [jax.random.uniform(k, (cfg.img_hw, cfg.img_hw, cfg.in_ch)) for k in keys]

    jax.clear_caches()                                 # count trace-time launches
    sc_ops.reset_launch_counts()
    dense_ops.reset_launch_counts()
    # warm (and trace) the graph on a throwaway core; measured core below
    _drain(EngineCore(runner, EngineConfig(slots=slots)), imgs[:1])
    sparse_launches = sc_ops.launch_counts().get("spike_matmul_mapped", 0)
    dense_launches = dense_ops.launch_counts().get("dense_conv_lif", 0)
    core = EngineCore(runner, EngineConfig(slots=slots))
    results, dt = _drain(core, imgs)

    skip = {}
    for layer in results[0].stats["skip_rate"]:
        skip[layer] = round(float(np.mean(
            [r.stats["skip_rate"][layer] for r in results])), 4)
    stats = core.stats()
    rec = {
        "name": "serve_engine_snn",
        "requests": n_req,
        "req_per_s": round(n_req / dt, 2),
        "slot_occupancy": round(stats["slot_occupancy"], 3),
        "batches_run": stats["batches_run"],
        "mean_skip_rate": skip,
        "mean_energy_j": float(np.mean([r.stats["energy_j"] for r in results])),
        "dense_launches_per_batch": dense_launches,
        "sparse_launches_per_batch": sparse_launches,
    }
    emit("serve_engine_snn", dt / n_req * 1e6,
         f"req/s={rec['req_per_s']} occ={rec['slot_occupancy']} "
         f"E={rec['mean_energy_j']:.2e}J",
         **{k: v for k, v in rec.items() if k != "name"})
    return rec


def run(smoke: bool = False) -> dict:
    lm = bench_lm(smoke)
    snn = bench_snn(smoke)
    record = {"name": "serve_engine", "lm": lm, "snn": snn}
    print("SERVE_ENGINE_JSON " + json.dumps(record, sort_keys=True))
    append_result(record)
    return record


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes for CI (2 slots, fewer requests)")
    run(**vars(ap.parse_args()))
