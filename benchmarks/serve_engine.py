"""Unified serving engine benchmark: admission, schedulers, budgets, SLOs,
and goodput under injected faults.

Eight experiments — six through one `EngineCore`, the last two through the
supervised multi-replica `Router`:

* LM — ragged greedy generation with *mixed decode budgets*: run-to-completion
  bucketed batching (``admission='batch'``, the PR-2 policy) vs step-level
  continuous admission (requests join freed KV-cache slots between decode
  steps). Reports requests/sec, tokens/sec and slot occupancy for both; the
  occupancy gap is the price of bucketing ragged budgets.
* SNN — batched spiking-VGG9 inference on a *mixed-sparsity trace*
  (interleaved near-silent and dense images, tagged by source): FIFO vs the
  sparsity-aware scheduler vs `slo:sparsity` (the SLO wrapper composed over
  it), all under continuous admission. Reports req/s, Eq. 3 energy/image —
  intrinsic (`energy_j`, invariant by construction) and as-served
  (`served_energy_j`, the request's share of the batch it rode in) — split
  by class, plus batch purity and the per-layer batch skip rates.
  Co-batching sparse with sparse is the paper's co-design loop closed in
  software: the sparse class's served energy drops toward its intrinsic
  cost instead of averaging with dense stragglers — and composing the SLO
  layer on top must not give that win back (asserted).
* LM chunked prefill — a long prompt joins a full decode batch; goodput
  (resident decode tokens per engine step) is swept over ``prefill_chunk``.
  Token-by-token (chunk 1, the old behavior) pins the joiner in its slot
  for prompt-length steps; chunking packs the same decode work into far
  fewer steps, outputs asserted bit-identical at every chunk size.
* LM latency SLOs — a mixed bulk/interactive trace on a deterministic
  step-counting engine clock: FIFO misses the interactive class's deadline
  (requests expire behind bulk residents), the `SLOScheduler` meets it by
  admitting tightest-deadline-first.
* Precision — adaptive per-request fp32/int4 selection (`serve.precision`)
  vs pinned single-precision fleets on the mixed-sparsity trace: served
  energy under both the Eq. 3 FPGA model and the analytical per-op model,
  accuracy proxies vs the fp32 reference, pinned requests asserted
  never-switched and all outputs asserted bit-identical per precision.
* Speculative — n-gram self-drafting verified on the `decode_chunk` seam
  vs plain one-token decode on the same greedy trace: outputs asserted
  bit-identical, accept rate > 0 (tiny-model token cycles are prompt-
  lookup's best case), and decode-tokens-per-step goodput strictly up.
  The sampled variant asserts seed determinism across engines and runs.
* Faults — chaos scenarios through a 3-replica router fleet: a wedged
  replica is condemned by the heartbeat and its in-flight request replays
  bit-identically on a healthy replica (recovery latency in router steps);
  a NaN-poisoned request retires ``'failed'`` with clean partials intact;
  a queue flood sheds overflow as ``'rejected'`` while high-priority work
  completes. Reports goodput under failure vs a fault-free fleet.
* Fleet — the same LM trace through an in-process 2-replica fleet and a
  2-worker *subprocess* fleet built from one wire-encodable `RunnerSpec`,
  reporting per-router-step IPC overhead; a chaos pass SIGKILLs a worker
  holding in-flight requests and asserts every request still completes
  bit-identical to the fault-free in-process run.

Both schedulers must return bit-identical outputs per request (asserted);
only composition, latency and energy attribution may differ.

Shapes are CPU/interpret friendly (``--smoke`` shrinks them further for CI);
as with the other interpret-mode benchmarks, absolute wall-clock is a
correctness harness, not a TPU perf signal — the portable signals are the
skip rates, energy attribution, batch purity and slot occupancy. Emits via
`common.emit` into ``BENCH_results.json``.
"""
import argparse
import json
import time

import jax
import numpy as np

from repro.configs import vgg9_snn
from repro.configs.base import ArchConfig
from repro.kernels.dense_conv_lif import ops as dense_ops
from repro.kernels.spike_conv import ops as sc_ops
from repro.models import transformer as tf
from repro.models.vgg9 import init_vgg9
from repro.serve.api import EngineConfig
from repro.serve.core import EngineCore, StepClock
from repro.serve.runners.lm import LMRunner
from repro.serve.runners.snn import SNNRunner

from .common import append_result, emit


def _drain(core, payloads, options=None):
    """Submit everything, drain the queue, return (results, seconds)."""
    options = options or [{}] * len(payloads)
    ids = [core.submit(p, **o) for p, o in zip(payloads, options)]
    t0 = time.perf_counter()
    results = core.run_until_complete()
    dt = time.perf_counter() - t0
    return [results[i] for i in ids], dt


# ---------------------------------------------------------------------------
# LM: batch vs continuous admission on mixed decode budgets
# ---------------------------------------------------------------------------

def _lm_cfg():
    return ArchConfig(name="bench-serve", family="dense", n_layers=2,
                      d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
                      d_ff=64, vocab=61, dtype="float32", remat="none",
                      q_chunk=8, kv_chunk=8)


def bench_lm(smoke: bool) -> dict:
    cfg = _lm_cfg()
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    slots, tokens = (2, 4) if smoke else (4, 8)
    runner = LMRunner(cfg, params, max_seq=64)

    rng = np.random.default_rng(0)
    n_req = slots + 1 if smoke else 2 * slots + 1      # forces partial batches
    prompts = [list(rng.integers(1, cfg.vocab, size=rng.integers(1, 6)))
               for _ in range(n_req)]
    # alternating decode budgets: two buckets for batch admission, co-resident
    # slot-mates under continuous admission
    options = [{"max_new_tokens": tokens if i % 2 == 0 else 2 * tokens}
               for i in range(n_req)]

    # warm the jit caches on a throwaway core so the measured cores'
    # occupancy/step stats cover only the timed drains
    for admission in ("batch", "continuous"):
        _drain(EngineCore(runner, EngineConfig(slots=slots, admission=admission)),
               prompts[:1], [options[0]])

    modes = {}
    outputs = {}
    for admission in ("batch", "continuous"):
        core = EngineCore(runner, EngineConfig(slots=slots, admission=admission))
        results, dt = _drain(core, prompts, options)
        stats = core.stats()
        total_tokens = sum(o["max_new_tokens"] for o in options)
        modes[admission] = {
            "req_per_s": round(n_req / dt, 2),
            "tok_per_s": round(total_tokens / dt, 1),
            "slot_occupancy": round(stats["slot_occupancy"], 3),
            "steps_run": stats["steps_run"],
        }
        outputs[admission] = [r.outputs for r in results]
        assert all(len(r.outputs) == r.stats["prompt_len"] + o["max_new_tokens"]
                   for r, o in zip(results, options))
    # continuous admission must not change a single token
    assert outputs["batch"] == outputs["continuous"]

    rec = {"name": "serve_engine_lm", "requests": n_req, "slots": slots,
           "admission": modes}
    emit("serve_engine_lm", 0.0,
         f"occ batch={modes['batch']['slot_occupancy']} "
         f"continuous={modes['continuous']['slot_occupancy']}",
         **{k: v for k, v in rec.items() if k != "name"})
    return rec


# ---------------------------------------------------------------------------
# SNN: FIFO vs sparsity-aware scheduling on a mixed-sparsity trace
# ---------------------------------------------------------------------------

def _mixed_trace(cfg, n_req: int):
    """Interleaved near-silent ('sparse') and dense requests, source-tagged."""
    keys = jax.random.split(jax.random.PRNGKey(1), n_req)
    payloads, options = [], []
    for i, k in enumerate(keys):
        img = jax.random.uniform(k, (cfg.img_hw, cfg.img_hw, cfg.in_ch))
        if i % 2 == 0:
            payloads.append(img * 0.05)        # rarely crosses the LIF threshold
            options.append({"source": "sparse"})
        else:
            payloads.append(img)
            options.append({"source": "dense"})
    return payloads, options


def _class_mean(results, options, source, field):
    vals = [r.stats[field] for r, o in zip(results, options)
            if o["source"] == source]
    return float(np.mean(vals)) if vals else 0.0


def bench_snn(smoke: bool) -> dict:
    import dataclasses
    cfg = vgg9_snn.TINY if smoke else dataclasses.replace(
        vgg9_snn.TINY, img_hw=32, stages=(16, 24, "MP", 32, 32, "MP"), fc_dim=64)
    params = init_vgg9(jax.random.PRNGKey(0), cfg)
    slots = 2 if smoke else 4
    runner = SNNRunner(cfg, params, interpret=True)
    n_req = 3 * slots
    payloads, options = _mixed_trace(cfg, n_req)

    jax.clear_caches()                                 # count trace-time launches
    sc_ops.reset_launch_counts()
    dense_ops.reset_launch_counts()
    # warm (and trace) the fused graph on a throwaway core; measured below
    _drain(EngineCore(runner, EngineConfig(slots=slots)), payloads[:1],
           options[:1])
    sparse_launches = sc_ops.launch_counts().get("spike_matmul_mapped", 0)
    dense_launches = dense_ops.launch_counts().get("dense_conv_lif", 0)

    scheds = {}
    outputs = {}
    for scheduler in ("fifo", "sparsity", "slo:sparsity"):
        core = EngineCore(runner, EngineConfig(slots=slots, scheduler=scheduler))
        results, dt = _drain(core, payloads, options)
        stats = core.stats()
        groups = [g for _, g in core.admission_log if len(g) > 1]
        klass = {r.request_id: o["source"]           # results in submit order
                 for r, o in zip(results, options)}
        purity = (sum(len({klass[r] for r in g}) == 1 for g in groups)
                  / len(groups) if groups else 1.0)
        skip = {}
        for layer in results[0].stats["skip_rate"]:
            skip[layer] = round(float(np.mean(
                [r.stats["skip_rate"][layer] for r in results])), 4)
        scheds[scheduler] = {
            "req_per_s": round(n_req / dt, 2),
            "slot_occupancy": round(stats["slot_occupancy"], 3),
            "steps_run": stats["steps_run"],
            "batch_purity": round(purity, 3),
            # intrinsic Eq. 3 energy: request served alone — invariant
            "energy_per_image_j": float(np.mean(
                [r.stats["energy_j"] for r in results])),
            # as-served: the request's share of the batch it actually rode in
            "served_energy_per_image_j": float(np.mean(
                [r.stats["served_energy_j"] for r in results])),
            "served_energy_sparse_j": _class_mean(results, options, "sparse",
                                                  "served_energy_j"),
            "served_energy_dense_j": _class_mean(results, options, "dense",
                                                 "served_energy_j"),
            "mean_skip_rate": skip,
        }
        outputs[scheduler] = [np.asarray(r.outputs) for r in results]

    # scheduling may change composition and energy attribution — never logits
    for name in ("sparsity", "slo:sparsity"):
        for a, b in zip(outputs["fifo"], outputs[name]):
            np.testing.assert_array_equal(a, b)
    # composing the SLO layer over the sparsity policy must keep the sparse
    # class's served-energy win (no deadlines in the trace -> the wrapper
    # delegates composition to its inner scheduler untouched)
    assert (scheds["slo:sparsity"]["served_energy_sparse_j"]
            <= scheds["fifo"]["served_energy_sparse_j"] * 0.67), scheds

    rec = {
        "name": "serve_engine_snn",
        "requests": n_req,
        "slots": slots,
        "dense_launches_per_batch": dense_launches,
        "sparse_launches_per_batch": sparse_launches,
        "schedulers": scheds,
    }
    f, s = scheds["fifo"], scheds["sparsity"]
    emit("serve_engine_snn", 0.0,
         f"sparse E/img fifo={f['served_energy_sparse_j']:.2e}J "
         f"sparsity={s['served_energy_sparse_j']:.2e}J "
         f"purity {f['batch_purity']}->{s['batch_purity']}",
         **{k: v for k, v in rec.items() if k != "name"})
    return rec


# ---------------------------------------------------------------------------
# LM: chunked prefill — goodput vs chunk size while a long prompt joins
# ---------------------------------------------------------------------------

def bench_chunked_prefill(smoke: bool) -> dict:
    """A long prompt joins a full decode batch; sweep ``prefill_chunk``.

    Goodput = resident decode tokens per engine step (`EngineCore.stats`).
    Token-by-token prefill (chunk 1) holds the joiner's slot for
    prompt-length steps; every larger chunk packs the same decode work into
    fewer steps. Outputs are asserted bit-identical across all chunk sizes
    and to a solo run of the long prompt.
    """
    cfg = _lm_cfg()
    rng = np.random.default_rng(7)
    if smoke:
        slots, prompt_len, chunks, max_seq = 2, 48, (1, 4, 16), 96
        resident_budget, joiner_budget = 24, 4
    else:
        slots, prompt_len, chunks, max_seq = 4, 512, (1, 8, 64), 544
        resident_budget, joiner_budget = 96, 8
    runner = LMRunner(cfg, params=tf.init_params(jax.random.PRNGKey(0), cfg),
                      max_seq=max_seq)
    long_prompt = [int(t) for t in rng.integers(1, cfg.vocab, size=prompt_len)]
    short_prompts = [[int(t) for t in rng.integers(1, cfg.vocab, size=3)]
                     for _ in range(slots)]

    solo_core = EngineCore(runner, EngineConfig(slots=slots))
    solo_id = solo_core.submit(long_prompt, max_new_tokens=joiner_budget)
    solo = solo_core.run_until_complete()[solo_id].outputs

    sweep = {}
    outputs = {}
    for chunk in chunks:
        core = EngineCore(runner, EngineConfig(slots=slots,
                                               prefill_chunk=chunk))
        resident_ids = [core.submit(p, max_new_tokens=resident_budget)
                        for p in short_prompts]
        core.step()                     # decode batch is full and live
        joiner = core.submit(long_prompt, max_new_tokens=joiner_budget)
        t0 = time.perf_counter()
        results = core.run_until_complete()
        dt = time.perf_counter() - t0
        stats = core.stats()
        sweep[chunk] = {
            "steps_run": stats["steps_run"],
            "decode_tokens": stats["decode_tokens"],
            "goodput_decode_tok_per_step":
                round(stats["goodput_decode_tok_per_step"], 4),
            "joiner_ttft_steps": results[joiner].stats["ttft_steps"],
            "joiner_prefill_chunks": results[joiner].stats["prefill_chunks"],
            "wall_s": round(dt, 3),
        }
        outputs[chunk] = [results[i].outputs
                          for i in resident_ids + [joiner]]
        assert results[joiner].outputs == solo, chunk

    base = outputs[chunks[0]]
    for chunk in chunks[1:]:
        assert outputs[chunk] == base, chunk           # bit-identical sweep
        # the acceptance bar: chunked prefill strictly beats token-by-token
        assert (sweep[chunk]["goodput_decode_tok_per_step"]
                > sweep[chunks[0]]["goodput_decode_tok_per_step"]), sweep

    rec = {"name": "serve_engine_lm_chunked_prefill", "slots": slots,
           "prompt_len": prompt_len, "sweep": {str(c): sweep[c] for c in chunks}}
    g1 = sweep[chunks[0]]["goodput_decode_tok_per_step"]
    gN = sweep[chunks[-1]]["goodput_decode_tok_per_step"]
    emit("serve_engine_lm_chunked_prefill", 0.0,
         f"goodput tok/step chunk{chunks[0]}={g1} chunk{chunks[-1]}={gN}",
         **{k: v for k, v in rec.items() if k != "name"})
    return rec


# ---------------------------------------------------------------------------
# LM: latency SLOs — FIFO misses a per-class deadline the SLO scheduler meets
# ---------------------------------------------------------------------------

def bench_slo(smoke: bool) -> dict:
    """Mixed bulk/interactive LM trace under a per-class deadline.

    Bulk requests (long decode budgets, no deadline) arrive first and fill
    the queue; interactive requests (short budgets, tight ``deadline_s`` in
    engine steps, higher priority) arrive behind them. FIFO admits in
    arrival order, so the interactive class expires behind bulk residents;
    the `SLOScheduler` admits tightest-deadline-first and meets the class
    deadline — without touching the bulk outputs.
    """
    cfg = _lm_cfg()
    rng = np.random.default_rng(11)
    slots = 2
    n_bulk, bulk_tokens = (3, 16) if smoke else (4, 24)
    n_inter, inter_tokens = 2, 4
    # prefill(4) + decode steps + one admission step of slack, per class
    deadline = 4 + inter_tokens + 4
    runner = LMRunner(cfg, params=tf.init_params(jax.random.PRNGKey(0), cfg),
                      max_seq=64)
    bulk = [[int(t) for t in rng.integers(1, cfg.vocab, size=4)]
            for _ in range(n_bulk)]
    inter = [[int(t) for t in rng.integers(1, cfg.vocab, size=4)]
             for _ in range(n_inter)]

    policies = {}
    for scheduler in ("fifo", "slo"):
        clock = StepClock()     # deadlines in engine steps: deterministic
        core = EngineCore(runner, EngineConfig(slots=slots,
                                               scheduler=scheduler),
                          clock=clock)
        clock.attach(core)
        bulk_ids = [core.submit(p, max_new_tokens=bulk_tokens) for p in bulk]
        inter_ids = [core.submit(p, max_new_tokens=inter_tokens,
                                 deadline_s=deadline, priority=1)
                     for p in inter]
        results = core.run_until_complete()
        met = sum(results[i].status == "ok" for i in inter_ids)
        policies[scheduler] = {
            "interactive_met": met,
            "interactive_total": n_inter,
            "interactive_expired": sum(results[i].status == "expired"
                                       for i in inter_ids),
            "bulk_done": sum(results[i].status == "ok" for i in bulk_ids),
            "steps_run": core.stats()["steps_run"],
            "deadline_steps": deadline,
        }
    # the acceptance bar: the SLO scheduler meets the class deadline FIFO
    # misses, and bulk traffic still completes
    assert policies["slo"]["interactive_met"] == n_inter, policies
    assert policies["fifo"]["interactive_met"] < n_inter, policies
    assert policies["slo"]["bulk_done"] == n_bulk, policies

    rec = {"name": "serve_engine_lm_slo", "slots": slots,
           "bulk": n_bulk, "interactive": n_inter, "policies": policies}
    emit("serve_engine_lm_slo", 0.0,
         f"interactive met fifo={policies['fifo']['interactive_met']}"
         f"/{n_inter} slo={policies['slo']['interactive_met']}/{n_inter}",
         **{k: v for k, v in rec.items() if k != "name"})
    return rec


# ---------------------------------------------------------------------------
# Precision: adaptive per-request fp32/int4 vs pinned fleets (serve.precision)
# ---------------------------------------------------------------------------

def bench_precision(smoke: bool) -> dict:
    """Adaptive-precision serving vs pinned fp32/int4 fleets on the mixed
    dense/near-silent SNN trace.

    Three fleets share one pre-warmed fp32+int4 `VariantRegistry` behind a
    `PrecisionRunner` (``EngineConfig.precision`` = 'fp32' / 'int4' /
    'adaptive'), each with a fresh `PrecisionController` bound to its
    sparsity scheduler. Every third request carries
    ``options['pin_precision']='fp32'`` (the accuracy-pinned class). The
    trace is served in two waves so the second wave's decisions use the
    skip-rate EWMAs the first wave taught the scheduler — the
    quantization->sparsity loop closing online.

    Acceptance (asserted): the adaptive fleet serves the trace at lower
    mean served energy than the pinned-fp32 fleet under BOTH cost models
    (paper Eq. 3 and the analytical per-op model — reported side by side
    per fleet); pinned requests are served fp32 in every fleet; and every
    request's logits are bit-identical to a plain single-precision
    `SNNRunner` engine at the precision it was actually served (row
    independence + single-precision launches). Accuracy proxy: top-1
    agreement and mean |logit delta| vs the fp32 reference.
    """
    import dataclasses
    from repro.serve.precision import (PrecisionController, PrecisionRunner,
                                       bind_controller, make_snn_pricer,
                                       make_snn_variants)
    from repro.serve.scheduler import make_scheduler

    cfg = vgg9_snn.TINY if smoke else dataclasses.replace(
        vgg9_snn.TINY, img_hw=32, stages=(16, 24, "MP", 32, 32, "MP"), fc_dim=64)
    params = init_vgg9(jax.random.PRNGKey(0), cfg)
    slots = 2 if smoke else 4
    n_req = 3 * slots
    payloads, options = _mixed_trace(cfg, n_req)
    for i, o in enumerate(options):
        if i % 3 == 0:
            o["pin_precision"] = "fp32"
    pinned_idx = [i for i, o in enumerate(options) if "pin_precision" in o]

    # one registry for everything: the variants quantize once and their jit
    # caches stay warm across fleets, so the comparison times serving only
    registry = make_snn_variants(cfg, params)
    registry.prewarm(slots)
    pricer = make_snn_pricer(cfg)

    # single-precision reference engines: plain SNNRunner variants, no
    # controller anywhere near them — the bit-identity baseline
    refs = {}
    for prec in registry.precisions:
        core = EngineCore(registry.runner(prec), EngineConfig(slots=slots))
        res, _ = _drain(core, payloads, options)
        refs[prec] = [np.asarray(r.outputs) for r in res]

    half = n_req // 2
    fleets = {}
    adaptive_summary = None
    for mode in ("fp32", "int4", "adaptive"):
        controller = PrecisionController(pricer=pricer, dense_threshold=0.8)
        runner = PrecisionRunner(registry, controller, mode=mode)
        scheduler = make_scheduler("sparsity")
        bind_controller(scheduler, controller)
        core = EngineCore(runner, EngineConfig(slots=slots,
                                               scheduler="sparsity",
                                               precision=mode),
                          scheduler=scheduler)
        res1, dt1 = _drain(core, payloads[:half], options[:half])
        res2, dt2 = _drain(core, payloads[half:], options[half:])
        results, dt = res1 + res2, dt1 + dt2

        served = [r.stats["precision"] for r in results]
        # pinned requests never switch, in any fleet or controller state
        assert all(served[i] == "fp32" for i in pinned_idx), (mode, served)
        # within a precision, logits are bit-identical to the pinned
        # single-precision engine that never saw a controller
        for i, r in enumerate(results):
            np.testing.assert_array_equal(np.asarray(r.outputs),
                                          refs[served[i]][i],
                                          err_msg=f"{mode} req {i}")
        counts = {p: served.count(p) for p in registry.precisions}
        fleets[mode] = {
            "req_per_s": round(n_req / dt, 2),
            "precision_counts": counts,
            # both cost models, per fleet, on the same served trace
            "served_energy_j": float(np.mean(
                [r.stats["served_energy_j"] for r in results])),
            "served_energy_analytical_j": float(np.mean(
                [r.stats["served_energy_analytical_j"] for r in results])),
            # accuracy proxy vs the fp32 reference logits
            "top1_agreement_vs_fp32": float(np.mean(
                [np.argmax(np.asarray(r.outputs)) == np.argmax(refs["fp32"][i])
                 for i, r in enumerate(results)])),
            "mean_abs_logit_delta": float(np.mean(
                [np.abs(np.asarray(r.outputs) - refs["fp32"][i]).mean()
                 for i, r in enumerate(results)])),
        }
        if mode == "adaptive":
            adaptive_summary = controller.summary()
            assert counts["int4"] > 0, "adaptive never harvested int4"

    # the acceptance bar: adaptive beats the pinned-fp32 fleet on served
    # energy under BOTH models while its pinned class stayed fp32-identical
    win_eq3 = (fleets["fp32"]["served_energy_j"]
               / fleets["adaptive"]["served_energy_j"])
    win_ana = (fleets["fp32"]["served_energy_analytical_j"]
               / fleets["adaptive"]["served_energy_analytical_j"])
    assert win_eq3 > 1.0 and win_ana > 1.0, fleets

    rec = {"name": "serve_engine_precision", "requests": n_req,
           "slots": slots, "pinned_fp32": len(pinned_idx),
           "fleets": fleets,
           "adaptive": {"energy_win_vs_fp32_eq3": round(win_eq3, 3),
                        "energy_win_vs_fp32_analytical": round(win_ana, 3),
                        "pinned_bit_identical": True,
                        "per_precision_bit_identical": True,
                        "controller": adaptive_summary}}
    emit("serve_engine_precision", 0.0,
         f"served E adaptive={fleets['adaptive']['served_energy_j']:.2e}J "
         f"fp32={fleets['fp32']['served_energy_j']:.2e}J "
         f"(win eq3 {win_eq3:.2f}x / analytical {win_ana:.2f}x)",
         **{k: v for k, v in rec.items() if k != "name"})
    return rec


# ---------------------------------------------------------------------------
# Speculative decode: accepted-tokens-per-step goodput vs plain decode
# ---------------------------------------------------------------------------

def bench_speculative(smoke: bool) -> dict:
    """Self-speculative decode (n-gram prompt lookup, verified on the
    `decode_chunk` seam) vs plain one-token decode on the same trace.

    Greedy decode on the tiny bench model falls into token cycles within a
    few steps — exactly the repetitive structure prompt-lookup drafting
    exploits — so the speculative engine accepts multi-token prefixes and
    packs the same decode work into fewer engine steps. The headline is
    goodput: decode tokens emitted per engine step, plain vs speculative,
    with outputs asserted bit-identical (speculation may never change a
    token, only how many one launch emits).

    A second scenario runs the same prompts sampled (temperature/top-p,
    per-request seeds) through fresh plain and speculative engines twice:
    sampled speculative output must equal sampled plain output (the
    per-(seed, index) sampling contract survives verify launches), and a
    re-run with the same seeds must be identical (seed determinism).
    """
    cfg = _lm_cfg()
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(5)
    slots, tokens = (2, 24) if smoke else (4, 48)
    spec_k = 4
    n_req = slots + 1
    prompts = [[int(t) for t in rng.integers(1, cfg.vocab, size=rng.integers(2, 6))]
               for _ in range(n_req)]
    options = [{"max_new_tokens": tokens} for _ in range(n_req)]

    plain_runner = LMRunner(cfg, params, max_seq=128)
    spec_runner = LMRunner(cfg, params, max_seq=128, speculate_k=spec_k)

    # warm both runners' launch-width buckets on throwaway cores
    for r in (plain_runner, spec_runner):
        _drain(EngineCore(r, EngineConfig(slots=slots)), prompts[:1],
               [options[0]])

    modes = {}
    outputs = {}
    for label, runner in (("plain", plain_runner), ("speculative", spec_runner)):
        core = EngineCore(runner, EngineConfig(slots=slots))
        results, dt = _drain(core, prompts, options)
        stats = core.stats()
        modes[label] = {
            "req_per_s": round(n_req / dt, 2),
            "steps_run": stats["steps_run"],
            "decode_tokens": stats["decode_tokens"],
            "goodput_decode_tok_per_step":
                round(stats["goodput_decode_tok_per_step"], 4),
            "drafted_tokens": stats["drafted_tokens"],
            "accepted_tokens": stats["accepted_tokens"],
            "goodput_accepted_tok_per_step":
                round(stats["goodput_accepted_tok_per_step"], 4),
        }
        outputs[label] = [r.outputs for r in results]
        # per-request ledger closes exactly
        assert all(r.stats["accepted_tokens"] + r.stats["rejected_tokens"]
                   == r.stats["drafted_tokens"] for r in results)

    # the correctness bar: speculation never changes a token
    bit_identical = outputs["plain"] == outputs["speculative"]
    assert bit_identical, "speculative greedy diverged from plain greedy"
    accept_rate = (modes["speculative"]["accepted_tokens"]
                   / modes["speculative"]["drafted_tokens"])
    assert accept_rate > 0, modes
    # the goodput bar: accepted drafts pack decode into fewer steps
    goodput_win = (modes["speculative"]["goodput_decode_tok_per_step"]
                   / modes["plain"]["goodput_decode_tok_per_step"])
    assert goodput_win > 1.0, modes

    # sampled scenario: determinism across engines and across runs
    sampled_opts = [{"max_new_tokens": tokens, "temperature": 0.8,
                     "top_p": 0.95, "seed": 100 + i} for i in range(n_req)]
    sampled = {}
    for label, runner in (("plain", plain_runner), ("speculative", spec_runner)):
        runs = []
        for _ in range(2):
            core = EngineCore(runner, EngineConfig(slots=slots))
            results, _ = _drain(core, prompts, sampled_opts)
            runs.append([r.outputs for r in results])
        assert runs[0] == runs[1], f"sampled {label} not seed-deterministic"
        sampled[label] = runs[0]
    seed_deterministic = True
    assert sampled["plain"] == sampled["speculative"], (
        "sampled speculative diverged from sampled plain")

    rec = {"name": "serve_engine_speculative", "requests": n_req,
           "slots": slots, "speculate_k": spec_k,
           "plain": modes["plain"], "speculative": modes["speculative"],
           "accept_rate": round(accept_rate, 4),
           "goodput_win": round(goodput_win, 4),
           "bit_identical": bit_identical,
           "sampling": {"seed_deterministic": seed_deterministic,
                        "matches_plain": True}}
    emit("serve_engine_speculative", 0.0,
         f"accept_rate={accept_rate:.2f} goodput tok/step "
         f"plain={modes['plain']['goodput_decode_tok_per_step']} "
         f"spec={modes['speculative']['goodput_decode_tok_per_step']} "
         f"({goodput_win:.2f}x)",
         **{k: v for k, v in rec.items() if k != "name"})
    return rec


# ---------------------------------------------------------------------------
# Faults: goodput + recovery latency under injected failures (serve.router)
# ---------------------------------------------------------------------------

def bench_faults(smoke: bool) -> dict:
    """Chaos scenarios through the supervised 3-replica router.

    Scenario 1 (wedge + NaN, the ISSUE-6 acceptance shape): replica 0
    wedges mid-stream, replica 1 NaN-poisons a slot. Every in-flight
    request reaches a terminal result; the wedged replica's request is
    re-routed by deterministic replay and asserted *bit-identical* to a
    fault-free single-replica run; the poisoned request retires
    ``'failed'`` with its clean partial tokens intact. Reported metrics:
    recovery latency (router steps from the drain to the replayed
    request's completion) and goodput under failure (ok results per
    router step, vs the fault-free fleet).

    Scenario 2 (overload shedding): a single small-queue replica is
    flooded with low-priority work behind a high-priority batch; the high
    class completes, overflow is shed with ``status='rejected'``, and
    every submission still gets exactly one terminal result.
    """
    from repro.serve.core import all_finite
    from repro.serve.faults import flood_queue, parse_fleet_plan
    from repro.serve.router import make_router

    cfg = _lm_cfg()
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    tokens = 6 if smoke else 10
    runner = LMRunner(cfg, params, max_seq=64)
    rng = np.random.default_rng(3)
    prompts = [list(int(t) for t in rng.integers(1, cfg.vocab, size=n))
               for n in (4, 3, 2)]

    # fault-free references: single replica for bit-identity, and a clean
    # 3-replica fleet for the goodput-under-failure comparison
    ref_core = EngineCore(runner, EngineConfig(slots=2), clock=StepClock())
    ref_ids = [ref_core.submit(p, max_new_tokens=tokens) for p in prompts]
    ref = ref_core.run_until_complete()
    clean = make_router(runner, 3, EngineConfig(slots=2))
    for i, p in enumerate(prompts):
        clean.submit(p, max_new_tokens=tokens, affinity=f"s{i}")
    clean.run_until_complete()
    clean_goodput = (clean.stats()["ok"] / clean.stats()["router_steps"])

    plans = parse_fleet_plan("0=wedge@4,1=nan@4:slot=0")
    router = make_router(runner, 3, EngineConfig(slots=2), plans=plans,
                         wedge_patience=3, obs=True)
    rids = [router.submit(p, max_new_tokens=tokens, affinity=f"s{i}")
            for i, p in enumerate(prompts)]
    a, b, c = rids
    streams = {rid: [] for rid in rids}
    for _ in range(400):
        router.step()
        for rid in rids:
            streams[rid].extend(router.poll_partial(rid))
        if not router._outstanding:
            break
    results = {rid: router.poll(rid) for rid in rids}
    stats = router.stats()

    # every in-flight request completed; re-route is bit-identical
    assert all(res is not None for res in results.values())
    assert results[a].status == "ok" and results[c].status == "ok"
    bit_identical = (results[a].outputs == ref[ref_ids[0]].outputs
                     and results[c].outputs == ref[ref_ids[2]].outputs)
    assert bit_identical, "replayed outputs diverged from fault-free run"
    # poisoned request: failed, clean partial prefix intact
    assert results[b].status == "failed"
    ref_b = ref[ref_ids[1]].outputs[len(prompts[1]):]
    partials_intact = (len(streams[b]) > 0 and all_finite(streams[b])
                      and streams[b] == ref_b[:len(streams[b])])
    assert partials_intact, "poisoned request lost its clean partials"

    wedge_drain = next(e for e in router.drain_log if e[1] == 0)
    recovery_steps = max((router.completed_at[rid] for rid in wedge_drain[3]),
                         default=wedge_drain[0]) - wedge_drain[0]
    wedge_reroute = {
        "reroutes": stats["rerouted"],
        "recovery_steps": recovery_steps,
        "bit_identical": bit_identical,
        "router_steps": stats["router_steps"],
        "goodput_ok_per_step": round(stats["ok"] / stats["router_steps"], 4),
        "goodput_fault_free_per_step": round(clean_goodput, 4),
        "replica_states": [r["state"] for r in stats["replicas"]],
    }
    nan_poison = {
        "failed": stats["failed"],
        "partials_intact": partials_intact,
        "clean_partial_tokens": len(streams[b]),
    }

    # the wedged replica's drain carries a flight-recorder postmortem: its
    # final StepReport frames (summaries), plus the heartbeat evidence the
    # router condemned it on
    detail = wedge_drain[4]
    dump = detail.get("dump")
    assert dump and dump.get("frames"), (
        "wedged replica drained without a flight-recorder dump")
    assert dump["frames"][-1]["step"] is not None
    flight_recorder = {
        "reason": dump["reason"],
        "frames": len(dump["frames"]),
        "notes": len(dump.get("notes", [])),
        "last_frame_step": dump["frames"][-1]["step"],
        "marker": list(detail["marker"]),
        "cost_finite": detail["cost_finite"],
    }

    # scenario 2: queue flood against one small replica
    shed_router = make_router(runner, 1,
                              EngineConfig(slots=2, max_queue=2),
                              max_waiting=2)
    high = [shed_router.submit(p, max_new_tokens=2, priority=5)
            for p in prompts]
    low = flood_queue(shed_router, prompts[0], count=8, max_new_tokens=2)
    shed_results = shed_router.run_until_complete()
    assert all(shed_results[r].status == "ok" for r in high)
    n_rejected = sum(shed_results[r].status == "rejected" for r in low)
    assert n_rejected > 0, "flood never triggered shedding"
    assert len(shed_results) == len(high) + len(low)    # exactly-once results
    overload = {
        "submitted": len(high) + len(low),
        "ok": sum(r.status == "ok" for r in shed_results.values()),
        "rejected": n_rejected,
        "high_priority_ok": len(high),
    }

    rec = {"name": "serve_engine_faults", "replicas": 3,
           "wedge_reroute": wedge_reroute, "nan_poison": nan_poison,
           "overload": overload, "flight_recorder": flight_recorder}
    emit("serve_engine_faults", 0.0,
         f"recovery={recovery_steps} steps, goodput "
         f"{wedge_reroute['goodput_ok_per_step']} vs clean "
         f"{wedge_reroute['goodput_fault_free_per_step']} ok/step, "
         f"rejected={n_rejected}, "
         f"recorder_frames={flight_recorder['frames']}",
         **{k: v for k, v in rec.items() if k != "name"})
    return rec


# ---------------------------------------------------------------------------
# Fleet: in-process replicas vs subprocess workers — IPC overhead + chaos
# ---------------------------------------------------------------------------

def bench_fleet(smoke: bool) -> dict:
    """In-process 2-replica fleet vs 2-worker *subprocess* fleet on the
    same LM trace, plus an observability-attached pass (tracing + metrics
    + flight recorder over the wire; measures the obs tax and asserts one
    merged cross-process trace) and a chaos pass with one worker killed
    mid-run.

    All three serving modes are built from one wire-encodable `RunnerSpec`
    (same seed -> same params in every process), so the comparison is pure
    transport: the subprocess fleet pays wire codec + pipe round trips per
    router step, reported as per-step wall time against the in-process
    fleet (``ipc_overhead_x``). The chaos pass kills a worker holding
    in-flight requests with SIGKILL; supervision condemns the dead replica
    and replays its work on the survivor. Acceptance (asserted): every
    request in every mode completes ``'ok'`` with outputs *bit-identical*
    to the fault-free in-process run.
    """
    from repro.serve.router import make_router, make_worker_fleet
    from repro.serve.worker import build_runner, lm_spec

    cfg = _lm_cfg()
    tokens = 4 if smoke else 8
    n_req = 4 if smoke else 6
    spec = lm_spec(cfg, seed=0, max_seq=64)
    config = EngineConfig(slots=2, max_queue=16)
    rng = np.random.default_rng(9)
    prompts = [[int(t) for t in rng.integers(1, cfg.vocab,
                                             size=rng.integers(2, 6))]
               for _ in range(n_req)]
    warm_prompt = [1, 2, 3]

    def serve(router, *, timed_after_warmup=True):
        if timed_after_warmup:      # compile jit caches outside the timing
            router.submit(warm_prompt, max_new_tokens=tokens)
            router.run_until_complete()
        rids = [router.submit(p, max_new_tokens=tokens) for p in prompts]
        t0 = time.perf_counter()
        results = router.run_until_complete()
        dt = time.perf_counter() - t0
        return [results[rid] for rid in rids], dt, router.stats()

    inproc = make_router(build_runner(spec), 2, config)
    res_in, dt_in, stats_in = serve(inproc)
    expected = [r.outputs for r in res_in]
    assert all(r.status == "ok" for r in res_in)

    t0 = time.perf_counter()
    fleet = make_worker_fleet(spec, 2, config)
    spawn_s = time.perf_counter() - t0
    try:
        res_sub, dt_sub, stats_sub = serve(fleet)
    finally:
        fleet.close()
    assert [r.outputs for r in res_sub] == expected, (
        "subprocess fleet outputs diverged from in-process fleet")

    # observability tax: the same 2-worker subprocess fleet with tracing,
    # metrics and flight recorders attached on both ends of the wire.
    # Contract (asserted): outputs stay bit-identical; the router merges
    # every worker's spans into one cross-process trace. Measured: per-step
    # wall overhead vs the detached subprocess fleet.
    fleet_obs = make_worker_fleet(spec, 2, config, obs=True)
    try:
        res_obs, dt_obs, stats_obs = serve(fleet_obs)
        tel = fleet_obs.telemetry()
    finally:
        fleet_obs.close()
    obs_identical = [r.outputs for r in res_obs] == expected
    assert obs_identical, "attached observability perturbed fleet outputs"
    span_replicas = sorted({str(s.get("replica")) for s in tel["trace"]})
    assert tel["trace"] and len(span_replicas) >= 2, (
        "router did not merge worker spans into one cross-process trace")
    step_ms_obs = 1e3 * dt_obs / max(1, stats_obs["router_steps"])

    # chaos pass: SIGKILL a worker that is holding in-flight requests
    chaos = make_worker_fleet(spec, 2, config)
    try:
        rids = [chaos.submit(p, max_new_tokens=tokens) for p in prompts]
        for _ in range(2):
            chaos.step()
        victim = chaos.replicas[0].transport
        assert victim.in_flight() > 0, "victim held no work before the kill"
        victim.kill()
        results = chaos.run_until_complete()
        res_chaos = [results[rid] for rid in rids]
        stats_chaos = chaos.stats()
    finally:
        chaos.close()
    assert len(chaos.drain_log) == 1, chaos.drain_log
    all_ok = all(r.status == "ok" for r in res_chaos)
    bit_identical = [r.outputs for r in res_chaos] == expected
    assert all_ok and bit_identical, (
        "killed-worker replay diverged from the fault-free in-process run")

    step_ms_in = 1e3 * dt_in / max(1, stats_in["router_steps"])
    step_ms_sub = 1e3 * dt_sub / max(1, stats_sub["router_steps"])
    rec = {
        "name": "serve_engine_fleet",
        "requests": n_req, "workers": 2, "tokens": tokens,
        "inproc": {"wall_s": round(dt_in, 3),
                   "router_steps": stats_in["router_steps"],
                   "step_ms": round(step_ms_in, 3),
                   "req_per_s": round(n_req / dt_in, 2)},
        "subprocess": {"wall_s": round(dt_sub, 3),
                       "router_steps": stats_sub["router_steps"],
                       "step_ms": round(step_ms_sub, 3),
                       "req_per_s": round(n_req / dt_sub, 2),
                       "spawn_s": round(spawn_s, 3)},
        "ipc_overhead_x": round(step_ms_sub / step_ms_in, 3),
        "bit_identical": bit_identical,
        "obs": {"wall_s": round(dt_obs, 3),
                "step_ms": round(step_ms_obs, 3),
                "overhead_x": round(step_ms_obs / step_ms_sub, 3),
                "merged_trace_spans": len(tel["trace"]),
                "trace_replicas": span_replicas,
                "engine_steps": tel["metrics"].get(
                    "engine_steps", {}).get("value", 0),
                "bit_identical": obs_identical},
        "chaos": {"drains": len(chaos.drain_log),
                  "rerouted": stats_chaos["rerouted"],
                  "router_steps": stats_chaos["router_steps"],
                  "all_ok": all_ok,
                  "bit_identical": bit_identical},
    }
    emit("serve_engine_fleet", 0.0,
         f"step {step_ms_in:.1f}ms inproc vs {step_ms_sub:.1f}ms subprocess "
         f"({rec['ipc_overhead_x']}x), kill->replay rerouted="
         f"{stats_chaos['rerouted']} bit_identical={bit_identical}",
         **{k: v for k, v in rec.items() if k != "name"})
    emit("serve_engine_obs", 0.0,
         f"obs tax {rec['obs']['overhead_x']}x/step over detached, "
         f"{rec['obs']['merged_trace_spans']} merged spans from "
         f"{len(span_replicas)} sources, bit_identical={obs_identical}",
         workers=2, obs=rec["obs"])
    return rec


def run(smoke: bool = False) -> dict:
    lm = bench_lm(smoke)
    snn = bench_snn(smoke)
    chunked = bench_chunked_prefill(smoke)
    slo = bench_slo(smoke)
    precision = bench_precision(smoke)
    speculative = bench_speculative(smoke)
    faults = bench_faults(smoke)
    fleet = bench_fleet(smoke)
    record = {"name": "serve_engine", "lm": lm, "snn": snn,
              "chunked_prefill": chunked, "slo": slo,
              "precision": precision, "speculative": speculative,
              "faults": faults, "fleet": fleet}
    print("SERVE_ENGINE_JSON " + json.dumps(record, sort_keys=True))
    append_result(record)
    return record


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes for CI (2 slots, fewer requests)")
    run(**vars(ap.parse_args()))
