"""Fused event-driven inference pipeline: old vs. new serving hot path.

Compares the pre-fusion pipeline (T separate in-kernel-gated spike_conv +
lif_step launches per layer from a Python loop) against the fused pipeline
(one occupancy-mapped gated-matmul launch per spiking layer, timesteps
folded into the batch, conv-epilogue LIF, whole-graph jit). Reports:

* wall-clock per image batch for both paths,
* gated-matmul launches per spiking conv layer (fused must be <= 1, the
  seed path issues T),
* per-layer tile-skip rates of the occupancy map on a spatially sparse
  input (localized stimulus -> empty spike tiles downstream).

Emits one machine-readable JSON record (stdout line starting with
``HYBRID_PIPELINE_JSON``) plus the usual CSV rows / BENCH_results.json
entries.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import vgg9_snn
from repro.core.hybrid import plan_vgg9_inference
from repro.kernels.spike_conv import ops as sc_ops
from repro.models.vgg9 import init_vgg9, vgg9_infer_hybrid, vgg9_infer_hybrid_unfused

from .common import append_result, emit, time_fn

# Bigger than TINY so the occupancy map has enough tiles to skip, still
# CPU/interpret friendly.
CFG = dataclasses.replace(
    vgg9_snn.TINY, img_hw=32, stages=(16, 24, "MP", 32, 32, "MP"), fc_dim=64)
BATCH = 4


def _sparse_images(batch: int, hw: int) -> jnp.ndarray:
    """A localized bright stimulus: most of the field never spikes, so the
    spiking layers see spatially sparse events (the regime the paper's
    sparse cores — and the occupancy map — are built for)."""
    rng = np.random.default_rng(0)
    imgs = np.zeros((batch, hw, hw, 3), np.float32)
    imgs[:, : hw // 4, : hw // 4, :] = rng.uniform(
        0.5, 1.0, size=(batch, hw // 4, hw // 4, 3)).astype(np.float32)
    return jnp.asarray(imgs)


def run() -> dict:
    params = init_vgg9(jax.random.PRNGKey(0), CFG)
    imgs = _sparse_images(BATCH, CFG.img_hw)
    plan = plan_vgg9_inference(CFG, BATCH)
    n_spiking = sum(1 for l in plan.layers
                    if l.kernel is not None and l.kernel.kernel == "spike_conv_mapped")

    # --- launches per traced forward (what the executed graph dispatches).
    # Counters increment at trace time, so force a fresh trace: a warm jit
    # cache would read as zero launches.
    jax.clear_caches()
    sc_ops.reset_launch_counts()
    _, _, stats = vgg9_infer_hybrid(params, imgs, CFG, interpret=True,
                                    plan=plan, return_stats=True)
    fused_launches = sc_ops.launch_counts().get("spike_matmul_mapped", 0)

    sc_ops.reset_launch_counts()
    vgg9_infer_hybrid_unfused(params, imgs, CFG, interpret=True)
    unfused_launches = sc_ops.launch_counts().get("spike_matmul", 0)

    skip_rates = {k: float(v["skip_rate"]) for k, v in stats.items()
                  if "skip_rate" in v}

    # --- wall clock. NOTE: kernels run in interpret mode on this CPU
    # container, so absolute times are a correctness harness, not a perf
    # signal — the TPU-relevant perf metrics are the launch counts and the
    # tile-skip rates (work the MXU never sees).
    fused_fn = lambda: vgg9_infer_hybrid(params, imgs, CFG, interpret=True, plan=plan)
    unfused_fn = lambda: vgg9_infer_hybrid_unfused(params, imgs, CFG, interpret=True)
    fused_us = time_fn(fused_fn, iters=3, warmup=1)
    unfused_us = time_fn(unfused_fn, iters=3, warmup=1)

    record = {
        "name": "hybrid_pipeline",
        "timesteps": CFG.timesteps,
        "batch": BATCH,
        "spiking_conv_layers": n_spiking,
        "launches_fused": fused_launches,
        "launches_unfused": unfused_launches,
        "launches_per_layer_fused": fused_launches / max(n_spiking, 1),
        "launches_per_layer_unfused": unfused_launches / max(n_spiking, 1),
        "skip_rates": skip_rates,
        "max_skip_rate": max(skip_rates.values()),
        "min_skip_rate": min(skip_rates.values()),
        "interpret_fused_us": round(fused_us, 1),
        "interpret_unfused_us": round(unfused_us, 1),
    }
    print("HYBRID_PIPELINE_JSON " + json.dumps(record, sort_keys=True))
    append_result(record)

    emit("hybrid_pipeline_fused", fused_us,
         f"launches/layer={record['launches_per_layer_fused']:.0f} "
         f"max_skip={record['max_skip_rate']:.2f}")
    emit("hybrid_pipeline_unfused", unfused_us,
         f"launches/layer={record['launches_per_layer_unfused']:.0f}")
    return record


if __name__ == "__main__":
    run()
