"""Paper Fig. 4: per-image energy, fp32 vs int4, LW / perf^2 / perf^4.

Uses the calibrated FPGA cost model with the paper's published LW core
allocations and a VGG9 spike profile; fp32 networks carry 1.1-1.15x the
spikes of int4 (Fig. 1). Paper claims: int4 cuts average energy 3.4x
(CIFAR10) and 1.7x (CIFAR100); perf^4 quantized cuts 28% vs LW.
"""
import numpy as np

from repro.configs.vgg9_snn import LW_ALLOCATIONS
from repro.core.energy import energy_per_image
from repro.core.workload import (conv_workload, dense_input_workload,
                                 fc_workload, scale_allocation)

from .common import emit

C_OUT = [112, 192, 216, 480, 504, 560]
# total spikes per image (Table II: 41K CIFAR10 int4; Fig. 1 bar ratios)
TOTALS = {"svhn": 35_000, "cifar10": 41_000, "cifar100": 48_000}
POP = {"svhn": 1000, "cifar10": 1000, "cifar100": 5000}


def spike_profile(ds):
    """Per-layer spike counts derived by INVERTING the paper's LW core
    allocations: the LW search balances layer latency, so Eq. 3 gives
    W_l = F*C_out*S_l proportional to NC_l, i.e. S_l ~ NC_l / C_out_l.
    Totals calibrated to the measured dataset spike counts."""
    nc = LW_ALLOCATIONS[ds]
    rel_conv = [nc[i + 1] / c for i, c in enumerate(C_OUT)]
    rel_fc = [nc[7] / 1064, nc[8] / POP[ds]]
    scale = TOTALS[ds] / sum(rel_conv + rel_fc)
    return [r * scale for r in rel_conv], [r * scale for r in rel_fc]


def workloads(ds, spike_scale=1.0, population=None):
    conv_s, fc_s = spike_profile(ds)
    ls = [dense_input_workload("conv0", 32, 32, 64, 2)]
    ls += [conv_workload(f"conv{i+1}", c, 9, s * spike_scale)
           for i, (c, s) in enumerate(zip(C_OUT, conv_s))]
    ls += [fc_workload("fc0", 1064, fc_s[0] * spike_scale),
           fc_workload("fc1", population or POP[ds], fc_s[1] * spike_scale)]
    return ls


def weight_bytes(bytes_per):
    ws = [3 * 64 * 9] + [a * b * 9 for a, b in zip([64, 112, 192, 216, 480, 504],
                                                   C_OUT)]
    ws += [4 * 4 * 560 * 1064, 1064 * 1000]
    return [w * bytes_per for w in ws]


def run():
    for ds in ("svhn", "cifar10", "cifar100"):
        lw = list(LW_ALLOCATIONS[ds])
        ratios = []
        for k, tag in ((1, "LW"), (2, "perf2"), (4, "perf4")):
            alloc = scale_allocation(lw, k)
            e4 = energy_per_image(workloads(ds), alloc, weight_bytes(0.5), "int4")
            e32 = energy_per_image(workloads(ds, 1.12), alloc, weight_bytes(4.0), "fp32")
            ratios.append(e32["energy_j"] / e4["energy_j"])
            emit(f"fig4/{ds}/{tag}", e4["latency_s"] * 1e6,
                 f"int4_mj={e4['energy_j']*1e3:.2f};fp32_mj={e32['energy_j']*1e3:.2f};"
                 f"ratio={ratios[-1]:.2f}")
        emit(f"fig4/{ds}/avg_ratio", 0.0,
             f"fp32_over_int4={np.mean(ratios):.2f};paper=1.7-3.4")


if __name__ == "__main__":
    run()
