"""Paper Table III: throughput/power vs prior work + kernel-level skip rates.

FPGA side: the calibrated model reproduces our accelerator's FPS/power for
the perf^2/perf^4 configs (paper: 120 FPS @0.73 W CIFAR10-perf^2, 218 FPS
@2.35 W CIFAR100-perf^4, 51x throughput vs [7]).

TPU side: measures the *occupancy-gated* spike-conv skip opportunity (the
fraction of MXU tiles the sparse-core kernel skips at real spike densities)
and the wall-clock of the jitted hybrid inference path on this host as a
relative sanity number.
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import vgg9_snn
from repro.configs.vgg9_snn import LW_ALLOCATIONS
from repro.core.energy import energy_per_image
from repro.core.sparsity import tile_occupancy
from repro.core.workload import scale_allocation
from repro.data.synthetic import image_batch
from repro.models.vgg9 import init_vgg9, vgg9_forward

from .common import emit, time_fn
from .fig4_energy import weight_bytes, workloads


def fpga_side():
    for ds, perf, paper_fps, paper_w in (("cifar10", 2, 120, 0.73),
                                         ("cifar100", 4, 218, 2.35),
                                         ("svhn", 4, 110, 0.89)):
        alloc = scale_allocation(list(LW_ALLOCATIONS[ds]), perf)
        e = energy_per_image(workloads(ds), alloc, weight_bytes(0.5), "int4")
        emit(f"table3/{ds}_perf{perf}", e["latency_s"] * 1e6,
             f"fps={e['throughput_fps']:.0f};paper_fps={paper_fps};"
             f"power_w={e['power_pipelined_w']:.2f};paper_w={paper_w}")
    # headline: 51x throughput vs [7] (4.7 FPS on CIFAR100)
    alloc = scale_allocation(list(LW_ALLOCATIONS["cifar100"]), 4)
    e = energy_per_image(workloads("cifar100"), alloc, weight_bytes(0.5), "int4")
    emit("table3/vs_prior_cifar100", 0.0,
         f"speedup_vs_4.7fps={e['throughput_fps']/4.7:.0f}x;paper=51x")


def tpu_side():
    cfg = dataclasses.replace(vgg9_snn.TINY, num_classes=4)
    params = init_vgg9(jax.random.PRNGKey(0), cfg)
    imgs = image_batch(0, 0, 32, num_classes=4, hw=cfg.img_hw)["images"]
    fwd = jax.jit(lambda im: vgg9_forward(params, im, cfg))
    us = time_fn(fwd, imgs)
    logits, counts = fwd(imgs)
    total = sum(float(v) for v in counts.values())
    emit("table3/tpu_hybrid_forward", us, f"spikes_per_batch={total:.0f}")

    # tile-skip opportunity at measured spike densities
    for density in (0.05, 0.15, 0.3):
        spikes = (jax.random.uniform(jax.random.PRNGKey(1), (64, 28 * 28 * 9)) < density)
        occ = float(tile_occupancy(spikes.astype(jnp.float32), 128))
        emit(f"table3/tile_skip_density_{density}", 0.0,
             f"occupied_frac={occ:.3f};mxu_skip_frac={1-occ:.3f}")


def run():
    fpga_side()
    tpu_side()


if __name__ == "__main__":
    run()
