"""Benchmark harness: one module per paper table/figure plus pipeline perf.

Prints ``name,us_per_call,derived`` CSV rows; every row is also appended to
``BENCH_results.json`` so the perf trajectory is tracked across PRs, and the
run ends with an aggregate summary of that file.

``--gate`` skips the benchmarks and instead replays the stored history as a
regression gate: for every record lineage (same ``name`` + same ``config``),
the latest ``us_per_call`` is compared against the best earlier run; any
lineage more than ``--threshold`` (default 20%) slower fails the gate.
CI runs this as a non-blocking step so perf cliffs are visible per PR
without flaking the build on shared-runner noise.
"""
import argparse
import json


def lineage(rec: dict) -> tuple:
    """A record's comparison key: same name + same config = same lineage.
    Timestamps are deliberately excluded — runs of one lineage across PRs
    form the trajectory the gate walks."""
    return (rec.get("name", "unnamed"),
            json.dumps(rec.get("config", {}), sort_keys=True))


def check_gate(data: list, threshold: float = 0.2) -> list:
    """Regressed lineages in ``data`` (file order = run order).

    Returns ``[(name, config_json, best_us, latest_us)]`` for every lineage
    whose latest ``us_per_call`` exceeds the best earlier run by more than
    ``threshold``. Lineages with fewer than two timed runs never fail.
    """
    groups: dict = {}
    for rec in data:
        if not isinstance(rec, dict):
            continue
        us = rec.get("metrics", {}).get("us_per_call", rec.get("us_per_call"))
        if not isinstance(us, (int, float)) or us <= 0:
            continue
        groups.setdefault(lineage(rec), []).append(float(us))
    regressions = []
    for (name, cfg), runs in sorted(groups.items()):
        if len(runs) < 2:
            continue
        best, latest = min(runs[:-1]), runs[-1]
        if latest > best * (1.0 + threshold):
            regressions.append((name, cfg, best, latest))
    return regressions


def gate_main(path: str, threshold: float) -> int:
    try:
        with open(path) as f:
            data = json.load(f)
    except (FileNotFoundError, json.JSONDecodeError) as e:
        print(f"perf gate: cannot read {path}: {e}")
        return 1
    regressions = check_gate(data, threshold=threshold)
    if not regressions:
        print(f"perf gate: OK ({path}, threshold {threshold:.0%})")
        return 0
    print(f"perf gate: {len(regressions)} regression(s) "
          f"(>{threshold:.0%} over the lineage's best run):")
    for name, cfg, best, latest in regressions:
        print(f"  {name} {cfg}: best {best:.1f}us -> latest {latest:.1f}us "
              f"({latest / best:.2f}x)")
    return 1


def run_benchmarks() -> None:
    print("name,us_per_call,derived")
    from . import fig1_quant_sparsity, table1_resources, fig4_energy
    from . import table2_direct_rate, table3_throughput, roofline
    from . import hybrid_pipeline
    table1_resources.run()
    fig4_energy.run()
    table2_direct_rate.run()
    table3_throughput.run()
    fig1_quant_sparsity.run()
    roofline.run()
    hybrid_pipeline.run()

    from .common import RESULTS_PATH, aggregate
    summary = aggregate()
    print(f"\n# BENCH_results.json aggregate ({RESULTS_PATH}):")
    for name, entry in sorted(summary.items()):
        latest = entry["latest_us"]
        latest_s = f"{latest:.1f}us" if isinstance(latest, (int, float)) else "-"
        print(f"#   {name}: runs={entry['runs']} latest={latest_s}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--gate", action="store_true",
                    help="perf-regression gate over BENCH_results.json "
                         "instead of running benchmarks (exit 1 on any "
                         "lineage regressing past --threshold)")
    ap.add_argument("--threshold", type=float, default=0.2,
                    help="fractional slowdown tolerated vs the lineage's "
                         "best run (default 0.2 = 20%%)")
    ap.add_argument("--results", default="",
                    help="results file (default: benchmarks.common."
                         "RESULTS_PATH, honouring $BENCH_RESULTS)")
    args = ap.parse_args()
    if args.gate:
        from .common import RESULTS_PATH
        raise SystemExit(gate_main(args.results or RESULTS_PATH,
                                   args.threshold))
    run_benchmarks()


if __name__ == '__main__':
    main()
