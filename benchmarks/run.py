"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.
"""


def main() -> None:
    print("name,us_per_call,derived")
    from . import fig1_quant_sparsity, table1_resources, fig4_energy
    from . import table2_direct_rate, table3_throughput, roofline
    table1_resources.run()
    fig4_energy.run()
    table2_direct_rate.run()
    table3_throughput.run()
    fig1_quant_sparsity.run()
    roofline.run()


if __name__ == '__main__':
    main()
