"""Benchmark harness: one module per paper table/figure plus pipeline perf.

Prints ``name,us_per_call,derived`` CSV rows; every row is also appended to
``BENCH_results.json`` so the perf trajectory is tracked across PRs, and the
run ends with an aggregate summary of that file.
"""


def main() -> None:
    print("name,us_per_call,derived")
    from . import fig1_quant_sparsity, table1_resources, fig4_energy
    from . import table2_direct_rate, table3_throughput, roofline
    from . import hybrid_pipeline
    table1_resources.run()
    fig4_energy.run()
    table2_direct_rate.run()
    table3_throughput.run()
    fig1_quant_sparsity.run()
    roofline.run()
    hybrid_pipeline.run()

    from .common import RESULTS_PATH, aggregate
    summary = aggregate()
    print(f"\n# BENCH_results.json aggregate ({RESULTS_PATH}):")
    for name, entry in sorted(summary.items()):
        latest = entry["latest_us"]
        latest_s = f"{latest:.1f}us" if isinstance(latest, (int, float)) else "-"
        print(f"#   {name}: runs={entry['runs']} latest={latest_s}")


if __name__ == '__main__':
    main()
