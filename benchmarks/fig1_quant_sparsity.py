"""Paper Fig. 1: quantization effect on total spikes (the headline ablation).

Trains the reduced VGG9 with fp32 weights and with int4 QAT on the synthetic
class-conditional image task, then compares total spike counts and accuracy.
Paper-scale claim: int4 emits 6.1-15.2% fewer spikes at <=3.1% accuracy cost.
At CPU/tiny scale we report the measured deltas (direction can be noisier at
this model size; the paper-scale trend is validated by the QAT-trained runs).
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import vgg9_snn
from repro.data.synthetic import image_batch
from repro.models.vgg9 import init_vgg9, vgg9_forward, vgg9_loss
from repro.train.optim import adamw
from repro.train.schedule import constant
from repro.train.train_step import init_train_state, make_train_step

from .common import emit, time_fn

CFG = dataclasses.replace(vgg9_snn.TINY, num_classes=4)
STEPS = 70


def train(cfg, seed=0):
    opt = adamw(weight_decay=0.0)
    step = jax.jit(make_train_step(lambda p, b: vgg9_loss(p, b, cfg), opt, constant(2e-3)))
    state = init_train_state(init_vgg9(jax.random.PRNGKey(seed), cfg), opt)
    for i in range(STEPS):
        state, m = step(state, image_batch(seed, i, 32, num_classes=cfg.num_classes,
                                           hw=cfg.img_hw))
    return state["params"]


def evaluate(params, cfg, n=4):
    correct = total = 0
    spikes = 0.0
    for i in range(n):
        b = image_batch(123, i, 32, num_classes=cfg.num_classes, hw=cfg.img_hw)
        logits, counts = vgg9_forward(params, b["images"], cfg)
        correct += int((jnp.argmax(logits, -1) == b["labels"]).sum())
        total += 32
        spikes += float(sum(counts.values()))
    return correct / total, spikes / total


def run():
    cfg_q = dataclasses.replace(CFG, quant_bits=4)
    p_f = train(CFG)
    p_q = train(cfg_q)
    us = time_fn(jax.jit(lambda im: vgg9_forward(p_f, im, CFG)[0]),
                 image_batch(0, 0, 32, num_classes=4, hw=CFG.img_hw)["images"])
    acc_f, spk_f = evaluate(p_f, CFG)
    acc_q, spk_q = evaluate(p_q, cfg_q)
    delta = (spk_f - spk_q) / spk_f * 100
    emit("fig1/fp32", us, f"acc={acc_f:.3f};spikes_per_img={spk_f:.0f}")
    emit("fig1/int4_qat", us, f"acc={acc_q:.3f};spikes_per_img={spk_q:.0f}")
    emit("fig1/quant_spike_reduction", us,
         f"pct={delta:.1f};paper_band=6.1-15.2;acc_delta={abs(acc_f-acc_q):.3f}")


if __name__ == "__main__":
    run()
