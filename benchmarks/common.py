"""Shared benchmark utilities.

Every `emit` both prints the human-readable CSV row and appends a JSON
record to ``BENCH_results.json`` (repo root, or ``$BENCH_RESULTS``), so the
perf trajectory is tracked across PRs. `benchmarks.run` aggregates the file
at the end of a run.

Record schema (enforced in CI by ``tools/check_bench_schema.py``):

    {"name": str, "config": dict, "metrics": dict, "timestamp": int}

``config`` holds the run's descriptive knobs (strings: derived labels,
scheduler names); ``metrics`` holds every measured quantity (numbers and
structured sub-dicts, ``us_per_call`` included). `append_result` normalizes
free-form records into this shape so legacy call sites keep working.
"""
import json
import os
import time

import jax

RESULTS_PATH = os.environ.get(
    "BENCH_RESULTS",
    os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "BENCH_results.json"),
)


def time_fn(fn, *args, iters: int = 5, warmup: int = 2):
    """Median wall-time per call in microseconds (jit-compiled fn)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


_SCHEMA_KEYS = ("name", "config", "metrics", "timestamp")


def normalize_record(record: dict) -> dict:
    """Coerce a free-form benchmark record into the canonical schema.

    Already-canonical records pass through. Otherwise: ``name`` and
    ``timestamp`` (or legacy ``unix_time``) lift to the top level, string
    payload fields file under ``config``, everything measured under
    ``metrics``.
    """
    if set(record) == set(_SCHEMA_KEYS):
        return dict(record)
    rec = dict(record)
    name = rec.pop("name", "unnamed")
    ts = rec.pop("timestamp", rec.pop("unix_time", int(time.time())))
    config = dict(rec.pop("config", {}))
    metrics = dict(rec.pop("metrics", {}))
    for k, v in rec.items():
        (config if isinstance(v, str) else metrics)[k] = v
    return {"name": name, "config": config, "metrics": metrics,
            "timestamp": int(ts)}


def _record_key(rec: dict) -> tuple:
    """Identity for duplicate suppression: same name + config + timestamp
    is the same measurement event (re-appends add no information and trip
    the schema checker's duplicate guard)."""
    return (rec.get("name"),
            json.dumps(rec.get("config", {}), sort_keys=True),
            rec.get("timestamp"))


def append_result(record: dict) -> None:
    """Append one benchmark record to BENCH_results.json (a JSON list),
    normalized to the canonical schema. Exact duplicates (same name,
    config and timestamp) are dropped rather than re-appended."""
    record = normalize_record(record)
    try:
        with open(RESULTS_PATH) as f:
            data = json.load(f)
        if not isinstance(data, list):
            data = []
    except (FileNotFoundError, json.JSONDecodeError):
        data = []
    key = _record_key(record)
    if any(isinstance(r, dict) and _record_key(r) == key for r in data):
        return
    data.append(record)
    with open(RESULTS_PATH, "w") as f:
        json.dump(data, f, indent=1)
        f.write("\n")


def emit(name: str, us_per_call: float, derived: str, **metrics):
    print(f"{name},{us_per_call:.1f},{derived}")
    append_result({
        "name": name,
        "config": {"derived": derived},
        "metrics": {"us_per_call": round(us_per_call, 1), **metrics},
        "timestamp": int(time.time()),
    })


def aggregate(path: str = None) -> dict:
    """Summarize BENCH_results.json: per benchmark name, the number of
    recorded runs and the latest median latency."""
    path = path or RESULTS_PATH
    try:
        with open(path) as f:
            data = json.load(f)
    except (FileNotFoundError, json.JSONDecodeError):
        return {}
    summary = {}
    for rec in data:
        if not isinstance(rec, dict) or "name" not in rec:
            continue
        entry = summary.setdefault(rec["name"], {"runs": 0, "latest_us": None})
        entry["runs"] += 1
        entry["latest_us"] = rec.get("metrics", {}).get(
            "us_per_call", rec.get("us_per_call"))
    return summary
