"""Roofline table generator: reads results/dryrun/*.json -> markdown/CSV.

Used to produce EXPERIMENTS.md §Dry-run and §Roofline. Run after
`python -m repro.launch.dryrun --all --mesh both`.
"""
import glob
import json
import os

from .common import emit


def load(out_dir="results/dryrun"):
    cells = []
    for f in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(f) as fh:
            cells.append(json.load(fh))
    return cells


def fmt_s(x):
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.1f}us"


def markdown_table(cells, mesh="pod", variant="baseline"):
    rows = []
    hdr = ("| arch | shape | kind | mem/chip | T_comp | T_mem | T_coll | dominant "
           "| MODEL_FLOPS/HLO | status |")
    rows.append(hdr)
    rows.append("|" + "---|" * 10)
    for c in sorted(cells, key=lambda c: (c["arch"], c["shape"])):
        if c.get("mesh") != mesh or c.get("variant", "baseline") != variant:
            continue
        if c["status"] == "skipped":
            rows.append(f"| {c['arch']} | {c['shape']} | - | - | - | - | - | - | - "
                        f"| SKIP: {c['reason'][:40]} |")
            continue
        if c["status"] != "ok" or "roofline" not in c:
            rows.append(f"| {c['arch']} | {c['shape']} | {c.get('kind','-')} | - | - | - "
                        f"| - | - | - | {c['status']} |")
            continue
        r = c["roofline"]
        rows.append(
            f"| {c['arch']} | {c['shape']} | {c['kind']} "
            f"| {c['memory']['peak_estimate_gib']:.1f}GiB "
            f"| {fmt_s(r['t_comp_s'])} | {fmt_s(r['t_mem_s'])} | {fmt_s(r['t_coll_s'])} "
            f"| **{r['dominant']}** | {c.get('useful_flops_ratio', '-')} | ok |")
    return "\n".join(rows)


def run():
    cells = [c for c in load() if c.get("variant", "baseline") == "baseline"]
    ok = sum(1 for c in cells if c["status"] == "ok")
    skip = sum(1 for c in cells if c["status"] == "skipped")
    fail = sum(1 for c in cells if c["status"] not in ("ok", "skipped"))
    emit("roofline/cells", 0.0, f"ok={ok};skipped={skip};failed={fail}")
    for c in cells:
        if c["status"] == "ok" and "roofline" in c:
            r = c["roofline"]
            emit(f"roofline/{c['arch']}/{c['shape']}/{c['mesh']}",
                 r["bound_s"] * 1e6,
                 f"dominant={r['dominant']};mem_gib={c['memory']['peak_estimate_gib']};"
                 f"useful={c.get('useful_flops_ratio')}")


if __name__ == "__main__":
    import sys
    if "--markdown" in sys.argv:
        cells = load()
        print("### Single-pod (16x16 = 256 chips)\n")
        print(markdown_table(cells, "pod"))
        print("\n### Multi-pod (2x16x16 = 512 chips)\n")
        print(markdown_table(cells, "multipod"))
    else:
        run()
