"""Ambient compute-mesh context.

Model code asks `current_mesh()` whenever it wants to insert sharding
constraints; launch code installs a mesh for the duration of a step with
`compute_mesh(mesh)`. Without an installed mesh every sharding helper is a
no-op, which is exactly the single-device semantics the tests run under.
"""
from __future__ import annotations

import contextlib
import contextvars

_MESH: contextvars.ContextVar = contextvars.ContextVar("repro_dist_mesh", default=None)


def current_mesh():
    """The mesh installed by the innermost `compute_mesh`, or None."""
    return _MESH.get()


@contextlib.contextmanager
def compute_mesh(mesh):
    """Install `mesh` as the ambient compute mesh for the enclosed scope."""
    token = _MESH.set(mesh)
    try:
        yield mesh
    finally:
        _MESH.reset(token)
