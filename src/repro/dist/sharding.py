"""Sharding rules: parameter partitioning, ZeRO-1, batch/cache specs.

Rules *propose* axes and ``_repair`` keeps only the feasible ones: GSPMD
rejects specs whose axis size doesn't divide the dimension, so every helper
degrades to replicated/no-op behavior when mesh axes are absent or dims
don't divide. The same call sites therefore work on one CPU device and on a
pod.

Parameter rules (``param_spec``), Megatron-style:

* 1-D tensors (norm gains, biases) and conv kernels replicate — norms are
  tiny, and the SNN's conv weights are served data-parallel (the batch
  shards, the weights ride along on every device).
* matmul weights are the *last two* dims; any leading dims (the scanned
  period stack, the expert stack) replicate. Default is column-parallel:
  the output dim shards over ``'model'``. Embeddings propose the vocab dim
  first; row-parallel names (``wo``, ``w_out``, ``w_down``) propose the
  input dim.
* divisibility repair: a proposed axis that doesn't divide is dropped, then
  the rule falls back to sharding the right-most divisible matrix dim over
  ``'model'`` (e.g. an odd vocab moves the embedding shard to d_model).
* FSDP-experts mode additionally shards the expert-stack axis over
  ``'data'`` so each DP replica stores 1/DP of the expert weights
  (gathered per layer by ``models.moe``).

ZeRO-1 (``zero1_opt_specs``): optimizer-state leaves inherit their
parameter's spec and additionally shard the first unsharded divisible axis
over ``'data'`` — Adam moments / fp32 masters are genuinely partitioned
across data-parallel replicas, and restore-time resharding in
``train.checkpoint`` keeps it elastic.
"""
from __future__ import annotations

from typing import Any, Sequence, Tuple

import jax
from jax.sharding import PartitionSpec as P

from .context import current_mesh

# last-two-dims matrices whose *input* dim shards over 'model' (row-parallel:
# their producer is already model-sharded, so the matmul contracts locally)
_ROW_PARALLEL = ("wo", "w_out", "w_down", "w2")
# embedding tables: propose the vocab dim first
_EMBED = ("w_tok",)


def dp_axes(mesh) -> Tuple[str, ...]:
    """The data-parallel mesh axes, outermost first."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axis_size(mesh, name: str) -> int:
    return int(mesh.shape[name]) if name in mesh.axis_names else 0


def _repair(axes: Sequence[str | None], shape: Tuple[int, ...], mesh) -> Tuple:
    """Drop sharding axes that the mesh lacks or that don't divide the dim.

    GSPMD rejects specs whose axis size doesn't divide the dimension; rather
    than special-casing every call site, rules propose axes and `_repair`
    keeps only the feasible ones.
    """
    out = []
    for ax, dim in zip(axes, shape):
        if ax is None or ax not in mesh.axis_names or mesh.shape[ax] <= 1 or dim % mesh.shape[ax]:
            out.append(None)
        else:
            out.append(ax)
    out.extend([None] * (len(shape) - len(out)))
    return tuple(out[: len(shape)])


def _path_key(path) -> str:
    """'embed/w_tok'-style key from a tree path of DictKey/SequenceKey."""
    parts = []
    for k in path:
        parts.append(str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k)))))
    return "/".join(parts)


def param_spec(path, leaf, mesh, fsdp_experts: bool = False) -> P:
    """PartitionSpec for one parameter leaf (see module docstring rules).

    Args:
        path: tree path (DictKey/... sequence) of the leaf.
        leaf: array or ShapeDtypeStruct (only ``.shape`` is read).
        mesh: the target mesh (``axis_names`` + ``shape`` mapping); ``None``
            replicates — 1-D leaves never consult it.
        fsdp_experts: shard the expert-stack axis of ``experts/*`` leaves
            over the data axis (MoE FSDP storage layout).
    """
    shape = tuple(leaf.shape)
    if len(shape) <= 1:
        return P()                       # norms/biases/scalars: replicated
    if mesh is None:
        return P()
    key = _path_key(path)
    name = key.rsplit("/", 1)[-1]

    if len(shape) == 4 and name == "w":
        return P()                       # conv kernels (SNN): replicated

    n_stack = len(shape) - 2             # scanned periods / expert stacks
    lead: list = [None] * n_stack
    if fsdp_experts and "experts" in key and n_stack >= 1:
        lead[-1] = "data"                # expert axis: FSDP over DP replicas

    mat = shape[-2:]
    if name in _EMBED:
        prop = ("model", None)           # vocab-sharded embedding
    elif name in _ROW_PARALLEL:
        prop = ("model", None)
    else:
        prop = (None, "model")           # column-parallel default

    spec = list(_repair(tuple(lead) + prop, shape, mesh))
    if "model" not in spec:
        # fallback: right-most divisible matrix dim takes the model axis
        tp = _axis_size(mesh, "model")
        for i in (len(shape) - 1, len(shape) - 2):
            if tp > 1 and spec[i] is None and mat[i - n_stack] % tp == 0:
                spec[i] = "model"
                break
    return P(*spec)


def param_specs(shapes, mesh, fsdp_experts: bool = False):
    """PartitionSpecs for a whole parameter tree (`param_spec` per leaf)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: param_spec(path, leaf, mesh, fsdp_experts), shapes)


def shard_cotangents(tree):
    """Constrain cotangent shardings to match the primal parameter layout.

    Identity on the primal values; on a mesh the VJP constrains each
    cotangent leaf to its parameter's `param_spec` layout. GSPMD fails to
    propagate shardings through the scan transpose for stacked-layer and
    embedding cotangents (they come out replicated, DPx the memory); the
    explicit constraint restores the sharded layout.
    """
    mesh = current_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return tree
    from jax.sharding import NamedSharding
    shardings = jax.tree_util.tree_map_with_path(
        lambda p, l: NamedSharding(mesh, param_spec(p, l, mesh)), tree)
    flat_sh, _ = jax.tree_util.tree_flatten(shardings)

    @jax.custom_vjp
    def _ident(t):
        return t

    def _fwd(t):
        return t, None

    def _bwd(_, ct):
        ct_flat, ctdef = jax.tree_util.tree_flatten(ct)
        out = [jax.lax.with_sharding_constraint(c, s) if hasattr(c, "shape") else c
               for c, s in zip(ct_flat, flat_sh)]
        return (jax.tree_util.tree_unflatten(ctdef, out),)

    _ident.defvjp(_fwd, _bwd)
    return _ident(tree)


def zero1_opt_specs(opt_shapes, param_part, mesh):
    """ZeRO-1 optimizer-state specs: parameter layout + data-axis partition.

    Each optimizer leaf (Adam moment, momentum, factored second-moment row)
    inherits the spec of the parameter it mirrors (matched by tree-path
    suffix: ``opt['m'][...path] <- params[...path]``), then the first axis
    that is still unsharded and divisible by the data-axis size additionally
    shards over ``'data'``. Leaves with no matching parameter (step counters,
    Adafactor's factored ``vr``/``vc``) partition on their own shape.
    """
    data = _axis_size(mesh, "data")
    flat_param = [
        (jax.tree_util.keystr(path), spec)
        for path, spec in jax.tree_util.tree_flatten_with_path(
            param_part, is_leaf=lambda x: isinstance(x, P))[0]
    ]

    def one(path, leaf):
        shape = tuple(leaf.shape)
        if not shape:
            return P()
        key = jax.tree_util.keystr(path)
        base: Sequence = ()
        for pkey, pspec in flat_param:
            if pkey and key.endswith(pkey):
                base = tuple(pspec)
                break
        entries = list(base) + [None] * (len(shape) - len(base))
        if data > 1:
            for i, (e, dim) in enumerate(zip(entries, shape)):
                if e is None and dim % data == 0 and dim >= data:
                    entries[i] = "data"
                    break
        return P(*entries)

    return jax.tree_util.tree_map_with_path(one, opt_shapes)


def batch_spec(b_specs, mesh):
    """Shard the leading (batch) dim over the data axes when they divide it."""
    dp = dp_axes(mesh)
    ndp = 1
    for a in dp:
        ndp *= mesh.shape[a]

    def spec(leaf):
        if dp and leaf.shape and leaf.shape[0] % ndp == 0:
            return P(dp, *([None] * (len(leaf.shape) - 1)))
        return P()

    return jax.tree.map(spec, b_specs)


def cache_spec(path, leaf, mesh):
    """Spec for one decode-cache leaf: batch-sharded over the data axes.

    Stacked period caches are [n_periods, B, ...] (their tree path goes
    through 'periods'); unstacked tail caches are [B, ...] — the path, not
    the shape, decides which axis is the batch.
    """
    dp = dp_axes(mesh)
    ndp = 1
    for a in dp:
        ndp *= mesh.shape[a]
    key = jax.tree_util.keystr(path) if path else ""
    axis = 1 if "periods" in key else 0
    if (dp and len(leaf.shape) > axis
            and leaf.shape[axis] % ndp == 0 and leaf.shape[axis] >= ndp):
        axes = [None] * len(leaf.shape)
        axes[axis] = dp
        return P(*axes)
    return P()


def cache_specs(cache_shapes, mesh):
    """Specs for a whole decode-cache tree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: cache_spec(path, leaf, mesh), cache_shapes)
