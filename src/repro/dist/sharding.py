"""Sharding rules (single-host subset).

Every helper degrades to replicated/no-op behavior when axes are absent or
dims don't divide, so the same call sites work on one CPU device and on a
mesh. Only the rules the model/launch code actually consults are implemented;
the full rule set (FSDP experts, ZeRO-1 partitioning that genuinely splits
states) ships with the distributed package (see ROADMAP open items).
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
from jax.sharding import PartitionSpec as P

from .context import current_mesh


def dp_axes(mesh) -> Tuple[str, ...]:
    """The data-parallel mesh axes, outermost first."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _repair(axes: Sequence[str | None], shape: Tuple[int, ...], mesh) -> Tuple:
    """Drop sharding axes that the mesh lacks or that don't divide the dim.

    GSPMD rejects specs whose axis size doesn't divide the dimension; rather
    than special-casing every call site, rules propose axes and `_repair`
    keeps only the feasible ones.
    """
    out = []
    for ax, dim in zip(axes, shape):
        if ax is None or ax not in mesh.axis_names or mesh.shape[ax] <= 1 or dim % mesh.shape[ax]:
            out.append(None)
        else:
            out.append(ax)
    out.extend([None] * (len(shape) - len(out)))
    return tuple(out[: len(shape)])


def shard_cotangents(tree):
    """Constrain cotangent shardings to match the primal layout.

    Single-host: identity. On a mesh this pins embedding/period cotangents so
    the backward pass doesn't replicate them; that constraint is installed by
    the distributed package.
    """
    if current_mesh() is None:
        return tree
    return tree


def param_specs(shapes, mesh, fsdp_experts: bool = False):
    """PartitionSpecs for a parameter tree: replicated single-host rules."""
    del fsdp_experts
    return jax.tree.map(lambda leaf: P(), shapes)


def zero1_opt_specs(opt_shapes, param_part, mesh):
    """Optimizer-state specs mirroring the parameter partitioning."""
    del param_part
    return jax.tree.map(lambda leaf: P(), opt_shapes)


def batch_spec(b_specs, mesh):
    """Shard the leading (batch) dim over the data axes when they divide it."""
    dp = dp_axes(mesh)
    ndp = 1
    for a in dp:
        ndp *= mesh.shape[a]

    def spec(leaf):
        if dp and leaf.shape and leaf.shape[0] % ndp == 0:
            return P(dp, *([None] * (len(leaf.shape) - 1)))
        return P()

    return jax.tree.map(spec, b_specs)


def cache_spec(path, leaf, mesh):
    """Spec for one decode-cache leaf: batch-sharded over the data axes.

    Stacked period caches are [n_periods, B, ...] (their tree path goes
    through 'periods'); unstacked tail caches are [B, ...] — the path, not
    the shape, decides which axis is the batch.
    """
    dp = dp_axes(mesh)
    ndp = 1
    for a in dp:
        ndp *= mesh.shape[a]
    key = jax.tree_util.keystr(path) if path else ""
    axis = 1 if "periods" in key else 0
    if (dp and len(leaf.shape) > axis
            and leaf.shape[axis] % ndp == 0 and leaf.shape[axis] >= ndp):
        axes = [None] * len(leaf.shape)
        axes[axis] = dp
        return P(*axes)
    return P()


def cache_specs(cache_shapes, mesh):
    """Specs for a whole decode-cache tree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: cache_spec(path, leaf, mesh), cache_shapes)
