"""Distribution utilities (single-host subset).

The model and launch code import sharding/mesh helpers from here so the same
forward functions run unmodified on one device or a pod. This package
currently implements the single-host semantics only: no ambient mesh, no-op
cotangent sharding, replicated parameter/optimizer specs, batch sharding over
the data axes when a mesh is supplied explicitly. The full distributed
package (error-feedback gradient compression, multi-device subprocess-tested
sharding rules — see tests/test_dist.py) is roadmap work.
"""
from . import context, sharding  # noqa: F401
