"""Distribution package: mesh context, sharding rules, gradient compression.

The model and launch code import sharding/mesh helpers from here so the same
forward functions run unmodified on one device or a pod:

* ``context``     — ambient compute-mesh (``compute_mesh`` / ``current_mesh``).
* ``sharding``    — partitioning rules: ``param_spec``/``param_specs`` with
  divisibility repair and FSDP-experts mode, ZeRO-1 optimizer-state
  partitioning (``zero1_opt_specs``), batch/cache specs, cotangent
  sharding constraints.
* ``compression`` — error-feedback int8 gradient compression
  (``quantize_error_feedback``) and the quantize → psum → dequantize
  all-reduce (``compressed_psum``) used inside ``shard_map`` train steps.
* ``compat``      — forward-compat shims for older jax (installed on import).

Every rule degrades to replicated/no-op behavior when axes are absent or
dims don't divide, so the same call sites work on one CPU device and on a
mesh (tests/test_dist.py runs the multi-device cases in subprocesses with
``XLA_FLAGS=--xla_force_host_platform_device_count``).
"""
from . import compat  # noqa: F401  (installs jax API shims first)
from . import compression, context, sharding  # noqa: F401
