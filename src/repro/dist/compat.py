"""Forward-compatibility shims for the jax sharding API.

The distributed package (and the seed's tests) are written against the
current jax surface — ``jax.shard_map`` (with ``check_vma``/``axis_names``),
``jax.make_mesh(..., axis_types=...)`` and ``jax.sharding.AxisType``. The
container pins an older jax where those live under ``jax.experimental`` or
don't exist yet. ``install()`` backfills the missing names so the same
model/test code runs on both; on a jax that already has them it is a no-op.

Installed (only when absent):

* ``jax.sharding.AxisType``   — enum with ``Auto`` / ``Explicit`` / ``Manual``
                                (old jax has only Auto-mode meshes, so the
                                value is accepted and dropped by make_mesh).
* ``jax.make_mesh``           — wrapped to accept ``axis_types=``.
* ``jax.shard_map``           — ``jax.experimental.shard_map.shard_map`` with
                                the new keyword surface: ``check_vma`` maps to
                                ``check_rep``, ``axis_names`` (manual axes) to
                                the complement ``auto`` frozenset.

Importing ``repro.dist`` installs the shims, so any entry point that touches
distribution (models, launch, tests, subprocess snippets) gets them before
the first mesh is built.
"""
from __future__ import annotations

import enum
import functools

import jax
import jax.sharding


def _install_axis_type() -> None:
    if hasattr(jax.sharding, "AxisType"):
        return

    class AxisType(enum.Enum):
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    jax.sharding.AxisType = AxisType


def _install_make_mesh() -> None:
    try:
        import inspect
        if "axis_types" in inspect.signature(jax.make_mesh).parameters:
            return
    except (TypeError, ValueError):  # pragma: no cover - builtins/signatures
        return
    orig = jax.make_mesh

    @functools.wraps(orig)
    def make_mesh(axis_shapes, axis_names, *, axis_types=None, **kw):
        del axis_types  # old jax: every mesh axis is Auto
        return orig(axis_shapes, axis_names, **kw)

    jax.make_mesh = make_mesh


def _install_shard_map() -> None:
    if hasattr(jax, "shard_map"):
        return
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, mesh=None, in_specs=None, out_specs=None, *,
                  check_vma=None, check_rep=None, axis_names=None,
                  auto=None):
        if auto is None:
            if axis_names is not None:
                auto = frozenset(mesh.axis_names) - set(axis_names)
            else:
                auto = frozenset()
        check = True
        if check_rep is not None:
            check = check_rep
        elif check_vma is not None:
            check = check_vma
        return _shard_map(f, mesh, in_specs, out_specs,
                          check_rep=check, auto=frozenset(auto))

    jax.shard_map = shard_map


#: True when this jax needed any shim — i.e. we are on the old API/XLA.
#: Model code uses this to avoid constructs the old XLA miscompiles
#: (partially-auto shard_map: see models.moe).
SHIMMED = False


def install() -> None:
    """Backfill missing jax sharding APIs (idempotent).

    SHIMMED latches: once the shims have been installed they satisfy the
    hasattr probes, so the flag must never be recomputed from scratch on a
    repeat call."""
    global SHIMMED
    SHIMMED = SHIMMED or not (
        hasattr(jax.sharding, "AxisType") and hasattr(jax, "shard_map"))
    _install_axis_type()
    _install_make_mesh()
    _install_shard_map()


install()
