"""Error-feedback int8 gradient compression for data-parallel all-reduce.

The analytical energy comparisons of event-driven systems put communication
on the same budget line as compute: a quantized all-reduce moves 4x fewer
wire bytes than f32 for gradients whose precision the optimizer never needed.
The catch is bias — naive per-step quantization loses the sub-LSB part of
the gradient forever. Error feedback (1-bit SGD / EF-SGD lineage) fixes it:
the quantization residual is carried in a per-shard state tensor and added
back into the *next* step's gradient, so the compression error telescopes
instead of accumulating.

Two layers:

* ``quantize_error_feedback`` — one tensor: int8 values + per-tensor scale +
  the new residual. Exact invariant: ``dequant(q) + residual == g + err_in``.
* ``compressed_psum``         — a gradient pytree inside ``shard_map``:
  shards agree on a shared scale (one scalar ``pmax``), quantize, ``psum``
  the int32 counts, dequantize to the *mean* gradient, and return the new
  residual state. Wire bytes per leaf: 1 byte/element + one scalar, vs 4
  bytes/element for the f32 psum it replaces.

Both support per-channel scales (``axis=-1`` / ``per_channel=True``): one
scale per last-axis slice instead of one per tensor, so a channel whose
gradients are orders of magnitude smaller than the tensor amax no longer
quantizes to a handful of levels — per-step relative error at large fan-in
drops well below the per-tensor ~1/127, at a wire cost of K scalars per
leaf. The error-feedback invariant is unchanged (it is elementwise).

The residual state is threaded through the train step by
``train.train_step.make_train_step(compress_axis=...)`` — see
``init_error_state`` for its layout.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

_QMAX = 127.0  # symmetric int8 range


def init_error_state(tree: Any) -> Any:
    """Zero f32 residuals shaped like a gradient/parameter pytree."""
    return jax.tree.map(lambda leaf: jnp.zeros(jnp.shape(leaf), jnp.float32), tree)


def quantize_error_feedback(
    g: jax.Array,
    err: jax.Array,
    *,
    scale: Optional[jax.Array] = None,
    axis: Optional[int] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Quantize ``g + err`` to int8, returning ``(q, scale, new_err)``.

    The residual invariant is exact up to f32 rounding:
    ``q * scale + new_err == g + err``, so feeding ``new_err`` back on the
    next step makes the long-run compressed gradient unbiased. The
    invariant is elementwise, so it holds for any scale shape.

    Args:
        g: gradient tensor (any float dtype; compensated in f32).
        err: residual carried from the previous step (same shape).
        scale: optional externally agreed scale (``compressed_psum`` passes
            the ``pmax``-shared one); default is ``max|g + err| / 127``
            per tensor, or per ``axis`` slice when ``axis`` is given.
        axis: optional scale axis (``-1``: one scale per last-axis channel,
            kept as a broadcastable vector). Tensors with fewer than two
            dims fall back to the per-tensor scalar — a "per-channel"
            scale of a 1-D tensor would be one f32 scale per element,
            more wire than the uncompressed value. Ignored when ``scale``
            is passed explicitly.

    Returns:
        q int8 tensor, the f32 scale actually used (scalar, or
        broadcastable per-channel vector), and the new f32 residual.
    """
    compensated = g.astype(jnp.float32) + err.astype(jnp.float32)
    if scale is None:
        if axis is None or compensated.ndim < 2:
            amax = jnp.max(jnp.abs(compensated))
        else:
            reduce_axes = tuple(a for a in range(compensated.ndim)
                                if a != axis % compensated.ndim)
            amax = jnp.max(jnp.abs(compensated), axis=reduce_axes,
                           keepdims=True)
        scale = jnp.where(amax > 0, amax, 1.0).astype(jnp.float32) / _QMAX
    q = jnp.clip(jnp.round(compensated / scale), -_QMAX, _QMAX).astype(jnp.int8)
    new_err = compensated - q.astype(jnp.float32) * scale
    return q, scale, new_err


def compressed_psum(grads: Any, err: Any, axis_name: str,
                    per_channel: bool = False) -> Tuple[Any, Any]:
    """Quantized mean-all-reduce of a gradient pytree inside ``shard_map``.

    Per leaf: (1) shards agree on one scale via a ``pmax`` of the
    error-compensated amax — a shared scale is what lets the int8 counts be
    summed directly; (2) quantize with error feedback; (3) ``psum`` the int32
    counts over ``axis_name``; (4) dequantize and divide by the axis size.

    Args:
        grads: per-shard gradient pytree (shard-local values).
        err: residual pytree from the previous step (``init_error_state``
            layout; stays shard-local — it is never reduced).
        axis_name: the mesh axis to reduce over (e.g. ``"data"``).
        per_channel: scale granularity. False — one scalar scale per leaf
            (1 byte/element + 1 scalar on the wire). True — one scale per
            last-axis channel for leaves with ndim >= 2 (``axis=-1``
            vector, ``pmax``-shared like the scalar): channels far below
            the tensor amax keep real resolution, which tightens the
            relative error at large fan-in well below the per-tensor
            ~1/127 for the extra K scalars of wire. 1-D leaves (biases,
            norms) keep the scalar scale — a per-element scale vector
            would cost more wire than the f32 psum it replaces.

    Returns:
        ``(mean_grads, new_err)`` — the dequantized global-mean gradients
        (identical on every shard) and the updated per-shard residuals.
    """
    n = jax.lax.psum(1, axis_name)

    def one(g, e):
        compensated = g.astype(jnp.float32) + e.astype(jnp.float32)
        if per_channel and compensated.ndim >= 2:
            reduce_axes = tuple(range(compensated.ndim - 1))
            amax = jax.lax.pmax(
                jnp.max(jnp.abs(compensated), axis=reduce_axes, keepdims=True),
                axis_name)
        else:
            amax = jax.lax.pmax(jnp.max(jnp.abs(compensated)), axis_name)
        scale = jnp.where(amax > 0, amax, 1.0) / _QMAX
        q, _, new_e = quantize_error_feedback(g, e, scale=scale)
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        return total.astype(jnp.float32) * scale / n, new_e

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(err)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))
