"""Layer-wise workload model and resource partitioner (paper Eq. 3, §V-A).

    W_CONV = F * C_out * sum_i S_i        (F = filter coefficients, e.g. 9)
    W_FC   = N * S                        (N = output neurons, S = input spikes)

Each sparse-core neural core (NC) retires one membrane update per cycle, so a
layer with allocation `nc` takes ~`W / nc` cycles. The paper's design-time
search allocates NCs to minimize the latency spread across layers (balanced
pipeline). We reproduce that with a water-filling allocator and validate it
against the paper's published configurations.

The dense core processes the direct-coded input layer at one output membrane
per cycle per row, with `rows` the parameterized row count:
    cycles_dense = H_out * W_out * C_out * T / rows
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class LayerWorkload:
    name: str
    kind: str          # 'conv' | 'fc' | 'dense_input'
    fan: int           # F*C_out for conv, N for fc, H*W*C_out*T for dense
    spikes: float      # sum_i S_i over all timesteps (1.0 for dense input)

    @property
    def work(self) -> float:
        """Total membrane updates (cycles at one NC)."""
        return float(self.fan) * float(max(self.spikes, 0.0)) if self.kind != "dense_input" else float(self.fan)


def conv_workload(name: str, c_out: int, filter_coeffs: int, spikes: float) -> LayerWorkload:
    return LayerWorkload(name, "conv", filter_coeffs * c_out, spikes)


def fc_workload(name: str, n_out: int, spikes: float) -> LayerWorkload:
    return LayerWorkload(name, "fc", n_out, spikes)


def dense_input_workload(name: str, h_out: int, w_out: int, c_out: int, timesteps: int) -> LayerWorkload:
    return LayerWorkload(name, "dense_input", h_out * w_out * c_out * timesteps, 1.0)


def layer_latencies(workloads: Sequence[LayerWorkload], alloc: Sequence[int], f_clk_hz: float = 100e6) -> np.ndarray:
    """Seconds per layer given an NC allocation."""
    w = np.array([l.work for l in workloads], dtype=np.float64)
    a = np.array(alloc, dtype=np.float64)
    return w / a / f_clk_hz


def balance_allocation(workloads: Sequence[LayerWorkload], budget: int) -> List[int]:
    """Water-filling NC allocation minimizing the max layer latency.

    Start with 1 NC per layer and greedily add an NC to the current
    bottleneck until the budget is spent — the discrete optimum for
    monotone 1/n latencies (exchange argument).
    """
    n = len(workloads)
    if budget < n:
        raise ValueError(f"budget {budget} < number of layers {n}")
    alloc = [1] * n
    work = [l.work for l in workloads]
    for _ in range(budget - n):
        lat = [w / a for w, a in zip(work, alloc)]
        # bottleneck layer; ties broken toward the least-provisioned layer
        # (plain argmax starves later layers when workloads are equal)
        peak = max(lat)
        cands = [i for i, l in enumerate(lat) if l >= peak * (1 - 1e-12)]
        alloc[min(cands, key=lambda i: alloc[i])] += 1
    return alloc


def latency_overheads(workloads: Sequence[LayerWorkload], alloc: Sequence[int]) -> np.ndarray:
    """Per-layer share of total execution time (paper reports these as %)."""
    lat = layer_latencies(workloads, alloc)
    return lat / lat.sum()


def scale_allocation(alloc: Sequence[int], factor: int) -> List[int]:
    """perf^k configurations scale the LW allocation by `factor` (paper §V-A)."""
    return [a * factor for a in alloc]
