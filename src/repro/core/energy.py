"""Energy/latency models: the paper's FPGA cost model + the TPU roofline model.

FPGA side (reproduction): per-image energy = sum over layers of
P_dyn(layer) * t(layer) (+ optional static energy), with layer latencies from
the Eq. 3 workload model. Coefficients are calibrated to the paper's
Table I (CIFAR100 perf^2 instance-level dynamic power, 100 MHz clock) so that
Table II / Fig. 4 ratios reproduce.

TPU side (target hardware): three-term roofline used by §Roofline —
    T_comp = FLOPs  / (chips * PEAK_FLOPS)
    T_mem  = bytes  / (chips * HBM_BW)
    T_coll = coll_bytes / (chips * ICI_BW)
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Sequence

import numpy as np

from .workload import LayerWorkload, layer_latencies

# ---------------------------------------------------------------------------
# TPU roofline constants (v5e-like target; see DESIGN.md §8)
# ---------------------------------------------------------------------------

PEAK_FLOPS_BF16 = 197e12   # FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link (conservative single-link)


@dataclasses.dataclass(frozen=True)
class RooflineTerms:
    t_comp: float
    t_mem: float
    t_coll: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_comp, "memory": self.t_mem, "collective": self.t_coll}
        return max(terms, key=terms.get)

    @property
    def bound(self) -> float:
        """Roofline step time lower bound (s), assuming perfect overlap."""
        return max(self.t_comp, self.t_mem, self.t_coll)

    def as_dict(self) -> Dict[str, float]:
        return {
            "t_comp_s": self.t_comp,
            "t_mem_s": self.t_mem,
            "t_coll_s": self.t_coll,
            "dominant": self.dominant,
            "bound_s": self.bound,
        }


def roofline(flops: float, bytes_hbm: float, coll_bytes: float, chips: int) -> RooflineTerms:
    """Terms in seconds. Pass chips=1 when the inputs are already per-chip
    quantities (the dry-run pieces are — GSPMD-partitioned HLO)."""
    return RooflineTerms(
        t_comp=flops / (chips * PEAK_FLOPS_BF16),
        t_mem=bytes_hbm / (chips * HBM_BW),
        t_coll=coll_bytes / (chips * ICI_BW),
    )


# ---------------------------------------------------------------------------
# FPGA energy model (paper reproduction)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FPGAPowerModel:
    """Per-layer dynamic power = p_per_nc * NC + p_mem * weight_bytes.

    Coefficients calibrated per precision from the paper's Table I
    (CIFAR100 perf^2): int4 total dynamic 1.231 W over 288 NCs; fp32 total
    3.471 W over the same allocation. Static power: 3.13 W (int4) /
    3.22 W (fp32) for the full device.
    """

    p_per_nc: float           # W per neural core (dynamic)
    p_mem_per_byte: float     # W per byte of on-chip weight storage
    p_static: float           # W (whole device)
    f_clk_hz: float = 100e6

    def layer_power(self, nc: int, weight_bytes: float) -> float:
        return self.p_per_nc * nc + self.p_mem_per_byte * weight_bytes


# Calibration: Table I int4 totals 1.231 W dynamic across allocation
# (1,28,12,54,16,72,70,19,4) = 276 cores and ~1.6 MB int4 weights;
# fp32 totals 3.471 W across the same cores and ~12.9 MB fp32 weights.
# Splitting dynamic power ~60/40 between compute and memory reproduces the
# per-layer ordering in Table I within ~20%.
INT4_POWER = FPGAPowerModel(p_per_nc=1.231 * 0.6 / 276, p_mem_per_byte=1.231 * 0.4 / 1.6e6, p_static=3.13)
FP32_POWER = FPGAPowerModel(p_per_nc=3.471 * 0.6 / 276, p_mem_per_byte=3.471 * 0.4 / 12.9e6, p_static=3.22)


def power_model(precision: str) -> FPGAPowerModel:
    return {"int4": INT4_POWER, "fp32": FP32_POWER}[precision]


def energy_per_image(
    workloads: Sequence[LayerWorkload],
    alloc: Sequence[int],
    weight_bytes: Sequence[float],
    precision: str = "int4",
    include_static: bool = False,
) -> Dict[str, float]:
    """Per-image energy/latency following the paper's §V-C methodology.

    Layers execute sequentially through BRAM-staged spike trains, so image
    latency = sum of layer latencies; energy sums per-layer dynamic power x
    per-layer time (the paper's "summing the energy per layer").
    """
    pm = power_model(precision)
    lat = layer_latencies(workloads, alloc, pm.f_clk_hz)
    p = np.array([pm.layer_power(a, wb) for a, wb in zip(alloc, weight_bytes)])
    e_dyn = float(np.sum(p * lat))
    t = float(np.sum(lat))
    e = e_dyn + (pm.p_static * t if include_static else 0.0)
    return {
        "latency_s": t,
        "energy_j": e,
        "energy_dynamic_j": e_dyn,
        "avg_power_w": e / t if t > 0 else 0.0,
        # layers are pipelined through BRAM-staged spike trains (paper §IV):
        # steady-state throughput is set by the slowest layer, latency by the
        # sum; at steady state every layer instance draws power concurrently
        "throughput_fps": 1.0 / float(np.max(lat)) if t > 0 else float("inf"),
        "power_pipelined_w": float(np.sum(p)),
        "energy_pipelined_j": float(np.sum(p) * np.max(lat)),
    }
