"""Energy/latency models: the paper's FPGA cost model, an analytical
energy-per-op model, and the TPU roofline model.

FPGA side (reproduction): per-image energy = sum over layers of
P_dyn(layer) * t(layer) (+ optional static energy), with layer latencies from
the Eq. 3 workload model. Coefficients are calibrated to the paper's
Table I (CIFAR100 perf^2 instance-level dynamic power, 100 MHz clock) so that
Table II / Fig. 4 ratios reproduce.

TPU side (target hardware): three-term roofline used by §Roofline —
    T_comp = FLOPs  / (chips * PEAK_FLOPS)
    T_mem  = bytes  / (chips * HBM_BW)
    T_coll = coll_bytes / (chips * ICI_BW)
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence

import numpy as np

from .workload import LayerWorkload, layer_latencies

# ---------------------------------------------------------------------------
# TPU roofline constants (v5e-like target; see DESIGN.md §8)
# ---------------------------------------------------------------------------

PEAK_FLOPS_BF16 = 197e12   # FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link (conservative single-link)


@dataclasses.dataclass(frozen=True)
class RooflineTerms:
    t_comp: float
    t_mem: float
    t_coll: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_comp, "memory": self.t_mem, "collective": self.t_coll}
        return max(terms, key=terms.get)

    @property
    def bound(self) -> float:
        """Roofline step time lower bound (s), assuming perfect overlap."""
        return max(self.t_comp, self.t_mem, self.t_coll)

    def as_dict(self) -> Dict[str, float]:
        return {
            "t_comp_s": self.t_comp,
            "t_mem_s": self.t_mem,
            "t_coll_s": self.t_coll,
            "dominant": self.dominant,
            "bound_s": self.bound,
        }


def roofline(flops: float, bytes_hbm: float, coll_bytes: float, chips: int) -> RooflineTerms:
    """Terms in seconds. Pass chips=1 when the inputs are already per-chip
    quantities (the dry-run pieces are — GSPMD-partitioned HLO)."""
    return RooflineTerms(
        t_comp=flops / (chips * PEAK_FLOPS_BF16),
        t_mem=bytes_hbm / (chips * HBM_BW),
        t_coll=coll_bytes / (chips * ICI_BW),
    )


# ---------------------------------------------------------------------------
# FPGA energy model (paper reproduction)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FPGAPowerModel:
    """Per-layer dynamic power = p_per_nc * NC + p_mem * weight_bytes.

    Coefficients calibrated per precision from the paper's Table I
    (CIFAR100 perf^2): int4 total dynamic 1.231 W over 288 NCs; fp32 total
    3.471 W over the same allocation. Static power: 3.13 W (int4) /
    3.22 W (fp32) for the full device.
    """

    p_per_nc: float           # W per neural core (dynamic)
    p_mem_per_byte: float     # W per byte of on-chip weight storage
    p_static: float           # W (whole device)
    f_clk_hz: float = 100e6

    def layer_power(self, nc: int, weight_bytes: float) -> float:
        return self.p_per_nc * nc + self.p_mem_per_byte * weight_bytes


# Calibration: Table I int4 totals 1.231 W dynamic across allocation
# (1,28,12,54,16,72,70,19,4) = 276 cores and ~1.6 MB int4 weights;
# fp32 totals 3.471 W across the same cores and ~12.9 MB fp32 weights.
# Splitting dynamic power ~60/40 between compute and memory reproduces the
# per-layer ordering in Table I within ~20%.
INT4_POWER = FPGAPowerModel(p_per_nc=1.231 * 0.6 / 276, p_mem_per_byte=1.231 * 0.4 / 1.6e6, p_static=3.13)
FP32_POWER = FPGAPowerModel(p_per_nc=3.471 * 0.6 / 276, p_mem_per_byte=3.471 * 0.4 / 12.9e6, p_static=3.22)


def power_model(precision: str) -> FPGAPowerModel:
    return {"int4": INT4_POWER, "fp32": FP32_POWER}[precision]


# ---------------------------------------------------------------------------
# Analytical energy-per-op model
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AnalyticalEnergyModel:
    """Bottom-up per-operation energy accounting, following the framing of
    "Reconsidering the energy efficiency of SNNs" (arXiv:2409.08290): instead
    of FPGA-calibrated power x latency (Eq. 3 / `FPGAPowerModel`), count the
    operations an image actually triggers and price each one —

    * compute: every membrane update is one accumulate (spiking layers have
      no multiplies; the dense-coded input layer pays full MACs);
    * memory: every update reads one weight (``wbytes`` bytes at the active
      precision) and reads+writes the membrane state word from on-chip SRAM.

    The two models deliberately disagree: Eq. 3 bills weight *storage*
    (per-layer memory power burns for the whole layer latency, spikes or
    not), this model bills weight *traffic* (silent layers cost nothing).
    A near-silent input therefore looks relatively cheaper here, and the
    int4/fp32 ratio differs measurably between the models — which is why
    the serving-time precision controller (`serve.precision`) prices every
    choice with both. Per-op constants are Horowitz-style 45 nm figures
    (ISSCC'14): fp32 add 0.9 pJ / mult 3.7 pJ; integer-datapath accumulate
    ~0.1 pJ; SRAM ~1.25 pJ per byte touched.
    """

    e_acc_j: float            # J per accumulate (one membrane update)
    e_mac_j: float            # J per multiply-accumulate (dense input layer)
    e_sram_j_per_byte: float  # J per byte of on-chip SRAM traffic
    wbytes: float             # bytes fetched per weight at this precision
    state_bytes: float = 8.0  # membrane word read + write per update


ANALYTICAL_FP32 = AnalyticalEnergyModel(
    e_acc_j=0.9e-12, e_mac_j=4.6e-12, e_sram_j_per_byte=1.25e-12, wbytes=4.0)
ANALYTICAL_INT4 = AnalyticalEnergyModel(
    e_acc_j=0.1e-12, e_mac_j=0.6e-12, e_sram_j_per_byte=1.25e-12, wbytes=0.5)


def analytical_model(precision: str) -> AnalyticalEnergyModel:
    return {"int4": ANALYTICAL_INT4, "fp32": ANALYTICAL_FP32}[precision]


def analytical_energy_per_image(
    workloads: Sequence[LayerWorkload],
    precision: str = "int4",
    model: Optional[AnalyticalEnergyModel] = None,
) -> Dict[str, float]:
    """Per-image energy by op counting (no latency term, no static power).

    ``LayerWorkload.work`` is already the membrane-update count (fan x input
    spikes; the dense input layer's fan alone), so compute energy is
    ``work * e_op`` and memory energy is ``work * (wbytes + state_bytes) *
    e_sram`` — weight traffic scales with spikes, which is exactly the
    sparsity-energy coupling the Eq. 3 storage-power model underweights.
    """
    m = model if model is not None else analytical_model(precision)
    e_comp = e_mem = 0.0
    for l in workloads:
        ops = l.work
        e_comp += ops * (m.e_mac_j if l.kind == "dense_input" else m.e_acc_j)
        e_mem += ops * (m.wbytes + m.state_bytes) * m.e_sram_j_per_byte
    return {
        "energy_j": e_comp + e_mem,
        "energy_compute_j": e_comp,
        "energy_memory_j": e_mem,
    }


def energy_per_image(
    workloads: Sequence[LayerWorkload],
    alloc: Sequence[int],
    weight_bytes: Sequence[float],
    precision: str = "int4",
    include_static: bool = False,
) -> Dict[str, float]:
    """Per-image energy/latency following the paper's §V-C methodology.

    Layers execute sequentially through BRAM-staged spike trains, so image
    latency = sum of layer latencies; energy sums per-layer dynamic power x
    per-layer time (the paper's "summing the energy per layer").
    """
    pm = power_model(precision)
    lat = layer_latencies(workloads, alloc, pm.f_clk_hz)
    p = np.array([pm.layer_power(a, wb) for a, wb in zip(alloc, weight_bytes)])
    e_dyn = float(np.sum(p * lat))
    t = float(np.sum(lat))
    e = e_dyn + (pm.p_static * t if include_static else 0.0)
    return {
        "latency_s": t,
        "energy_j": e,
        "energy_dynamic_j": e_dyn,
        "avg_power_w": e / t if t > 0 else 0.0,
        # layers are pipelined through BRAM-staged spike trains (paper §IV):
        # steady-state throughput is set by the slowest layer, latency by the
        # sum; at steady state every layer instance draws power concurrently
        "throughput_fps": 1.0 / float(np.max(lat)) if t > 0 else float("inf"),
        "power_pipelined_w": float(np.sum(p)),
        "energy_pipelined_j": float(np.sum(p) * np.max(lat)),
    }
