"""Core library: the paper's contribution as composable JAX modules."""
from .lif import LIFParams, lif_scan, lif_step, spike_surrogate, leaky_integrate
from .coding import direct_code, rate_code, spike_count, sparsity
from .quant import QTensor, fake_quant, quantize_int4, dequantize, pack_int4, unpack_int4, qat_params
from .sparsity import SpikeStats, tile_occupancy
from .workload import (
    LayerWorkload,
    balance_allocation,
    conv_workload,
    dense_input_workload,
    fc_workload,
    layer_latencies,
    latency_overheads,
    scale_allocation,
)
from .energy import (
    PEAK_FLOPS_BF16,
    HBM_BW,
    ICI_BW,
    RooflineTerms,
    roofline,
    energy_per_image,
    power_model,
)
from .hybrid import (HybridPlan, KernelSpec, LayerPlan, plan_hybrid,
                     plan_vgg9_inference)
