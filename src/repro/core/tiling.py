"""Tile-shape arithmetic shared by the kernel wrappers and the planner."""
from __future__ import annotations


def round_up(x: int, multiple: int = 128) -> int:
    """Smallest multiple of `multiple` >= x (lane-width 128 by default)."""
    return ((x + multiple - 1) // multiple) * multiple
