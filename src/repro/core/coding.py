"""Input coding schemes for SNNs: direct coding and rate coding (paper §I, §V-D).

Direct coding: the raw floating-point input is presented identically at every
timestep; the *first convolution layer* produces floating-point membrane
currents and its LIF layer emits the binary spikes that drive the rest of the
network. Because the input is timestep-invariant, the input-layer convolution
can be hoisted out of the timestep loop (computed once, reused T times) — the
optimized hybrid path does this; the faithful path recomputes per timestep.

Rate coding: each pixel intensity p in [0,1] becomes an independent Bernoulli
spike train with rate p (one draw per timestep).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def direct_code(x: jax.Array, num_steps: int) -> jax.Array:
    """Repeat input over T timesteps: [B, ...] -> [T, B, ...]."""
    return jnp.broadcast_to(x[None], (num_steps,) + x.shape)


def rate_code(key: jax.Array, x: jax.Array, num_steps: int) -> jax.Array:
    """Bernoulli spike trains with per-pixel rate x (clipped to [0,1]).

    Returns binary [T, B, ...] in x.dtype.
    """
    p = jnp.clip(x, 0.0, 1.0)
    u = jax.random.uniform(key, (num_steps,) + x.shape, dtype=jnp.float32)
    return (u < p[None].astype(jnp.float32)).astype(x.dtype)


def spike_count(spikes: jax.Array) -> jax.Array:
    """Total number of spikes in a (binary) spike train."""
    return jnp.sum(spikes != 0)


def sparsity(spikes: jax.Array) -> jax.Array:
    """Fraction of zero entries (the event-driven skip opportunity)."""
    return 1.0 - jnp.mean((spikes != 0).astype(jnp.float32))
