"""Hybrid dense/sparse execution planning (paper §IV).

The planner is the software analogue of the paper's architecture overview:
the direct-coded input layer (dense, non-binary activations) goes to the
dense path; every later layer (binary spike activations) goes to the sparse,
event-driven path. Core counts per layer come from the Eq. 3 workload model;
`perf^k` configurations scale the lightweight allocation by k.

On TPU the "paths" select kernels: dense path -> kernels/dense_conv_lif
(weight-stationary MXU conv fused with LIF); sparse path ->
kernels/spike_conv (occupancy-gated binary-spike matmul). The plan also
carries the FPGA-model core allocation so the energy benchmarks can evaluate
the same network under the paper's cost model.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

from .workload import (
    LayerWorkload,
    balance_allocation,
    conv_workload,
    dense_input_workload,
    fc_workload,
    latency_overheads,
    scale_allocation,
)


@dataclasses.dataclass(frozen=True)
class LayerPlan:
    name: str
    path: str          # 'dense' | 'sparse'
    cores: int         # NC allocation (FPGA model) / relative share (TPU)


@dataclasses.dataclass(frozen=True)
class HybridPlan:
    layers: List[LayerPlan]
    overheads: List[float]     # per-layer latency share, paper-style
    budget: int

    def cores(self) -> List[int]:
        return [l.cores for l in self.layers]


def plan_hybrid(
    layer_specs: Sequence[dict],
    spike_counts: Dict[str, float],
    budget: int,
    perf_scale: int = 1,
) -> HybridPlan:
    """Build the hybrid plan for a network.

    layer_specs: list of dicts with keys
        name, kind ('conv'|'fc'|'dense_input'), c_out / n_out,
        filter_coeffs (conv), h_out/w_out/timesteps (dense_input).
    spike_counts: measured sum of input spikes per layer (Eq. 3 S terms),
        from a profiling pass (`core.sparsity.SpikeStats`).
    budget: total NC budget for the lightweight configuration.
    perf_scale: 1 for LW, 2 for perf^2, 4 for perf^4.
    """
    workloads: List[LayerWorkload] = []
    for spec in layer_specs:
        kind = spec["kind"]
        name = spec["name"]
        if kind == "dense_input":
            workloads.append(
                dense_input_workload(name, spec["h_out"], spec["w_out"], spec["c_out"], spec["timesteps"])
            )
        elif kind == "conv":
            workloads.append(conv_workload(name, spec["c_out"], spec["filter_coeffs"], spike_counts[name]))
        elif kind == "fc":
            workloads.append(fc_workload(name, spec["n_out"], spike_counts[name]))
        else:
            raise ValueError(f"unknown layer kind {kind}")

    alloc = scale_allocation(balance_allocation(workloads, budget), perf_scale)
    overheads = latency_overheads(workloads, alloc).tolist()
    layers = [
        LayerPlan(w.name, "dense" if w.kind == "dense_input" else "sparse", a)
        for w, a in zip(workloads, alloc)
    ]
    return HybridPlan(layers, overheads, budget * perf_scale)
