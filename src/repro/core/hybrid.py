"""Hybrid dense/sparse execution planning (paper §IV).

The planner is the software analogue of the paper's architecture overview:
the direct-coded input layer (dense, non-binary activations) goes to the
dense path; every later layer (binary spike activations) goes to the sparse,
event-driven path. Core counts per layer come from the Eq. 3 workload model;
`perf^k` configurations scale the lightweight allocation by k.

On TPU the "paths" select kernels: dense path -> kernels/dense_conv_lif
(weight-stationary MXU conv fused with LIF); sparse path ->
kernels/spike_conv (occupancy-gated binary-spike matmul). Each `LayerPlan`
additionally carries a `KernelSpec` — the block shapes the kernels should run
with, chosen from the layer's matmul geometry — so the serving pipeline
(`models.vgg9.vgg9_infer_hybrid`) takes its launch configuration from the
plan instead of hard-coding it. The plan also carries the FPGA-model core
allocation so the energy benchmarks can evaluate the same network under the
paper's cost model.

Plans are frozen, tuple-backed dataclasses: hashable, so they ride along as
`jax.jit` static arguments of the fused inference function.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

from .tiling import round_up as _round_up
from .workload import (
    LayerWorkload,
    balance_allocation,
    conv_workload,
    dense_input_workload,
    fc_workload,
    latency_overheads,
    scale_allocation,
)

# MXU/VPU-friendly ceilings; per-layer specs clamp to the padded problem size.
MAX_BLOCK_M = 256
MAX_BLOCK_K = 128
MAX_BLOCK_N = 128


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """Launch configuration for one layer's kernel.

    kernel: 'dense_conv_lif' | 'spike_conv_mapped' | 'fc_lif'
    m, k, n: padded matmul geometry (M = T*B*H*W rows for the fused path).
    block_*: tile shapes for the gated matmul / conv kernels.
    gate: whether occupancy gating is on (dense layers never gate).
    """
    kernel: str
    m: int
    k: int
    n: int
    block_m: int
    block_k: int
    block_n: int
    gate: bool = True


def select_blocks(m: int, k: int, n: int, *, sparse: bool = False) -> Tuple[int, int, int]:
    """Tile-shape selection from matmul geometry.

    Dense layers take the largest M tile (amortize weight loads). Sparse
    layers take the MXU-minimum M tile (128): the occupancy gate skips work
    at tile granularity, so smaller spike tiles expose strictly more
    skippable zeros — the software knob the co-design papers say must match
    the hardware's skip granularity.
    """
    max_m = 128 if sparse else MAX_BLOCK_M
    return (
        min(max_m, _round_up(m)),
        min(MAX_BLOCK_K, _round_up(k)),
        min(MAX_BLOCK_N, _round_up(n)),
    )


@dataclasses.dataclass(frozen=True)
class LayerPlan:
    name: str
    path: str          # 'dense' | 'sparse'
    cores: int         # NC allocation (FPGA model) / relative share (TPU)
    kernel: Optional[KernelSpec] = None


@dataclasses.dataclass(frozen=True)
class HybridPlan:
    layers: Tuple[LayerPlan, ...]
    overheads: Tuple[float, ...]   # per-layer latency share, paper-style
    budget: int

    def cores(self) -> Tuple[int, ...]:
        return tuple(l.cores for l in self.layers)

    def layer(self, name: str) -> LayerPlan:
        for l in self.layers:
            if l.name == name:
                return l
        raise KeyError(name)


def plan_hybrid(
    layer_specs: Sequence[dict],
    spike_counts: Dict[str, float],
    budget: int,
    perf_scale: int = 1,
) -> HybridPlan:
    """Build the hybrid plan for a network.

    layer_specs: list of dicts with keys
        name, kind ('conv'|'fc'|'dense_input'), c_out / n_out,
        filter_coeffs (conv), h_out/w_out/timesteps (dense_input),
        and optionally 'kernel' (a KernelSpec to attach).
    spike_counts: measured sum of input spikes per layer (Eq. 3 S terms),
        from a profiling pass (`core.sparsity.SpikeStats`).
    budget: total NC budget for the lightweight configuration.
    perf_scale: 1 for LW, 2 for perf^2, 4 for perf^4.
    """
    workloads: list[LayerWorkload] = []
    for spec in layer_specs:
        kind = spec["kind"]
        name = spec["name"]
        if kind == "dense_input":
            workloads.append(
                dense_input_workload(name, spec["h_out"], spec["w_out"], spec["c_out"], spec["timesteps"])
            )
        elif kind == "conv":
            workloads.append(conv_workload(name, spec["c_out"], spec["filter_coeffs"], spike_counts[name]))
        elif kind == "fc":
            workloads.append(fc_workload(name, spec["n_out"], spike_counts[name]))
        else:
            raise ValueError(f"unknown layer kind {kind}")

    alloc = scale_allocation(balance_allocation(workloads, budget), perf_scale)
    overheads = tuple(latency_overheads(workloads, alloc).tolist())
    layers = tuple(
        LayerPlan(w.name, "dense" if w.kind == "dense_input" else "sparse", a,
                  spec.get("kernel"))
        for w, a, spec in zip(workloads, alloc, layer_specs)
    )
    return HybridPlan(layers, overheads, budget * perf_scale)


def plan_vgg9_inference(cfg, batch: int, *, est_density: float = 0.1,
                        budget: int | None = None, perf_scale: int = 1) -> HybridPlan:
    """Plan the fused VGG9 serving pipeline for a batch size.

    Walks the stage list of a `models.vgg9.VGG9Config`, derives each layer's
    fused matmul geometry (timesteps folded into the batch: M = T*B*H*W), and
    selects kernels + block shapes. Spike counts aren't known before running,
    so the Eq. 3 core allocation uses `est_density` spikes per input element —
    the allocation only feeds the FPGA cost model, not the TPU kernels.

    Args:
        cfg: a `models.vgg9.VGG9Config` (stage list, timesteps, image size,
            quantization) — must match the params the plan will serve.
        batch: slot/batch width the fused graph will run at. Plans are
            per-batch-size: block shapes clamp to the padded M = T*B*H*W
            geometry, and the plan rides along as a static `jax.jit`
            argument, so one plan <-> one compiled graph (`SNNRunner.plan`
            caches them per width).
        est_density: assumed spikes per input element for the pre-run Eq. 3
            workload estimate (only prices the FPGA-model NC allocation;
            serving recomputes energy from *measured* spikes).
        budget: total NC budget for the lightweight configuration
            (default: 3 per layer).
        perf_scale: 1 for the paper's LW configuration, 2/4 for perf^2 /
            perf^4 scaled allocations.

    Returns:
        A frozen, hashable `HybridPlan`: one `LayerPlan` per layer (conv0 on
        the dense path with ``gate=False``; later convs on the sparse path
        with M tiled at 128 for finest skip granularity; fc layers folded to
        M = T*B), each carrying its `KernelSpec` launch configuration and
        FPGA-model core count, plus the paper-style per-layer latency
        overhead shares.
    """
    t = cfg.timesteps
    convs = cfg.conv_channels
    specs: list[dict] = []
    spike_counts: Dict[str, float] = {}

    hw = cfg.img_hw
    m0, k0, n0 = batch * hw * hw, 9 * cfg.in_ch, convs[0]
    specs.append({
        "name": "conv0", "kind": "dense_input", "h_out": hw, "w_out": hw,
        "c_out": convs[0], "timesteps": t,
        "kernel": KernelSpec("dense_conv_lif", m0, k0, n0,
                             *select_blocks(m0, k0, n0), gate=False),
    })

    # stage walk keeps conv indices aligned with models.vgg9
    cin = convs[0]
    idx = 0
    for s in cfg.stages:
        if s == "MP":
            hw //= 2
            continue
        if idx > 0:
            m, k, n = t * batch * hw * hw, 9 * cin, s
            name = f"conv{idx}"
            specs.append({
                "name": name, "kind": "conv", "c_out": s, "filter_coeffs": 9,
                "kernel": KernelSpec("spike_conv_mapped", m, k, n,
                                     *select_blocks(m, k, n, sparse=True)),
            })
            spike_counts[name] = est_density * t * batch * hw * hw * cin
        cin = s
        idx += 1

    flat = hw * hw * convs[-1]
    for name, d_in, d_out in (("fc0", flat, cfg.fc_dim),
                              ("fc1", cfg.fc_dim, cfg.population)):
        m, k, n = t * batch, d_in, d_out
        specs.append({
            "name": name, "kind": "fc", "n_out": d_out,
            "kernel": KernelSpec("fc_lif", m, k, n, *select_blocks(m, k, n)),
        })
        spike_counts[name] = est_density * t * batch * d_in

    if budget is None:
        budget = 3 * len(specs)
    return plan_hybrid(specs, spike_counts, budget, perf_scale)
