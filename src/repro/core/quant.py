"""Quantization support: QAT fake-quant (STE), int4 packing, W4A16 serving.

The paper quantizes weights and biases to int4 with quantization-aware
training (Jacob et al. QAT, error folded into the loss via straight-through
estimation), keeps neuronal parameters (beta, theta, membrane) in float, and
de-quantizes accumulated data for the spiking phase (paper §II-B, §IV-D).

This module provides:
  * fake_quant        — symmetric uniform fake-quantization with STE, used in
                        training (QAT) for both the SNN and LM paths.
  * quantize/dequantize, pack_int4/unpack_int4 — storage-side int4 with two
    nibbles per int8 byte (HBM traffic is the TPU analogue of FPGA LUT/BRAM
    savings; see DESIGN.md §2).
  * QTensor           — a quantized parameter container (packed data + scale)
                        consumed by kernels/int4_matmul for W4A16 serving.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp


def _qrange(bits: int) -> Tuple[int, int]:
    qmax = 2 ** (bits - 1) - 1
    return -qmax, qmax  # symmetric, e.g. int4 -> [-7, 7]


# ---------------------------------------------------------------------------
# QAT fake quantization (straight-through estimator)
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def fake_quant(w: jax.Array, bits: int = 4, axis: int | None = None) -> jax.Array:
    """Quantize-dequantize with symmetric uniform quantization.

    Forward: w -> round(w/s).clip(qmin,qmax) * s with s = max|w| / qmax
    (per-tensor, or per-channel over `axis`).
    Backward: straight-through (identity within range, zero outside).
    """
    return _fake_quant_fwd_impl(w, bits, axis)[0]


def _scale(w, bits, axis):
    _, qmax = _qrange(bits)
    if axis is None:
        amax = jnp.max(jnp.abs(w))
    else:
        amax = jnp.max(jnp.abs(w), axis=axis, keepdims=True)
    return jnp.maximum(amax, 1e-8) / qmax


def _fake_quant_fwd_impl(w, bits, axis):
    qmin, qmax = _qrange(bits)
    s = _scale(w, bits, axis)
    q = jnp.clip(jnp.round(w / s), qmin, qmax)
    in_range = (jnp.abs(w) <= (qmax + 0.5) * s).astype(w.dtype)
    return q * s, in_range


def _fq_fwd(w, bits, axis):
    out, in_range = _fake_quant_fwd_impl(w, bits, axis)
    return out, in_range


def _fq_bwd(bits, axis, in_range, g):
    return (g * in_range,)


fake_quant.defvjp(_fq_fwd, _fq_bwd)


# ---------------------------------------------------------------------------
# Storage-side quantization (serving / checkpoints)
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QTensor:
    """Packed quantized tensor: int4 values (2 per int8 byte) + fp scale.

    `shape` is the logical (unpacked) shape; packing is along the last axis,
    which must be even. Scales are per-out-channel (last axis of the logical
    weight), shaped to broadcast on dequantize.
    """

    packed: jax.Array  # int8 [..., K//2]
    scale: jax.Array   # float [..., 1] or [1, N] per-channel
    shape: tuple       # logical shape (static)
    bits: int = 4      # static

    def tree_flatten(self):
        return (self.packed, self.scale), (self.shape, self.bits)

    @classmethod
    def tree_unflatten(cls, aux, children):
        packed, scale = children
        shape, bits = aux
        return cls(packed, scale, shape, bits)

    @property
    def nbytes_logical(self) -> int:
        import numpy as np
        return int(np.prod(self.shape)) * self.bits // 8


def quantize_int4(w: jax.Array, axis: int | None = -1) -> QTensor:
    """Quantize to int4 (per-channel over `axis`≠packing axis) and pack."""
    qmin, qmax = _qrange(4)
    # per-channel scale over the *output* dim: reduce over all other dims.
    if axis is None:
        s = _scale(w, 4, None)
    else:
        red = tuple(i for i in range(w.ndim) if i != (axis % w.ndim))
        s = _scale(w, 4, red)
    q = jnp.clip(jnp.round(w / s), qmin, qmax).astype(jnp.int8)
    return QTensor(pack_int4(q), s.astype(jnp.float32), tuple(w.shape), 4)


def dequantize(qt: QTensor, dtype=jnp.float32) -> jax.Array:
    q = unpack_int4(qt.packed, qt.shape)
    return (q.astype(dtype) * qt.scale.astype(dtype)).reshape(qt.shape)


def pack_int4(q: jax.Array) -> jax.Array:
    """Pack int8 values in [-8,7] into int8 bytes, two nibbles per byte.

    Packing is along the last axis (must be even): out[..., i] holds
    q[..., 2i] in the low nibble and q[..., 2i+1] in the high nibble.
    """
    assert q.shape[-1] % 2 == 0, "packing axis must be even"
    lo = q[..., 0::2] & 0xF
    hi = q[..., 1::2] & 0xF
    return (lo | (hi << 4)).astype(jnp.int8)


def unpack_int4(packed: jax.Array, shape: tuple) -> jax.Array:
    """Inverse of pack_int4; returns int8 values in [-8,7] with `shape`."""
    lo = (packed & 0xF).astype(jnp.int8)
    hi = ((packed >> 4) & 0xF).astype(jnp.int8)
    # sign-extend the 4-bit values
    lo = jnp.where(lo >= 8, lo - 16, lo)
    hi = jnp.where(hi >= 8, hi - 16, hi)
    out = jnp.stack([lo, hi], axis=-1).reshape(packed.shape[:-1] + (-1,))
    return out.reshape(shape)


# ---------------------------------------------------------------------------
# Convenience: QAT treatment of a parameter pytree
# ---------------------------------------------------------------------------

def qat_params(params, bits_w: int = 4, bits_b: int = 8):
    """Apply fake-quant to every 'w*' leaf (bits_w) and 'b*' leaf (bits_b).

    Neuronal parameters (beta/theta) and norm scales are left untouched,
    matching the paper's scheme. Leaves are identified by dict key prefix.
    """

    def walk(d):
        out = {}
        for k, v in d.items():
            if isinstance(v, dict):
                out[k] = walk(v)
            elif k.startswith("w"):
                out[k] = fake_quant(v, bits_w, None)
            elif k.startswith("b"):
                out[k] = fake_quant(v, bits_b, None)
            else:
                out[k] = v
        return out

    return walk(params)
