"""Layer-wise sparsity instrumentation (paper Fig. 1, Eq. 3 inputs).

Spike counts per layer drive (a) the quantization-sparsity study, (b) the
workload model used for core allocation, and (c) the energy model. Stats are
gathered functionally: model forward passes return a `SpikeStats` pytree so
everything stays jit-able and psum-reducible across data-parallel shards.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SpikeStats:
    """Per-layer spike counts and element counts for one forward pass."""

    counts: Dict[str, jax.Array]  # layer name -> total spikes (scalar)
    sizes: Dict[str, jax.Array]   # layer name -> total elements (scalar)

    def tree_flatten(self):
        keys = sorted(self.counts)
        return ([self.counts[k] for k in keys] + [self.sizes[k] for k in keys]), tuple(keys)

    @classmethod
    def tree_unflatten(cls, keys, children):
        n = len(keys)
        return cls(dict(zip(keys, children[:n])), dict(zip(keys, children[n:])))

    @staticmethod
    def empty() -> "SpikeStats":
        return SpikeStats({}, {})

    def record(self, name: str, spikes: jax.Array) -> "SpikeStats":
        counts = dict(self.counts)
        sizes = dict(self.sizes)
        counts[name] = jnp.sum(spikes != 0).astype(jnp.float32)
        sizes[name] = jnp.asarray(spikes.size, jnp.float32)
        return SpikeStats(counts, sizes)

    def total_spikes(self) -> jax.Array:
        if not self.counts:
            return jnp.asarray(0.0)
        return sum(self.counts.values())

    def layer_sparsity(self) -> Dict[str, jax.Array]:
        return {k: 1.0 - self.counts[k] / self.sizes[k] for k in self.counts}

    def cross_replica_sum(self, axis_names) -> "SpikeStats":
        """psum stats across data-parallel shards (inside shard_map/pmap)."""
        return jax.tree.map(lambda x: jax.lax.psum(x, axis_names), self)


def tile_occupancy(spikes: jax.Array, tile: int = 128) -> jax.Array:
    """Fraction of `tile`-wide blocks (last axis) containing >=1 spike.

    This is the quantity that determines how much compute the TPU
    occupancy-gated spike kernel can actually skip — the block-granular
    analogue of the paper's per-event skipping.
    """
    flat = spikes.reshape(-1, spikes.shape[-1])
    pad = (-flat.shape[-1]) % tile
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    blocks = flat.reshape(flat.shape[0], -1, tile)
    occupied = jnp.any(blocks != 0, axis=-1)
    return jnp.mean(occupied.astype(jnp.float32))
