"""Leaky integrate-and-fire neuron dynamics (paper Eq. 1-2) with surrogate gradients.

The paper's LIF (soft reset by threshold subtraction):

    u_j[t+1] = beta * u_j[t] + sum_i w_ij * s_i[t] - s_j[t] * theta      (Eq. 1)
    s_j[t]   = 1 if u_j[t] > theta else 0                                 (Eq. 2)

Training uses surrogate gradients (fast sigmoid, snnTorch default slope=25).
The same leaky-integrator scan generalizes to RG-LRU (no threshold) — see
`repro.models.rglru`.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class LIFParams:
    """Neuronal hyperparameters. Paper defaults: beta=0.15, theta=0.5."""

    beta: float = 0.15
    theta: float = 0.5
    surrogate_slope: float = 25.0

    def astuple(self):
        return (self.beta, self.theta, self.surrogate_slope)


# ---------------------------------------------------------------------------
# Surrogate spike function
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(2,))
def spike_surrogate(u: jax.Array, theta: float | jax.Array, slope: float = 25.0) -> jax.Array:
    """Heaviside(u - theta) forward; fast-sigmoid surrogate backward.

    Forward is the exact Eq. 2 threshold. Backward uses
    d s/d u = 1 / (1 + slope*|u - theta|)^2  (fast sigmoid derivative).
    """
    return (u > theta).astype(u.dtype)


def _spike_fwd(u, theta, slope):
    return spike_surrogate(u, theta, slope), (u, theta)


def _spike_bwd(slope, res, g):
    u, theta = res
    x = u - theta
    surr = 1.0 / (1.0 + slope * jnp.abs(x)) ** 2
    du = g * surr.astype(g.dtype)
    # theta enters as -theta: d/d theta = -surr; theta is usually a static float,
    # but support array thresholds for completeness.
    dtheta = -du if isinstance(theta, jax.Array) else None
    return (du, dtheta)


spike_surrogate.defvjp(_spike_fwd, _spike_bwd)


# ---------------------------------------------------------------------------
# Single-step LIF update
# ---------------------------------------------------------------------------

def lif_step(
    u: jax.Array,
    current: jax.Array,
    prev_spike: jax.Array,
    p: LIFParams,
) -> Tuple[jax.Array, jax.Array]:
    """One LIF timestep per paper Eq. 1-2.

    Args:
      u: membrane potential at t (any shape).
      current: weighted input current sum_i w_ij * s_i[t] (same shape).
      prev_spike: s_j[t] of the *previous* evaluation (soft reset term).
    Returns:
      (u_next, spike) where spike = 1[u_next > theta].
    """
    u_next = p.beta * u + current - prev_spike * p.theta
    s = spike_surrogate(u_next, p.theta, p.surrogate_slope)
    return u_next, s


def lif_scan(
    currents: jax.Array,
    p: LIFParams,
    u0: jax.Array | None = None,
) -> Tuple[jax.Array, jax.Array]:
    """Run LIF over a [T, ...] current sequence with lax.scan.

    Returns (spikes [T, ...], final membrane potential).
    """
    if u0 is None:
        u0 = jnp.zeros(currents.shape[1:], currents.dtype)
    s0 = jnp.zeros_like(u0)

    def body(carry, cur):
        u, s_prev = carry
        u_next, s = lif_step(u, cur, s_prev, p)
        return (u_next, s), s

    (u_final, _), spikes = jax.lax.scan(body, (u0, s0), currents)
    return spikes, u_final


# ---------------------------------------------------------------------------
# Generic leaky integrator (shared machinery with RG-LRU / SSM family)
# ---------------------------------------------------------------------------

def leaky_integrate(decay: jax.Array, inputs: jax.Array, h0: jax.Array | None = None):
    """h[t+1] = decay * h[t] + inputs[t]; returns all h and the final state.

    `decay` broadcasts against the state; this is LIF Eq. 1 without the
    threshold/reset nonlinearity, and is exactly the RG-LRU recurrence with
    per-channel gates when `decay` is an array.
    """
    if h0 is None:
        h0 = jnp.zeros(inputs.shape[1:], inputs.dtype)

    def body(h, x):
        h = decay * h + x
        return h, h

    h_final, hs = jax.lax.scan(body, h0, inputs)
    return hs, h_final
