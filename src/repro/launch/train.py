"""Training driver: real training on the local device(s), or any mesh.

    PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m --steps 50 \
        --d-model 64 --n-layers 4 --vocab 512 --seq 128 --batch 8

    # data-parallel with error-feedback int8 gradient compression:
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python -m repro.launch.train --steps 10 --compress-grads

Production posture: the same code path drives the 512-chip mesh (see
launch/dryrun.py for the compile-level proof); on this CPU container the
reduced configs actually train. Checkpoint/restart: --ckpt-dir + --resume.
"""
from __future__ import annotations

import argparse
import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import get_arch
from ..data.synthetic import token_batch
from ..dist import sharding as shd
from ..dist.context import compute_mesh
from ..models import transformer as tf
from ..models.frontends import synth_frontend
from ..train.loop import TrainLoop
from ..train.optim import make_optimizer
from ..train.schedule import warmup_cosine
from ..train.train_step import (init_train_state, make_train_step,
                                shard_map_compressed_step, stack_error_state)
from .mesh import make_host_mesh


def reduce_cfg(cfg, args):
    kw = {"dtype": "float32", "remat": "none"}
    if args.d_model:
        hd = max(args.d_model // cfg.n_heads, 8)
        kw.update(d_model=args.d_model, head_dim=hd,
                  d_ff=0 if cfg.d_ff == 0 else 2 * args.d_model,
                  moe_d_ff=min(cfg.moe_d_ff, args.d_model) if cfg.moe_d_ff else 0,
                  d_rnn=args.d_model if cfg.d_rnn else 0)
    if args.n_layers:
        period = len(cfg.pattern)
        n = max(period, (args.n_layers // period) * period)
        kw.update(n_layers=n + len(cfg.tail))
    if args.vocab:
        kw.update(vocab=args.vocab)
    if cfg.n_frontend_tokens:
        kw.update(n_frontend_tokens=min(cfg.n_frontend_tokens, 8), d_frontend=16)
    if cfg.n_experts > 8:
        kw.update(n_experts=8, top_k=min(cfg.top_k, 2), n_experts_padded=0,
                  fsdp_experts=False)
    return cfg.with_(**kw)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--d-model", type=int, default=64)
    ap.add_argument("--n-layers", type=int, default=4)
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--full-size", action="store_true",
                    help="use the arch's full config (needs real hardware)")
    ap.add_argument("--compress-grads", action="store_true",
                    help="error-feedback int8 gradient all-reduce over the "
                         "data axis (dist.compression; shard_map train step)")
    ap.add_argument("--compress-per-channel", action="store_true",
                    help="with --compress-grads: per-channel (leading-axis) "
                         "quantization scales instead of one per-tensor "
                         "scale — tighter for tensors with wide channel "
                         "magnitude spread")
    args = ap.parse_args()
    if args.compress_per_channel and not args.compress_grads:
        ap.error("--compress-per-channel requires --compress-grads")

    cfg = get_arch(args.arch)
    if not args.full_size:
        cfg = reduce_cfg(cfg, args)
    mesh = make_host_mesh()

    opt = make_optimizer(cfg.optimizer)
    lr_fn = warmup_cosine(args.lr, 10, args.steps)
    loss_fn = functools.partial(tf.train_loss, cfg=cfg)
    n_data = int(mesh.shape["data"])
    if args.compress_grads:
        assert args.batch % n_data == 0, (args.batch, n_data)
        inner = make_train_step(lambda p, b: loss_fn(p, b), opt, lr_fn,
                                compress_axis="data",
                                compress_per_channel=args.compress_per_channel)
        step = jax.jit(shard_map_compressed_step(inner, mesh))
    else:
        step = jax.jit(make_train_step(lambda p, b: loss_fn(p, b), opt, lr_fn))

    def make_batch(i):
        s_tok = args.seq - (cfg.n_frontend_tokens if cfg.frontend else 0)
        b = token_batch(args.seed, i, args.batch, s_tok, cfg.vocab)
        if cfg.frontend:
            b["frontend_embeds"] = synth_frontend(
                jax.random.fold_in(jax.random.PRNGKey(args.seed), i), cfg, args.batch)
        return b

    # compressed steps are already manual over 'data' (shard_map): no ambient
    # mesh, or the model's internal sharding constraints would nest into it
    import contextlib
    mesh_ctx = contextlib.nullcontext() if args.compress_grads else compute_mesh(mesh)
    with mesh, mesh_ctx:
        params = tf.init_params(jax.random.PRNGKey(args.seed), cfg)
        state = init_train_state(params, opt, compress=args.compress_grads)
        if args.compress_grads:
            state = stack_error_state(state, n_data)
        loop = TrainLoop(step, make_batch, ckpt_dir=args.ckpt_dir,
                         ckpt_every=args.ckpt_every, log_every=5)
        restored, start = loop.maybe_restore(jax.eval_shape(lambda: state))
        if restored is not None:
            state, = (restored,)
            print(f"resumed from step {start}")
        state = loop.run(state, args.steps, start_step=start)
    print("final loss:", float(loop.history[-1][1]["loss"]))


if __name__ == "__main__":
    main()
