import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. jit-lowers the real step function (train_step / prefill_step /
     decode_step) with production shardings and ShapeDtypeStruct inputs
     (no parameter allocation — jax.eval_shape),
  3. compiles it (proves the distribution config is coherent: shardings
     consistent, collectives lowerable, memory analyzable),
  4. prints memory_analysis() and cost_analysis(),
  5. lowers the cost *pieces* (launch/costing.py) and composes the roofline
     terms (compute / memory / collective), written to results/dryrun/*.json.

Usage:
    python -m repro.launch.dryrun --arch granite-34b --shape train_4k --mesh pod
    python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax


def run_cell(arch_name: str, shape_name: str, multi_pod: bool,
             out_dir: str = "results/dryrun", skip_pieces: bool = False,
             variant: str = "") -> dict:
    from ..configs import SHAPES, get_arch, shape_applicable
    from ..core.energy import roofline
    from . import costing, specs
    from .mesh import make_production_mesh

    cfg = get_arch(arch_name)
    if variant:
        cfg = apply_variant(cfg, variant)
    shape = SHAPES[shape_name]
    mesh_name = "multipod" if multi_pod else "pod"
    tag = f"{arch_name}_{shape_name}_{mesh_name}" + (f"_{variant}" if variant else "")

    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        rec = {"arch": arch_name, "shape": shape_name, "mesh": mesh_name,
               "status": "skipped", "reason": reason}
        _write(out_dir, tag, rec)
        print(f"[{tag}] SKIPPED: {reason}")
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    t0 = time.time()

    if shape.kind == "train":
        step_fn, state_specs, b_specs, state_sh, b_sh = specs.make_train_objects(cfg, shape, mesh)
        jitted = jax.jit(step_fn, in_shardings=(state_sh, b_sh), donate_argnums=(0,))
        args = (state_specs, b_specs)
        pieces = None if skip_pieces else costing.train_pieces(cfg, shape, mesh)
    elif shape.kind == "prefill":
        step_fn, args, shs = specs.make_prefill_objects(cfg, shape, mesh)
        jitted = jax.jit(step_fn, in_shardings=shs)
        pieces = None if skip_pieces else costing.serve_pieces(cfg, shape, mesh, decode=False)
    else:  # decode
        step_fn, args, shs = specs.make_decode_objects(cfg, shape, mesh)
        jitted = jax.jit(step_fn, in_shardings=shs, donate_argnums=(1,))
        pieces = None if skip_pieces else costing.serve_pieces(cfg, shape, mesh, decode=True)

    from ..dist.context import compute_mesh
    with mesh, compute_mesh(mesh):
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
    mem = compiled.memory_analysis()
    raw = costing.compiled_costs(lowered, compiled, chips)
    compile_s = time.time() - t0

    rec = {
        "arch": arch_name, "shape": shape_name, "mesh": mesh_name,
        "variant": variant or "baseline",
        "status": "ok", "kind": shape.kind, "chips": chips,
        "compile_s": round(compile_s, 1),
        "memory": {
            "argument_bytes_per_chip": mem.argument_size_in_bytes,
            "output_bytes_per_chip": mem.output_size_in_bytes,
            "temp_bytes_per_chip": mem.temp_size_in_bytes,
            "alias_bytes_per_chip": mem.alias_size_in_bytes,
            "peak_estimate_gib": round(
                (mem.argument_size_in_bytes + mem.output_size_in_bytes
                 + mem.temp_size_in_bytes - mem.alias_size_in_bytes) / 2**30, 3),
        },
        "hlo_raw": raw,  # scan bodies counted once — see §Methodology
    }

    if pieces is not None:
        t1 = time.time()
        cost = costing.measure_pieces(pieces, mesh)
        rec["pieces"] = cost["pieces"]
        rec["totals"] = cost["totals"]
        rec["pieces_s"] = round(time.time() - t1, 1)
        terms = roofline(cost["totals"]["flops"], cost["totals"]["bytes"],
                         cost["totals"]["coll_bytes"], 1)  # piece costs are per-chip
        rec["roofline"] = terms.as_dict()

        total, active = specs.count_params(cfg)
        tokens = shape.global_batch * (1 if shape.kind == "decode" else shape.seq_len)
        factor = 6 if shape.kind == "train" else 2
        model_flops = factor * active * tokens
        rec["model_flops"] = model_flops
        rec["params_total"] = total
        rec["params_active"] = active
        # per-chip HLO flops * chips vs global model flops
        hlo_global = cost["totals"]["flops"] * chips
        rec["useful_flops_ratio"] = round(model_flops / hlo_global, 4) if hlo_global else None

    _write(out_dir, tag, rec)
    print(f"[{tag}] OK compile={compile_s:.0f}s "
          f"mem/chip={rec['memory']['peak_estimate_gib']}GiB "
          + (f"dominant={rec['roofline']['dominant']}" if "roofline" in rec else ""))
    print(f"  memory_analysis: {mem}")
    ca = compiled.cost_analysis()
    ca = ca[0] if isinstance(ca, list) else ca
    print(f"  cost_analysis: flops={ca.get('flops', 0):.3e} "
          f"bytes={ca.get('bytes accessed', 0):.3e}")
    return rec


def apply_variant(cfg, variant: str):
    """Named optimization variants for the §Perf hillclimb (EXPERIMENTS.md)."""
    if variant == "baseline":
        return cfg
    mods = {}
    for kv in variant.split(","):
        k, _, v = kv.partition("=")
        mods[k] = v
    out = cfg
    if "remat" in mods:
        out = out.with_(remat=mods["remat"])
    if "qc" in mods:
        out = out.with_(q_chunk=int(mods["qc"]))
    if "kc" in mods:
        out = out.with_(kv_chunk=int(mods["kc"]))
    if "dtype" in mods:
        out = out.with_(dtype=mods["dtype"])
    if "attnf32" in mods:
        out = out.with_(attn_f32_streams=mods["attnf32"] == "1")
    if "cf" in mods:
        out = out.with_(capacity_factor=float(mods["cf"]))
    if "graddt" in mods:
        out = out.with_(grad_dtype=mods["graddt"])
    if "spblocks" in mods:
        out = out.with_(sp_blocks=mods["spblocks"] == "1")
    return out


def _write(out_dir: str, tag: str, rec: dict):
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, f"{tag}.json"), "w") as f:
        json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-pieces", action="store_true",
                    help="compile-only (no roofline pieces)")
    ap.add_argument("--variant", default="", help="perf variant, e.g. remat=none,qc=1024")
    ap.add_argument("--skip-existing", action="store_true",
                    help="skip cells whose JSON already reports status=ok")
    args = ap.parse_args()

    from ..configs import SHAPES, all_archs

    archs = list(all_archs()) if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = [False, True] if args.mesh == "both" else [args.mesh == "multipod"]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                if args.skip_existing:
                    tag = f"{arch}_{shape}_{'multipod' if mp else 'pod'}"
                    pth = os.path.join(args.out, f"{tag}.json")
                    if os.path.exists(pth):
                        try:
                            with open(pth) as f:
                                if json.load(f).get("status") in ("ok", "skipped"):
                                    continue
                        except Exception:
                            pass
                try:
                    run_cell(arch, shape, mp, args.out, args.skip_pieces, args.variant)
                except Exception as e:
                    failures.append((arch, shape, mp, repr(e)))
                    traceback.print_exc()
                    _write(args.out,
                           f"{arch}_{shape}_{'multipod' if mp else 'pod'}",
                           {"arch": arch, "shape": shape,
                            "mesh": "multipod" if mp else "pod",
                            "status": "failed", "error": repr(e)})
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print("\nAll dry-run cells passed.")


if __name__ == "__main__":
    main()
