"""Input/state ShapeDtypeStruct builders for the dry-run (no allocation).

Everything is built with jax.eval_shape so 34B-400B parameter trees never
materialize; shardings come from dist.sharding rules.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig, ShapeConfig
from ..dist import sharding as shd
from ..models import transformer as tf
from ..train.optim import make_optimizer
from ..train.schedule import warmup_cosine
from ..train.train_step import make_train_step


def batch_shapes(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    b = shape.global_batch
    if shape.kind == "decode":
        specs = {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
    else:
        s_tok = shape.seq_len - (cfg.n_frontend_tokens if cfg.frontend else 0)
        specs = {"tokens": jax.ShapeDtypeStruct((b, s_tok), jnp.int32)}
        if shape.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((b, s_tok), jnp.int32)
        if cfg.frontend:
            specs["frontend_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.n_frontend_tokens, cfg.d_frontend), jnp.dtype(cfg.dtype))
    return specs


def param_shapes(cfg: ArchConfig):
    return jax.eval_shape(lambda: tf.init_params(jax.random.PRNGKey(0), cfg))


def count_params(cfg: ArchConfig) -> Tuple[int, int]:
    """(total, active) parameter counts; active discounts unrouted experts."""
    shapes = param_shapes(cfg)
    leaves = jax.tree_util.tree_flatten_with_path(shapes)[0]
    total = active = 0
    for path, leaf in leaves:
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
        key = jax.tree_util.keystr(path)
        if "experts" in key and cfg.n_experts:
            active += n * cfg.top_k / cfg.n_experts
        else:
            active += n
    return int(total), int(active)


def make_train_objects(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh):
    """(step_fn, state_specs, batch_specs, state_shardings, batch_shardings)."""
    opt = make_optimizer(cfg.optimizer)
    lr_fn = warmup_cosine(3e-4, 200, 10_000)
    loss_fn = functools.partial(tf.train_loss, cfg=cfg)

    p_shapes = param_shapes(cfg)
    opt_shapes = jax.eval_shape(opt.init, p_shapes)
    state_specs = {"params": p_shapes, "opt": opt_shapes,
                   "step": jax.ShapeDtypeStruct((), jnp.int32)}
    b_specs = batch_shapes(cfg, shape)

    p_part = shd.param_specs(p_shapes, mesh, cfg.fsdp_experts)
    grad_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), p_part,
                           is_leaf=lambda x: isinstance(x, P))
    step_fn = make_train_step(lambda p, b: loss_fn(p, b), opt, lr_fn,
                              grad_shardings=grad_sh, grad_dtype=cfg.grad_dtype)
    opt_part = shd.zero1_opt_specs(opt_shapes, p_part, mesh)
    state_part = {"params": p_part, "opt": opt_part, "step": P()}
    state_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), state_part,
                            is_leaf=lambda x: isinstance(x, P))
    b_part = shd.batch_spec(b_specs, mesh)
    b_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), b_part,
                        is_leaf=lambda x: isinstance(x, P))
    return step_fn, state_specs, b_specs, state_sh, b_sh


def make_decode_objects(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh):
    p_shapes = param_shapes(cfg)
    cache_shapes = jax.eval_shape(
        lambda: tf.init_cache(cfg, shape.global_batch, shape.seq_len))
    b_specs = batch_shapes(cfg, shape)
    pos_spec = jax.ShapeDtypeStruct((), jnp.int32)

    p_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), shd.param_specs(p_shapes, mesh, cfg.fsdp_experts),
                        is_leaf=lambda x: isinstance(x, P))
    cache_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                            shd.cache_specs(cache_shapes, mesh),
                            is_leaf=lambda x: isinstance(x, P))
    b_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), shd.batch_spec(b_specs, mesh),
                        is_leaf=lambda x: isinstance(x, P))

    def step_fn(params, cache, batch, pos):
        return tf.decode_step(params, cache, batch, pos, cfg)

    return (step_fn, (p_shapes, cache_shapes, b_specs, pos_spec),
            (p_sh, cache_sh, b_sh, NamedSharding(mesh, P())))


def make_prefill_objects(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh):
    p_shapes = param_shapes(cfg)
    b_specs = batch_shapes(cfg, shape)
    p_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), shd.param_specs(p_shapes, mesh, cfg.fsdp_experts),
                        is_leaf=lambda x: isinstance(x, P))
    b_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), shd.batch_spec(b_specs, mesh),
                        is_leaf=lambda x: isinstance(x, P))

    def step_fn(params, batch):
        return tf.prefill_step(params, batch, cfg)

    return step_fn, (p_shapes, b_specs), (p_sh, b_sh)
