"""Serving driver: batched greedy generation with the ServeEngine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-4b --tokens 16
"""
from __future__ import annotations

import argparse

import jax

from ..configs import get_arch
from ..models import transformer as tf
from ..serve.engine import ServeEngine
from .train import reduce_cfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-4b")
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--d-model", type=int, default=64)
    ap.add_argument("--n-layers", type=int, default=4)
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--int4", action="store_true", help="int4-weight numerics")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    cfg = reduce_cfg(cfg, args).with_(frontend="", n_frontend_tokens=0)
    params = tf.init_params(jax.random.PRNGKey(args.seed), cfg)
    engine = ServeEngine(cfg, params, batch_slots=4, max_seq=args.seq,
                         quant_bits=4 if args.int4 else 0)
    prompts = [[1, 2, 3], [7, 8], [11], [4, 4, 4]]
    out = engine.generate(prompts, args.tokens)
    for i, o in enumerate(out):
        print(f"req{i}: prompt={prompts[i]} -> {o[len(prompts[i]):]}")


if __name__ == "__main__":
    main()
