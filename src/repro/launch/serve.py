"""Serving driver: the unified engine over either workload.

    PYTHONPATH=src python -m repro.launch.serve --workload lm --arch qwen1.5-4b --tokens 16
    PYTHONPATH=src python -m repro.launch.serve --workload snn --requests 6 --int4
    PYTHONPATH=src python -m repro.launch.serve --workload snn --scheduler sparsity --mixed-trace

    # chunked prefill + latency SLOs (budgeted-session serving):
    PYTHONPATH=src python -m repro.launch.serve --workload lm \\
        --prefill-chunk 16 --scheduler slo --slo-ms 3000

    # data-mesh sharded SNN serving (slot batch split over 2 devices):
    XLA_FLAGS=--xla_force_host_platform_device_count=2 \\
        PYTHONPATH=src python -m repro.launch.serve --workload snn --data-shard 2

    # fault-tolerant fleet: 3 replicas behind the supervised router, with
    # an injected wedge on replica 0 and a NaN-poison on replica 1:
    PYTHONPATH=src python -m repro.launch.serve --workload lm --replicas 3 \\
        --fault-plan '0=wedge@4,1=nan@6:slot=0'

    # adaptive-precision serving: fp32+int4 variants behind one engine, the
    # controller picking per request from the sparsity scheduler's EWMAs:
    PYTHONPATH=src python -m repro.launch.serve --workload snn \\
        --scheduler sparsity --mixed-trace --precision adaptive

    # speculative decode (n-gram self-drafting, verify K=4 tokens per
    # launch) with seed-deterministic nucleus sampling:
    PYTHONPATH=src python -m repro.launch.serve --workload lm \\
        --speculate 4 --temperature 0.8 --top-p 0.95 --seed 7

    # multi-process fleet: 2 worker subprocesses (one EngineCore + runner
    # each) supervised over the versioned wire protocol:
    PYTHONPATH=src python -m repro.launch.serve --workload lm --workers 2

    # observability plane: request traces, typed metrics and a flight
    # recorder on every replica, exported at exit (json|prom):
    PYTHONPATH=src python -m repro.launch.serve --workload lm --metrics prom
"""
from __future__ import annotations

import argparse
import contextlib
import dataclasses
import json
from typing import Callable, List

import jax

from ..configs import get_arch
from ..dist.context import compute_mesh
from ..models import transformer as tf
from ..serve.api import EngineConfig
from ..serve.core import EngineCore
from .mesh import make_data_mesh
from .train import reduce_cfg


def engine_config(args) -> EngineConfig:
    return EngineConfig(slots=args.slots, admission=args.admission,
                        scheduler=args.scheduler,
                        prefill_chunk=args.prefill_chunk,
                        precision=args.precision)


def make_obs(args):
    """One `Observability` bundle when --metrics asked for one, else None
    (detached serving is the default and is bit-identical by contract)."""
    if not args.metrics:
        return None
    from ..obs import Observability
    return Observability()


def precision_engine(runner_factory, pricer, args):
    """Precision-capable single engine: fp32+int4 variant registry behind a
    `PrecisionRunner`, pre-warmed, with the controller bound to the sparsity
    scheduler's prediction/observation stream when one is in play."""
    from ..serve.precision import (PrecisionController, PrecisionRunner,
                                   bind_controller)
    from ..serve.scheduler import SparsityAwareScheduler, make_scheduler

    registry = runner_factory()
    controller = PrecisionController(
        pricer=pricer,
        slo_tight_s=args.slo_ms / 1000.0 if args.slo_ms > 0 else None)
    runner = PrecisionRunner(registry, controller, mode=args.precision)
    registry.prewarm(args.slots)
    scheduler = make_scheduler(args.scheduler)
    inner = getattr(scheduler, "inner", scheduler)
    if isinstance(inner, SparsityAwareScheduler):
        bind_controller(inner, controller)
    core = EngineCore(runner, engine_config(args), scheduler=scheduler,
                      obs=make_obs(args))
    return core, controller


def build_engine(runner, args):
    """One `EngineCore`, or a supervised `Router` fleet when --replicas > 1.

    Any --fault-plan also routes through the fleet path so a single replica
    can be chaos-tested; the router runs on a shared deterministic tick
    clock, which is why --slo-ms (wall clock) is rejected alongside it.
    """
    if args.replicas > 1 or args.fault_plan:
        from ..serve.faults import parse_fleet_plan
        from ..serve.router import make_router
        plans = parse_fleet_plan(args.fault_plan) if args.fault_plan else None
        return make_router(runner, max(1, args.replicas),
                           engine_config(args), plans=plans,
                           obs=bool(args.metrics))
    return EngineCore(runner, engine_config(args), obs=make_obs(args))


def print_fleet_report(core) -> None:
    print(f"engine: {core.stats()}")
    for entry in getattr(core, "drain_log", []):
        step, idx, condition, rerouted = entry[:4]
        detail = entry[4] if len(entry) > 4 else {}
        extra = (f"; marker={detail.get('marker')} "
                 f"cost_finite={detail.get('cost_finite')}")
        dump = detail.get("dump")
        if dump:
            extra += f" recorder_frames={len(dump.get('frames', []))}"
        print(f"drain @step {step}: replica {idx} condemned ({condition}), "
              f"re-routed requests {rerouted}{extra}")


def print_observability(core, fmt: str) -> None:
    """--metrics export: the run's metrics snapshot (JSON or Prometheus
    text) plus a one-line trace / flight-recorder summary. Routers merge
    replica telemetry; a lone engine exports its own bundle."""
    from ..obs import to_prometheus
    if hasattr(core, "telemetry"):              # router fleet: merged view
        tel = core.telemetry()
    elif getattr(core, "obs", None) is not None:
        tel = core.obs.snapshot()
    else:
        return
    snap = tel.get("metrics", {})
    if fmt == "prom":
        print(to_prometheus(snap), end="")
    else:
        print("METRICS_JSON " + json.dumps(snap, sort_keys=True))
    print(f"trace: {len(tel.get('trace', []))} spans; "
          f"recorder dumps: {len(tel.get('dumps', []))}")


def serve_lm(args) -> None:
    cfg = get_arch(args.arch)
    cfg = reduce_cfg(cfg, args).with_(frontend="", n_frontend_tokens=0)
    controller, runner = None, None
    if args.workers > 0:
        from ..serve.router import make_worker_fleet
        from ..serve.worker import lm_spec
        # every worker rebuilds params from the same wire-encodable spec
        # (seed included), so re-routes after a worker death replay
        # bit-identically and the parent never materialises the model
        spec = lm_spec(cfg, seed=args.seed, max_seq=args.seq,
                       quant_bits=4 if args.int4 else 0,
                       speculate_k=args.speculate)
        core = make_worker_fleet(spec, args.workers, engine_config(args),
                                 obs=bool(args.metrics))
    elif args.precision:
        from ..serve.precision import make_lm_variants
        params = tf.init_params(jax.random.PRNGKey(args.seed), cfg)
        core, controller = precision_engine(
            lambda: make_lm_variants(cfg, params, max_seq=args.seq),
            None, args)
    else:
        from ..serve.runners.lm import LMRunner
        params = tf.init_params(jax.random.PRNGKey(args.seed), cfg)
        runner = LMRunner(cfg, params, max_seq=args.seq,
                          quant_bits=4 if args.int4 else 0,
                          speculate_k=args.speculate)
        core = build_engine(runner, args)

    sampling_opts = {}
    if args.temperature > 0 or args.top_k > 0 or args.top_p < 1.0:
        sampling_opts = {"temperature": args.temperature,
                         "top_k": args.top_k, "top_p": args.top_p}

    rng = jax.random.PRNGKey(args.seed + 1)
    prompts = []
    for i in range(args.requests):
        rng, k1, k2 = jax.random.split(rng, 3)
        length = int(jax.random.randint(k1, (), 1, 6))
        prompts.append([int(t) for t in
                        jax.random.randint(k2, (length,), 1, cfg.vocab)])
    deadline = args.slo_ms / 1000.0 if args.slo_ms > 0 else None
    if deadline is not None and runner is not None:
        # (the --precision path pre-warms both variants' bucketed widths via
        # VariantRegistry.prewarm instead)
        # wall-clock SLOs start at submit(): warm the jit caches first so
        # no XLA compile lands inside a sub-second deadline. Two layers:
        # the same trace (the launch widths this run's prompts produce),
        # plus every pow2-bucketed width up to the SLO scheduler's boost
        # cap, since its budget split can boost a prefill chunk past
        # --prefill-chunk mid-deadline.
        from ..serve.api import Request, StepBudget
        from ..serve.scheduler import SLOScheduler
        warm = EngineCore(runner, engine_config(args))
        for p in prompts:
            warm.submit(p, max_new_tokens=args.tokens)
        warm.run_until_complete()
        # runtime launch widths are pow2-bucketed by the session, so this
        # loop covers every width the boost can reach: chunk w produces a
        # take of min(w, prompt) whose bucket is w (the last iteration's
        # shorter max_seq-bounded prompt still buckets up to w)
        w, cap = 2, SLOScheduler.DEFAULT_BOOST_CAP
        while w <= cap and w // 2 < args.seq - 2:
            plen = min(w + 1, args.seq - 2)
            sess = runner.open_session(args.slots)
            sess.admit(0, Request(-1, [1] * plen, {"max_new_tokens": 1}))
            sess.step(StepBudget(chunk=w))
            w *= 2
    ids = [core.submit(p, max_new_tokens=args.tokens, deadline_s=deadline,
                       # per-request seed: each request gets its own stream,
                       # deterministic across runs/replays for a fixed --seed
                       **(dict(sampling_opts, seed=args.seed + i)
                          if sampling_opts else {}))
           for i, p in enumerate(prompts)]
    results = core.run_until_complete()
    for i, rid in enumerate(ids):
        res = results[rid]
        # expired-in-queue requests never produced outputs
        new = res.outputs[len(prompts[i]):] if res.outputs is not None else None
        print(f"req{rid}: prompt={prompts[i]} -> {new} "
              f"status={res.status} stats={dict(res.stats)}")
    if args.speculate > 0:
        s = core.stats() if hasattr(core, "stats") else {}
        if s.get("drafted_tokens"):
            print(f"speculative: drafted={s['drafted_tokens']} "
                  f"accepted={s['accepted_tokens']} "
                  f"accept_rate={s['accept_rate']:.3f} "
                  f"goodput={s['goodput_decode_tok_per_step']:.2f} tok/step")
    print_fleet_report(core)
    if controller is not None:
        print(f"precision controller: {controller.summary()}")
    if args.metrics:
        print_observability(core, args.metrics)
    if hasattr(core, "close"):                  # worker fleets need a reap
        core.close()


def serve_snn(args) -> None:
    from ..configs import vgg9_snn

    cfg = vgg9_snn.TINY_INT4 if args.int4 else vgg9_snn.TINY
    if args.img_hw:
        cfg = dataclasses.replace(cfg, img_hw=args.img_hw)
    controller = None
    if args.workers > 0:
        from ..serve.router import make_worker_fleet
        from ..serve.worker import snn_spec
        core = make_worker_fleet(snn_spec(cfg, seed=args.seed),
                                 args.workers, engine_config(args),
                                 obs=bool(args.metrics))
    elif args.precision:
        from ..models.vgg9 import init_vgg9
        from ..serve.precision import make_snn_pricer, make_snn_variants
        params = init_vgg9(jax.random.PRNGKey(args.seed), cfg)
        core, controller = precision_engine(
            lambda: make_snn_variants(cfg, params, interpret=True),
            make_snn_pricer(cfg), args)
    else:
        from ..models.vgg9 import init_vgg9
        from ..serve.runners.snn import SNNRunner
        params = init_vgg9(jax.random.PRNGKey(args.seed), cfg)
        runner = SNNRunner(cfg, params, interpret=True)
        core = build_engine(runner, args)

    if args.data_shard > 1:
        n_dev = len(jax.devices())
        assert args.data_shard <= n_dev, (
            f"--data-shard {args.data_shard} needs that many devices "
            f"(have {n_dev}; on CPU set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={args.data_shard})")
        mesh_ctx = compute_mesh(make_data_mesh(args.data_shard))
        print(f"data-mesh serving: slot batches split over {args.data_shard} devices")
    else:
        mesh_ctx = contextlib.nullcontext()

    keys = jax.random.split(jax.random.PRNGKey(args.seed + 1), args.requests)
    shape = (cfg.img_hw, cfg.img_hw, cfg.in_ch)
    ids = []
    for i, k in enumerate(keys):
        img = jax.random.uniform(k, shape)
        opts = {}
        if args.precision and i % 3 == 0:
            # exercise the never-switch invariant from the CLI: every third
            # request is accuracy-pinned to fp32 regardless of controller
            opts["pin_precision"] = "fp32"
        if args.mixed_trace and i % 2 == 0:
            # alternate near-silent requests: the mixed-sparsity trace the
            # sparsity-aware scheduler separates from the dense stream
            img = img * 0.02
            ids.append(core.submit(img, source="sparse", **opts))
        else:
            ids.append(core.submit(img, source="dense", **opts))
    with mesh_ctx:
        results = core.run_until_complete()
    for rid in ids:
        res = results[rid]
        pred = int(res.outputs.argmax())
        skip = {k: round(v, 3) for k, v in res.stats["skip_rate"].items()}
        print(f"req{rid}: class={pred} spikes={res.stats['spike_total']:.0f} "
              f"skip={skip} precision={res.stats['precision']} "
              f"energy={res.stats['energy_j']:.3e} J "
              f"served={res.stats['served_energy_j']:.3e} J "
              f"(analytical {res.stats['served_energy_analytical_j']:.3e} J)")
    print_fleet_report(core)
    if controller is not None:
        print(f"precision controller: {controller.summary()}")
    if args.metrics:
        print_observability(core, args.metrics)
    if hasattr(core, "admission_log"):          # single engine, not a fleet
        print(f"admissions: {core.admission_log}")
    if hasattr(core, "close"):                  # worker fleets need a reap
        core.close()


@dataclasses.dataclass(frozen=True)
class FlagRule:
    """One CLI compatibility constraint: ``when(args)`` true => reject the
    invocation with ``error``. `FLAG_RULES` below *is* the compatibility
    policy — a data table unit tests iterate directly
    (tests/test_launch_flags.py) instead of an opaque if/ap.error chain."""

    name: str
    when: Callable
    error: str


def _sampling(a) -> bool:
    return a.temperature > 0 or a.top_k > 0 or a.top_p < 1.0


FLAG_RULES = (
    FlagRule("replicas-range", lambda a: a.replicas < 1,
             "--replicas must be >= 1"),
    FlagRule("workers-range", lambda a: a.workers < 0,
             "--workers must be >= 0 (0 = in-process serving)"),
    FlagRule("slo-needs-continuous",
             lambda a: a.slo_ms > 0 and a.admission == "batch",
             "--slo-ms requires --admission continuous "
             "(deadlines are step-level; the batch path ignores them)"),
    FlagRule("slo-vs-fleet",
             lambda a: a.slo_ms > 0 and (a.replicas > 1 or a.fault_plan),
             "--slo-ms is a wall-clock SLO; the replica router runs on "
             "a deterministic tick clock (drop --replicas/--fault-plan, "
             "or use deadline-free requests with the fleet)"),
    FlagRule("precision-vs-int4", lambda a: a.precision and a.int4,
             "--int4 pins numerics at runner construction; with "
             "--precision the engine holds both variants (use "
             "--precision int4 for a pinned int4 fleet)"),
    FlagRule("precision-vs-fleet",
             lambda a: a.precision and (a.replicas > 1 or a.fault_plan),
             "--precision builds a single controller-bound engine; "
             "drop --replicas/--fault-plan"),
    FlagRule("lm-only-knobs",
             lambda a: (a.speculate or _sampling(a)) and a.workload != "lm",
             "--speculate/--temperature/--top-k/--top-p are LM-only"),
    FlagRule("sampling-needs-continuous",
             lambda a: (a.speculate or _sampling(a))
             and a.admission == "batch",
             "--speculate and sampling need --admission continuous "
             "(the run-to-completion batch path is greedy-only)"),
    FlagRule("speculate-vs-precision",
             lambda a: a.speculate and a.precision,
             "--speculate drafts against one resident KV cache; the "
             "--precision variant registry swaps runners per request "
             "(drop one of the two)"),
    FlagRule("workers-vs-replicas",
             lambda a: a.workers > 0 and a.replicas > 1,
             "--workers and --replicas are both fleet sizes (subprocess "
             "vs in-process replicas); pick one"),
    FlagRule("workers-vs-fault-plan",
             lambda a: a.workers > 0 and bool(a.fault_plan),
             "--fault-plan injects faults into in-process replicas; "
             "subprocess workers are chaos-tested by killing the process, "
             "not by injection"),
    FlagRule("workers-vs-precision",
             lambda a: a.workers > 0 and bool(a.precision),
             "--precision builds a single controller-bound engine; it "
             "does not serve through subprocess workers"),
    FlagRule("workers-vs-slo",
             lambda a: a.workers > 0 and a.slo_ms > 0,
             "--slo-ms deadlines are stamped on each worker's own wall "
             "clock at submit; cross-process SLO accounting is not "
             "supported (drop one of the two)"),
    FlagRule("workers-vs-data-shard",
             lambda a: a.workers > 0 and a.data_shard > 1,
             "--data-shard builds a device mesh in this process; workers "
             "serve from their own processes (shard inside a worker is "
             "not wired up)"),
)


def check_flags(args) -> List[FlagRule]:
    """Every violated `FlagRule` for this namespace (empty = accepted)."""
    return [rule for rule in FLAG_RULES if rule.when(args)]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", choices=("lm", "snn"), default="lm")
    ap.add_argument("--arch", default="qwen1.5-4b")
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=64)
    ap.add_argument("--n-layers", type=int, default=4)
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--img-hw", type=int, default=0, help="SNN image size override")
    ap.add_argument("--int4", action="store_true", help="int4-weight numerics")
    ap.add_argument("--precision", choices=("fp32", "int4", "adaptive"),
                    default="",
                    help="precision-controlled serving (serve.precision): "
                         "both fp32 and int4 variants behind one engine. "
                         "'fp32'/'int4' pin every unpinned request; "
                         "'adaptive' picks per request from EWMA sparsity "
                         "estimates, SLO slack and the accuracy budget. "
                         "Pair with --scheduler sparsity to close the "
                         "quantization->sparsity feedback loop online")
    ap.add_argument("--scheduler",
                    choices=("fifo", "sparsity", "slo", "slo:fifo",
                             "slo:sparsity"),
                    default="fifo",
                    help="batch-composition policy (serve.scheduler); the "
                         "slo* forms add deadline/priority admission and "
                         "per-step budget splitting")
    ap.add_argument("--admission", choices=("continuous", "batch"),
                    default="continuous",
                    help="step-level admission vs run-to-completion batching")
    ap.add_argument("--prefill-chunk", type=int, default=1,
                    help="LM continuous admission: prompt tokens a joining "
                         "request prefills per engine step (1 = token-by-"
                         "token; larger chunks keep decode goodput up while "
                         "long prompts join; outputs are bit-identical)")
    ap.add_argument("--slo-ms", type=float, default=0.0,
                    help="LM: per-request latency SLO in milliseconds "
                         "(wall clock); expired requests surface "
                         "status='expired'. Pair with --scheduler slo")
    ap.add_argument("--replicas", type=int, default=1,
                    help="serve through a supervised router over N engine "
                         "replicas (heartbeat + numerics probe; wedged or "
                         "poisoned replicas drain, in-flight requests "
                         "re-route by deterministic replay)")
    ap.add_argument("--fault-plan", default="",
                    help="fault-injection schedule per replica, e.g. "
                         "'0=wedge@4,1=nan@6:slot=0' (kinds: wedge, slow, "
                         "raise, nan, flood). Implies the router path even "
                         "with --replicas 1")
    ap.add_argument("--workers", type=int, default=0, metavar="N",
                    help="serve through N worker *subprocesses* (one "
                         "EngineCore + runner each, supervised over the "
                         "versioned wire protocol; a killed worker's "
                         "in-flight requests replay elsewhere "
                         "bit-identically). 0 serves in-process")
    ap.add_argument("--speculate", type=int, default=0, metavar="K",
                    help="LM: speculative decode — draft up to K tokens per "
                         "pure-decode row via n-gram prompt lookup and "
                         "verify them in one launch (outputs bit-identical "
                         "to plain decode; needs --admission continuous)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="LM sampling temperature (0 = greedy argmax)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="LM: sample from the k highest logits (0 = all)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="LM: nucleus sampling mass (1.0 = all)")
    ap.add_argument("--mixed-trace", action="store_true",
                    help="SNN: alternate near-silent and dense requests")
    ap.add_argument("--data-shard", type=int, default=0,
                    help="SNN: split slot batches over this many devices "
                         "(a ('data',) mesh; needs the devices to exist)")
    ap.add_argument("--metrics", choices=("json", "prom"), default="",
                    help="attach the observability plane (repro.obs): "
                         "per-request trace spans, typed metrics and a "
                         "flight recorder on every engine/replica, "
                         "exported at exit as JSON or Prometheus text. "
                         "Outputs stay bit-identical with it on or off")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    for rule in check_flags(args):
        ap.error(rule.error)

    if args.workload == "snn":
        serve_snn(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()
