"""Production mesh construction (multi-pod dry-run spec).

A function, not a module-level constant — importing this module never
touches jax device state. Single pod: 16x16 = 256 chips ('data' x 'model');
multi-pod: 2x16x16 = 512 chips ('pod' x 'data' x 'model'), the 'pod' axis
carrying only data parallelism + gradient reduction (DCN-friendly).
"""
from __future__ import annotations

import jax

from ..dist import compat as _compat  # noqa: F401  (jax API shims)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh():
    """Degenerate 1x1 mesh on the real local device (tests/examples)."""
    n = len(jax.devices())
    return jax.make_mesh(
        (n, 1), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)


def make_data_mesh(n: int = 0):
    """1-D ``('data',)`` mesh over ``n`` local devices (0 = all).

    The serving-side mesh: `serve.runners.snn.SNNRunner` splits its slot
    batch over this axis when it is installed as the ambient compute mesh
    (``dist.context.compute_mesh``). On CPU, force the device count with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
    """
    n = n or len(jax.devices())
    return jax.make_mesh(
        (n,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
