"""Roofline cost accounting from compiled dry-run artifacts.

XLA's HLO cost analysis counts while-loop (lax.scan) bodies ONCE, so the cost
of a depth-P scanned model is undercounted by ~P. Methodology
(EXPERIMENTS.md §Methodology): lower *pieces* whose HLO contains no hidden
trip counts and compose

    total = stem + n_periods * period + sum(tail blocks) + slstm corrections

Each piece is jit-lowered with the production shardings (GSPMD partitions
it), so FLOPs / HBM bytes / collective bytes are per-chip quantities of the
real partitioned program. The chunked attention / mLSTM scans inside a piece
are unrolled (cfg.unroll_chunks) so every chunk is visible to cost analysis.

Collective bytes are parsed from the partitioned HLO text: per-op wire bytes
use ring-algorithm factors ((g-1)/g, 2x for all-reduce) with the group size
taken from the op's replica_groups.
"""
from __future__ import annotations

import dataclasses
import functools
import re
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig, ShapeConfig
from ..dist import sharding as shd
from ..models import transformer as tf
from ..models import xlstm as xl
from ..train.optim import apply_updates, make_optimizer


# ---------------------------------------------------------------------------
# HLO parsing
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16, "s4": 0.5, "u4": 0.5}

# collectives can return TUPLE shapes: `%x = (f32[a,b], f32[c,d]) all-reduce(...)`
_COLL_OP_RE = re.compile(
    r"=\s*(\(?[a-z0-9\[\],{}()\s/]*?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACES_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(dtype: str, dims: str) -> float:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collective_bytes(hlo: str, world: int) -> Dict[str, float]:
    """Per-chip wire bytes by collective kind (ring factors applied)."""
    out: Dict[str, float] = {}
    for line in hlo.splitlines():
        m = _COLL_OP_RE.search(line)
        if not m:
            continue
        shapes, kind, phase = m.group(1), m.group(2), m.group(3)
        if phase == "-done":
            continue                       # counted at the -start op
        size = sum(_shape_bytes(dt, dims) for dt, dims in _SHAPE_RE.findall(shapes))
        if size == 0:
            continue
        g = world
        gm = _GROUPS_RE.search(line)
        if gm:
            g = int(gm.group(2))           # [n_groups, group_size]
        else:
            gb = _GROUPS_BRACES_RE.search(line)
            if gb:
                g = len([t for t in gb.group(1).split(",") if t.strip()])
        g = max(g, 1)
        if kind == "all-reduce":
            wire = 2 * size * (g - 1) / g
        elif kind == "all-gather":
            wire = size * (g - 1) / g      # size = gathered output
        elif kind == "reduce-scatter":
            wire = size * (g - 1)          # size = scattered output
        elif kind == "all-to-all":
            wire = size * (g - 1) / g
        else:                               # collective-permute
            wire = size
        out[kind] = out.get(kind, 0.0) + wire
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


def compiled_costs(lowered, compiled, world: int) -> Dict[str, float]:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    coll = parse_collective_bytes(compiled.as_text(), world)
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "coll_bytes": coll["total"],
        "coll_detail": {k: v for k, v in coll.items() if k != "total"},
    }


# ---------------------------------------------------------------------------
# Cost pieces
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Piece:
    name: str
    fn: Callable
    arg_specs: Tuple
    in_shardings: Tuple
    mult: float


def _sh(mesh, tree_specs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))


def _cost_cfg(cfg: ArchConfig, shape: ShapeConfig) -> ArchConfig:
    s = shape.seq_len
    return cfg.with_(unroll_chunks=True,
                     q_chunk=min(4096, s), kv_chunk=min(4096, s))


def _single_period_shapes(cfg: ArchConfig):
    """Per-period (unstacked) block param shapes."""
    def build():
        key = jax.random.PRNGKey(0)
        return {f"slot{si}": tf.init_block(key, cfg, kind)
                for si, kind in enumerate(cfg.pattern)}
    return jax.eval_shape(build)


def _x_spec(cfg: ArchConfig, shape: ShapeConfig, decode: bool):
    b = shape.global_batch
    s = 1 if decode else shape.seq_len
    return jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.dtype(cfg.dtype))


def _x_part(mesh, batch: int = 0):
    dp = shd.dp_axes(mesh)
    ndp = 1
    for a in dp:
        ndp *= mesh.shape[a]
    if batch and batch % ndp != 0:
        return P()
    return P(dp, None, None)


def train_pieces(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh) -> List[Piece]:
    ccfg = _cost_cfg(cfg, shape)
    opt = make_optimizer(cfg.optimizer)
    pieces = []

    # --- stem: embed + final norm + unembed + CE + stem param update ---
    stem_shapes = jax.eval_shape(lambda: {
        k: v for k, v in tf.init_params(jax.random.PRNGKey(0),
                                        cfg.with_(n_layers=len(cfg.pattern), tail=())).items()
        if k in ("embed", "final_norm", "lm_head")})
    stem_opt_shapes = jax.eval_shape(opt.init, stem_shapes)
    from .specs import batch_shapes as _bs
    b_specs = _bs(cfg, dataclasses.replace(shape, kind="train"))
    if "labels" not in b_specs:
        s_tok = b_specs["tokens"].shape[1]
        b_specs = dict(b_specs)
        b_specs["labels"] = jax.ShapeDtypeStruct((shape.global_batch, s_tok), jnp.int32)

    def stem_fn(sp, so, batch):
        def loss(sp):
            from ..models.layers import rmsnorm
            x = tf._embed(sp, batch, cfg)
            x = rmsnorm(x, sp["final_norm"], cfg.norm_eps)
            logits = tf._unembed(sp, x, cfg).astype(jnp.float32)
            labels = batch["labels"]
            if cfg.frontend:
                logits = logits[:, cfg.n_frontend_tokens:]
            logz = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
            return jnp.mean(logz - gold)
        l, g = jax.value_and_grad(loss)(sp)
        if cfg.grad_dtype:
            g = jax.tree.map(lambda x_: x_.astype(cfg.grad_dtype), g)
        upd, so2 = opt.update(g, so, sp, 1e-3)
        return apply_updates(sp, upd), so2, l

    sp_part = shd.param_specs(stem_shapes, mesh)
    so_part = shd.zero1_opt_specs(stem_opt_shapes, sp_part, mesh)
    b_part = shd.batch_spec(b_specs, mesh)
    pieces.append(Piece("stem", stem_fn, (stem_shapes, stem_opt_shapes, b_specs),
                        (_sh(mesh, sp_part), _sh(mesh, so_part), _sh(mesh, b_part)), 1.0))

    # --- one period: fwd + vjp + param update ---
    pp_shapes = _single_period_shapes(cfg)
    pp_opt_shapes = jax.eval_shape(opt.init, pp_shapes)
    x_spec = _x_spec(cfg, shape, decode=False)

    def period_apply(pp, x):
        aux = jnp.zeros((), jnp.float32)
        for si, kind in enumerate(ccfg.pattern):
            x, a = tf._apply_block(kind, pp[f"slot{si}"], x, ccfg)
            aux = aux + a
        return x, aux

    if cfg.remat == "full":
        period_apply = jax.checkpoint(period_apply)

    def period_fn(pp, po, x):
        (y, aux), vjp = jax.vjp(period_apply, pp, x)
        dpp, dx = vjp((jnp.ones_like(y), jnp.ones_like(aux)))
        if cfg.grad_dtype:
            dpp = jax.tree.map(lambda g: g.astype(cfg.grad_dtype), dpp)
        upd, po2 = opt.update(dpp, po, pp, 1e-3)
        return apply_updates(pp, upd), po2, dx

    pp_part = shd.param_specs(pp_shapes, mesh, cfg.fsdp_experts)
    po_part = shd.zero1_opt_specs(pp_opt_shapes, pp_part, mesh)
    pieces.append(Piece("period", period_fn, (pp_shapes, pp_opt_shapes, x_spec),
                        (_sh(mesh, pp_part), _sh(mesh, po_part),
                         NamedSharding(mesh, _x_part(mesh, shape.global_batch))), float(cfg.n_periods)))

    # --- tail blocks ---
    for ti, kind in enumerate(cfg.tail):
        t_shapes = jax.eval_shape(
            lambda kd=kind: tf.init_block(jax.random.PRNGKey(0), cfg, kd))
        t_opt = jax.eval_shape(opt.init, t_shapes)

        def tail_fn(tp, to, x, kd=kind):
            def f(tp, x):
                return tf._apply_block(kd, tp, x, ccfg)
            (y, aux), vjp = jax.vjp(f, tp, x)
            dtp, dx = vjp((jnp.ones_like(y), jnp.ones_like(aux)))
            upd, to2 = opt.update(dtp, to, tp, 1e-3)
            return apply_updates(tp, upd), to2, dx

        t_part = shd.param_specs(t_shapes, mesh)
        to_part = shd.zero1_opt_specs(t_opt, t_part, mesh)
        pieces.append(Piece(f"tail{ti}_{kind}", tail_fn, (t_shapes, t_opt, x_spec),
                            (_sh(mesh, t_part), _sh(mesh, to_part),
                             NamedSharding(mesh, _x_part(mesh, shape.global_batch))), 1.0))

    pieces.extend(_slstm_correction(cfg, shape, mesh, train=True))
    return pieces


def serve_pieces(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh,
                 decode: bool) -> List[Piece]:
    ccfg = _cost_cfg(cfg, shape)
    pieces = []
    x_spec = _x_spec(cfg, shape, decode)
    from .specs import batch_shapes as _bs
    b_specs = _bs(cfg, shape)

    # stem: embed + final norm + unembed
    stem_shapes = jax.eval_shape(lambda: {
        k: v for k, v in tf.init_params(jax.random.PRNGKey(0),
                                        cfg.with_(n_layers=len(cfg.pattern), tail=())).items()
        if k in ("embed", "final_norm", "lm_head")})

    def stem_fn(sp, batch):
        x = tf._embed(sp, batch, cfg) if not decode else sp["embed"]["w_tok"][batch["tokens"]]
        from ..models.layers import rmsnorm
        x = rmsnorm(x, sp["final_norm"], cfg.norm_eps)
        return tf._unembed(sp, x, cfg)

    sp_part = shd.param_specs(stem_shapes, mesh)
    b_part = shd.batch_spec(b_specs, mesh)
    pieces.append(Piece("stem", stem_fn, (stem_shapes, b_specs),
                        (_sh(mesh, sp_part), _sh(mesh, b_part)), 1.0))

    pp_shapes = _single_period_shapes(cfg)
    pp_part = shd.param_specs(pp_shapes, mesh, cfg.fsdp_experts)

    if decode:
        cache_one = jax.eval_shape(lambda: {
            f"slot{si}": tf._init_block_cache(kind, cfg, shape.global_batch,
                                              shape.seq_len, jnp.dtype(cfg.dtype))
            for si, kind in enumerate(cfg.pattern)})
        cache_part = jax.tree_util.tree_map_with_path(
            lambda path, leaf: shd.cache_spec(path, leaf, mesh), cache_one)

        def period_fn(pp, cache, x):
            new_cache = {}
            for si, kind in enumerate(ccfg.pattern):
                x, c = tf._decode_block(kind, pp[f"slot{si}"], x,
                                        cache[f"slot{si}"], jnp.int32(shape.seq_len - 1), ccfg)
                new_cache[f"slot{si}"] = c
            return x, new_cache

        pieces.append(Piece("period", period_fn, (pp_shapes, cache_one, x_spec),
                            (_sh(mesh, pp_part), _sh(mesh, cache_part),
                             NamedSharding(mesh, _x_part(mesh, shape.global_batch))), float(cfg.n_periods)))
    else:
        def period_fn(pp, x):
            for si, kind in enumerate(ccfg.pattern):
                x, _ = tf._apply_block(kind, pp[f"slot{si}"], x, ccfg)
            return x

        pieces.append(Piece("period", period_fn, (pp_shapes, x_spec),
                            (_sh(mesh, pp_part), NamedSharding(mesh, _x_part(mesh, shape.global_batch))),
                            float(cfg.n_periods)))

    for ti, kind in enumerate(cfg.tail):
        t_shapes = jax.eval_shape(
            lambda kd=kind: tf.init_block(jax.random.PRNGKey(0), cfg, kd))
        t_part = shd.param_specs(t_shapes, mesh)
        if decode:
            tc = jax.eval_shape(lambda kd=kind: tf._init_block_cache(
                kd, cfg, shape.global_batch, shape.seq_len, jnp.dtype(cfg.dtype)))
            tc_part = jax.tree_util.tree_map_with_path(
                lambda path, leaf: shd.cache_spec(path, leaf, mesh), tc)

            def tail_fn(tp, cache, x, kd=kind):
                return tf._decode_block(kd, tp, x, cache, jnp.int32(shape.seq_len - 1), ccfg)

            pieces.append(Piece(f"tail{ti}_{kind}", tail_fn, (t_shapes, tc, x_spec),
                                (_sh(mesh, t_part), _sh(mesh, tc_part),
                                 NamedSharding(mesh, _x_part(mesh, shape.global_batch))), 1.0))
        else:
            def tail_fn(tp, x, kd=kind):
                y, _ = tf._apply_block(kd, tp, x, ccfg)
                return y

            pieces.append(Piece(f"tail{ti}_{kind}", tail_fn, (t_shapes, x_spec),
                                (_sh(mesh, t_part), NamedSharding(mesh, _x_part(mesh, shape.global_batch))), 1.0))

    if not decode:
        pieces.extend(_slstm_correction(cfg, shape, mesh, train=False))
    return pieces


def _slstm_correction(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh,
                      train: bool) -> List[Piece]:
    """(S-1) extra sLSTM steps per slstm layer (scan body counted once)."""
    n_slstm = sum(1 for k in cfg.pattern if k == "slstm") * cfg.n_periods \
        + sum(1 for k in cfg.tail if k == "slstm")
    if n_slstm == 0 or shape.kind == "decode":
        return []
    b, d = shape.global_batch, cfg.d_model
    p_shapes = jax.eval_shape(
        lambda: xl.slstm_init(jax.random.PRNGKey(0), d, cfg.n_heads, jnp.dtype(cfg.dtype)))
    carry = tuple(jax.ShapeDtypeStruct((b, d), jnp.float32) for _ in range(4))
    wx = jax.ShapeDtypeStruct((b, 4 * d), jnp.float32)

    def step_fn(p, carry, wx):
        if train:
            # differentiate carry/wx only: the real scan accumulates param
            # grads locally and all-reduces ONCE at the end, not per step
            def f(carry, wx):
                c, h = xl._slstm_step(p, cfg.n_heads, carry, wx)
                return h
            y, vjp = jax.vjp(f, carry, wx)
            return vjp(jnp.ones_like(y))
        return xl._slstm_step(p, cfg.n_heads, carry, wx)

    p_part = shd.param_specs(p_shapes, mesh, cfg.fsdp_experts)
    xp = _x_part(mesh, shape.global_batch)
    dp = xp[0] if len(xp) else None
    carry_part = tuple(P(dp, None) for _ in range(4))
    mult = float(n_slstm * (shape.seq_len - 1))
    return [Piece("slstm_step", step_fn, (p_shapes, carry, wx),
                  (_sh(mesh, p_part), _sh(mesh, carry_part),
                   NamedSharding(mesh, P(dp, None))), mult)]


# ---------------------------------------------------------------------------
# Aggregation
# ---------------------------------------------------------------------------

def measure_pieces(pieces: List[Piece], mesh: Mesh) -> Dict[str, Any]:
    from ..dist.context import compute_mesh
    world = mesh.size
    per_piece = {}
    totals = {"flops": 0.0, "bytes": 0.0, "coll_bytes": 0.0}
    with mesh, compute_mesh(mesh):
        for pc in pieces:
            lowered = jax.jit(pc.fn, in_shardings=pc.in_shardings).lower(*pc.arg_specs)
            compiled = lowered.compile()
            costs = compiled_costs(lowered, compiled, world)
            costs["mult"] = pc.mult
            per_piece[pc.name] = costs
            for k in totals:
                totals[k] += costs[k] * pc.mult
    return {"pieces": per_piece, "totals": totals}
