"""Train-step builder: loss -> grads -> clip -> schedule -> optimizer update.

Features: microbatch gradient accumulation (lax.scan over accumulation
steps — overlaps the per-microbatch gradient reduce with the next
microbatch's compute under the XLA latency-hiding scheduler), global-norm
clipping, pluggable optimizer/schedule, optional int8 gradient compression
state (error feedback) threaded through the train state.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from .optim import Optimizer, apply_updates, clip_by_global_norm


def init_train_state(params: Any, opt: Optimizer, *, compress: bool = False) -> Dict[str, Any]:
    """Train-state pytree. With ``compress=True`` the state additionally
    carries ``grad_err`` — the per-shard error-feedback residuals consumed by
    a step built with ``make_train_step(compress_axis=...)``. The residual is
    shard-local (each data-parallel rank keeps its own), so a compressed
    step must run inside ``shard_map`` with the residual's leading layout
    matching the data axis."""
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    if compress:
        from ..dist.compression import init_error_state
        state["grad_err"] = init_error_state(params)
    return state


def make_train_step(
    loss_fn: Callable[[Any, Dict], jax.Array],
    opt: Optimizer,
    lr_fn: Callable[[jax.Array], jax.Array],
    *,
    accum_steps: int = 1,
    clip_norm: float = 1.0,
    grad_shardings: Any = None,
    grad_dtype: str = "",
    compress_axis: str = "",
    compress_per_channel: bool = False,
) -> Callable[[Dict, Dict], Tuple[Dict, Dict]]:
    """loss_fn(params, batch) -> scalar. Batch leading dim must divide
    accum_steps when accumulation is enabled.

    grad_shardings: optional pytree of NamedShardings (param layout) —
    constrains gradients to the parameter sharding. GSPMD fails to propagate
    shardings through the scan transpose for stacked-layer parameter grads
    (they come out replicated, 16x the memory); the explicit constraint
    restores the sharded layout.

    compress_axis: mesh axis name for error-feedback int8 gradient
    compression (``dist.compression.compressed_psum``). When set, the step
    must run *inside* ``shard_map`` over that axis (it issues ``psum``/
    ``pmax``), the state must come from ``init_train_state(compress=True)``,
    and per-shard gradients are reduced to the quantized global mean before
    clipping — the loss metric is likewise ``pmean``-ed so every shard
    reports the global value. The residual state is threaded through
    ``state['grad_err']``. ``compress_per_channel`` selects per-channel
    (leading-axis) quantization scales instead of one per-tensor scale —
    tighter scales for tensors whose channel magnitudes vary widely, at the
    cost of transmitting one scale per row."""

    raw_grad_fn = jax.value_and_grad(loss_fn)

    def grad_fn(params, batch):
        loss, grads = raw_grad_fn(params, batch)
        if grad_dtype:
            # cast before the cross-replica reduction: halves all-reduce wire
            # bytes for f32 cotangents (error < stochastic gradient noise)
            grads = jax.tree.map(lambda g: g.astype(grad_dtype), grads)
        if grad_shardings is not None:
            grads = jax.tree.map(jax.lax.with_sharding_constraint, grads, grad_shardings)
        return loss, grads

    def compute_grads(params, batch):
        if accum_steps == 1:
            return grad_fn(params, batch)

        def micro(batch_i):
            return jax.tree.map(
                lambda x: x.reshape((accum_steps, x.shape[0] // accum_steps) + x.shape[1:])[batch_i]
                if hasattr(x, "shape") and x.ndim > 0 else x,
                batch)

        def body(carry, i):
            loss_acc, grad_acc = carry
            loss_i, grads_i = grad_fn(params, micro(i))
            grad_acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32) / accum_steps,
                                    grad_acc, grads_i)
            return (loss_acc + loss_i / accum_steps, grad_acc), None

        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, grads), _ = jax.lax.scan(body, (jnp.zeros(()), zero),
                                        jnp.arange(accum_steps))
        return loss, grads

    def train_step(state: Dict, batch: Dict) -> Tuple[Dict, Dict]:
        loss, grads = compute_grads(state["params"], batch)
        new_err = None
        if compress_axis:
            from ..dist.compression import compressed_psum
            grads, new_err = compressed_psum(grads, state["grad_err"],
                                             compress_axis,
                                             per_channel=compress_per_channel)
            loss = jax.lax.pmean(loss, compress_axis)
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        lr = lr_fn(state["step"])
        updates, new_opt = opt.update(grads, state["opt"], state["params"], lr)
        new_params = apply_updates(state["params"], updates)
        new_state = {"params": new_params, "opt": new_opt, "step": state["step"] + 1}
        if new_err is not None:
            new_state["grad_err"] = new_err
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return new_state, metrics

    return train_step


def stack_error_state(state: Dict, n_shards: int) -> Dict:
    """Give ``grad_err`` leaves the leading ``[n_shards]`` device axis that
    `shard_map_compressed_step` shards over (residuals are per-rank)."""
    return dict(state, grad_err=jax.tree.map(
        lambda e: jnp.zeros((n_shards,) + e.shape, e.dtype), state["grad_err"]))


def shard_map_compressed_step(step, mesh, data_axis: str = "data"):
    """Run a ``compress_axis`` train step data-parallel under ``shard_map``.

    The wrapped step sees shard-local batches and its own residual slice
    (``grad_err`` is stored with a leading device axis — `stack_error_state`
    — and sharded over ``data_axis``; everything else is replicated). The
    compressed psum inside the step reduces gradients to the global mean, so
    params/opt update identically on every shard and come back replicated.
    Do NOT install the mesh as the ambient compute mesh around this step:
    the body is already manual over ``data_axis`` and nested sharding
    constraints would conflict.
    """
    from jax.sharding import PartitionSpec as P
    from ..dist import compat as _compat  # noqa: F401  (jax.shard_map shim)
    state_specs = {"params": P(), "opt": P(), "step": P(),
                   "grad_err": P(data_axis)}

    def local(state, batch):
        state = dict(state, grad_err=jax.tree.map(lambda e: e[0], state["grad_err"]))
        new_state, metrics = step(state, batch)
        new_state = dict(new_state,
                         grad_err=jax.tree.map(lambda e: e[None], new_state["grad_err"]))
        return new_state, metrics

    return jax.shard_map(local, mesh=mesh,
                         in_specs=(state_specs, P(data_axis)),
                         out_specs=(state_specs, P()), check_vma=False)
