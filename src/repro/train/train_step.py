"""Train-step builder: loss -> grads -> clip -> schedule -> optimizer update.

Features: microbatch gradient accumulation (lax.scan over accumulation
steps — overlaps the per-microbatch gradient reduce with the next
microbatch's compute under the XLA latency-hiding scheduler), global-norm
clipping, pluggable optimizer/schedule, optional int8 gradient compression
state (error feedback) threaded through the train state.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from .optim import Optimizer, apply_updates, clip_by_global_norm


def init_train_state(params: Any, opt: Optimizer) -> Dict[str, Any]:
    return {"params": params, "opt": opt.init(params), "step": jnp.zeros((), jnp.int32)}


def make_train_step(
    loss_fn: Callable[[Any, Dict], jax.Array],
    opt: Optimizer,
    lr_fn: Callable[[jax.Array], jax.Array],
    *,
    accum_steps: int = 1,
    clip_norm: float = 1.0,
    grad_shardings: Any = None,
    grad_dtype: str = "",
) -> Callable[[Dict, Dict], Tuple[Dict, Dict]]:
    """loss_fn(params, batch) -> scalar. Batch leading dim must divide
    accum_steps when accumulation is enabled.

    grad_shardings: optional pytree of NamedShardings (param layout) —
    constrains gradients to the parameter sharding. GSPMD fails to propagate
    shardings through the scan transpose for stacked-layer parameter grads
    (they come out replicated, 16x the memory); the explicit constraint
    restores the sharded layout."""

    raw_grad_fn = jax.value_and_grad(loss_fn)

    def grad_fn(params, batch):
        loss, grads = raw_grad_fn(params, batch)
        if grad_dtype:
            # cast before the cross-replica reduction: halves all-reduce wire
            # bytes for f32 cotangents (error < stochastic gradient noise)
            grads = jax.tree.map(lambda g: g.astype(grad_dtype), grads)
        if grad_shardings is not None:
            grads = jax.tree.map(jax.lax.with_sharding_constraint, grads, grad_shardings)
        return loss, grads

    def compute_grads(params, batch):
        if accum_steps == 1:
            return grad_fn(params, batch)

        def micro(batch_i):
            return jax.tree.map(
                lambda x: x.reshape((accum_steps, x.shape[0] // accum_steps) + x.shape[1:])[batch_i]
                if hasattr(x, "shape") and x.ndim > 0 else x,
                batch)

        def body(carry, i):
            loss_acc, grad_acc = carry
            loss_i, grads_i = grad_fn(params, micro(i))
            grad_acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32) / accum_steps,
                                    grad_acc, grads_i)
            return (loss_acc + loss_i / accum_steps, grad_acc), None

        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, grads), _ = jax.lax.scan(body, (jnp.zeros(()), zero),
                                        jnp.arange(accum_steps))
        return loss, grads

    def train_step(state: Dict, batch: Dict) -> Tuple[Dict, Dict]:
        loss, grads = compute_grads(state["params"], batch)
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        lr = lr_fn(state["step"])
        updates, new_opt = opt.update(grads, state["opt"], state["params"], lr)
        new_params = apply_updates(state["params"], updates)
        new_state = {"params": new_params, "opt": new_opt, "step": state["step"] + 1}
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return new_state, metrics

    return train_step
