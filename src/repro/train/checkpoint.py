"""Checkpointing: atomic, keep-k, elastic (resharding) restore.

Layout:  <dir>/step_<N>/arrays.npz + manifest.json, published by atomic
rename of a tmp directory — a reader never sees a partial checkpoint, and a
writer dying mid-save leaves the previous checkpoint intact (fault-tolerance
invariant tested in tests/test_checkpoint.py).

Restore takes a *template* pytree (e.g. from jax.eval_shape) and optional
target shardings: leaves are device_put to the target sharding, so a
checkpoint written on one mesh restores onto any other mesh/device count
(elastic scaling). On multi-host deployments each process writes its
addressable shards (`process_index` suffix); this container is single-process
so the suffix is constant, but the layout is multi-host-shaped.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional

import jax
import numpy as np


def _leafkey(path) -> str:
    return jax.tree_util.keystr(path)


def save(ckpt_dir: str, step: int, tree: Any, *, keep: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + f".tmp.{os.getpid()}"
    os.makedirs(tmp, exist_ok=True)

    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    arrays = {}
    manifest = {"step": step, "leaves": [], "process": jax.process_index()}
    for i, (path, leaf) in enumerate(leaves):
        key = f"leaf_{i}"
        arrays[key] = np.asarray(jax.device_get(leaf))
        manifest["leaves"].append({"key": key, "path": _leafkey(path),
                                   "shape": list(arrays[key].shape),
                                   "dtype": str(arrays[key].dtype)})
    np.savez(os.path.join(tmp, f"arrays_p{jax.process_index()}.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)                     # atomic publish
    _cleanup(ckpt_dir, keep)
    return final


def _cleanup(ckpt_dir: str, keep: int):
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)


def all_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp") and ".tmp." not in name:
            try:
                out.append(int(name[5:]))
            except ValueError:
                pass
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: int, template: Any, *, shardings: Any = None) -> Any:
    """Restore into the structure of `template` (shapes/dtypes validated).

    shardings: optional pytree of jax.sharding.Sharding matching template —
    leaves are placed directly onto the (possibly different) target mesh.
    """
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(final, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(final, f"arrays_p{jax.process_index()}.npz")) as data:
        loaded = {m["path"]: data[m["key"]] for m in manifest["leaves"]}

    paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(paths_leaves))
    out = []
    for (path, tleaf), sh in zip(paths_leaves, shard_leaves):
        key = _leafkey(path)
        if key not in loaded:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = loaded[key]
        expect = tuple(tleaf.shape)
        if tuple(arr.shape) != expect:
            raise ValueError(f"shape mismatch for {key}: ckpt {arr.shape} vs template {expect}")
        arr = arr.astype(tleaf.dtype)
        out.append(jax.device_put(arr, sh) if sh is not None else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)
