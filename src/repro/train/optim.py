"""Optimizers (pure pytree transforms): SGD-M, AdamW, Adafactor.

No external deps — each optimizer is (init, update):
    state = init(params)
    updates, state = update(grads, state, params, lr)
    params = apply_updates(params, updates)

ZeRO-1: `zero1_sharding()` produces optimizer-state shardings with the
leading divisible axis additionally sharded over the data axis, so Adam
moments / fp32 masters are partitioned across data-parallel replicas
(the standard optimizer-state sharding trick; restore-time resharding in
train.checkpoint makes this elastic).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jax.Array], Tuple[Any, Any]]


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


# ---------------------------------------------------------------------------
# SGD with momentum
# ---------------------------------------------------------------------------

def sgd(momentum: float = 0.9, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return {"mu": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)}

    def update(grads, state, params, lr):
        mu = jax.tree.map(lambda m, g: momentum * m + g.astype(jnp.float32),
                          state["mu"], grads)
        upd = jax.tree.map(
            lambda m, p: -lr * (m + weight_decay * p.astype(jnp.float32)), mu, params)
        return upd, {"mu": mu}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# AdamW (fp32 master moments; bias-corrected)
# ---------------------------------------------------------------------------

def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros_like(p, jnp.float32)
        return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params),
                "t": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        t = state["t"] + 1
        m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                         state["v"], grads)
        c1 = 1 - b1 ** t.astype(jnp.float32)
        c2 = 1 - b2 ** t.astype(jnp.float32)
        upd = jax.tree.map(
            lambda m, v, p: -lr * ((m / c1) / (jnp.sqrt(v / c2) + eps)
                                   + weight_decay * p.astype(jnp.float32)),
            m, v, params)
        return upd, {"m": m, "v": v, "t": t}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# Adafactor (factored second moment; memory ~ O(rows+cols))
# ---------------------------------------------------------------------------

def adafactor(decay: float = 0.8, eps: float = 1e-30, clip_thresh: float = 1.0,
              weight_decay: float = 0.0) -> Optimizer:
    """Simplified Adafactor (Shazeer & Stern): factored v for >=2D params,
    no momentum — the optimizer-state choice for the 400B MoE config."""

    def _factored(shape):
        return len(shape) >= 2

    def init(params):
        def leaf(p):
            if _factored(p.shape):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros_like(p, jnp.float32)}
        return {"s": jax.tree.map(leaf, params,
                                  is_leaf=lambda x: isinstance(x, jax.Array)),
                "t": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        t = state["t"] + 1
        beta = 1.0 - (t.astype(jnp.float32) + 1.0) ** (-decay)

        def leaf(g, s, p):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if _factored(p.shape):
                vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                rfac = jax.lax.rsqrt(vr / jnp.maximum(
                    jnp.mean(vr, axis=-1, keepdims=True), eps) + eps)
                cfac = jax.lax.rsqrt(vc + eps)
                u = g * rfac[..., None] * cfac[..., None, :]
                ns = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g * jax.lax.rsqrt(v + eps)
                ns = {"v": v}
            # update clipping (RMS <= clip_thresh)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
            u = u / jnp.maximum(1.0, rms / clip_thresh)
            upd = -lr * (u + weight_decay * p.astype(jnp.float32))
            return upd, ns

        flat_g, tdef = jax.tree.flatten(grads)
        flat_s = tdef.flatten_up_to(state["s"])
        flat_p = tdef.flatten_up_to(params)
        out = [leaf(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        upd = tdef.unflatten([o[0] for o in out])
        ns = tdef.unflatten([o[1] for o in out])
        return upd, {"s": ns, "t": t}

    return Optimizer(init, update)


def make_optimizer(name: str, **kw) -> Optimizer:
    return {"sgd": sgd, "adamw": adamw, "adafactor": adafactor}[name](**kw)


# ---------------------------------------------------------------------------
# ZeRO-1 sharding of optimizer state
# ---------------------------------------------------------------------------

def zero1_spec(param_spec, shape, data_axis: str = "data", data_size: int = 2):
    """Add `data` sharding to the first axis that is unsharded & divisible.

    param_spec: jax.sharding.PartitionSpec of the parameter.
    data_size: the data axis size to check divisibility against (pass the
    mesh's actual ``mesh.shape[data_axis]``; `dist.sharding.zero1_opt_specs`
    is the tree-level form that does this for a whole optimizer state).
    Returns a PartitionSpec for fp32 optimizer moments of the same shape.
    """
    from jax.sharding import PartitionSpec as P
    entries = list(param_spec) + [None] * (len(shape) - len(param_spec))
    if data_size > 1:
        for i, (e, dim) in enumerate(zip(entries, shape)):
            if e is None and dim % data_size == 0:
                entries[i] = data_axis
                return P(*entries)
    return P(*entries)
