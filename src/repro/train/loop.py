"""Fault-tolerant training loop: checkpoint/restart, preemption handling.

The loop is restart-idempotent: state (params/opt/step) round-trips through
checkpoints, and the data pipeline is step-keyed, so `run()` after a crash
resumes bit-identically (tested). A preemption signal (SIGTERM) triggers a
final checkpoint before exit — the standard TPU-pod eviction contract.
Straggler/elasticity posture is documented in DESIGN.md §5; restore accepts
a different mesh via sharding-aware checkpoint restore.
"""
from __future__ import annotations

import signal
import time
from typing import Any, Callable, Dict, Optional

import jax

from . import checkpoint as ckpt


class TrainLoop:
    def __init__(
        self,
        train_step: Callable,
        make_batch: Callable[[int], Dict],
        ckpt_dir: Optional[str] = None,
        ckpt_every: int = 100,
        keep: int = 3,
        log_every: int = 10,
        log_fn: Callable[[int, Dict], None] = None,
    ):
        self.train_step = train_step
        self.make_batch = make_batch
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.keep = keep
        self.log_every = log_every
        self.log_fn = log_fn or (lambda step, m: print(
            f"step {step}: " + " ".join(f"{k}={float(v):.4g}" for k, v in m.items())))
        self._preempted = False

    def _install_signal_handler(self):
        def handler(signum, frame):
            self._preempted = True
        try:
            signal.signal(signal.SIGTERM, handler)
        except ValueError:
            pass  # non-main thread (tests)

    def maybe_restore(self, state_template: Any, shardings: Any = None):
        """Resume from the latest checkpoint if one exists."""
        if not self.ckpt_dir:
            return None, 0
        step = ckpt.latest_step(self.ckpt_dir)
        if step is None:
            return None, 0
        state = ckpt.restore(self.ckpt_dir, step, state_template, shardings=shardings)
        return state, step

    def run(self, state: Any, num_steps: int, start_step: int = 0,
            fail_at_step: Optional[int] = None) -> Any:
        """Run to `num_steps` total steps. `fail_at_step` simulates a node
        failure (raises) for the fault-tolerance tests."""
        self._install_signal_handler()
        metrics_hist = []
        for step in range(start_step, num_steps):
            if fail_at_step is not None and step == fail_at_step:
                raise RuntimeError(f"simulated node failure at step {step}")
            batch = self.make_batch(step)
            state, metrics = self.train_step(state, batch)
            if step % self.log_every == 0 or step == num_steps - 1:
                metrics = jax.device_get(metrics)
                self.log_fn(step, metrics)
                metrics_hist.append((step, metrics))
            if self.ckpt_dir and ((step + 1) % self.ckpt_every == 0 or self._preempted
                                  or step == num_steps - 1):
                ckpt.save(self.ckpt_dir, step + 1, jax.device_get(state), keep=self.keep)
                if self._preempted:
                    break
        self.history = metrics_hist
        return state
