"""EngineCore: the one fixed-slot scheduler both workloads share.

Decoupled-processing SNN architectures (Windhager et al., arXiv:2311.14447)
separate request admission from execution; this module is that split in
software. `EngineCore` owns the admission queue, bucketed batch formation,
slot lifecycle and result routing, and delegates tensors to a
`api.ModelRunner`. The same `submit()` / `poll()` / `run_until_complete()`
surface serves greedy LM decoding (`runners.lm.LMRunner`) and batched
spiking-VGG9 inference (`runners.snn.SNNRunner`) — the seam every later
scaling PR (sharded serving, async admission, multi-backend) plugs into.

Scheduling policy: FIFO with same-bucket batching. A step takes the bucket
key of the oldest queued request, collects up to ``slots`` queued requests
with an equal key (preserving queue order for the rest), pads the batch to
the full slot count with runner fillers, and executes it. Static batch
shapes mean each distinct bucket compiles once.
"""
from __future__ import annotations

import collections
from typing import Any, Dict, List, Optional

from .api import (EngineConfig, ModelRunner, QueueFull, Request, Result)


class _Slot:
    """One batch lane. Tracks which request occupies it (None = free) and
    how many requests it has served — the lifecycle the benchmarks report
    as slot occupancy."""

    __slots__ = ("index", "request_id", "served")

    def __init__(self, index: int):
        self.index = index
        self.request_id: Optional[int] = None
        self.served = 0

    def acquire(self, request_id: int) -> None:
        assert self.request_id is None, f"slot {self.index} busy"
        self.request_id = request_id

    def release(self) -> None:
        if self.request_id is not None:
            self.served += 1
        self.request_id = None


class EngineCore:
    """Fixed-slot admission queue + scheduler over a `ModelRunner`."""

    def __init__(self, runner: ModelRunner, config: EngineConfig = EngineConfig()):
        self.runner = runner
        self.config = config
        self.slots = [_Slot(i) for i in range(config.slots)]
        self._queue: collections.deque[Request] = collections.deque()
        self._results: Dict[int, Result] = {}
        self._next_id = 0
        self._batches_run = 0
        self._requests_done = 0

    # -- admission ----------------------------------------------------------

    def submit(self, payload: Any, **options: Any) -> int:
        """Admit one request; returns its id. Raises `QueueFull` at capacity."""
        if len(self._queue) >= self.config.max_queue:
            raise QueueFull(
                f"admission queue at capacity ({self.config.max_queue})")
        rid = self._next_id
        self._next_id += 1
        self._queue.append(Request(rid, payload, dict(options)))
        return rid

    def pending(self) -> int:
        return len(self._queue)

    # -- results ------------------------------------------------------------

    def poll(self, request_id: int) -> Optional[Result]:
        """Return (and retire) the result for ``request_id``, or None if it
        has not completed yet."""
        return self._results.pop(request_id, None)

    # -- scheduling ---------------------------------------------------------

    def _form_batch(self) -> List[Request]:
        """FIFO same-bucket batch formation, queue order preserved for the
        requests left behind."""
        key = self.runner.bucket_key(self._queue[0])
        batch: List[Request] = []
        keep: List[Request] = []
        while self._queue and len(batch) < self.config.slots:
            req = self._queue.popleft()
            if self.runner.bucket_key(req) == key:
                batch.append(req)
            else:
                keep.append(req)
        self._queue.extendleft(reversed(keep))
        return batch

    def step(self) -> int:
        """Run one batch if any work is queued; returns #requests completed."""
        if not self._queue:
            return 0
        batch = self._form_batch()
        for slot, req in zip(self.slots, batch):
            slot.acquire(req.request_id)
        # pad to the full slot count: the runner always sees static shapes
        while len(batch) < self.config.slots:
            batch.append(self.runner.filler(batch[0]))

        results = self.runner.run(batch)
        assert len(results) == self.config.slots, (
            f"runner returned {len(results)} results for {self.config.slots} slots")

        done = 0
        for req, res in zip(batch, results):
            if req.is_pad:
                continue
            assert res.request_id == req.request_id, (res.request_id, req.request_id)
            self._results[res.request_id] = res
            done += 1
        for slot in self.slots:
            slot.release()
        self._batches_run += 1
        self._requests_done += done
        return done

    def run_until_complete(self) -> Dict[int, Result]:
        """Drain the queue; returns every unretrieved result keyed by id
        (retiring them from `poll`)."""
        while self._queue:
            self.step()
        out, self._results = self._results, {}
        return out

    # -- introspection ------------------------------------------------------

    def stats(self) -> Dict[str, Any]:
        served = [s.served for s in self.slots]
        return {
            "batches_run": self._batches_run,
            "requests_done": self._requests_done,
            "pending": len(self._queue),
            "slots": self.config.slots,
            "slot_served": served,
            # mean fraction of slots doing real work per batch
            "slot_occupancy": (self._requests_done
                               / (self._batches_run * self.config.slots)
                               if self._batches_run else 0.0),
        }
