"""EngineCore: the one fixed-slot serving core both workloads share.

Decoupled-processing SNN architectures (Windhager et al., arXiv:2311.14447)
separate request admission from execution; this module is that split in
software. `EngineCore` owns the admission queue, slot lifecycle and result
routing, delegates *batch composition* to a pluggable `scheduler.Scheduler`,
and delegates tensors to an `api.ModelRunner`. The same `submit()` /
`poll()` / `run_until_complete()` surface serves greedy LM decoding
(`runners.lm.LMRunner`) and batched spiking-VGG9 inference
(`runners.snn.SNNRunner`).

Two admission policies (``EngineConfig.admission``):

* ``'continuous'`` (default) — step-level admission. The engine holds one
  live `api.RunnerSession` per session key; each `step()` first asks the
  scheduler to refill freed slots from the queue, then advances the session
  one iteration. For the LM an iteration is one token — a newly admitted
  request prefills its prompt token-by-token in the same `decode_step`
  launches its slot-mates decode in (per-row positions + ``active`` cache
  masking keep it bit-identical to a solo run), so a freed KV-cache slot
  never idles while other requests still decode. For the SNN an iteration is
  one fused T-timestep batch: freed (zero-image padding) slots are refilled
  with real work every step. Requests with different decode budgets
  co-reside; nothing waits for a bucket.
* ``'batch'`` — the PR-2 run-to-completion policy: one `step()` forms one
  batch (scheduler-composed, same `bucket_key`), pads it to the slot count
  and runs it to completion. Kept for offline/throughput use and as the
  reference semantics.

Per-step occupancy/goodput accounting lives on `stats()`; the admission
history (which requests entered which step) on `admission_log`.
"""
from __future__ import annotations

import collections
from typing import Any, Dict, Hashable, List, Optional, Tuple

from .api import (EngineConfig, ModelRunner, QueueFull, Request, Result,
                  RunnerSession)
from .scheduler import Scheduler, make_scheduler


class _Slot:
    """One batch lane. Tracks which request occupies it (None = free) and
    how many requests it has served — the lifecycle the benchmarks report
    as slot occupancy."""

    __slots__ = ("index", "request_id", "served")

    def __init__(self, index: int):
        self.index = index
        self.request_id: Optional[int] = None
        self.served = 0

    def acquire(self, request_id: int) -> None:
        assert self.request_id is None, f"slot {self.index} busy"
        self.request_id = request_id

    def release(self) -> None:
        if self.request_id is not None:
            self.served += 1
        self.request_id = None


class EngineCore:
    """Fixed-slot admission queue + pluggable scheduler over a `ModelRunner`."""

    def __init__(self, runner: ModelRunner, config: EngineConfig = EngineConfig(),
                 scheduler: Optional[Scheduler] = None):
        assert config.admission in ("continuous", "batch"), config.admission
        self.runner = runner
        self.config = config
        self.scheduler = scheduler if scheduler is not None else make_scheduler(config.scheduler)
        self.slots = [_Slot(i) for i in range(config.slots)]
        self._queue: collections.deque[Request] = collections.deque()
        self._results: Dict[int, Result] = {}
        self._next_id = 0
        # request_id -> Request for everything currently resident in a slot
        self._resident: Dict[int, Request] = {}
        self._session: Optional[RunnerSession] = None
        self._session_key: Optional[Hashable] = None
        # accounting
        self._batches_run = 0          # runner invocations (compute steps)
        self._requests_done = 0
        self._steps_run = 0            # compute steps (== batches_run today)
        self._occupied_slot_steps = 0  # sum over steps of occupied slots
        #: [(step_index, [request_ids admitted])] — the scheduler's decisions,
        #: in order; tests and benchmarks read batch composition off this.
        self.admission_log: List[Tuple[int, List[int]]] = []

    # -- admission ----------------------------------------------------------

    def submit(self, payload: Any, **options: Any) -> int:
        """Admit one request; returns its id. Raises `QueueFull` at capacity."""
        if len(self._queue) >= self.config.max_queue:
            raise QueueFull(
                f"admission queue at capacity ({self.config.max_queue})")
        rid = self._next_id
        self._next_id += 1
        self._queue.append(Request(rid, payload, dict(options)))
        return rid

    def pending(self) -> int:
        return len(self._queue)

    def in_flight(self) -> int:
        """Requests currently resident in slots (continuous admission)."""
        return sum(1 for s in self.slots if s.request_id is not None)

    # -- results ------------------------------------------------------------

    def poll(self, request_id: int) -> Optional[Result]:
        """Return (and retire) the result for ``request_id``, or None if it
        has not completed yet."""
        return self._results.pop(request_id, None)

    # -- scheduling ---------------------------------------------------------

    def step(self) -> int:
        """Advance the engine; returns #requests completed.

        continuous: refill freed slots from the queue, then run one session
        iteration. batch: form and run one batch to completion.
        """
        if self.config.admission == "batch":
            return self._step_batch()
        return self._step_continuous()

    def run_until_complete(self) -> Dict[int, Result]:
        """Drain queue and live slots; returns every unretrieved result
        keyed by id (retiring them from `poll`)."""
        while self._queue or self.in_flight():
            self.step()
        out, self._results = self._results, {}
        return out

    def _take_from_queue(self, picks: List[Request], key_fn) -> Hashable:
        """Validate a scheduler selection and remove it from the queue;
        returns the selection's (single) session/bucket key."""
        keys = {key_fn(r) for r in picks}
        assert len(keys) == 1, f"scheduler mixed keys in one selection: {keys}"
        chosen = {r.request_id for r in picks}
        assert len(chosen) == len(picks), "scheduler returned duplicate requests"
        self._queue = collections.deque(
            r for r in self._queue if r.request_id not in chosen)
        return keys.pop()

    def _complete(self, slot: _Slot, result: Result) -> None:
        req = self._resident.pop(result.request_id)
        self.scheduler.observe(req, result)
        self._results[result.request_id] = result
        slot.release()
        self._requests_done += 1

    # -- continuous admission ------------------------------------------------

    def _step_continuous(self) -> int:
        done = 0
        free = [s for s in self.slots if s.request_id is None]
        resident = self.config.slots - len(free)
        if (resident and self._queue
                and self.runner.session_key(self._queue[0]) != self._session_key):
            # the *oldest* queued request needs a different session: stop
            # refilling and let the residents drain so its key takes over —
            # PR-2's oldest-bucket-first fairness at session granularity.
            # Without this, a steady same-key stream arriving behind it
            # would keep the session resident and starve it forever.
            free = []
        if self._queue and free:
            active_key = self._session_key if resident else None
            picks = self.scheduler.select(
                tuple(self._queue), len(free),
                key_fn=self.runner.session_key, active_key=active_key)
            if picks:
                key = self._take_from_queue(picks, self.runner.session_key)
                assert active_key is None or key == active_key, (key, active_key)
                if resident == 0 and (self._session is None
                                      or key != self._session_key):
                    # no live work: safe to swap in a session for the new key
                    self._session = self.runner.open_session(self.config.slots)
                    self._session_key = key
                self.admission_log.append(
                    (self._steps_run, [r.request_id for r in picks]))
                for req, slot in zip(picks, free):
                    slot.acquire(req.request_id)
                    self._resident[req.request_id] = req
                    self.scheduler.on_admit(req)
                    immediate = self._session.admit(slot.index, req)
                    if immediate is not None:   # degenerate request: 0 work
                        self._complete(slot, immediate)
                        done += 1
            elif resident == 0:
                raise RuntimeError(
                    "scheduler admitted nothing into an idle engine with a "
                    "non-empty queue (Scheduler.select contract: with "
                    "active_key=None it must pick at least one request)")

        occupied = [s for s in self.slots if s.request_id is not None]
        if not occupied:
            return done
        finished = self._session.step()
        self._steps_run += 1
        self._batches_run += 1
        self._occupied_slot_steps += len(occupied)
        for idx, res in finished.items():
            slot = self.slots[idx]
            assert slot.request_id == res.request_id, (slot.request_id,
                                                       res.request_id)
            self._complete(slot, res)
            done += 1
        return done

    # -- run-to-completion batching (PR-2 semantics) -------------------------

    def _step_batch(self) -> int:
        if not self._queue:
            return 0
        picks = self.scheduler.select(
            tuple(self._queue), self.config.slots,
            key_fn=self.runner.bucket_key, active_key=None)
        assert picks, "Scheduler.select returned nothing for an idle engine"
        self._take_from_queue(picks, self.runner.bucket_key)
        self.admission_log.append(
            (self._steps_run, [r.request_id for r in picks]))

        batch: List[Request] = list(picks)
        for slot, req in zip(self.slots, batch):
            slot.acquire(req.request_id)
            self._resident[req.request_id] = req
            self.scheduler.on_admit(req)
        # pad to the full slot count: the runner always sees static shapes
        while len(batch) < self.config.slots:
            batch.append(self.runner.filler(batch[0]))

        results = self.runner.run(batch)
        assert len(results) == self.config.slots, (
            f"runner returned {len(results)} results for {self.config.slots} slots")

        done = 0
        for slot, (req, res) in zip(self.slots, zip(batch, results)):
            if req.is_pad:
                continue
            assert res.request_id == req.request_id, (res.request_id, req.request_id)
            self._complete(slot, res)
            done += 1
        for slot in self.slots:
            slot.release()                 # pad slots; real ones already free
        self._batches_run += 1
        self._steps_run += 1
        self._occupied_slot_steps += len(picks)
        return done

    # -- introspection ------------------------------------------------------

    def stats(self) -> Dict[str, Any]:
        served = [s.served for s in self.slots]
        steps = self._steps_run
        return {
            "batches_run": self._batches_run,
            "steps_run": steps,
            "requests_done": self._requests_done,
            "pending": len(self._queue),
            "in_flight": self.in_flight(),
            "slots": self.config.slots,
            "slot_served": served,
            "admission": self.config.admission,
            "scheduler": getattr(self.scheduler, "name", type(self.scheduler).__name__),
            # mean fraction of slots holding real work per compute step
            "slot_occupancy": (self._occupied_slot_steps
                               / (steps * self.config.slots) if steps else 0.0),
            # requests retired per compute step (continuous: tokens cost
            # steps, so LM goodput < 1; SNN completes whole slots per step)
            "goodput_req_per_step": (self._requests_done / steps if steps else 0.0),
        }
