"""EngineCore: the one fixed-slot serving core both workloads share.

Decoupled-processing SNN architectures (Windhager et al., arXiv:2311.14447)
separate request admission from execution; this module is that split in
software. `EngineCore` owns the admission queue, slot lifecycle and result
routing, delegates *batch composition* to a pluggable `scheduler.Scheduler`,
and delegates tensors to an `api.ModelRunner`. The same `submit()` /
`poll()` / `run_until_complete()` surface serves greedy LM decoding
(`runners.lm.LMRunner`) and batched spiking-VGG9 inference
(`runners.snn.SNNRunner`).

Two admission policies (``EngineConfig.admission``):

* ``'continuous'`` (default) — step-level admission. The engine holds one
  live `api.RunnerSession` per session key; each `step()` first retires
  expired requests, asks the scheduler to refill freed slots from the
  queue, plans a work budget (`api.StepBudget` — default
  ``EngineConfig.prefill_chunk``, or the scheduler's ``plan_step`` split),
  then advances the session by that budget. For the LM a step consumes one
  decode token per resident plus up to ``chunk`` prompt tokens per
  prefilling slot — a newly admitted request prefills its prompt in
  scheduler-sized chunks in the same launches its slot-mates decode in
  (per-row positions + ``active`` cache masking keep it bit-identical to a
  solo run), so a long prompt no longer holds goodput down for its whole
  prefill and a freed KV-cache slot never idles while other requests still
  decode. For the SNN a step is one fused T-timestep batch: freed
  (zero-image padding) slots are refilled with real work every step.
  Requests with different decode budgets co-reside; nothing waits for a
  bucket.
* ``'batch'`` — the PR-2 run-to-completion policy: one `step()` forms one
  batch (scheduler-composed, same `bucket_key`), pads it to the slot count
  and runs it to completion. Kept for offline/throughput use and as the
  reference semantics. Budgets, deadlines and partial results are
  continuous-admission concepts; the batch path ignores them.

Request lifecycle beyond completion (continuous admission):

* **streaming** — every `api.StepReport` carries per-slot partial outputs
  (`SlotProgress.emitted`: new LM tokens, per-timestep SNN stats); the
  engine accumulates them per request for `poll_partial`.
* **cancellation** — `cancel(request_id)` removes a queued request or
  reclaims a resident's slot via `RunnerSession.cancel` (row-independence
  keeps neighbours bit-identical); the `Result` carries
  ``status='cancelled'`` and whatever partial outputs existed.
* **deadlines** — requests submitted with ``deadline_s`` are retired with
  ``status='expired'`` once the engine clock passes their deadline
  (queued or resident), and a scheduler ``expire`` hook may evict
  provably-late residents early. The clock is injectable (``clock=``) so
  tests and benchmarks can drive deadlines deterministically in steps.
* **fault containment** — every step's emitted partials and finished
  results pass a NaN/Inf screen (``EngineConfig.numerics_screen``); a
  poisoned slot is retired with ``status='failed'`` (clean partials
  preserved) instead of streaming the poison or corrupting its own next
  step, and `run_until_complete(max_idle_steps=...)` raises
  `api.EngineStalled` instead of spinning forever when no slot makes
  progress. `serve.router.Router` builds fleet-level supervision (drain +
  replay re-route) on these per-engine guarantees.

Per-step occupancy/goodput accounting lives on `stats()`; the admission
history (which requests entered which step) on `admission_log`.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Callable, Dict, Hashable, List, Optional, Tuple

import numpy as np

from .api import (EngineConfig, EngineStalled, ModelRunner, QueueFull,
                  Request, Result, RunnerSession, SlotProgress, StepBudget,
                  SubmitSpec)
from .scheduler import Scheduler, make_scheduler


def all_finite(value) -> bool:
    """True when ``value`` contains no NaN/Inf anywhere (recursing into
    lists/tuples/dicts and array-likes). The numerics probe the engine (and
    `serve.router.Router`) runs over step outputs: ints, strings, None and
    non-numeric leaves are vacuously finite."""
    if value is None or isinstance(value, (bool, int, str, bytes)):
        return True
    if isinstance(value, float):
        return value == value and value not in (float("inf"), float("-inf"))
    if isinstance(value, dict):
        return all(all_finite(v) for v in value.values())
    if isinstance(value, (list, tuple, set)):
        return all(all_finite(v) for v in value)
    if hasattr(value, "dtype"):
        arr = np.asarray(value)
        if not np.issubdtype(arr.dtype, np.floating) and \
                not np.issubdtype(arr.dtype, np.complexfloating):
            return True
        return bool(np.isfinite(arr).all())
    return True


class StepClock:
    """Deterministic engine clock: one 'second' per completed engine step.

    Deadlines expressed in steps make SLO behavior machine-independent.
    `EngineCore` auto-attaches itself to an unattached clock it is
    constructed with, so the usual form is just::

        core = EngineCore(runner, config, clock=StepClock())
    """

    def __init__(self):
        self.core: Optional["EngineCore"] = None

    def attach(self, core: "EngineCore") -> "StepClock":
        self.core = core
        return self

    def __call__(self) -> float:
        return 0.0 if self.core is None else float(self.core._steps_run)


class _Slot:
    """One batch lane. Tracks which request occupies it (None = free) and
    how many requests it has served — the lifecycle the benchmarks report
    as slot occupancy."""

    __slots__ = ("index", "request_id", "served")

    def __init__(self, index: int):
        self.index = index
        self.request_id: Optional[int] = None
        self.served = 0

    def acquire(self, request_id: int) -> None:
        assert self.request_id is None, f"slot {self.index} busy"
        self.request_id = request_id

    def release(self) -> None:
        if self.request_id is not None:
            self.served += 1
        self.request_id = None


class EngineCore:
    """Fixed-slot admission queue + pluggable scheduler over a `ModelRunner`."""

    def __init__(self, runner: ModelRunner, config: EngineConfig = EngineConfig(),
                 scheduler: Optional[Scheduler] = None,
                 clock: Callable[[], float] = time.monotonic,
                 obs: Optional[Any] = None):
        assert config.admission in ("continuous", "batch"), config.admission
        self.runner = runner
        self.config = config
        if config.precision:
            set_precision = getattr(runner, "set_precision", None)
            if set_precision is None:
                raise ValueError(
                    f"EngineConfig.precision={config.precision!r} needs a "
                    "precision-capable runner "
                    "(serve.precision.PrecisionRunner); "
                    f"{type(runner).__name__} has no set_precision")
            set_precision(config.precision)
        self.scheduler = scheduler if scheduler is not None else make_scheduler(config.scheduler)
        self.slots = [_Slot(i) for i in range(config.slots)]
        self._queue: collections.deque[Request] = collections.deque()
        self._results: Dict[int, Result] = {}
        self._next_id = 0
        # request_id -> Request for everything currently resident in a slot
        self._resident: Dict[int, Request] = {}
        self._session: Optional[RunnerSession] = None
        self._session_key: Optional[Hashable] = None
        #: engine clock: deadlines and arrival stamps are measured on it.
        #: Wall time by default; tests/benchmarks inject a step counter for
        #: deterministic deadline behavior. An unattached `StepClock` (or
        #: anything with the same attach/core surface) is bound to this
        #: engine here, so forgetting the attach call cannot silently
        #: freeze the clock at 0.
        if getattr(clock, "core", False) is None and callable(
                getattr(clock, "attach", None)):
            clock.attach(self)
        self._clock = clock
        # request_id -> partial outputs emitted but not yet polled
        self._partials: Dict[int, List[Any]] = {}
        # slot index -> last SlotProgress (scheduler budget/evict input)
        self._progress: Dict[int, SlotProgress] = {}
        # accounting
        self._batches_run = 0          # runner invocations (compute steps)
        self._requests_done = 0
        self._cancelled = 0
        self._expired = 0
        self._failed = 0               # numerics screen retirements
        self._steps_run = 0            # compute steps (== batches_run today)
        self._occupied_slot_steps = 0  # sum over steps of occupied slots
        self._decode_tokens = 0        # LM decode tokens emitted (goodput)
        self._work_units = 0           # budget units consumed (StepReport.cost)
        self._drafted_tokens = 0       # speculative drafts verified
        self._accepted_tokens = 0      # drafts accepted (free decode tokens)
        #: [(step_index, [request_ids admitted])] — the scheduler's decisions,
        #: in order; tests and benchmarks read batch composition off this.
        self.admission_log: List[Tuple[int, List[int]]] = []
        #: the last `StepReport` a continuous-admission step produced —
        #: supervision surface for `serve.router.Router`'s health probes.
        self.last_report: Optional[Any] = None
        #: optional `repro.obs.Observability` bundle. Hooks only receive
        #: values the engine computed anyway (clock readings, reports,
        #: results) — attaching one is bit-identical to running without
        #: (the no-perturbation contract `tests/test_obs.py` asserts).
        self.obs = obs
        if obs is not None:
            obs.attach_engine(self)

    # -- admission ----------------------------------------------------------

    def submit(self, payload: Any, *, deadline_s: Optional[float] = None,
               priority: int = 0, **options: Any) -> int:
        """Admit one request; returns its id. Raises `QueueFull` at capacity.

        The kwarg surface parses into one canonical `api.SubmitSpec`
        (shared verbatim by `Router.submit` and the wire `SubmitMsg`);
        unknown or ill-typed option keys raise ValueError *here*, at the
        submit boundary, not mid-step inside a runner.

        deadline_s: optional latency SLO in engine-clock seconds from now —
        the request is retired with ``status='expired'`` if it has not
        completed by then. priority: admission tie-break for deadline-aware
        schedulers (higher wins).
        """
        return self.submit_spec(SubmitSpec.make(
            payload, deadline_s=deadline_s, priority=priority, **options))

    def submit_spec(self, spec: SubmitSpec) -> int:
        """Admit one already-validated `api.SubmitSpec` (the primitive
        `submit` wraps; transports call this directly)."""
        if len(self._queue) >= self.config.max_queue:
            raise QueueFull(
                f"admission queue at capacity ({self.config.max_queue})")
        rid = self._next_id
        self._next_id += 1
        now = self._clock()
        self._queue.append(Request(rid, spec.payload, dict(spec.options),
                                   deadline_s=spec.deadline_s,
                                   priority=spec.priority,
                                   arrival_s=now))
        if self.obs is not None:
            self.obs.on_submit(rid, self._steps_run, now,
                               priority=spec.priority,
                               deadline_s=spec.deadline_s)
        return rid

    def pending(self) -> int:
        return len(self._queue)

    def in_flight(self) -> int:
        """Requests currently resident in slots (continuous admission)."""
        return sum(1 for s in self.slots if s.request_id is not None)

    # -- results ------------------------------------------------------------

    def poll(self, request_id: int) -> Optional[Result]:
        """Return (and retire) the result for ``request_id``, or None if it
        has not completed yet. Retiring a result also drops its undrained
        partials (the full outputs are on the `Result`)."""
        res = self._results.pop(request_id, None)
        if res is not None:
            self._partials.pop(request_id, None)
        return res

    def poll_partial(self, request_id: int) -> List[Any]:
        """Drain the partial outputs streamed for ``request_id`` since the
        last call: new tokens for LM requests, per-timestep sparsity stats
        for SNN requests (`api.SlotProgress.emitted`). Empty list when
        nothing new was emitted; works while the request is in flight and —
        until the final `Result` is polled — after completion."""
        return self._partials.pop(request_id, [])

    # -- lifecycle -----------------------------------------------------------

    def cancel(self, request_id: int, *, status: str = "cancelled") -> bool:
        """Cancel a queued or resident request; False if the engine does not
        hold it (already completed, polled, or never submitted).

        The `Result` (retrievable via `poll`) carries ``status`` and, for a
        resident request, its partial outputs. Reclaiming the slot does not
        perturb slot-mates: sessions are row-independent and the freed row's
        state is re-zeroed before reuse, exactly as on normal completion.
        """
        for req in self._queue:
            if req.request_id == request_id:
                self._queue.remove(req)
                res = Result(request_id, None, stats={}, status=status)
                # the scheduler may hold queue-side state for this request
                # (e.g. pass-over counters); let it retire that too
                self.scheduler.observe(req, res)
                self._results[request_id] = res
                self._count_retired(status)
                self._obs_retire(res)
                return True
        if request_id not in self._resident:
            return False
        slot = next(s for s in self.slots if s.request_id == request_id)
        res = self._session.cancel(slot.index)
        assert res.request_id == request_id, (res.request_id, request_id)
        if res.status != status:
            res = dataclasses.replace(res, status=status)
        req = self._resident.pop(request_id)
        self.scheduler.observe(req, res)
        self._results[request_id] = res
        self._progress.pop(slot.index, None)
        slot.release()
        self._count_retired(status)
        self._obs_retire(res)
        return True

    def _count_retired(self, status: str) -> None:
        if status == "expired":
            self._expired += 1
        elif status == "failed":
            self._failed += 1
        else:
            self._cancelled += 1

    def _expire_due(self, now: float) -> None:
        """Retire every request whose deadline has passed: queued ones drop
        with an empty result, residents are evicted with their partial
        progress. A scheduler ``expire`` hook may additionally evict
        residents that are predicted (by a lower-bound estimate) to miss."""
        for req in [r for r in self._queue
                    if r.deadline_at is not None and now >= r.deadline_at]:
            self.cancel(req.request_id, status="expired")
        for rid, req in list(self._resident.items()):
            if req.deadline_at is not None and now >= req.deadline_at:
                self.cancel(rid, status="expired")
        hook = getattr(self.scheduler, "expire", None)
        if hook is not None and self._resident:
            residents = {s.index: self._resident[s.request_id]
                         for s in self.slots if s.request_id is not None}
            for rid in hook(residents, dict(self._progress), now=now):
                if rid in self._resident:
                    self.cancel(rid, status="expired")

    # -- scheduling ---------------------------------------------------------

    def step(self) -> int:
        """Advance the engine; returns #requests completed.

        continuous: refill freed slots from the queue, then run one session
        iteration. batch: form and run one batch to completion.
        """
        if self.config.admission == "batch":
            return self._step_batch()
        return self._step_continuous()

    def _progress_marker(self) -> Tuple[int, int, int, int]:
        """Anything that changes between steps when the engine is healthy:
        work consumed, requests retired (any status), queue drained."""
        retired = (self._requests_done + self._cancelled + self._expired
                   + self._failed)
        return (retired, self._work_units, self._decode_tokens,
                len(self._queue))

    def run_until_complete(self, *,
                           max_idle_steps: Optional[int] = None
                           ) -> Dict[int, Result]:
        """Drain queue and live slots; returns every unretrieved result
        keyed by id (retiring them from `poll`).

        max_idle_steps bounds the wedged-session failure mode: after that
        many consecutive steps with zero progress (no work units, nothing
        retired, queue unmoved) the drain raises `EngineStalled` naming the
        stuck residents, instead of spinning forever on a session that
        stopped advancing. Defaults to `EngineConfig.max_idle_steps`
        (finite); 0 disables the guard.
        """
        limit = self.config.max_idle_steps if max_idle_steps is None \
            else max_idle_steps
        idle = 0
        while self._queue or self.in_flight():
            before = self._progress_marker()
            self.step()
            idle = 0 if self._progress_marker() != before else idle + 1
            if limit and idle >= limit:
                stuck = sorted(self._resident)
                if self.obs is not None:
                    self.obs.on_dump("stalled", self._steps_run,
                                     resident=stuck, queued=len(self._queue))
                raise EngineStalled(
                    f"no slot made progress for {idle} consecutive steps "
                    f"(steps_run={self._steps_run}, resident request ids "
                    f"{stuck}, queued={len(self._queue)}, last progress "
                    f"phases={[ (p.request_id, p.phase, p.units_done, p.units_total) for p in self._progress.values() ]})")
        out, self._results = self._results, {}
        for rid in out:
            self._partials.pop(rid, None)
        return out

    def _take_from_queue(self, picks: List[Request], key_fn) -> Hashable:
        """Validate a scheduler selection and remove it from the queue;
        returns the selection's (single) session/bucket key."""
        keys = {key_fn(r) for r in picks}
        assert len(keys) == 1, f"scheduler mixed keys in one selection: {keys}"
        chosen = {r.request_id for r in picks}
        assert len(chosen) == len(picks), "scheduler returned duplicate requests"
        self._queue = collections.deque(
            r for r in self._queue if r.request_id not in chosen)
        return keys.pop()

    def _complete(self, slot: _Slot, result: Result) -> None:
        req = self._resident.pop(result.request_id)
        self.scheduler.observe(req, result)
        self._results[result.request_id] = result
        slot.release()
        self._requests_done += 1
        self._obs_retire(result)

    def _obs_retire(self, result: Result) -> None:
        """Every terminal-result path funnels here for the trace's sake."""
        if self.obs is not None:
            self.obs.on_retire(result, self._steps_run, self._clock())

    # -- continuous admission ------------------------------------------------

    def _step_continuous(self) -> int:
        done = 0
        now = self._clock()
        tick = getattr(self.scheduler, "on_clock", None)
        if tick is not None:        # select()'s signature carries no clock
            tick(now)
        self._expire_due(now)
        free = [s for s in self.slots if s.request_id is None]
        resident = self.config.slots - len(free)
        if (resident and self._queue
                and self.runner.session_key(self._queue[0]) != self._session_key):
            # the *oldest* queued request needs a different session: stop
            # refilling and let the residents drain so its key takes over —
            # PR-2's oldest-bucket-first fairness at session granularity.
            # Without this, a steady same-key stream arriving behind it
            # would keep the session resident and starve it forever.
            free = []
        if self._queue and free:
            active_key = self._session_key if resident else None
            picks = self.scheduler.select(
                tuple(self._queue), len(free),
                key_fn=self.runner.session_key, active_key=active_key)
            if picks:
                key = self._take_from_queue(picks, self.runner.session_key)
                assert active_key is None or key == active_key, (key, active_key)
                if resident == 0 and (self._session is None
                                      or key != self._session_key):
                    # no live work: safe to swap in a session for the new key
                    self._session = self.runner.open_session(self.config.slots)
                    self._session_key = key
                self.admission_log.append(
                    (self._steps_run, [r.request_id for r in picks]))
                if self.obs is not None:
                    self.obs.on_admit([r.request_id for r in picks],
                                      self._steps_run, now)
                for req, slot in zip(picks, free):
                    slot.acquire(req.request_id)
                    self._resident[req.request_id] = req
                    self.scheduler.on_admit(req)
                    immediate = self._session.admit(slot.index, req)
                    if immediate is not None:   # degenerate request: 0 work
                        self._complete(slot, immediate)
                        done += 1
            elif resident == 0:
                raise RuntimeError(
                    "scheduler admitted nothing into an idle engine with a "
                    "non-empty queue (Scheduler.select contract: with "
                    "active_key=None it must pick at least one request)")

        occupied = [s for s in self.slots if s.request_id is not None]
        if not occupied:
            return done

        budget = StepBudget(chunk=self.config.prefill_chunk)
        plan = getattr(self.scheduler, "plan_step", None)
        if plan is not None:
            residents = {s.index: self._resident[s.request_id] for s in occupied}
            budget = plan(residents, dict(self._progress), now=now,
                          default=budget)
        t0 = self._clock()
        report = self._session.step(budget)
        self._steps_run += 1          # before the clock read: a step-counting
        self._batches_run += 1        # clock must see this step as elapsed
        seconds = self._clock() - t0
        self._occupied_slot_steps += len(occupied)
        self._decode_tokens += int(report.cost.get("decode_tokens", 0))
        self._work_units += int(report.cost.get("units", 0))
        self._drafted_tokens += int(report.cost.get("drafted_tokens", 0))
        self._accepted_tokens += int(report.cost.get("accepted_tokens", 0))

        # numerics probe: a slot whose step outputs carry NaN/Inf is retired
        # with status='failed' before the poison can stream to the caller or
        # feed the slot's next step — batchmates are row-independent, so the
        # retirement never perturbs them.
        poisoned: Dict[int, SlotProgress] = {}
        if self.config.numerics_screen:
            for idx, prog in report.progress.items():
                res = report.finished.get(idx)
                if not all_finite(prog.emitted) or (
                        res is not None and not (all_finite(res.outputs)
                                                 and all_finite(res.stats))):
                    poisoned[idx] = prog

        self._progress = dict(report.progress)
        for idx, prog in report.progress.items():
            if prog.emitted and idx not in poisoned:
                self._partials.setdefault(prog.request_id, []).extend(prog.emitted)
        hook = getattr(self.scheduler, "on_report", None)
        if hook is not None:
            hook(report, seconds=seconds, now=self._clock())
        self.last_report = report
        if self.obs is not None:
            self.obs.on_step(
                report, step=self._steps_run - 1, now=t0 + seconds,
                seconds=seconds, queue_len=len(self._queue),
                occupied=len(occupied),
                poisoned=[p.request_id for p in poisoned.values()])

        for idx, res in report.finished.items():
            slot = self.slots[idx]
            assert slot.request_id == res.request_id, (slot.request_id,
                                                       res.request_id)
            self._progress.pop(idx, None)
            if idx in poisoned:
                # finished but poisoned: surface the result as 'failed'
                # (outputs/stats kept for diagnosis; clean partials already
                # streamed stay available through poll_partial)
                res = dataclasses.replace(res, status="failed")
                req = self._resident.pop(res.request_id)
                self.scheduler.observe(req, res)
                self._results[res.request_id] = res
                slot.release()
                self._failed += 1
                self._obs_retire(res)
                continue
            self._complete(slot, res)
            done += 1
        for idx, prog in poisoned.items():
            # mid-flight poison: reclaim the slot via the cancel path — the
            # session rebuilds a clean partial Result (the poison lived only
            # in the reported outputs, e.g. a fault wrapper's injection)
            if idx not in report.finished and prog.request_id in self._resident:
                self.cancel(prog.request_id, status="failed")
        if poisoned and self.obs is not None:
            self.obs.on_dump("numerics-poison", self._steps_run - 1,
                             rids=[p.request_id for p in poisoned.values()])
        return done

    # -- run-to-completion batching (PR-2 semantics) -------------------------

    def _step_batch(self) -> int:
        if not self._queue:
            return 0
        picks = self.scheduler.select(
            tuple(self._queue), self.config.slots,
            key_fn=self.runner.bucket_key, active_key=None)
        assert picks, "Scheduler.select returned nothing for an idle engine"
        self._take_from_queue(picks, self.runner.bucket_key)
        self.admission_log.append(
            (self._steps_run, [r.request_id for r in picks]))
        if self.obs is not None:
            self.obs.on_admit([r.request_id for r in picks],
                              self._steps_run, self._clock())

        batch: List[Request] = list(picks)
        for slot, req in zip(self.slots, batch):
            slot.acquire(req.request_id)
            self._resident[req.request_id] = req
            self.scheduler.on_admit(req)
        # pad to the full slot count: the runner always sees static shapes
        while len(batch) < self.config.slots:
            batch.append(self.runner.filler(batch[0]))

        results = self.runner.run(batch)
        assert len(results) == self.config.slots, (
            f"runner returned {len(results)} results for {self.config.slots} slots")

        done = 0
        for slot, (req, res) in zip(self.slots, zip(batch, results)):
            if req.is_pad:
                continue
            assert res.request_id == req.request_id, (res.request_id, req.request_id)
            self._complete(slot, res)
            done += 1
        for slot in self.slots:
            slot.release()                 # pad slots; real ones already free
        self._batches_run += 1
        self._steps_run += 1
        self._occupied_slot_steps += len(picks)
        return done

    # -- introspection ------------------------------------------------------

    def stats(self) -> Dict[str, Any]:
        served = [s.served for s in self.slots]
        steps = self._steps_run
        return {
            "batches_run": self._batches_run,
            "steps_run": steps,
            "requests_done": self._requests_done,
            "cancelled": self._cancelled,
            "expired": self._expired,
            "failed": self._failed,
            "pending": len(self._queue),
            "in_flight": self.in_flight(),
            "slots": self.config.slots,
            "slot_served": served,
            "admission": self.config.admission,
            "scheduler": getattr(self.scheduler, "name", type(self.scheduler).__name__),
            "prefill_chunk": self.config.prefill_chunk,
            # active weight-numerics policy: the config override if set,
            # else the runner's native precision ('native' if it has none)
            "precision": self.config.precision
                         or getattr(self.runner, "precision", "native"),
            # mean fraction of slots holding real work per compute step
            "slot_occupancy": (self._occupied_slot_steps
                               / (steps * self.config.slots) if steps else 0.0),
            # requests retired per compute step (continuous: tokens cost
            # steps, so LM goodput < 1; SNN completes whole slots per step)
            "goodput_req_per_step": (self._requests_done / steps if steps else 0.0),
            # budget-units consumed and LM decode tokens emitted, total and
            # per step — decode goodput is what chunked prefill raises: the
            # same decode work packs into fewer wall-clock steps
            "work_units": self._work_units,
            "decode_tokens": self._decode_tokens,
            "goodput_decode_tok_per_step": (self._decode_tokens / steps
                                            if steps else 0.0),
            # speculative decode: drafts verified, drafts accepted, and the
            # fraction accepted — accepted tokens are the decode tokens a
            # step emitted beyond one-per-slot, i.e. exactly the goodput
            # speculation buys (zero everywhere when speculation is off)
            "drafted_tokens": self._drafted_tokens,
            "accepted_tokens": self._accepted_tokens,
            "accept_rate": (self._accepted_tokens / self._drafted_tokens
                            if self._drafted_tokens else 0.0),
            "goodput_accepted_tok_per_step": (self._accepted_tokens / steps
                                              if steps else 0.0),
        }
