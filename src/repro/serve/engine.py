"""DEPRECATED back-compat alias: `ServeEngine` over the unified serving core.

The seed-era engine is fully retired: `serve.api` owns the request/result
vocabulary (now including `StepBudget`/`StepReport`), `serve.core.EngineCore`
owns admission/slots/lifecycle, and `serve.runners.lm.LMRunner` owns the LM
tensors. Every in-repo call site constructs those directly
(``EngineCore(LMRunner(cfg, params, ...))``); this alias exists for one
release so external callers get a `DeprecationWarning` instead of an
ImportError, and carries no machinery of its own — the eagerly-built
engine-owned prefill path the PR-2 shim still dragged along is gone (the
runner's batch-prefill scan lives in `LMRunner.run`, compiled only when the
batch admission path actually uses it).
"""
from __future__ import annotations

import warnings
from typing import List

from ..configs.base import ArchConfig
from .api import EngineConfig
from .core import EngineCore
from .runners.lm import LMRunner


class ServeEngine:
    """Deprecated alias for ``EngineCore(LMRunner(...))`` — use those."""

    def __init__(self, cfg: ArchConfig, params, *, batch_slots: int = 8,
                 max_seq: int = 512, quant_bits: int = 0):
        warnings.warn(
            "serve.engine.ServeEngine is deprecated; build "
            "EngineCore(LMRunner(cfg, params, max_seq=..., quant_bits=...), "
            "EngineConfig(slots=...)) directly. This alias will be removed "
            "next release.",
            DeprecationWarning, stacklevel=2)
        # keep the PR-2 shim's public surface intact for the alias release
        self.cfg = cfg
        self.batch = batch_slots
        self.max_seq = max_seq
        self.runner = LMRunner(cfg, params, max_seq=max_seq,
                               quant_bits=quant_bits)
        self.core = EngineCore(self.runner, EngineConfig(slots=batch_slots))

    @property
    def params(self):
        """The (possibly quantized) parameter view the runner serves with."""
        return self.runner.params

    def generate(self, prompts: List[List[int]], num_tokens: int) -> List[List[int]]:
        """Greedy-decode `num_tokens` for a batch of prompts (see
        `EngineCore.submit` / `run_until_complete`)."""
        assert len(prompts) <= self.core.config.slots
        ids = [self.core.submit(p, max_new_tokens=num_tokens) for p in prompts]
        results = self.core.run_until_complete()
        return [results[i].outputs for i in ids]
