"""Back-compat shim: `ServeEngine` over the unified serving core.

The real machinery now lives in `serve.api` (Request/Result/ModelRunner),
`serve.core` (EngineCore: fixed-slot admission queue, pluggable scheduler,
continuous or run-to-completion admission) and `serve.runners.lm`
(prefill-scan + greedy decode, with per-request prompt-length masking).
This class keeps the seed's constructor and ``generate`` signature for
existing callers/tests and simply routes through an `EngineCore` with an
`LMRunner` under the default continuous admission (numerics are identical
either way: every request decodes exactly as if served alone).
"""
from __future__ import annotations

from typing import List

from ..configs.base import ArchConfig
from .api import EngineConfig
from .core import EngineCore
from .runners.lm import LMRunner


class ServeEngine:
    """Greedy batched generation over the unified LM (compat wrapper)."""

    def __init__(self, cfg: ArchConfig, params, *, batch_slots: int = 8,
                 max_seq: int = 512, quant_bits: int = 0):
        self.cfg = cfg
        self.batch = batch_slots
        self.max_seq = max_seq
        self.runner = LMRunner(cfg, params, max_seq=max_seq, quant_bits=quant_bits)
        self.core = EngineCore(self.runner, EngineConfig(slots=batch_slots))

    @property
    def params(self):
        """The (possibly quantized) parameter view the runner serves with."""
        return self.runner.params

    def generate(self, prompts: List[List[int]], num_tokens: int) -> List[List[int]]:
        """Greedy-decode `num_tokens` for a batch of prompts. Each prompt is
        prefilled against its own length (shorter prompts in a ragged batch
        are no longer teacher-forced on pad zeros)."""
        assert len(prompts) <= self.batch
        ids = [self.core.submit(p, max_new_tokens=num_tokens) for p in prompts]
        results = self.core.run_until_complete()
        return [results[i].outputs for i in ids]
