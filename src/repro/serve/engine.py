"""Batched serving engine: prefill + greedy decode with KV caches.

A deliberately small but real engine: fixed-slot batching (the production
pattern for TPU serving — static decode shapes, no per-token recompilation),
jit'd decode step shared across requests, optional int4-weight numerics (the
paper's quantization pipeline generalized to LM serving; on TPU the packed
kernels/int4_matmul path provides the same numerics with 4x less HBM
traffic — equivalence tested in tests/test_kernels_int4.py).

Prefill runs as one jit'd scan over the whole prompt block (one dispatch
instead of one per prompt token). The scan length is the batch's max prompt
length, so each *distinct* prompt-block length compiles once (the scan body
is compiled once regardless of length); production callers should bucket
prompt lengths. Greedy-decode numerics are identical to stepping token by
token (tests assert).
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..core.quant import fake_quant
from ..models import transformer as tf


def _quantized_params(params, bits: int):
    def walk(path, x):
        key = jax.tree_util.keystr(path)
        if x.ndim >= 2 and (".w" in key or "w_" in key) and "norm" not in key:
            return fake_quant(x, bits, None)
        return x
    return jax.tree_util.tree_map_with_path(walk, params)


class ServeEngine:
    """Greedy batched generation over the unified LM."""

    def __init__(self, cfg: ArchConfig, params, *, batch_slots: int = 8,
                 max_seq: int = 512, quant_bits: int = 0):
        self.cfg = cfg
        self.batch = batch_slots
        self.max_seq = max_seq
        self.params = _quantized_params(params, quant_bits) if quant_bits else params

        @functools.partial(jax.jit, static_argnums=())
        def step(params, cache, tokens, pos):
            logits, cache = tf.decode_step(params, cache, {"tokens": tokens}, pos, cfg)
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            return nxt[:, None], cache            # [B, 1] — feeds the next step

        @jax.jit
        def prefill(params, cache, toks):
            """Chunked teacher-forced prefill: one jit'd scan over the whole
            prompt block (one dispatch instead of plen), decode numerics
            bit-identical to stepping token by token."""

            def body(cache, xs):
                tok, pos = xs                     # tok [B], pos scalar
                logits, cache = tf.decode_step(
                    params, cache, {"tokens": tok[:, None]}, pos, cfg)
                nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
                return cache, nxt

            plen = toks.shape[1]
            positions = jnp.arange(plen, dtype=jnp.int32)
            cache, nxts = jax.lax.scan(body, cache, (toks.T, positions))
            return nxts[-1][:, None], cache       # [B, 1] — first decode input

        self._step = step
        self._prefill = prefill

    def generate(self, prompts: List[List[int]], num_tokens: int) -> List[List[int]]:
        """Greedy-decode `num_tokens` for a batch of prompts (padded to the
        slot count; prompts consumed teacher-forced during prefill)."""
        assert len(prompts) <= self.batch
        plen = max(len(p) for p in prompts)
        toks = jnp.zeros((self.batch, plen), jnp.int32)
        for i, p in enumerate(prompts):
            toks = toks.at[i, :len(p)].set(jnp.array(p, jnp.int32))

        cache = tf.init_cache(self.cfg, self.batch, self.max_seq)
        # prefill: teacher-forced decode over the whole prompt block in a
        # single jit'd scan (fills the caches; one dispatch, not plen)
        nxt, cache = self._prefill(self.params, cache, toks)
        out = [list(p) for p in prompts]
        cur = nxt
        for k in range(num_tokens):
            pos = jnp.int32(plen + k)
            for i in range(len(prompts)):
                out[i].append(int(cur[i, 0]))
            cur, cache = self._step(self.params, cache, cur, pos)
        return out
