"""Supervised multi-replica serving: the fleet layer over `EngineCore`.

The ROADMAP's fleet north star — N replicas behind one `submit()` — is only
worth having if it *survives* the faults production traffic generates: a
wedged session, a NaN-poisoned kernel, a queue flood. `Router` is that
layer, in-process:

* **load balancing** — `submit()` places each request on the healthy
  replica with the cheapest estimated backlog: outstanding work units
  (tokens/timesteps the router already routed there) priced by a learned
  per-replica seconds-per-unit EWMA, the fleet-level counterpart of
  `SLOScheduler`'s per-workload cost model. Streaming callers pass
  ``affinity=`` to pin a stream's requests to one replica (KV locality).
* **health supervision** — every `step()` the router advances each healthy
  replica and probes it. Heartbeat: a replica holding work that makes no
  progress (`EngineCore._progress_marker`) for ``wedge_patience``
  consecutive steps — or whose step takes longer than the learned fleet
  baseline times ``stall_factor`` (or an absolute ``stall_seconds``) — is
  WEDGED. Numerics: a step that trips the engine's NaN/Inf screen
  (``stats()['failed']`` delta, or non-finite `StepReport.cost`) marks the
  replica POISONED. A replica whose ``step()`` raises is WEDGED with the
  exception recorded. Either way it is drained and retired from placement.
* **drain + re-route by deterministic replay** — in-flight requests on a
  condemned replica are re-submitted from their frozen `Request` payloads
  to a healthy replica. Runners are deterministic (greedy decode,
  row-independent slots), so the replay is bit-identical to a fault-free
  run; partials the caller already saw are deduplicated by count, and the
  absolute deadline is preserved (the remaining budget is recomputed on
  the shared clock). Each request carries ``max_retries`` re-routes; past
  that it retires ``status='failed'``, past its deadline ``'expired'``.
* **graceful overload** — `submit()` never raises: a replica's `QueueFull`
  parks the request in a router-side waiting line with exponential backoff
  (retry after 1, 2, 4, ... router steps), and when the line itself
  overflows ``max_waiting`` the *lowest-priority* (then newest) waiters
  are shed with ``status='rejected'`` — an explicit outcome instead of
  silently blowing the deadline of everything behind them.

The router speaks the same request surface as a single engine (`submit` /
`poll` / `poll_partial` / `cancel` / `run_until_complete` / `stats`), so
drivers like `launch/serve.py --replicas N` swap it in transparently.
Fault schedules for chaos tests/benches come from `serve.faults`
(`make_router(..., plans=...)` wraps each replica in a `FaultyRunner`).
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, Set

from .api import (EngineConfig, EngineStalled, ModelRunner, QueueFull,
                  Request, Result)
from .core import EngineCore, all_finite
from .faults import FaultPlan, FaultyRunner, TickClock

#: replica lifecycle: healthy -> (wedged | poisoned) -> drained
HEALTHY, WEDGED, POISONED, DRAINED = "healthy", "wedged", "poisoned", "drained"


def _est_units(payload: Any, options: Mapping[str, Any]) -> int:
    """Outstanding-work estimate for load balancing: prompt + decode tokens
    for token-sequence (LM) payloads, 1 unit for anything else (an SNN
    request completes in one fused step). Only relative magnitudes matter —
    the same heuristic as `SLOScheduler._service_units`."""
    prefill = len(payload) if isinstance(payload, (list, tuple)) else 0
    return max(1, prefill + int(options.get("max_new_tokens", 0)))


@dataclasses.dataclass
class _Tracked:
    """Router-side record of one submitted request — everything needed to
    replay it from scratch on another replica."""
    rid: int
    payload: Any
    options: Dict[str, Any]
    priority: int
    deadline_at: Optional[float]        # absolute, on the shared clock
    affinity: Optional[Any]
    retries_left: int
    forwarded: int = 0                  # partial items surfaced to caller
    skip: int = 0                       # replayed partials to drop (dedup)
    attempts: int = 0                   # QueueFull backoff exponent


class _Replica:
    """One supervised `EngineCore` and its health bookkeeping."""

    def __init__(self, idx: int, core: EngineCore):
        self.idx = idx
        self.core = core
        self.state = HEALTHY
        self.condition: Optional[str] = None    # why it left HEALTHY
        self.reason: Optional[str] = None
        self.idle_steps = 0                     # consecutive no-progress steps
        self.placed: Dict[int, int] = {}        # local rid -> router rid
        self.sec_per_unit = 1.0                 # EWMA, placement cost prior

    def busy(self) -> bool:
        return self.core.in_flight() > 0 or self.core.pending() > 0


class Router:
    """Fault-tolerant front end over N `EngineCore` replicas.

    replicas share one engine clock (deadlines are absolute on it); build
    fleets with `make_router`, which wires the shared clock and optional
    per-replica `FaultPlan`s.

    wedge_patience: consecutive no-progress steps of a busy replica before
                    it is condemned as WEDGED.
    stall_factor:   a step slower than ``stall_factor x`` the fastest
                    observed fleet step is treated as a stall (wall-clock
                    fleets); ``stall_seconds`` is the absolute variant for
                    deterministic clocks, where healthy steps cost 0.
    max_retries:    re-route budget per request; exhausting it retires the
                    request ``status='failed'``.
    max_waiting:    bound on the backoff line; beyond it the lowest-priority
                    waiters are shed ``status='rejected'``.
    tick_s:         seconds the router advances an owned `TickClock` per
                    `step()` (deterministic deadline pacing, like
                    `core.StepClock`); 0 leaves the clock alone.
    """

    def __init__(self, replicas: Sequence[EngineCore], *,
                 clock: Optional[Callable[[], float]] = None,
                 wedge_patience: int = 3, stall_factor: float = 8.0,
                 stall_seconds: Optional[float] = None,
                 max_retries: int = 2, max_waiting: int = 64,
                 tick_s: float = 0.0):
        assert replicas, "router needs at least one replica"
        self.replicas = [_Replica(i, core) for i, core in enumerate(replicas)]
        self._clock = clock if clock is not None else replicas[0]._clock
        self.wedge_patience = max(1, wedge_patience)
        self.stall_factor = stall_factor
        self.stall_seconds = stall_seconds
        self.max_retries = max_retries
        self.max_waiting = max_waiting
        self.tick_s = tick_s
        self._next_id = 0
        self._step_idx = 0
        self._requests: Dict[int, _Tracked] = {}
        self._placement: Dict[int, int] = {}        # router rid -> replica idx
        self._results: Dict[int, Result] = {}
        self._partials: Dict[int, List[Any]] = {}
        self._outstanding: Set[int] = set()
        self._waiting: Dict[int, int] = {}          # router rid -> due step
        self._affinity: Dict[Any, int] = {}         # key -> replica idx
        self._fastest_dt: Optional[float] = None    # learned fleet baseline
        self._counts = collections.Counter()
        self._rerouted = 0
        #: [(router step, replica idx, condition, [router rids re-routed])]
        #: — the supervision audit trail benches mine for recovery latency.
        self.drain_log: List[tuple] = []
        #: router rid -> router step of its terminal result
        self.completed_at: Dict[int, int] = {}

    # -- request surface -----------------------------------------------------

    def submit(self, payload: Any, *, deadline_s: Optional[float] = None,
               priority: int = 0, affinity: Optional[Any] = None,
               **options: Any) -> int:
        """Admit one request to the fleet; returns its router-scoped id.

        Never raises `QueueFull`: overload parks the request in the backoff
        line and, past ``max_waiting``, sheds by priority with
        ``status='rejected'`` (see class docstring)."""
        rid = self._next_id
        self._next_id += 1
        now = self._clock()
        self._requests[rid] = _Tracked(
            rid, payload, dict(options), priority,
            None if deadline_s is None else now + deadline_s,
            affinity, self.max_retries)
        self._outstanding.add(rid)
        self._try_place(rid)
        return rid

    def poll(self, request_id: int) -> Optional[Result]:
        """Return (and retire) the terminal `Result`, or None while the
        request is queued/running. Statuses: ok | cancelled | expired |
        failed | rejected. Unlike `EngineCore.poll`, retrieving a *non-ok*
        result keeps its undrained partials available to `poll_partial` —
        for a failed/expired request the clean partial stream is the only
        output there is ("partials intact")."""
        res = self._results.pop(request_id, None)
        if res is not None and res.status == "ok":
            self._partials.pop(request_id, None)
        return res

    def poll_partial(self, request_id: int) -> List[Any]:
        """Drain partial outputs streamed since the last call. Replayed
        requests never re-deliver items the caller already saw."""
        return self._partials.pop(request_id, [])

    def cancel(self, request_id: int) -> bool:
        """Cancel a waiting or in-flight request fleet-wide."""
        if request_id in self._waiting:
            del self._waiting[request_id]
            self._finish(request_id, Result(request_id, None, {}, "cancelled"))
            return True
        idx = self._placement.get(request_id)
        if idx is None:
            return False
        replica = self.replicas[idx]
        local = next(l for l, r in replica.placed.items() if r == request_id)
        self._drain_partials(replica)
        if not replica.core.cancel(local):
            return False
        del replica.placed[local]
        res = replica.core.poll(local)
        self._finish(request_id,
                     res if res is not None
                     else Result(request_id, None, {}, "cancelled"))
        return True

    # -- placement -----------------------------------------------------------

    def _healthy(self) -> List[_Replica]:
        return [r for r in self.replicas if r.state == HEALTHY]

    def _outstanding_units(self, replica: _Replica) -> int:
        units = 0
        for rid in replica.placed.values():
            t = self._requests.get(rid)
            if t is not None:
                units += _est_units(t.payload, t.options)
        return units

    def _pick_replica(self, tracked: _Tracked) -> Optional[_Replica]:
        healthy = self._healthy()
        if not healthy:
            return None
        if tracked.affinity is not None:
            pinned = self._affinity.get(tracked.affinity)
            if pinned is not None and self.replicas[pinned].state == HEALTHY:
                return self.replicas[pinned]
        est = _est_units(tracked.payload, tracked.options)
        best = min(healthy, key=lambda r: (
            (self._outstanding_units(r) + r.core.pending() + est)
            * r.sec_per_unit, r.idx))
        if tracked.affinity is not None:
            self._affinity[tracked.affinity] = best.idx
        return best

    def _try_place(self, rid: int) -> bool:
        """Place a tracked request on the best healthy replica; on
        `QueueFull` park it in the backoff line. Returns True if placed."""
        tracked = self._requests[rid]
        now = self._clock()
        if tracked.deadline_at is not None and now >= tracked.deadline_at:
            self._waiting.pop(rid, None)
            self._finish(rid, Result(rid, None, {}, "expired"))
            return False
        replica = self._pick_replica(tracked)
        if replica is None:
            # every replica condemned: nothing can ever run this request
            self._waiting.pop(rid, None)
            self._finish(rid, Result(rid, None, {}, "failed"))
            return False
        deadline_s = (None if tracked.deadline_at is None
                      else tracked.deadline_at - now)
        try:
            local = replica.core.submit(tracked.payload,
                                        deadline_s=deadline_s,
                                        priority=tracked.priority,
                                        **tracked.options)
        except QueueFull:
            tracked.attempts += 1
            self._waiting[rid] = self._step_idx + 2 ** (tracked.attempts - 1)
            self._shed_overflow()
            return False
        self._waiting.pop(rid, None)
        replica.placed[local] = rid
        self._placement[rid] = replica.idx
        return True

    def _shed_overflow(self) -> None:
        while len(self._waiting) > self.max_waiting:
            rid = min(self._waiting,
                      key=lambda r: (self._requests[r].priority, -r))
            del self._waiting[rid]
            self._finish(rid, Result(rid, None, {}, "rejected"))

    # -- supervision ---------------------------------------------------------

    def step(self) -> int:
        """Advance the fleet one supervision round; returns requests that
        reached a terminal result this round. Order: retry waiters, step +
        probe every healthy replica, collect partials/results, drain and
        re-route condemned replicas."""
        self._step_idx += 1
        if self.tick_s and hasattr(self._clock, "advance"):
            self._clock.advance(self.tick_s)
        finished_before = sum(self._counts.values())

        for rid, due in sorted(self._waiting.items(),
                               key=lambda kv: (-self._requests[kv[0]].priority,
                                               kv[0])):
            if due <= self._step_idx:
                self._try_place(rid)

        for replica in list(self.replicas):
            if replica.state != HEALTHY:
                continue
            if not replica.busy():
                replica.idle_steps = 0
                continue
            marker0 = replica.core._progress_marker()
            failed0 = replica.core._failed
            t0 = self._clock()
            try:
                replica.core.step()
            except Exception as e:          # mid-step fault: condemn replica
                self._condemn(replica, WEDGED, f"step raised: {e!r}")
                continue
            dt = self._clock() - t0
            self._drain_partials(replica)
            self._collect_results(replica)
            self._learn_cost(replica, marker0, dt)
            if replica.core._failed > failed0 or (
                    replica.core.last_report is not None
                    and not all_finite(replica.core.last_report.cost)):
                self._condemn(replica, POISONED,
                              "numerics screen tripped on step outputs")
                continue
            if self._stalled(dt):
                self._condemn(replica, WEDGED,
                              f"step took {dt:.3f}s vs fleet baseline "
                              f"{self._fastest_dt}")
                continue
            if replica.core._progress_marker() == marker0 and replica.busy():
                replica.idle_steps += 1
                if replica.idle_steps >= self.wedge_patience:
                    self._condemn(replica, WEDGED,
                                  f"no progress for {replica.idle_steps} "
                                  "consecutive steps with work resident")
            else:
                replica.idle_steps = 0
        return sum(self._counts.values()) - finished_before

    def _learn_cost(self, replica: _Replica, marker0, dt: float) -> None:
        units = replica.core._progress_marker()[1] - marker0[1]
        if dt > 0:
            self._fastest_dt = dt if self._fastest_dt is None \
                else min(self._fastest_dt, dt)
            if units > 0:
                sample = dt / units
                replica.sec_per_unit = (0.3 * sample
                                        + 0.7 * replica.sec_per_unit)

    def _stalled(self, dt: float) -> bool:
        if self.stall_seconds is not None and dt >= self.stall_seconds:
            return True
        return (self._fastest_dt is not None and dt > 0
                and dt > self.stall_factor * self._fastest_dt
                and self._fastest_dt > 0)

    def _drain_partials(self, replica: _Replica) -> None:
        for local, rid in list(replica.placed.items()):
            items = replica.core.poll_partial(local)
            if not items:
                continue
            tracked = self._requests.get(rid)
            if tracked is None:
                continue
            fresh: List[Any] = []
            for item in items:
                if tracked.skip > 0:    # replay re-emitted a seen partial
                    tracked.skip -= 1
                    continue
                fresh.append(item)
            if fresh:
                tracked.forwarded += len(fresh)
                self._partials.setdefault(rid, []).extend(fresh)

    def _collect_results(self, replica: _Replica) -> None:
        for local, rid in list(replica.placed.items()):
            res = replica.core.poll(local)
            if res is None:
                continue
            del replica.placed[local]
            self._finish(rid, res)

    def _condemn(self, replica: _Replica, condition: str, reason: str) -> None:
        """Mark a replica WEDGED/POISONED, salvage what it finished, and
        re-route its in-flight requests by deterministic replay."""
        replica.condition = condition
        replica.reason = reason
        replica.state = condition
        self._drain_partials(replica)
        self._collect_results(replica)      # salvage already-finished work
        rerouted: List[int] = []
        now = self._clock()
        for local, rid in list(replica.placed.items()):
            tracked = self._requests.get(rid)
            # reclaim the slot/queue entry; the inner session is clean, so
            # this cannot disturb anything else on the replica
            replica.core.cancel(local)
            self._drain_partials(replica)
            salvage = replica.core.poll(local)
            del replica.placed[local]
            self._placement.pop(rid, None)
            if tracked is None:
                continue
            if tracked.deadline_at is not None and now >= tracked.deadline_at:
                self._finish(rid, dataclasses.replace(
                    salvage or Result(rid, None, {}), status="expired"))
            elif tracked.retries_left > 0:
                tracked.retries_left -= 1
                tracked.skip = tracked.forwarded    # dedup the replay stream
                rerouted.append(rid)
                self._rerouted += 1
                self._try_place(rid)
            else:
                self._finish(rid, dataclasses.replace(
                    salvage or Result(rid, None, {}), status="failed"))
        replica.state = DRAINED
        self.drain_log.append((self._step_idx, replica.idx, condition,
                               rerouted))

    def _finish(self, rid: int, result: Result) -> None:
        if result.request_id != rid:
            result = dataclasses.replace(result, request_id=rid)
        self._results[rid] = result
        self._placement.pop(rid, None)
        self._outstanding.discard(rid)
        self._requests.pop(rid, None)
        self._counts[result.status] += 1
        self.completed_at[rid] = self._step_idx

    # -- drain loop ----------------------------------------------------------

    def run_until_complete(self, *, max_idle_steps: Optional[int] = None
                           ) -> Dict[int, Result]:
        """Step the fleet until every submitted request has a terminal
        result; returns (and retires) all unpolled results. Raises
        `EngineStalled` after ``max_idle_steps`` consecutive rounds with no
        fleet-wide progress (default: the first replica's configured
        guard) — possible only if supervision itself cannot retire the
        stuck work (e.g. the guard is set too tight)."""
        limit = (self.replicas[0].core.config.max_idle_steps
                 if max_idle_steps is None else max_idle_steps)
        idle = 0
        while self._outstanding:
            before = self._fleet_marker()
            self.step()
            idle = 0 if self._fleet_marker() != before else idle + 1
            if limit and idle >= limit:
                raise EngineStalled(
                    f"fleet made no progress for {idle} consecutive router "
                    f"steps (outstanding={sorted(self._outstanding)}, "
                    f"states={[r.state for r in self.replicas]}, "
                    f"waiting={sorted(self._waiting)})")
        out, self._results = self._results, {}
        for rid, res in out.items():
            if res.status == "ok":      # non-ok keeps partials pollable
                self._partials.pop(rid, None)
        return out

    def _fleet_marker(self) -> tuple:
        return (sum(self._counts.values()), len(self._waiting),
                tuple(r.core._progress_marker() for r in self.replicas),
                tuple(r.state for r in self.replicas))

    # -- introspection -------------------------------------------------------

    def stats(self) -> Dict[str, Any]:
        return {
            "router_steps": self._step_idx,
            "replicas": [{
                "idx": r.idx,
                "state": r.state,
                "condition": r.condition,
                "reason": r.reason,
                "sec_per_unit": r.sec_per_unit,
                "stats": r.core.stats(),
            } for r in self.replicas],
            "healthy": len(self._healthy()),
            "rerouted": self._rerouted,
            "waiting": len(self._waiting),
            "outstanding": len(self._outstanding),
            "drains": len(self.drain_log),
            **{status: self._counts.get(status, 0)
               for status in ("ok", "cancelled", "expired", "failed",
                              "rejected")},
        }


def make_router(runner: ModelRunner, n: int,
                config: EngineConfig = EngineConfig(), *,
                plans: Optional[Mapping[int, FaultPlan]] = None,
                clock: Optional[Callable[[], float]] = None,
                **router_kwargs) -> Router:
    """Build an N-replica fleet over one `ModelRunner`.

    Every replica gets its own `EngineCore` (own queue, slots, sessions)
    over the shared ``runner``, wrapped in a `serve.faults.FaultyRunner` so
    replica behavior differs only by its `FaultPlan` (``plans`` maps
    replica index -> plan; missing indices get the empty, transparent
    plan). All replicas and the router share one clock; when none is
    passed, a deterministic `TickClock` advanced 1 s per router step is
    created — the fleet analogue of `core.StepClock`."""
    owned = clock is None
    if owned:
        clock = TickClock()
    plans = dict(plans or {})
    cores = [EngineCore(FaultyRunner(runner, plans.get(i), clock),
                        config, clock=clock)
             for i in range(n)]
    if owned:
        router_kwargs.setdefault("tick_s", 1.0)
    return Router(cores, clock=clock, **router_kwargs)
