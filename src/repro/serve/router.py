"""Supervised multi-replica serving: the fleet layer over `EngineCore`.

The ROADMAP's fleet north star — N replicas behind one `submit()` — is only
worth having if it *survives* the faults production traffic generates: a
wedged session, a NaN-poisoned kernel, a queue flood. `Router` is that
layer, in-process:

* **load balancing** — `submit()` places each request on the healthy
  replica with the cheapest estimated backlog: outstanding work units
  (tokens/timesteps the router already routed there) priced by a learned
  per-replica seconds-per-unit EWMA, the fleet-level counterpart of
  `SLOScheduler`'s per-workload cost model. Streaming callers pass
  ``affinity=`` to pin a stream's requests to one replica (KV locality).
* **health supervision** — every `step()` the router advances each healthy
  replica and probes it. Heartbeat: a replica holding work that makes no
  progress (`EngineCore._progress_marker`) for ``wedge_patience``
  consecutive steps — or whose step takes longer than the learned fleet
  baseline times ``stall_factor`` (or an absolute ``stall_seconds``) — is
  WEDGED. Numerics: a step that trips the engine's NaN/Inf screen
  (``stats()['failed']`` delta, or non-finite `StepReport.cost`) marks the
  replica POISONED. A replica whose ``step()`` raises is WEDGED with the
  exception recorded. Either way it is drained and retired from placement.
* **drain + re-route by deterministic replay** — in-flight requests on a
  condemned replica are re-submitted from their frozen `Request` payloads
  to a healthy replica. Runners are deterministic (greedy decode,
  row-independent slots), so the replay is bit-identical to a fault-free
  run; partials the caller already saw are deduplicated by count, and the
  absolute deadline is preserved (the remaining budget is recomputed on
  the shared clock). Each request carries ``max_retries`` re-routes; past
  that it retires ``status='failed'``, past its deadline ``'expired'``.
* **graceful overload** — `submit()` never raises: a replica's `QueueFull`
  parks the request in a router-side waiting line with exponential backoff
  (retry after 1, 2, 4, ... router steps), and when the line itself
  overflows ``max_waiting`` the *lowest-priority* (then newest) waiters
  are shed with ``status='rejected'`` — an explicit outcome instead of
  silently blowing the deadline of everything behind them.

The router speaks the same request surface as a single engine (`submit` /
`poll` / `poll_partial` / `cancel` / `run_until_complete` / `stats`), so
drivers like `launch/serve.py --replicas N` swap it in transparently.
Fault schedules for chaos tests/benches come from `serve.faults`
(`make_router(..., plans=...)` wraps each replica in a `FaultyRunner`).

**Transports.** The router never talks to an `EngineCore` directly any
more — it talks to a `Transport`, the seam that makes supervision
deployment-agnostic. `InProcTransport` wraps an in-process engine
bit-identically (the default: `make_router` fleets behave exactly as
before), and `serve.worker.SubprocessTransport` speaks the versioned wire
protocol (`serve.wire`) to an engine hosted in a worker subprocess
(`make_worker_fleet`, `launch/serve.py --workers N`). Every health probe
above reads transport methods (`progress_marker`, `failed_count`,
`cost_finite`) that in-process delegate to engine internals and over the
wire come from `HeartbeatMsg` piggybacked on step replies — so stall
detection, the NaN probe and drain + deterministic-replay re-route work
unchanged when a worker hangs or dies outright: a dead pipe raises
`TransportError` from `step()`/`submit_spec()`, which condemns the replica
exactly like an in-process step fault.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import (Any, Callable, Dict, List, Mapping, Optional, Protocol,
                    Sequence, Set, Tuple, runtime_checkable)

from ..obs import Observability, aggregate, merge_traces
from .api import (EngineConfig, EngineStalled, ModelRunner, QueueFull,
                  Request, Result, SubmitSpec)
from .core import EngineCore, all_finite
from .faults import FaultPlan, FaultyRunner, TickClock

#: replica lifecycle: healthy -> (wedged | poisoned) -> drained
HEALTHY, WEDGED, POISONED, DRAINED = "healthy", "wedged", "poisoned", "drained"


class TransportError(RuntimeError):
    """A transport lost its replica (dead worker, broken pipe, timed-out
    step). Raised from `Transport.step`/`submit_spec`; the router responds
    by condemning the replica and re-routing its in-flight requests, the
    same path an in-process step exception takes."""


@runtime_checkable
class Transport(Protocol):
    """What the router needs from a replica, wherever it lives.

    The probe surface is exactly the supervision contract: a cumulative
    progress marker (retired, work_units, decode_tokens, queue_len), the
    numerics-screen failure count, and whether the last step's cost was
    finite. In-process these read engine internals; over the wire they are
    the `serve.wire.HeartbeatMsg` fields.
    """

    #: clock the replica stamps deadlines on (the router adopts the first
    #: replica's clock when none is passed)
    clock: Callable[[], float]

    def submit_spec(self, spec: SubmitSpec) -> int: ...
    def poll(self, request_id: int) -> Optional[Result]: ...
    def poll_partial(self, request_id: int) -> List[Any]: ...
    def cancel(self, request_id: int, *, status: str = "cancelled") -> bool: ...
    def step(self) -> None: ...
    def progress_marker(self) -> Tuple[int, int, int, int]: ...
    def failed_count(self) -> int: ...
    def cost_finite(self) -> bool: ...
    def in_flight(self) -> int: ...
    def pending(self) -> int: ...
    def stats(self) -> Dict[str, Any]: ...
    def max_idle_steps(self) -> int: ...
    def close(self) -> None: ...


class InProcTransport:
    """`Transport` over an in-process `EngineCore` — the default deployment
    mode, bit-identical to the pre-seam router (every method is a direct
    delegation; no serialization, no copies). The wrapped engine stays
    reachable as ``.core`` for tests and schedulers that introspect slots."""

    def __init__(self, core: EngineCore):
        self.core = core
        self.clock = core._clock

    def submit_spec(self, spec: SubmitSpec) -> int:
        return self.core.submit_spec(spec)

    def poll(self, request_id: int) -> Optional[Result]:
        return self.core.poll(request_id)

    def poll_partial(self, request_id: int) -> List[Any]:
        return self.core.poll_partial(request_id)

    def cancel(self, request_id: int, *, status: str = "cancelled") -> bool:
        return self.core.cancel(request_id, status=status)

    def step(self) -> None:
        self.core.step()

    def progress_marker(self) -> Tuple[int, int, int, int]:
        return self.core._progress_marker()

    def failed_count(self) -> int:
        return self.core._failed

    def cost_finite(self) -> bool:
        report = self.core.last_report
        return report is None or all_finite(report.cost)

    def in_flight(self) -> int:
        return self.core.in_flight()

    def pending(self) -> int:
        return self.core.pending()

    def stats(self) -> Dict[str, Any]:
        return self.core.stats()

    def max_idle_steps(self) -> int:
        return self.core.config.max_idle_steps

    def close(self) -> None:
        pass


def _est_units(payload: Any, options: Mapping[str, Any]) -> int:
    """Outstanding-work estimate for load balancing: prompt + decode tokens
    for token-sequence (LM) payloads, 1 unit for anything else (an SNN
    request completes in one fused step). Only relative magnitudes matter —
    the same heuristic as `SLOScheduler._service_units`."""
    prefill = len(payload) if isinstance(payload, (list, tuple)) else 0
    return max(1, prefill + int(options.get("max_new_tokens", 0)))


@dataclasses.dataclass
class _Tracked:
    """Router-side record of one submitted request — everything needed to
    replay it from scratch on another replica."""
    rid: int
    payload: Any
    options: Dict[str, Any]
    priority: int
    deadline_at: Optional[float]        # absolute, on the shared clock
    affinity: Optional[Any]
    retries_left: int
    forwarded: int = 0                  # partial items surfaced to caller
    skip: int = 0                       # replayed partials to drop (dedup)
    attempts: int = 0                   # QueueFull backoff exponent


class _Replica:
    """One supervised replica (behind a `Transport`) and its health
    bookkeeping."""

    def __init__(self, idx: int, transport: Any):
        self.idx = idx
        self.transport = transport
        self.state = HEALTHY
        self.condition: Optional[str] = None    # why it left HEALTHY
        self.reason: Optional[str] = None
        self.idle_steps = 0                     # consecutive no-progress steps
        self.placed: Dict[int, int] = {}        # local rid -> router rid
        self.sec_per_unit = 1.0                 # EWMA, placement cost prior

    @property
    def core(self) -> Optional[EngineCore]:
        """The in-process engine, when there is one (`InProcTransport`);
        None for subprocess replicas. Tests and in-proc tooling reach
        through this."""
        return getattr(self.transport, "core", None)

    def busy(self) -> bool:
        return self.transport.in_flight() > 0 or self.transport.pending() > 0


class Router:
    """Fault-tolerant front end over N `EngineCore` replicas.

    replicas share one engine clock (deadlines are absolute on it); build
    fleets with `make_router`, which wires the shared clock and optional
    per-replica `FaultPlan`s.

    wedge_patience: consecutive no-progress steps of a busy replica before
                    it is condemned as WEDGED.
    stall_factor:   a step slower than ``stall_factor x`` the fastest
                    observed fleet step is treated as a stall (wall-clock
                    fleets); ``stall_seconds`` is the absolute variant for
                    deterministic clocks, where healthy steps cost 0.
    max_retries:    re-route budget per request; exhausting it retires the
                    request ``status='failed'``.
    max_waiting:    bound on the backoff line; beyond it the lowest-priority
                    waiters are shed ``status='rejected'``.
    tick_s:         seconds the router advances an owned `TickClock` per
                    `step()` (deterministic deadline pacing, like
                    `core.StepClock`); 0 leaves the clock alone.
    obs:            optional `repro.obs.Observability` bundle for
                    *router-level* spans (one per request, submit ->
                    terminal status, on the router's step index) and fleet
                    counters. Per-replica observability lives on the
                    engines/workers themselves (`make_router(obs=True)` /
                    `make_worker_fleet(obs=True)`); `telemetry()` merges
                    both layers into one trace + one metrics snapshot.
    """

    def __init__(self, replicas: Sequence[Any], *,
                 clock: Optional[Callable[[], float]] = None,
                 wedge_patience: int = 3, stall_factor: float = 8.0,
                 stall_seconds: Optional[float] = None,
                 max_retries: int = 2, max_waiting: int = 64,
                 tick_s: float = 0.0, obs: Optional[Observability] = None):
        assert replicas, "router needs at least one replica"
        transports = [r if not isinstance(r, EngineCore) else InProcTransport(r)
                      for r in replicas]
        self.replicas = [_Replica(i, t) for i, t in enumerate(transports)]
        self._clock = clock if clock is not None else transports[0].clock
        self.wedge_patience = max(1, wedge_patience)
        self.stall_factor = stall_factor
        self.stall_seconds = stall_seconds
        self.max_retries = max_retries
        self.max_waiting = max_waiting
        self.tick_s = tick_s
        self._next_id = 0
        self._step_idx = 0
        self._requests: Dict[int, _Tracked] = {}
        self._placement: Dict[int, int] = {}        # router rid -> replica idx
        self._results: Dict[int, Result] = {}
        self._partials: Dict[int, List[Any]] = {}
        self._outstanding: Set[int] = set()
        self._waiting: Dict[int, int] = {}          # router rid -> due step
        self._affinity: Dict[Any, int] = {}         # key -> replica idx
        self._fastest_dt: Optional[float] = None    # learned fleet baseline
        self._counts = collections.Counter()
        self._rerouted = 0
        #: [(router step, replica idx, condition, [router rids re-routed],
        #: detail)] — the supervision audit trail benches mine for recovery
        #: latency. ``detail`` carries the condemned replica's last progress
        #: marker + cost_finite probe and, when the replica was observed,
        #: its flight-recorder postmortem under ``'dump'``.
        self.drain_log: List[tuple] = []
        #: router rid -> router step of its terminal result
        self.completed_at: Dict[int, int] = {}
        self.obs = obs

    # -- request surface -----------------------------------------------------

    def submit(self, payload: Any, *, deadline_s: Optional[float] = None,
               priority: int = 0, affinity: Optional[Any] = None,
               **options: Any) -> int:
        """Admit one request to the fleet; returns its router-scoped id.

        The kwarg surface is `EngineCore.submit`'s exactly (one shared
        `api.SubmitSpec` shape; unknown/ill-typed options raise here) plus
        ``affinity`` — a routing concern, not a request option, so it stays
        a first-class router kwarg.

        Never raises `QueueFull`: overload parks the request in the backoff
        line and, past ``max_waiting``, sheds by priority with
        ``status='rejected'`` (see class docstring)."""
        return self.submit_spec(
            SubmitSpec.make(payload, deadline_s=deadline_s,
                            priority=priority, **options),
            affinity=affinity)

    def submit_spec(self, spec: SubmitSpec, *,
                    affinity: Optional[Any] = None) -> int:
        """Admit one already-validated `api.SubmitSpec` to the fleet."""
        rid = self._next_id
        self._next_id += 1
        now = self._clock()
        self._requests[rid] = _Tracked(
            rid, spec.payload, dict(spec.options), spec.priority,
            None if spec.deadline_s is None else now + spec.deadline_s,
            affinity, self.max_retries)
        self._outstanding.add(rid)
        if self.obs is not None:
            if self.obs.tracer is not None:
                self.obs.tracer.begin(rid, self._step_idx, now,
                                      layer="router", priority=spec.priority)
            if self.obs.metrics is not None:
                self.obs.metrics.counter(
                    "router_submitted", "requests admitted to the fleet").inc()
        self._try_place(rid)
        return rid

    def poll(self, request_id: int) -> Optional[Result]:
        """Return (and retire) the terminal `Result`, or None while the
        request is queued/running. Statuses: ok | cancelled | expired |
        failed | rejected. Unlike `EngineCore.poll`, retrieving a *non-ok*
        result keeps its undrained partials available to `poll_partial` —
        for a failed/expired request the clean partial stream is the only
        output there is ("partials intact")."""
        res = self._results.pop(request_id, None)
        if res is not None and res.status == "ok":
            self._partials.pop(request_id, None)
        return res

    def poll_partial(self, request_id: int) -> List[Any]:
        """Drain partial outputs streamed since the last call. Replayed
        requests never re-deliver items the caller already saw."""
        return self._partials.pop(request_id, [])

    def cancel(self, request_id: int) -> bool:
        """Cancel a waiting or in-flight request fleet-wide."""
        if request_id in self._waiting:
            del self._waiting[request_id]
            self._finish(request_id, Result(request_id, None, {}, "cancelled"))
            return True
        idx = self._placement.get(request_id)
        if idx is None:
            return False
        replica = self.replicas[idx]
        local = next(l for l, r in replica.placed.items() if r == request_id)
        self._drain_partials(replica)
        if not replica.transport.cancel(local):
            return False
        del replica.placed[local]
        res = replica.transport.poll(local)
        self._finish(request_id,
                     res if res is not None
                     else Result(request_id, None, {}, "cancelled"))
        return True

    # -- placement -----------------------------------------------------------

    def _healthy(self) -> List[_Replica]:
        return [r for r in self.replicas if r.state == HEALTHY]

    def _outstanding_units(self, replica: _Replica) -> int:
        units = 0
        for rid in replica.placed.values():
            t = self._requests.get(rid)
            if t is not None:
                units += _est_units(t.payload, t.options)
        return units

    def _pick_replica(self, tracked: _Tracked) -> Optional[_Replica]:
        healthy = self._healthy()
        if not healthy:
            return None
        if tracked.affinity is not None:
            pinned = self._affinity.get(tracked.affinity)
            if pinned is not None and self.replicas[pinned].state == HEALTHY:
                return self.replicas[pinned]
        est = _est_units(tracked.payload, tracked.options)
        best = min(healthy, key=lambda r: (
            (self._outstanding_units(r) + r.transport.pending() + est)
            * r.sec_per_unit, r.idx))
        if tracked.affinity is not None:
            self._affinity[tracked.affinity] = best.idx
        return best

    def _try_place(self, rid: int) -> bool:
        """Place a tracked request on the best healthy replica; on
        `QueueFull` park it in the backoff line. Returns True if placed."""
        tracked = self._requests[rid]
        now = self._clock()
        if tracked.deadline_at is not None and now >= tracked.deadline_at:
            self._waiting.pop(rid, None)
            self._finish(rid, Result(rid, None, {}, "expired"))
            return False
        replica = self._pick_replica(tracked)
        if replica is None:
            # every replica condemned: nothing can ever run this request
            self._waiting.pop(rid, None)
            self._finish(rid, Result(rid, None, {}, "failed"))
            return False
        deadline_s = (None if tracked.deadline_at is None
                      else tracked.deadline_at - now)
        # options were validated at Router.submit; the replay spec skips
        # re-parsing (plain constructor) so a re-route can never be rejected
        spec = SubmitSpec(payload=tracked.payload, deadline_s=deadline_s,
                          priority=tracked.priority, options=tracked.options)
        try:
            local = replica.transport.submit_spec(spec)
        except QueueFull:
            tracked.attempts += 1
            self._waiting[rid] = self._step_idx + 2 ** (tracked.attempts - 1)
            self._shed_overflow()
            return False
        except TransportError as e:
            # the worker died between supervision steps; condemn it now and
            # place the request elsewhere (the replica is no longer healthy,
            # so the recursion is bounded by the fleet size)
            self._condemn(replica, WEDGED, f"transport failed at submit: {e}")
            return self._try_place(rid)
        self._waiting.pop(rid, None)
        replica.placed[local] = rid
        self._placement[rid] = replica.idx
        return True

    def _shed_overflow(self) -> None:
        while len(self._waiting) > self.max_waiting:
            rid = min(self._waiting,
                      key=lambda r: (self._requests[r].priority, -r))
            del self._waiting[rid]
            self._finish(rid, Result(rid, None, {}, "rejected"))

    # -- supervision ---------------------------------------------------------

    def step(self) -> int:
        """Advance the fleet one supervision round; returns requests that
        reached a terminal result this round. Order: retry waiters, step +
        probe every healthy replica, collect partials/results, drain and
        re-route condemned replicas."""
        self._step_idx += 1
        if self.tick_s and hasattr(self._clock, "advance"):
            self._clock.advance(self.tick_s)
        finished_before = sum(self._counts.values())

        for rid, due in sorted(self._waiting.items(),
                               key=lambda kv: (-self._requests[kv[0]].priority,
                                               kv[0])):
            if due <= self._step_idx:
                self._try_place(rid)

        for replica in list(self.replicas):
            if replica.state != HEALTHY:
                continue
            if not replica.busy():
                replica.idle_steps = 0
                continue
            marker0 = replica.transport.progress_marker()
            failed0 = replica.transport.failed_count()
            t0 = self._clock()
            try:
                replica.transport.step()
            except Exception as e:          # mid-step fault: condemn replica
                self._condemn(replica, WEDGED, f"step raised: {e!r}")
                continue
            dt = self._clock() - t0
            self._drain_partials(replica)
            self._collect_results(replica)
            self._learn_cost(replica, marker0, dt)
            if replica.transport.failed_count() > failed0 or (
                    not replica.transport.cost_finite()):
                self._condemn(replica, POISONED,
                              "numerics screen tripped on step outputs")
                continue
            if self._stalled(dt):
                self._condemn(replica, WEDGED,
                              f"step took {dt:.3f}s vs fleet baseline "
                              f"{self._fastest_dt}")
                continue
            if replica.transport.progress_marker() == marker0 and replica.busy():
                replica.idle_steps += 1
                if replica.idle_steps >= self.wedge_patience:
                    self._condemn(replica, WEDGED,
                                  f"no progress for {replica.idle_steps} "
                                  "consecutive steps with work resident")
            else:
                replica.idle_steps = 0
        if self.obs is not None and self.obs.metrics is not None:
            m = self.obs.metrics
            m.counter("router_steps", "fleet supervision rounds").inc()
            m.gauge("router_waiting",
                    "requests parked in the backoff line").set(
                        len(self._waiting))
            m.gauge("router_healthy_replicas",
                    "replicas in HEALTHY state").set(len(self._healthy()))
        return sum(self._counts.values()) - finished_before

    def _learn_cost(self, replica: _Replica, marker0, dt: float) -> None:
        units = replica.transport.progress_marker()[1] - marker0[1]
        if dt > 0:
            self._fastest_dt = dt if self._fastest_dt is None \
                else min(self._fastest_dt, dt)
            if units > 0:
                sample = dt / units
                replica.sec_per_unit = (0.3 * sample
                                        + 0.7 * replica.sec_per_unit)

    def _stalled(self, dt: float) -> bool:
        if self.stall_seconds is not None and dt >= self.stall_seconds:
            return True
        return (self._fastest_dt is not None and dt > 0
                and dt > self.stall_factor * self._fastest_dt
                and self._fastest_dt > 0)

    def _drain_partials(self, replica: _Replica) -> None:
        for local, rid in list(replica.placed.items()):
            items = replica.transport.poll_partial(local)
            if not items:
                continue
            tracked = self._requests.get(rid)
            if tracked is None:
                continue
            fresh: List[Any] = []
            for item in items:
                if tracked.skip > 0:    # replay re-emitted a seen partial
                    tracked.skip -= 1
                    continue
                fresh.append(item)
            if fresh:
                tracked.forwarded += len(fresh)
                self._partials.setdefault(rid, []).extend(fresh)

    def _collect_results(self, replica: _Replica) -> None:
        for local, rid in list(replica.placed.items()):
            res = replica.transport.poll(local)
            if res is None:
                continue
            del replica.placed[local]
            self._finish(rid, res)

    def _condemn(self, replica: _Replica, condition: str, reason: str) -> None:
        """Mark a replica WEDGED/POISONED, salvage what it finished, and
        re-route its in-flight requests by deterministic replay."""
        replica.condition = condition
        replica.reason = reason
        replica.state = condition
        self._drain_partials(replica)
        self._collect_results(replica)      # salvage already-finished work
        rerouted: List[int] = []
        now = self._clock()
        for local, rid in list(replica.placed.items()):
            tracked = self._requests.get(rid)
            # reclaim the slot/queue entry; the inner session is clean, so
            # this cannot disturb anything else on the replica (a dead
            # transport returns False/None here — nothing left to salvage)
            replica.transport.cancel(local)
            self._drain_partials(replica)
            salvage = replica.transport.poll(local)
            del replica.placed[local]
            self._placement.pop(rid, None)
            if tracked is None:
                continue
            if tracked.deadline_at is not None and now >= tracked.deadline_at:
                self._finish(rid, dataclasses.replace(
                    salvage or Result(rid, None, {}), status="expired"))
            elif tracked.retries_left > 0:
                tracked.retries_left -= 1
                tracked.skip = tracked.forwarded    # dedup the replay stream
                rerouted.append(rid)
                self._rerouted += 1
                self._try_place(rid)
            else:
                self._finish(rid, dataclasses.replace(
                    salvage or Result(rid, None, {}), status="failed"))
        replica.state = DRAINED
        # postmortem detail: the supervision probes the parent already holds
        # (heartbeat-cached for workers, direct reads in-process) plus the
        # replica's flight-recorder dump when it was observed
        detail: Dict[str, Any] = {
            "reason": reason,
            "marker": tuple(replica.transport.progress_marker()),
            "cost_finite": replica.transport.cost_finite(),
        }
        dump = None
        core = replica.core
        if core is not None and getattr(core, "obs", None) is not None:
            dump = core.obs.on_dump(condition, self._step_idx,
                                    replica=replica.idx)
        else:
            dump_fn = getattr(replica.transport, "recorder_dump", None)
            if dump_fn is not None:
                dump = dump_fn(condition)
        if dump is not None:
            detail["dump"] = dump
        if self.obs is not None and self.obs.metrics is not None:
            self.obs.metrics.counter(
                "router_drains", "replicas condemned and drained").inc()
            self.obs.metrics.counter(
                "router_rerouted",
                "requests re-routed by deterministic replay").inc(
                    len(rerouted))
        self.drain_log.append((self._step_idx, replica.idx, condition,
                               rerouted, detail))

    def _finish(self, rid: int, result: Result) -> None:
        if result.request_id != rid:
            result = dataclasses.replace(result, request_id=rid)
        self._results[rid] = result
        self._placement.pop(rid, None)
        self._outstanding.discard(rid)
        self._requests.pop(rid, None)
        self._counts[result.status] += 1
        self.completed_at[rid] = self._step_idx
        if self.obs is not None:
            if self.obs.tracer is not None:
                self.obs.tracer.end(rid, result.status, self._step_idx,
                                    self._clock())
            if self.obs.metrics is not None:
                self.obs.metrics.counter(
                    f"router_retired_{result.status}",
                    f"requests retired with status={result.status}").inc()

    # -- drain loop ----------------------------------------------------------

    def run_until_complete(self, *, max_idle_steps: Optional[int] = None
                           ) -> Dict[int, Result]:
        """Step the fleet until every submitted request has a terminal
        result; returns (and retires) all unpolled results. Raises
        `EngineStalled` after ``max_idle_steps`` consecutive rounds with no
        fleet-wide progress (default: the first replica's configured
        guard) — possible only if supervision itself cannot retire the
        stuck work (e.g. the guard is set too tight)."""
        limit = (self.replicas[0].transport.max_idle_steps()
                 if max_idle_steps is None else max_idle_steps)
        idle = 0
        while self._outstanding:
            before = self._fleet_marker()
            self.step()
            idle = 0 if self._fleet_marker() != before else idle + 1
            if limit and idle >= limit:
                raise EngineStalled(
                    f"fleet made no progress for {idle} consecutive router "
                    f"steps (outstanding={sorted(self._outstanding)}, "
                    f"states={[r.state for r in self.replicas]}, "
                    f"waiting={sorted(self._waiting)})")
        out, self._results = self._results, {}
        for rid, res in out.items():
            if res.status == "ok":      # non-ok keeps partials pollable
                self._partials.pop(rid, None)
        return out

    def _fleet_marker(self) -> tuple:
        return (sum(self._counts.values()), len(self._waiting),
                tuple(r.transport.progress_marker() for r in self.replicas),
                tuple(r.state for r in self.replicas))

    # -- introspection -------------------------------------------------------

    def stats(self) -> Dict[str, Any]:
        return {
            "router_steps": self._step_idx,
            "replicas": [{
                "idx": r.idx,
                "state": r.state,
                "condition": r.condition,
                "reason": r.reason,
                "sec_per_unit": r.sec_per_unit,
                "stats": r.transport.stats(),
            } for r in self.replicas],
            "healthy": len(self._healthy()),
            "rerouted": self._rerouted,
            "waiting": len(self._waiting),
            "outstanding": len(self._outstanding),
            "drains": len(self.drain_log),
            **{status: self._counts.get(status, 0)
               for status in ("ok", "cancelled", "expired", "failed",
                              "rejected")},
        }

    def telemetry(self) -> Dict[str, Any]:
        """One merged observability view of the whole fleet: every
        replica's spans namespaced by replica index (plus the router's own
        spans under ``'router'``) via `repro.obs.merge_traces`, per-replica
        metrics folded with `repro.obs.aggregate`, and every
        flight-recorder dump taken anywhere. Works for in-process replicas
        (read off `EngineCore.obs` directly) and subprocess workers (read
        off the heartbeat telemetry their transport accumulated); replicas
        that were never observed simply contribute nothing."""
        parts: List[Tuple[Any, List[Dict[str, Any]]]] = []
        metrics_parts: Dict[Any, Mapping[str, Any]] = {}
        dumps: List[Dict[str, Any]] = []
        if self.obs is not None:
            if self.obs.tracer is not None:
                parts.append(("router", self.obs.tracer.export()))
            if self.obs.metrics is not None:
                metrics_parts["router"] = self.obs.metrics.snapshot()
        for replica in self.replicas:
            core = replica.core
            if core is not None and getattr(core, "obs", None) is not None:
                snap = core.obs.snapshot()
                parts.append((replica.idx, snap.get("trace", [])))
                if "metrics" in snap:
                    metrics_parts[replica.idx] = snap["metrics"]
                dumps.extend(snap.get("dumps", ()))
            elif getattr(replica.transport, "obs", False):
                tel = replica.transport.telemetry()
                parts.append((replica.idx, tel.get("spans", [])))
                if tel.get("metrics"):
                    metrics_parts[replica.idx] = tel["metrics"]
                dumps.extend(tel.get("dumps", ()))
        return {"trace": merge_traces(parts),
                "metrics": aggregate(metrics_parts),
                "dumps": dumps}

    def close(self) -> None:
        """Release every replica's transport (terminates subprocess
        workers; a no-op for in-process fleets)."""
        for replica in self.replicas:
            replica.transport.close()


def make_router(runner: ModelRunner, n: int,
                config: EngineConfig = EngineConfig(), *,
                plans: Optional[Mapping[int, FaultPlan]] = None,
                clock: Optional[Callable[[], float]] = None,
                obs: bool = False, **router_kwargs) -> Router:
    """Build an N-replica fleet over one `ModelRunner`.

    Every replica gets its own `EngineCore` (own queue, slots, sessions)
    over the shared ``runner``, wrapped in a `serve.faults.FaultyRunner` so
    replica behavior differs only by its `FaultPlan` (``plans`` maps
    replica index -> plan; missing indices get the empty, transparent
    plan). All replicas and the router share one clock; when none is
    passed, a deterministic `TickClock` advanced 1 s per router step is
    created — the fleet analogue of `core.StepClock`.

    obs=True attaches one `repro.obs.Observability` bundle per replica and
    one to the router; `Router.telemetry()` then yields the merged fleet
    trace/metrics/dumps. Off by default and bit-identical when on."""
    owned = clock is None
    if owned:
        clock = TickClock()
    plans = dict(plans or {})
    cores = [EngineCore(FaultyRunner(runner, plans.get(i), clock),
                        config, clock=clock,
                        obs=Observability() if obs else None)
             for i in range(n)]
    if owned:
        router_kwargs.setdefault("tick_s", 1.0)
    return Router(cores, clock=clock,
                  obs=Observability() if obs else None, **router_kwargs)


def make_worker_fleet(spec: Any, n: int,
                      config: EngineConfig = EngineConfig(), *,
                      step_timeout_s: float = 120.0, obs: bool = False,
                      **router_kwargs) -> Router:
    """Build an N-worker *subprocess* fleet: one `serve.worker` process per
    replica, each hosting its own `EngineCore` + runner built from the
    wire-encodable ``spec`` (`serve.worker.RunnerSpec`), supervised over
    the versioned wire protocol.

    Workers run on wall clocks (each stamps deadlines on its own
    ``time.monotonic``; the router forwards *remaining* deadline seconds,
    so absolute deadlines survive re-routes). The relative stall-ratio
    probe is disabled by default — a worker's first step jit-compiles, so
    honest wall-clock variance would trip ``stall_factor`` — while the
    heartbeat progress probe, the NaN probe, and dead-pipe detection
    (`TransportError` -> condemn -> replay) carry the supervision load.
    Pass ``stall_seconds`` for an absolute hang bound below the
    transport's own ``step_timeout_s``.

    obs=True asks every worker (via the v2 hello) to observe its engine
    and ship telemetry increments on each heartbeat; `Router.telemetry()`
    merges them — spans from all workers plus the router's own — into one
    cross-process trace.
    """
    from .worker import SubprocessTransport
    transports = [SubprocessTransport(spec, config,
                                      step_timeout_s=step_timeout_s, obs=obs)
                  for _ in range(n)]
    router_kwargs.setdefault("stall_factor", float("inf"))
    return Router(transports,
                  obs=Observability() if obs else None, **router_kwargs)
