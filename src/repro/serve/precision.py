"""Adaptive-precision serving: per-request fp32/int4 selection that closes
the paper's quantization->sparsity loop at serving time.

The paper's core finding — quantization raises spike sparsity by up to 15.2%
with minimal accuracy loss, compounding into a 3.4x energy win — is a
*static* ``quant_bits`` knob everywhere else in this repo, chosen once at
engine construction. This module makes it a per-request control decision:

* `VariantRegistry` holds one `ModelRunner` per precision over the *same*
  raw params (the LM quantizes its weights once at construction; the SNN's
  quantized view constant-folds into its one compiled fused graph per
  precision), with a ``prewarm`` hook that compiles every launch width each
  variant can be asked for — so a precision flip mid-trace never hides an
  XLA compile inside a deadline.
* `PrecisionController` decides each unpinned request's precision from the
  scheduler's EWMA sparsity estimates, SLO slack and an accuracy budget,
  pricing the choice with BOTH the paper's Eq. 3 FPGA model and the
  analytical energy-per-op model (`core.energy.analytical_energy_per_image`)
  so the two cost models can disagree measurably on the same decision.
  Requests carrying ``options['pin_precision']`` are NEVER switched — that
  invariant holds under any controller state, including the pinned fleet
  modes. Predicted-*dense* inputs go int4: they are the requests whose
  sparsity (and therefore energy) quantization improves the most.
* `PrecisionRunner` / `_PrecisionSession` serve both precisions behind one
  `EngineCore`: each precision gets its own full-width sub-session (its own
  KV cache / fused SNN batch), a slot index is owned by exactly one
  precision at a time, and every launch stays single-precision — which is
  why outputs within a precision are bit-identical to a pinned
  single-precision engine (row independence does the rest; the tests sweep
  this property).
* `bind_controller` closes the loop online: the controller predicts with
  `SparsityAwareScheduler.predict` and listens to every observed result's
  realized skip rate *per precision* — the learned
  ``skip_ewma['int4'] - skip_ewma['fp32']`` delta is the
  quantization->sparsity interplay, fed back into the int4 price.

Wiring: ``EngineConfig.precision='fp32'|'int4'|'adaptive'`` (the engine
calls `PrecisionRunner.set_precision`), ``launch/serve.py --precision``,
and ``benchmarks/serve_engine.bench_precision`` for the adaptive-vs-pinned
served-energy comparison.
"""
from __future__ import annotations

import dataclasses
from typing import (Any, Callable, Dict, Hashable, List, Mapping, Optional,
                    Sequence, Tuple)

from .api import (PAD_REQUEST_ID, ModelRunner, Request, Result, StepBudget,
                  StepReport)

PRECISIONS = ("fp32", "int4")

#: pricer signature: (precision, activity in [0, 1]) -> both cost models'
#: energy estimates, e.g. {"eq3_j": 1.2e-5, "analytical_j": 3.4e-7}
Pricer = Callable[[str, float], Dict[str, float]]


# ---------------------------------------------------------------------------
# Variant registry: one runner per precision, pre-warmed launch widths
# ---------------------------------------------------------------------------

class VariantRegistry:
    """Per-precision `ModelRunner` variants of one model.

    Variants are built once (quantized params / quantized-view configs are
    cached on the runners themselves) and must agree on ``session_key`` and
    ``filler`` semantics — they are the same model at different numerics, so
    an engine session can hold both behind one slot array.
    """

    def __init__(self, variants: Mapping[str, ModelRunner], *,
                 default: str = "fp32",
                 warm_fn: Optional[Callable[["VariantRegistry", int], None]] = None):
        assert default in variants, (default, tuple(variants))
        self.variants: Dict[str, ModelRunner] = dict(variants)
        self.default = default
        self._warm_fn = warm_fn
        self._warmed = False

    @property
    def precisions(self) -> Tuple[str, ...]:
        return tuple(self.variants)

    def runner(self, precision: str) -> ModelRunner:
        return self.variants[precision]

    def prewarm(self, slots: int) -> None:
        """Compile every launch width each variant can be asked for, once.

        Bucketed widths are pre-warmed so a controller precision flip never
        hides an XLA compile: after this call, serving either precision at
        any session width the builders anticipated reuses a cached
        executable. Idempotent."""
        if self._warmed:
            return
        if self._warm_fn is not None:
            self._warm_fn(self, slots)
        self._warmed = True


def make_snn_variants(cfg, params, *, interpret: bool = True) -> VariantRegistry:
    """fp32 + int4 spiking-VGG9 variants over one set of raw params.

    The int4 variant's quantized weight view lives inside its jitted fused
    graph (constant-folded at compile time), so both variants share
    ``params`` and differ only in ``cfg.quant_bits``. Prewarm runs one
    full-width fused batch per precision — the single compiled graph each
    variant ever launches at that slot count."""
    from ..models.vgg9 import VGG9Config  # noqa: F401  (type anchor)
    from .runners.snn import SNNRunner

    fp32_cfg = dataclasses.replace(cfg, quant_bits=0)
    int4_cfg = dataclasses.replace(cfg, quant_bits=4)
    variants = {"fp32": SNNRunner(fp32_cfg, params, interpret=interpret),
                "int4": SNNRunner(int4_cfg, params, interpret=interpret)}

    def warm(reg: VariantRegistry, slots: int) -> None:
        import jax.numpy as jnp
        img = jnp.zeros((cfg.img_hw, cfg.img_hw, cfg.in_ch))
        for runner in reg.variants.values():
            sess = runner.open_session(slots)
            sess.admit(0, Request(PAD_REQUEST_ID, img))
            sess.step(StepBudget())

    return VariantRegistry(variants, warm_fn=warm)


def make_lm_variants(cfg, params, *, max_seq: int = 512,
                     prompt_bucket: int = 8, quant_bits: int = 4,
                     warm_chunk_cap: int = 64) -> VariantRegistry:
    """fp32 + quantized LM variants over one set of raw params.

    The quantized variant fake-quants its weight matrices once at
    construction (`runners.lm.quantized_lm_params`) — serving never
    re-quantizes. Prewarm mirrors the SLO driver's warm loop: each variant
    compiles the width-1 launch plus every pow2-bucketed chunk width up to
    ``warm_chunk_cap`` (the widest chunk an `SLOScheduler` budget boost can
    request), so a mid-deadline precision flip finds its kernels hot."""
    from .runners.lm import LMRunner

    name = f"int{quant_bits}"
    variants = {"fp32": LMRunner(cfg, params, max_seq=max_seq,
                                 prompt_bucket=prompt_bucket),
                name: LMRunner(cfg, params, max_seq=max_seq,
                               quant_bits=quant_bits,
                               prompt_bucket=prompt_bucket)}

    def warm(reg: VariantRegistry, slots: int) -> None:
        for runner in reg.variants.values():
            w = 1
            while True:
                plen = min(w + 1, max_seq - 2)
                sess = runner.open_session(slots)
                sess.admit(0, Request(PAD_REQUEST_ID, [1] * plen,
                                      {"max_new_tokens": 1}))
                sess.step(StepBudget(chunk=w))
                if w >= warm_chunk_cap or w >= max_seq:
                    break
                w *= 2

    return VariantRegistry(variants, warm_fn=warm)


# ---------------------------------------------------------------------------
# Pricing: both cost models over a predicted-activity workload estimate
# ---------------------------------------------------------------------------

def _snn_reference_spikes(cfg) -> Dict[str, float]:
    """Upper-bound input spike counts per sparse layer: every input neuron
    firing at every timestep. Scaled by a predicted activity fraction
    (1 - predicted skip rate) these become the workload estimate the
    controller prices a not-yet-served request with."""
    from ..models.vgg9 import conv_names

    t = cfg.timesteps
    size = cfg.img_hw
    names = conv_names(cfg)
    ref: Dict[str, float] = {}
    conv_i = 0
    prev_c = cfg.in_ch
    for s in cfg.stages:
        if s == "MP":
            size //= 2
            continue
        if conv_i > 0:     # conv0 is the dense-coded input layer: no spikes in
            ref[names[conv_i]] = float(t * size * size * prev_c)
        prev_c = s
        conv_i += 1
    n_mp = sum(1 for s in cfg.stages if s == "MP")
    flat = (cfg.img_hw // (2 ** n_mp)) ** 2 * cfg.conv_channels[-1]
    ref["fc0"] = float(t * flat)
    ref["fc1"] = float(t * cfg.fc_dim)
    return ref


def make_snn_pricer(cfg) -> Pricer:
    """Price (precision, activity) with both cost models for a VGG9 config.

    Builds the same Eq. 3 workload/weight geometry `runners.snn.SNNRunner`
    prices measured requests with, but from *estimated* spikes (reference
    counts x predicted activity), so the controller can compare fp32 vs
    int4 before a request has ever run. Returns
    ``{"eq3_j": ..., "analytical_j": ...}`` per call."""
    from ..core.energy import analytical_energy_per_image, energy_per_image
    from ..core.hybrid import plan_vgg9_inference
    from ..core.workload import (conv_workload, dense_input_workload,
                                 fc_workload)
    from ..models.vgg9 import conv_names

    ref = _snn_reference_spikes(cfg)
    cores = plan_vgg9_inference(cfg, 1).cores()
    convs = cfg.conv_channels
    t, hw = cfg.timesteps, cfg.img_hw
    n_mp = sum(1 for s in cfg.stages if s == "MP")
    flat = (hw // (2 ** n_mp)) ** 2 * convs[-1]
    names = conv_names(cfg)

    def price(precision: str, activity: float) -> Dict[str, float]:
        activity = min(1.0, max(0.0, float(activity)))
        wb = 0.5 if precision == "int4" else 4.0
        workloads = [dense_input_workload("conv0", hw, hw, convs[0], t)]
        weight_bytes = [9 * cfg.in_ch * convs[0] * wb]
        cin = convs[0]
        for i, name in enumerate(names[1:], start=1):
            workloads.append(conv_workload(name, convs[i], 9,
                                           ref[name] * activity))
            weight_bytes.append(9 * cin * convs[i] * wb)
            cin = convs[i]
        for name, d_in, d_out in (("fc0", flat, cfg.fc_dim),
                                  ("fc1", cfg.fc_dim, cfg.population)):
            workloads.append(fc_workload(name, d_out, ref[name] * activity))
            weight_bytes.append(d_in * d_out * wb)
        eq3 = energy_per_image(workloads, cores, weight_bytes, precision)
        ana = analytical_energy_per_image(workloads, precision)
        return {"eq3_j": eq3["energy_j"], "analytical_j": ana["energy_j"]}

    return price


# ---------------------------------------------------------------------------
# The controller
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PrecisionDecision:
    """One logged precision choice (``PrecisionController.decisions``)."""
    request_id: int
    precision: str
    reason: str                 # 'pinned' | 'slo_tight' | 'harvest' |
                                # 'budget_exhausted' | 'priced_out' | 'default'
    predicted_skip: float
    prices: Dict[str, Dict[str, float]]   # precision -> {eq3_j, analytical_j}
    models_agree: bool          # did Eq. 3 and analytical rank the choice alike


class PrecisionController:
    """Per-request precision policy: sparsity estimate + SLO slack +
    accuracy budget, priced under two energy models.

    Decision order for `decide` (first hit wins):

    1. ``options['pin_precision']`` — always honored, never switched.
    2. A tight SLO (``deadline_s <= slo_tight_s``) — int4: cheaper under
       both cost models, so the latency-critical request also burns the
       least energy while racing its deadline.
    3. Predicted-dense input (predicted skip < ``dense_threshold``) — int4
       to harvest the extra tile-skips quantization buys, *if* the accuracy
       budget allows (at most ``accuracy_budget`` of unpinned requests may
       be downshifted) and the priced int4 energy actually wins under
       ``price_with``.
    4. Otherwise ``default`` (fp32: already-sparse requests are cheap, so
       the accuracy budget is spent where quantization buys the most).

    Predictions come from ``options['skip_hint']``, then the bound
    predictor (`bind_controller` wires `SparsityAwareScheduler.predict`),
    then ``prior``. The int4 branch's predicted skip additionally includes
    the *learned* interplay delta (`interplay_delta`): realized skip-rate
    EWMAs per precision, fed by the scheduler's observation stream — the
    paper's quantization->sparsity coupling, learned online.

    Decisions are cached by request id and never re-made: a replayed
    request (router re-route) re-resolves to the same precision, which
    keeps replay bit-identical.
    """

    def __init__(self, *, default: str = "fp32",
                 dense_threshold: float = 0.5,
                 slo_tight_s: Optional[float] = None,
                 accuracy_budget: float = 1.0,
                 prior: float = 0.5, alpha: float = 0.3,
                 pricer: Optional[Pricer] = None,
                 price_with: str = "eq3",
                 predictor: Optional[Callable[[Request], float]] = None):
        assert default in PRECISIONS, default
        assert price_with in ("eq3", "analytical"), price_with
        assert 0.0 <= accuracy_budget <= 1.0, accuracy_budget
        self.default = default
        self.dense_threshold = dense_threshold
        self.slo_tight_s = slo_tight_s
        self.accuracy_budget = accuracy_budget
        self.prior = prior
        self.alpha = alpha
        self.pricer = pricer
        self.price_with = price_with
        self.predictor = predictor
        #: realized mean skip-rate EWMA per served precision (the observed
        #: side of the sparsity-quantization interplay)
        self.skip_ewma: Dict[str, float] = {}
        self.decisions: List[PrecisionDecision] = []
        self._decided: Dict[int, PrecisionDecision] = {}
        self._unpinned = 0
        self._downshifted = 0

    # -- prediction & learning ----------------------------------------------

    def predict_skip(self, request: Request) -> float:
        hint = request.options.get("skip_hint") if request.options else None
        if hint is not None:
            return float(hint)
        if self.predictor is not None:
            return float(self.predictor(request))
        return self.prior

    def observe_skip(self, request: Request, result: Result,
                     skip: float) -> None:
        """Realized skip-rate feedback, keyed by the precision the result
        was actually served at (`Result.stats['precision']`). Wired to the
        scheduler's observation stream by `bind_controller`."""
        precision = result.stats.get("precision")
        if precision is None or skip is None:
            return
        old = self.skip_ewma.get(precision)
        self.skip_ewma[precision] = (
            skip if old is None else self.alpha * skip + (1 - self.alpha) * old)

    def interplay_delta(self) -> Optional[float]:
        """Learned extra skip rate int4 delivers over fp32 (the paper's
        headline coupling), or None until both precisions have been
        observed."""
        if "int4" in self.skip_ewma and "fp32" in self.skip_ewma:
            return self.skip_ewma["int4"] - self.skip_ewma["fp32"]
        return None

    # -- pricing -------------------------------------------------------------

    def _price(self, predicted_skip: float) -> Dict[str, Dict[str, float]]:
        if self.pricer is None:
            return {}
        delta = self.interplay_delta() or 0.0
        skip_int4 = min(1.0, predicted_skip + max(0.0, delta))
        return {"fp32": self.pricer("fp32", 1.0 - predicted_skip),
                "int4": self.pricer("int4", 1.0 - skip_int4)}

    @staticmethod
    def _models_agree(prices: Dict[str, Dict[str, float]]) -> bool:
        if not prices:
            return True
        return ((prices["int4"]["eq3_j"] < prices["fp32"]["eq3_j"])
                == (prices["int4"]["analytical_j"]
                    < prices["fp32"]["analytical_j"]))

    # -- decision ------------------------------------------------------------

    def decide(self, request: Request) -> str:
        """Precision for ``request``; cached by request id (idempotent).

        A ``pin_precision`` always wins — even over a stale cached decision
        for the same id (an id reuse or replay must never unpin a request),
        in which case the stale entry is re-decided as pinned."""
        rid = request.request_id
        pin = (request.options or {}).get("pin_precision")
        cached = self._decided.get(rid)
        if cached is not None and (pin is None or cached.precision == pin):
            return cached.precision
        d = self._decide(request)
        if rid >= 0:             # pad fillers are not logged or budgeted
            self._decided[rid] = d
            self.decisions.append(d)
        return d.precision

    def _decide(self, request: Request) -> PrecisionDecision:
        rid = request.request_id
        options = request.options or {}
        pin = options.get("pin_precision")
        pred = self.predict_skip(request)
        if pin is not None:
            assert pin in PRECISIONS, pin
            return PrecisionDecision(rid, pin, "pinned", pred, {}, True)

        prices = self._price(pred)
        agree = self._models_agree(prices)
        if (self.slo_tight_s is not None and request.deadline_s is not None
                and request.deadline_s <= self.slo_tight_s):
            self._count(rid, downshift=True)
            return PrecisionDecision(rid, "int4", "slo_tight", pred, prices,
                                     agree)
        if pred < self.dense_threshold:
            # predicted-dense: the class quantization helps the most
            if not self._budget_allows():
                self._count(rid, downshift=False)
                return PrecisionDecision(rid, self.default,
                                         "budget_exhausted", pred, prices,
                                         agree)
            if prices and (prices["int4"][f"{self.price_with}_j"]
                           >= prices["fp32"][f"{self.price_with}_j"]):
                self._count(rid, downshift=False)
                return PrecisionDecision(rid, self.default, "priced_out",
                                         pred, prices, agree)
            self._count(rid, downshift=True)
            return PrecisionDecision(rid, "int4", "harvest", pred, prices,
                                     agree)
        self._count(rid, downshift=False)
        return PrecisionDecision(rid, self.default, "default", pred, prices,
                                 agree)

    def _budget_allows(self) -> bool:
        return (self._downshifted + 1) <= self.accuracy_budget * (
            self._unpinned + 1)

    def _count(self, rid: int, *, downshift: bool) -> None:
        if rid < 0:
            return
        self._unpinned += 1
        if downshift:
            self._downshifted += 1

    # -- reporting -----------------------------------------------------------

    def summary(self) -> Dict[str, Any]:
        by_reason: Dict[str, int] = {}
        by_precision: Dict[str, int] = {}
        disagreements = 0
        for d in self.decisions:
            by_reason[d.reason] = by_reason.get(d.reason, 0) + 1
            by_precision[d.precision] = by_precision.get(d.precision, 0) + 1
            disagreements += not d.models_agree
        return {
            "decisions": len(self.decisions),
            "by_reason": by_reason,
            "by_precision": by_precision,
            "skip_ewma": dict(self.skip_ewma),
            "interplay_delta": self.interplay_delta(),
            "model_disagreements": disagreements,
            "unpinned": self._unpinned,
            "downshifted": self._downshifted,
        }

    def metrics_into(self, registry) -> None:
        """Publish controller state into a `repro.obs` registry — the pull
        hook `Observability.attach_engine` finds through the engine's
        `PrecisionRunner.controller` and runs at snapshot time."""
        summary = self.summary()
        registry.gauge("precision_decisions",
                       "precision choices made so far").set(
                           summary["decisions"])
        registry.gauge("precision_downshifted",
                       "unpinned requests downshifted to int4").set(
                           self._downshifted)
        registry.gauge("precision_model_disagreements",
                       "decisions where Eq. 3 and the analytical model "
                       "ranked precisions differently").set(
                           summary["model_disagreements"])
        for precision, count in sorted(summary["by_precision"].items()):
            registry.gauge(f"precision_served_{precision}",
                           f"requests decided to {precision}").set(count)
        for reason, count in sorted(summary["by_reason"].items()):
            registry.gauge(f"precision_reason_{reason}",
                           f"decisions made for reason={reason!r}").set(count)
        for precision, ewma in sorted(self.skip_ewma.items()):
            registry.gauge(f"precision_skip_ewma_{precision}",
                           f"realized skip-rate EWMA at {precision}").set(
                               ewma)
        delta = self.interplay_delta()
        if delta is not None:
            registry.gauge("precision_interplay_delta",
                           "learned extra skip rate int4 delivers over "
                           "fp32 (paper SIII coupling)").set(delta)


def bind_controller(scheduler, controller: PrecisionController
                    ) -> PrecisionController:
    """Close the co-design loop between a `SparsityAwareScheduler` and a
    controller: predictions flow scheduler -> controller (`predict`'s
    per-source EWMAs), realized per-precision skip rates flow back
    controller <- scheduler (its ``listeners`` observation stream)."""
    controller.predictor = scheduler.predict
    scheduler.listeners.append(controller.observe_skip)
    return controller


# ---------------------------------------------------------------------------
# The runner: both precisions behind one EngineCore
# ---------------------------------------------------------------------------

class PrecisionRunner:
    """`ModelRunner` serving every registry precision behind one engine.

    mode: ``'adaptive'`` — the controller decides per request; or a pinned
    precision name — every *unpinned* request is served at that precision
    (``options['pin_precision']`` is still honored, so the never-switch
    invariant holds in every mode).

    Bucketing (batch admission) includes the decided precision, so the
    engine only ever forms single-precision batches; the session key does
    NOT, so both precisions co-reside in one continuous-admission session
    (`_PrecisionSession`)."""

    def __init__(self, registry: VariantRegistry,
                 controller: Optional[PrecisionController] = None,
                 mode: str = "adaptive"):
        self.registry = registry
        self.controller = (controller if controller is not None
                           else PrecisionController())
        self.set_precision(mode)

    # -- precision surface (EngineConfig.precision wiring) -------------------

    def set_precision(self, mode: str) -> None:
        assert mode == "adaptive" or mode in self.registry.precisions, mode
        self.mode = mode

    @property
    def precision(self) -> str:
        """Engine-facing label: the pinned precision, or 'adaptive'."""
        return self.mode

    @property
    def reference(self) -> ModelRunner:
        return self.registry.runner(self.registry.default)

    def decide_precision(self, request: Request) -> str:
        if request.is_pad:
            return self.registry.default
        pin = request.options.get("pin_precision") if request.options else None
        if self.mode != "adaptive":
            if pin is not None:
                assert pin in self.registry.precisions, pin
                return pin
            return self.mode
        return self.controller.decide(request)

    # -- ModelRunner protocol ------------------------------------------------

    def bucket_key(self, request: Request) -> Hashable:
        return (self.decide_precision(request),
                self.reference.bucket_key(request))

    def filler(self, request: Request) -> Request:
        return self.reference.filler(request)

    def run(self, batch: Sequence[Request]) -> Sequence[Result]:
        real = [r for r in batch if not r.is_pad]
        if not real:
            return self.reference.run(batch)
        decided = {self.decide_precision(r) for r in real}
        assert len(decided) == 1, (
            f"mixed-precision batch reached run(): {decided} — bucket_key "
            "must keep batches single-precision")
        return self.registry.runner(decided.pop()).run(batch)

    def session_key(self, request: Request) -> Hashable:
        # precision deliberately excluded: both variants co-reside in one
        # live session, each owning its own slots (see _PrecisionSession)
        return self.reference.session_key(request)

    def open_session(self, slots: int) -> "_PrecisionSession":
        return _PrecisionSession(self, slots)


class _PrecisionSession:
    """One engine session spanning every precision variant.

    Holds one full-width sub-session per precision (its own KV cache /
    fused-batch state); a slot index is owned by exactly one precision at a
    time (``owner``), so a precision flip between a slot's occupants can
    never leak the slot or double-release it — `admit`/`cancel`/`step`
    all assert the ownership transfer. Each sub-session only ever sees
    requests of its own precision, so every launch is single-precision and
    outputs are bit-identical to a pinned single-precision engine.
    """

    def __init__(self, runner: PrecisionRunner, slots: int):
        self.runner = runner
        self.slots = slots
        self.sub = {p: runner.registry.runner(p).open_session(slots)
                    for p in runner.registry.precisions}
        self.owner: List[Optional[str]] = [None] * slots

    def admit(self, slot: int, request: Request) -> Optional[Result]:
        assert self.owner[slot] is None, (
            f"slot {slot} already owned by {self.owner[slot]}")
        precision = self.runner.decide_precision(request)
        res = self.sub[precision].admit(slot, request)
        if res is not None:        # degenerate request: done on arrival,
            return res             # the sub-session never occupied the slot
        self.owner[slot] = precision
        return None

    def cancel(self, slot: int) -> Result:
        precision = self.owner[slot]
        assert precision is not None, f"slot {slot} empty"
        self.owner[slot] = None
        return self.sub[precision].cancel(slot)

    def step(self, budget: StepBudget) -> StepReport:
        """Advance each precision's sub-session that holds occupants, and
        merge the reports (slot sets are disjoint by ownership; costs sum —
        co-resident precisions really do launch once each per engine
        step)."""
        finished: Dict[int, Result] = {}
        progress: Dict[int, Any] = {}
        cost: Dict[str, float] = {}
        for precision, sess in self.sub.items():
            if not any(o == precision for o in self.owner):
                continue
            report = sess.step(budget)
            for idx, res in report.finished.items():
                assert self.owner[idx] == precision, (
                    f"slot {idx} finished in {precision} but owned by "
                    f"{self.owner[idx]}")
                self.owner[idx] = None
                assert idx not in finished, f"slot {idx} finished twice"
                finished[idx] = res
            for idx, prog in report.progress.items():
                assert idx not in progress, f"slot {idx} progressed twice"
                progress[idx] = prog
            for k, v in report.cost.items():
                cost[k] = cost.get(k, 0) + v
        return StepReport(finished=finished, progress=progress, cost=cost)
