"""Pluggable batch-composition schedulers for `serve.core.EngineCore`.

The paper's co-design loop runs: quantization raises spike sparsity, the
hybrid dense/sparse hardware turns sparsity into energy savings — but only
if the work actually arriving at the cores *is* sparse. Sparsity-aware
co-design (Aliyev et al., arXiv:2408.14437) asks the software stack to
exploit workload sparsity when scheduling; the Eq. 3 energy model
(`core.energy`) makes the cost of ignoring it concrete: a batch's latency
and energy follow its total spike workload, so one dense request co-batched
with sparse ones drags every slot-mate up to its own cost ("dense stragglers
poisoning sparse batches").

This module is the seam where that policy plugs in. `EngineCore` delegates
every admission decision — which queued requests go into the currently free
slots — to a `Scheduler`:

* `FIFOScheduler`            — arrival order, filtered to the compatible
                               session key. Reproduces the PR-2 run-to-
                               completion batching when used with
                               ``admission='batch'``.
* `SparsityAwareScheduler`   — co-batches requests by observed/predicted
                               tile-skip rate. Every completed `Result`
                               already carries per-request ``skip_rate``
                               stats (that is why they exist); the scheduler
                               folds them into EWMAs keyed by the request's
                               ``source`` option and ranks the queue by
                               distance to the resident batch's predicted
                               sparsity.

Schedulers are deliberately workload-agnostic: they see only `Request`
(payload opaque), the session-compatibility key function, and `Result.stats`.
LM results carry no skip rates, so the sparsity scheduler degrades to FIFO
for them — prediction falls back to the prior for every request and the
ranking sort is stable.
"""
from __future__ import annotations

from typing import Callable, Dict, Hashable, List, Optional, Protocol, Sequence, runtime_checkable

from .api import Request, Result

KeyFn = Callable[[Request], Hashable]


def observed_skip_rate(result: Result) -> Optional[float]:
    """Mean per-layer tile-skip rate of a completed request, or None.

    Reads ``Result.stats['skip_rate']`` — the per-request, served-alone skip
    rates the SNN runner splits out of the folded occupancy maps (fractions
    in [0, 1], one per sparse layer). Results without the field (e.g. LM
    requests) yield None and leave the scheduler's state untouched.
    """
    rates = result.stats.get("skip_rate")
    if rates is None:
        return None
    if isinstance(rates, dict):
        if not rates:
            return None
        vals = list(rates.values())
    else:
        vals = [float(rates)]        # scalar form: 0.0 is a valid observation
    return float(sum(vals)) / len(vals)


@runtime_checkable
class Scheduler(Protocol):
    """Admission policy: picks which queued requests enter free slots.

    Contract (enforced by `EngineCore`):

    * ``select`` returns requests drawn from ``queue`` (at most ``free``),
      all sharing one session key. When ``active_key`` is not None only
      key-matching requests may be returned (they will join live slots of
      that session); when it is None the scheduler chooses the key — and
      MUST return at least one request if the queue is non-empty, so the
      engine can always make progress.
    * ``on_admit`` is called for every selected request when it takes a
      slot; ``observe`` when its `Result` completes. Between the two calls
      the request is "resident" — the sparsity scheduler anchors admission
      on the residents' predicted skip rates.
    """

    def select(self, queue: Sequence[Request], free: int, *,
               key_fn: KeyFn, active_key: Optional[Hashable]) -> List[Request]:
        ...

    def on_admit(self, request: Request) -> None:
        ...

    def observe(self, request: Request, result: Result) -> None:
        ...


class FIFOScheduler:
    """Arrival order, filtered to one session key (the PR-2 policy)."""

    name = "fifo"

    def select(self, queue: Sequence[Request], free: int, *,
               key_fn: KeyFn, active_key: Optional[Hashable]) -> List[Request]:
        if not queue or free <= 0:
            return []
        key = active_key if active_key is not None else key_fn(queue[0])
        return [r for r in queue if key_fn(r) == key][:free]

    def on_admit(self, request: Request) -> None:
        pass

    def observe(self, request: Request, result: Result) -> None:
        pass


class SparsityAwareScheduler:
    """Co-batch requests with similar observed/predicted tile-skip rates.

    Prediction, per request (first hit wins):

    1. ``request.options['skip_hint']`` — caller-supplied estimate in [0, 1];
    2. EWMA of observed skip rates for ``request.options['source']`` (a
       client/stream tag: requests from one source tend to share sparsity);
    3. global EWMA over all observed results;
    4. ``prior`` (no history yet).

    Selection: the seed is the oldest compatible request when the batch is
    empty (no starvation of whoever waited longest); the anchor is the mean
    predicted skip of the resident requests, or the seed's own prediction.
    Remaining slots are filled by predicted-skip distance to the anchor
    (stable sort: FIFO breaks ties, so workloads without skip stats degrade
    to FIFO exactly). Requests passed over more than ``patience`` times jump
    the ranking — an aging escape hatch so dense requests cannot starve
    behind an endless sparse stream.

    ``spread`` (optional) defers requests whose prediction is farther than
    ``spread`` from the anchor even when slots are free — trading occupancy
    for batch purity. Off by default; aging overrides it.
    """

    name = "sparsity"

    def __init__(self, *, alpha: float = 0.3, prior: float = 0.5,
                 patience: int = 16, spread: Optional[float] = None):
        assert 0.0 < alpha <= 1.0, alpha
        self.alpha = alpha
        self.prior = prior
        self.patience = patience
        self.spread = spread
        self._by_source: Dict[Hashable, float] = {}
        self._global: Optional[float] = None
        self._resident: Dict[int, float] = {}   # request_id -> predicted skip
        self._passes: Dict[int, int] = {}       # request_id -> times passed over

    # -- prediction ---------------------------------------------------------

    def predict(self, request: Request) -> float:
        hint = request.options.get("skip_hint")
        if hint is not None:
            return float(hint)
        src = request.options.get("source")
        if src is not None and src in self._by_source:
            return self._by_source[src]
        if self._global is not None:
            return self._global
        return self.prior

    def _ewma(self, old: Optional[float], new: float) -> float:
        return new if old is None else self.alpha * new + (1 - self.alpha) * old

    # -- Scheduler protocol -------------------------------------------------

    def select(self, queue: Sequence[Request], free: int, *,
               key_fn: KeyFn, active_key: Optional[Hashable]) -> List[Request]:
        if not queue or free <= 0:
            return []
        picked: List[Request] = []
        if active_key is None:
            seed = queue[0]                       # oldest request: never starved
            active_key = key_fn(seed)
            picked.append(seed)
            free -= 1
        compatible = [r for r in queue if key_fn(r) == active_key
                      and (not picked or r.request_id != picked[0].request_id)]

        anchor_pool = list(self._resident.values()) or [self.predict(p) for p in picked]
        anchor = sum(anchor_pool) / len(anchor_pool) if anchor_pool else self.prior

        aged = [r for r in compatible
                if self._passes.get(r.request_id, 0) >= self.patience]
        fresh = [r for r in compatible
                 if self._passes.get(r.request_id, 0) < self.patience]
        fresh.sort(key=lambda r: abs(self.predict(r) - anchor))  # stable: FIFO ties
        if self.spread is not None:
            fresh = [r for r in fresh if abs(self.predict(r) - anchor) <= self.spread]
        ranked = aged + fresh

        picked.extend(ranked[:free])
        chosen = {r.request_id for r in picked}
        for r in compatible:
            if r.request_id not in chosen:
                self._passes[r.request_id] = self._passes.get(r.request_id, 0) + 1
        return picked

    def on_admit(self, request: Request) -> None:
        self._resident[request.request_id] = self.predict(request)
        self._passes.pop(request.request_id, None)

    def observe(self, request: Request, result: Result) -> None:
        self._resident.pop(request.request_id, None)
        skip = observed_skip_rate(result)
        if skip is None:
            return
        self._global = self._ewma(self._global, skip)
        src = request.options.get("source")
        if src is not None:
            self._by_source[src] = self._ewma(self._by_source.get(src), skip)


SCHEDULERS = {
    "fifo": FIFOScheduler,
    "sparsity": SparsityAwareScheduler,
}


def make_scheduler(name: str, **kwargs) -> Scheduler:
    """Build a scheduler by `EngineConfig.scheduler` name ('fifo'|'sparsity')."""
    try:
        cls = SCHEDULERS[name]
    except KeyError:
        raise ValueError(
            f"unknown scheduler {name!r}; choose from {sorted(SCHEDULERS)}")
    return cls(**kwargs)
