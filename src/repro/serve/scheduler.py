"""Pluggable batch-composition schedulers for `serve.core.EngineCore`.

The paper's co-design loop runs: quantization raises spike sparsity, the
hybrid dense/sparse hardware turns sparsity into energy savings — but only
if the work actually arriving at the cores *is* sparse. Sparsity-aware
co-design (Aliyev et al., arXiv:2408.14437) asks the software stack to
exploit workload sparsity when scheduling; the Eq. 3 energy model
(`core.energy`) makes the cost of ignoring it concrete: a batch's latency
and energy follow its total spike workload, so one dense request co-batched
with sparse ones drags every slot-mate up to its own cost ("dense stragglers
poisoning sparse batches").

This module is the seam where that policy plugs in. `EngineCore` delegates
every admission decision — which queued requests go into the currently free
slots — to a `Scheduler`:

* `FIFOScheduler`            — arrival order, filtered to the compatible
                               session key. Reproduces the PR-2 run-to-
                               completion batching when used with
                               ``admission='batch'``.
* `SparsityAwareScheduler`   — co-batches requests by observed/predicted
                               tile-skip rate. Every completed `Result`
                               already carries per-request ``skip_rate``
                               stats (that is why they exist); the scheduler
                               folds them into EWMAs keyed by the request's
                               ``source`` option and ranks the queue by
                               distance to the resident batch's predicted
                               sparsity.
* `SLOScheduler`             — deadline/priority admission plus per-step
                               budget splitting, layered *over* an inner
                               scheduler ('slo:sparsity' composes with the
                               sparsity policy rather than replacing it).
                               Learns the engine's measured cost per work
                               unit from `StepReport`s, admits deadlined
                               requests first (by priority class, then
                               tightest deadline), boosts the prefill chunk
                               of slots racing a deadline, and evicts
                               residents that cannot make their deadline
                               even under an optimistic estimate.

Schedulers are deliberately workload-agnostic: they see only `Request`
(payload opaque), the session-compatibility key function, and `Result.stats`
/ `StepReport` costs. LM results carry no skip rates, so the sparsity
scheduler degrades to FIFO for them — prediction falls back to the prior for
every request and the ranking sort is stable.

Beyond the required `Scheduler` protocol, `EngineCore` probes three
*optional* hooks with ``getattr`` (so FIFO/sparsity need not implement
them):

* ``on_clock(now)``                                 — the engine clock at
  the start of every step, before ``select`` (whose protocol signature
  carries no clock);
* ``plan_step(residents, progress, now, default)`` -> `StepBudget` — set
  this step's work budget and its per-slot split;
* ``on_report(report, seconds, now)``               — observe each step's
  `StepReport` and measured wall seconds (cost-model learning);
* ``expire(residents, progress, now)`` -> [request_id] — residents to evict
  early because they can no longer meet their deadline.
"""
from __future__ import annotations

import math
from typing import (Callable, Dict, Hashable, List, Mapping, Optional,
                    Protocol, Sequence, runtime_checkable)

from .api import Request, Result, SlotProgress, StepBudget, StepReport

KeyFn = Callable[[Request], Hashable]


def observed_skip_rate(result: Result) -> Optional[float]:
    """Mean per-layer tile-skip rate of a completed request, or None.

    Reads ``Result.stats['skip_rate']`` — the per-request, served-alone skip
    rates the SNN runner splits out of the folded occupancy maps (fractions
    in [0, 1], one per sparse layer). Results without the field (e.g. LM
    requests) yield None and leave the scheduler's state untouched.
    """
    rates = result.stats.get("skip_rate")
    if rates is None:
        return None
    if isinstance(rates, dict):
        if not rates:
            return None
        vals = list(rates.values())
    else:
        vals = [float(rates)]        # scalar form: 0.0 is a valid observation
    return float(sum(vals)) / len(vals)


@runtime_checkable
class Scheduler(Protocol):
    """Admission policy: picks which queued requests enter free slots.

    Contract (enforced by `EngineCore`):

    * ``select`` returns requests drawn from ``queue`` (at most ``free``),
      all sharing one session key. When ``active_key`` is not None only
      key-matching requests may be returned (they will join live slots of
      that session); when it is None the scheduler chooses the key — and
      MUST return at least one request if the queue is non-empty, so the
      engine can always make progress.
    * ``on_admit`` is called for every selected request when it takes a
      slot; ``observe`` when its `Result` is produced — normal completion,
      cancellation, expiry, and also for requests retired straight from
      the queue (which never saw ``on_admit``), so schedulers can drop any
      queue-side state they hold. Between ``on_admit`` and ``observe`` the
      request is "resident" — the sparsity scheduler anchors admission on
      the residents' predicted skip rates.
    """

    def select(self, queue: Sequence[Request], free: int, *,
               key_fn: KeyFn, active_key: Optional[Hashable]) -> List[Request]:
        ...

    def on_admit(self, request: Request) -> None:
        ...

    def observe(self, request: Request, result: Result) -> None:
        ...


class FIFOScheduler:
    """Arrival order, filtered to one session key (the PR-2 policy)."""

    name = "fifo"

    def select(self, queue: Sequence[Request], free: int, *,
               key_fn: KeyFn, active_key: Optional[Hashable]) -> List[Request]:
        if not queue or free <= 0:
            return []
        key = active_key if active_key is not None else key_fn(queue[0])
        return [r for r in queue if key_fn(r) == key][:free]

    def on_admit(self, request: Request) -> None:
        pass

    def observe(self, request: Request, result: Result) -> None:
        pass


class SparsityAwareScheduler:
    """Co-batch requests with similar observed/predicted tile-skip rates.

    Prediction, per request (first hit wins):

    1. ``request.options['skip_hint']`` — caller-supplied estimate in [0, 1];
    2. EWMA of observed skip rates for ``request.options['source']`` (a
       client/stream tag: requests from one source tend to share sparsity);
    3. global EWMA over all observed results;
    4. ``prior`` (no history yet).

    Selection: the seed is the oldest compatible request when the batch is
    empty (no starvation of whoever waited longest); the anchor is the mean
    predicted skip of the resident requests, or the seed's own prediction.
    Remaining slots are filled by predicted-skip distance to the anchor
    (stable sort: FIFO breaks ties, so workloads without skip stats degrade
    to FIFO exactly). Requests passed over more than ``patience`` times jump
    the ranking — an aging escape hatch so dense requests cannot starve
    behind an endless sparse stream.

    ``spread`` (optional) defers requests whose prediction is farther than
    ``spread`` from the anchor even when slots are free — trading occupancy
    for batch purity. Off by default; aging overrides it.
    """

    name = "sparsity"

    def __init__(self, *, alpha: float = 0.3, prior: float = 0.5,
                 patience: int = 16, spread: Optional[float] = None):
        assert 0.0 < alpha <= 1.0, alpha
        self.alpha = alpha
        self.prior = prior
        self.patience = patience
        self.spread = spread
        self._by_source: Dict[Hashable, float] = {}
        self._global: Optional[float] = None
        self._resident: Dict[int, float] = {}   # request_id -> predicted skip
        self._passes: Dict[int, int] = {}       # request_id -> times passed over
        # skip-rate observation fan-out: callables (request, result, skip)
        # invoked for every result that carried a skip rate. The serving-time
        # precision controller (`serve.precision.bind_controller`) attaches
        # here to learn realized skip-rate deltas *per precision* — the
        # scheduler is the one place every completed Result already flows
        # through, so the quantization->sparsity feedback rides the same
        # channel the EWMAs do.
        self.listeners: List[Callable[[Request, Result, float], None]] = []

    # -- prediction ---------------------------------------------------------

    def predict(self, request: Request) -> float:
        hint = request.options.get("skip_hint")
        if hint is not None:
            return float(hint)
        src = request.options.get("source")
        if src is not None and src in self._by_source:
            return self._by_source[src]
        if self._global is not None:
            return self._global
        return self.prior

    def _ewma(self, old: Optional[float], new: float) -> float:
        return new if old is None else self.alpha * new + (1 - self.alpha) * old

    # -- Scheduler protocol -------------------------------------------------

    def select(self, queue: Sequence[Request], free: int, *,
               key_fn: KeyFn, active_key: Optional[Hashable]) -> List[Request]:
        if not queue or free <= 0:
            return []
        picked: List[Request] = []
        if active_key is None:
            seed = queue[0]                       # oldest request: never starved
            active_key = key_fn(seed)
            picked.append(seed)
            free -= 1
        compatible = [r for r in queue if key_fn(r) == active_key
                      and (not picked or r.request_id != picked[0].request_id)]

        anchor_pool = list(self._resident.values()) or [self.predict(p) for p in picked]
        anchor = sum(anchor_pool) / len(anchor_pool) if anchor_pool else self.prior

        aged = [r for r in compatible
                if self._passes.get(r.request_id, 0) >= self.patience]
        fresh = [r for r in compatible
                 if self._passes.get(r.request_id, 0) < self.patience]
        fresh.sort(key=lambda r: abs(self.predict(r) - anchor))  # stable: FIFO ties
        if self.spread is not None:
            fresh = [r for r in fresh if abs(self.predict(r) - anchor) <= self.spread]
        ranked = aged + fresh

        picked.extend(ranked[:free])
        chosen = {r.request_id for r in picked}
        for r in compatible:
            if r.request_id not in chosen:
                self._passes[r.request_id] = self._passes.get(r.request_id, 0) + 1
        return picked

    def on_admit(self, request: Request) -> None:
        self._resident[request.request_id] = self.predict(request)
        self._passes.pop(request.request_id, None)

    def observe(self, request: Request, result: Result) -> None:
        self._resident.pop(request.request_id, None)
        # a request can be retired straight from the queue (cancel/expiry)
        # without ever being admitted: drop its pass-over counter too
        self._passes.pop(request.request_id, None)
        skip = observed_skip_rate(result)
        if skip is None:
            return
        self._global = self._ewma(self._global, skip)
        src = request.options.get("source")
        if src is not None:
            self._by_source[src] = self._ewma(self._by_source.get(src), skip)
        for listener in self.listeners:
            listener(request, result, skip)

    def metrics_into(self, registry) -> None:
        """Publish learned skip-rate state into a `repro.obs` registry —
        the pull hook `Observability.attach_engine` registers as a
        snapshot-time collector (never called on the hot path)."""
        registry.gauge(
            "scheduler_skip_ewma_global",
            "global EWMA of observed tile-skip rates").set(
                self._global if self._global is not None else self.prior)
        registry.gauge(
            "scheduler_resident_requests",
            "requests the scheduler currently tracks as resident").set(
                len(self._resident))
        for src, ewma in sorted(self._by_source.items()):
            registry.gauge(
                f"scheduler_skip_ewma_source_{src}",
                f"per-source skip-rate EWMA (source={src!r})").set(ewma)


class SLOScheduler:
    """Deadline/priority admission + per-step budget split over an inner policy.

    Composes with, rather than replaces, the batch-composition schedulers:
    requests carrying a ``deadline_s`` are admitted ahead of the rest,
    ordered by priority class first (strict: a higher ``priority`` beats
    any deadline below it), tightest deadline within a class; everything
    else is delegated to the ``inner`` scheduler ('slo:sparsity' keeps the
    sparsity co-batching for the non-deadlined stream).

    The cost model is learned, not configured: every `StepReport` the engine
    forwards through ``on_report`` updates two *fastest observed* figures —
    seconds per engine step, and seconds per *work unit* (LM token / SNN
    timestep) keyed by workload kind. Deadline estimates prefer the per-unit
    model: the step model prices every step at the fastest observed step
    (usually a wide prefill chunk), so mixed chunk widths misprice decode-
    heavy requests; seconds-per-unit is invariant to chunking. A minimum
    (not a mean) keeps every estimate built on it a lower bound on real
    service — required for the never-evict-the-feasible guarantee below —
    and makes the model immune to wall-clock outliers like the XLA compile
    on a step's first launch width. On top of it:

    * ``plan_step`` sets the step's `StepBudget` split — a prefilling
      resident racing its deadline gets its chunk boosted to
      ``ceil(prefill_remaining / slack_steps)`` (capped at ``boost_cap``),
      so a long prompt finishes prefill inside its SLO instead of at the
      engine-wide default pace;
    * ``expire`` evicts residents that cannot meet their deadline even
      under an *optimistic* estimate (prefill at ``boost_cap`` per step,
      one step per remaining decode token) — the estimate is a lower bound
      on real service, so a request that could still finish is never
      evicted;
    * ``select`` defers queued deadlined requests that are already hopeless
      by the same estimate (they expire in the queue instead of wasting a
      slot), falling back to admitting the head when the engine would
      otherwise sit idle.

    Deadlines are in engine-clock seconds (`EngineCore`'s injectable clock;
    wall time by default, steps in the deterministic benchmarks/tests).
    """

    #: default ceiling on the per-slot prefill chunk this scheduler will
    #: grant; drivers that pre-compile launch widths key off it
    DEFAULT_BOOST_CAP = 64

    def __init__(self, inner: Optional[Scheduler] = None, *,
                 boost_cap: int = DEFAULT_BOOST_CAP):
        self.inner: Scheduler = inner if inner is not None else FIFOScheduler()
        self.name = "slo" if inner is None else f"slo:{self.inner.name}"
        self.boost_cap = max(1, boost_cap)
        # fastest observed step: the optimistic (lower-bound) cost model
        self._sec_per_step: Optional[float] = None
        # fastest observed seconds per *work unit* (LM token / SNN timestep),
        # keyed by workload kind — see `_estimate_seconds` for why the step
        # model alone misprices mixed chunk widths
        self._sec_per_unit: Dict[str, float] = {}
        # most decode tokens one LM slot has emitted in one step: 1 under
        # plain decode, up to speculate_k+1 when speculative verification
        # accepts a draft. The step lower bound divides by it — an
        # optimistic model must assume every future step speculates as well
        # as the best step observed, or it over-prices decode phases and
        # evicts requests speculation would have finished in time.
        self._max_decode_per_slot_step = 1
        self._now = 0.0

    def on_clock(self, now: float) -> None:
        """Engine clock at the start of each step — keeps the hopeless-
        deferral check in ``select`` (fixed protocol signature, no clock
        argument) evaluating deadlines against the current time rather
        than a timestamp from before an idle gap."""
        self._now = now

    # -- cost model ---------------------------------------------------------

    def _optimistic_steps(self, prefill_rem: int, decode_rem: int) -> float:
        """Lower bound on remaining engine steps: prefill at the maximum
        chunk this scheduler would ever grant, decode at the best
        emitted-tokens-per-slot-step observed so far (1 until a
        speculative step demonstrates more — see ``on_report``) — minus
        one when both phases remain, because the step that consumes the
        last prompt token also emits the first decode token."""
        steps = (math.ceil(prefill_rem / self.boost_cap)
                 + math.ceil(decode_rem / self._max_decode_per_slot_step))
        if prefill_rem > 0 and decode_rem > 0:
            steps -= 1
        return steps

    def _service_units(self, request: Request) -> "tuple[int, int]":
        """(prefill, decode) units a queued request will need. Workload
        heuristic: a token-sequence payload (LM) prefills its length; the
        decode budget is the ``max_new_tokens`` option. Anything else
        (e.g. an SNN image array, which completes in one fused step)
        estimates 0 — the estimate must stay a *lower bound* on real
        service, so an unknown payload shape never defers/evicts a request
        that could still finish."""
        payload = request.payload
        prefill = len(payload) if isinstance(payload, (list, tuple)) else 0
        return prefill, int(request.options.get("max_new_tokens", 0))

    @staticmethod
    def _request_kind(request: Request) -> str:
        """Workload kind for the per-unit cost model. Mirrors the
        `_service_units` heuristic: a token-sequence payload is LM work
        (units = tokens), anything else is treated as SNN work (units =
        timesteps)."""
        return "lm" if isinstance(request.payload, (list, tuple)) else "snn"

    @staticmethod
    def _report_kind(cost: Mapping) -> Optional[str]:
        """Workload kind of a `StepReport.cost` dict, by the fields the
        runners actually emit: LM steps break units down into prompt/decode
        tokens, SNN steps report timesteps."""
        if "prompt_tokens" in cost or "decode_tokens" in cost:
            return "lm"
        if "timesteps" in cost:
            return "snn"
        return None

    def _optimistic_units(self, prefill_rem: int, decode_rem: int) -> int:
        """Lower bound on remaining *work units* (tokens): every prompt
        token plus every decode token, minus one when both phases remain —
        the forward pass that consumes the last prompt token also emits the
        first decode token."""
        units = prefill_rem + decode_rem
        if prefill_rem > 0 and decode_rem > 0:
            units -= 1
        return units

    def _estimate_seconds(self, prefill_rem: int, decode_rem: int,
                          kind: str) -> Optional[float]:
        """Optimistic (lower-bound) seconds of remaining service.

        Prefers the per-unit model when it has been learned for ``kind``:
        the step model prices every step at the fastest *observed* step —
        usually a wide prefill chunk — so a decode phase of N one-token
        steps is under-priced by up to the chunk width, while conversely a
        request whose remaining work is mostly prefill is over-priced when
        the fastest step was a narrow decode. Seconds-per-unit is invariant
        to how the engine chunks the work, so mixed chunk widths no longer
        misprice deadlines. Falls back to the step model until a costed
        report for ``kind`` arrives; None when nothing is learned yet.
        """
        spu = self._sec_per_unit.get(kind)
        if spu is not None:
            return self._optimistic_units(prefill_rem, decode_rem) * spu
        if self._sec_per_step is not None:
            return (self._optimistic_steps(prefill_rem, decode_rem)
                    * self._sec_per_step)
        return None

    def _hopeless(self, request: Request, now: float) -> bool:
        if request.deadline_at is None:
            return False
        prefill, decode = self._service_units(request)
        est = self._estimate_seconds(prefill, decode,
                                     self._request_kind(request))
        if est is None:
            return False
        return now + est > request.deadline_at

    # -- Scheduler protocol -------------------------------------------------

    def select(self, queue: Sequence[Request], free: int, *,
               key_fn: KeyFn, active_key: Optional[Hashable]) -> List[Request]:
        if not queue or free <= 0:
            return []
        deadlined = sorted(
            (r for r in queue if r.deadline_s is not None),
            key=lambda r: (-r.priority, r.deadline_at, r.arrival_s))
        key = active_key
        if key is None and deadlined:
            key = key_fn(deadlined[0])
        if key is None:                       # no deadlines anywhere: pure inner
            return self.inner.select(queue, free, key_fn=key_fn,
                                     active_key=None)
        urgent = [r for r in deadlined if key_fn(r) == key]
        picks = [r for r in urgent
                 if not self._hopeless(r, self._now)][:free]
        if len(picks) < free:
            rest = [r for r in queue if r.deadline_s is None]
            picks = picks + self.inner.select(
                rest, free - len(picks), key_fn=key_fn, active_key=key)
        if not picks and active_key is None:
            # contract: an idle engine with a non-empty queue must make
            # progress — admit the head even if it is predicted to miss
            # (the engine will expire it with a partial result)
            picks = [r for r in queue if key_fn(r) == key][:1]
        return picks

    def on_admit(self, request: Request) -> None:
        self.inner.on_admit(request)

    def observe(self, request: Request, result: Result) -> None:
        self.inner.observe(request, result)

    # -- optional EngineCore hooks ------------------------------------------

    def plan_step(self, residents: Mapping[int, Request],
                  progress: Mapping[int, SlotProgress], *,
                  now: float, default: StepBudget) -> StepBudget:
        self._now = now
        if self._sec_per_step is None:
            return default
        per = dict(default.per_slot or {})
        for slot, req in residents.items():
            prog = progress.get(slot)
            if req.deadline_at is None or prog is None or prog.phase != "prefill":
                continue
            decode = int(req.options.get("max_new_tokens", 0))
            prefill_rem = max(0, prog.units_total - decode - prog.units_done)
            slack_steps = (req.deadline_at - now) / self._sec_per_step - decode
            if slack_steps <= 0:
                chunk = self.boost_cap      # racing an already-tight deadline
            else:
                chunk = math.ceil(prefill_rem / max(1.0, slack_steps))
            if chunk > default.for_slot(slot):
                per[slot] = min(self.boost_cap, chunk)
        if per == (default.per_slot or {}):
            return default
        return StepBudget(units=default.units, chunk=default.chunk,
                          per_slot=per)

    def on_report(self, report: StepReport, *, seconds: float,
                  now: float) -> None:
        self._now = now
        if seconds <= 0:
            return
        old = self._sec_per_step
        self._sec_per_step = seconds if old is None else min(old, seconds)
        units = int(report.cost.get("units", 0) or 0)
        kind = self._report_kind(report.cost)
        if units > 0 and kind is not None:
            spu = seconds / units
            prev = self._sec_per_unit.get(kind)
            self._sec_per_unit[kind] = spu if prev is None else min(prev, spu)
        if kind == "lm":
            # the per-unit model stays a lower bound under speculation
            # (every emitted token costs >= 1 forward unit); the *step*
            # model must additionally learn that one step can emit several
            # tokens per slot, or it over-prices pure-decode tails
            for prog in report.progress.values():
                emitted = len(prog.emitted)
                if emitted > self._max_decode_per_slot_step:
                    self._max_decode_per_slot_step = emitted

    def expire(self, residents: Mapping[int, Request],
               progress: Mapping[int, SlotProgress], *,
               now: float) -> List[int]:
        self._now = now
        out: List[int] = []
        for slot, req in residents.items():
            prog = progress.get(slot)
            if req.deadline_at is None or prog is None:
                continue
            decode = int(req.options.get("max_new_tokens", 0))
            if prog.phase == "prefill":
                prefill_rem = max(0, prog.units_total - decode - prog.units_done)
                decode_rem = decode
            else:
                prefill_rem = 0
                decode_rem = max(0, prog.units_total - prog.units_done)
            est = self._estimate_seconds(prefill_rem, decode_rem,
                                         self._request_kind(req))
            if est is not None and now + est > req.deadline_at:
                out.append(req.request_id)
        return out

    def metrics_into(self, registry) -> None:
        """Publish the learned cost model into a `repro.obs` registry
        (snapshot-time pull hook; see `SparsityAwareScheduler.metrics_into`).
        Unlearned figures read 0. Delegates to the inner policy too, so
        'slo:sparsity' publishes both layers."""
        registry.gauge(
            "scheduler_sec_per_step",
            "fastest observed engine-clock seconds per step").set(
                self._sec_per_step or 0.0)
        for kind in ("lm", "snn"):
            registry.gauge(
                f"scheduler_sec_per_unit_{kind}",
                f"fastest observed seconds per {kind} work unit").set(
                    self._sec_per_unit.get(kind, 0.0))
        registry.gauge(
            "scheduler_max_decode_per_slot_step",
            "most decode tokens one slot emitted in one step").set(
                self._max_decode_per_slot_step)
        inner_publish = getattr(self.inner, "metrics_into", None)
        if inner_publish is not None:
            inner_publish(registry)


SCHEDULERS = {
    "fifo": FIFOScheduler,
    "sparsity": SparsityAwareScheduler,
    "slo": SLOScheduler,
}


def make_scheduler(name: str, **kwargs) -> Scheduler:
    """Build a scheduler by `EngineConfig.scheduler` name.

    'fifo' | 'sparsity' | 'slo' — and the composed form 'slo:<inner>'
    (e.g. 'slo:sparsity'), which wraps the inner policy in an
    `SLOScheduler`; kwargs go to the outer scheduler in that case.
    """
    if name.startswith("slo:"):
        inner = make_scheduler(name.split(":", 1)[1])
        return SLOScheduler(inner, **kwargs)
    try:
        cls = SCHEDULERS[name]
    except KeyError:
        raise ValueError(
            f"unknown scheduler {name!r}; choose from "
            f"{sorted(SCHEDULERS) + ['slo:<inner>']}")
    return cls(**kwargs)
