"""Deterministic fault injection for the serving stack.

The ROADMAP's fleet-serving north star stands or falls on failure handling:
a wedged session, a NaN-poisoned kernel output, or a queue flood must not
take the engine down ("Reconsidering the energy efficiency of SNNs" makes
the broader point — claimed wins must hold under realistic operating
conditions, not just clean benchmark runs). This module is the harness that
*creates* those conditions on a reproducible schedule, so the chaos tests
in `tests/test_serve_faults.py` / `tests/test_serve_router.py` and the
`bench_faults` benchmark are deterministic:

* `Fault` / `FaultPlan` — a declarative schedule of faults keyed to the
  wrapped session's *own step index* (not wall time), parseable from a
  compact CLI spec (``"wedge@3;nan@5:slot=0"``).
* `FaultyRunner` / `FaultySession` — a `ModelRunner` wrapper that delegates
  everything to the inner runner but applies the plan's active faults at
  each ``step()``: wedge (no progress, inner untouched), slow (advance an
  injectable clock before stepping), raise (a mid-step `FaultError`), nan
  (poison the *reported* outputs — the inner session state stays clean, so
  a cancel still yields clean partials).
* `TickClock` — a manually advanced clock; pair it with the ``slow`` fault
  so latency faults are visible to supervision without real sleeps.
* `flood_queue` — drive-side helper for the ``flood`` fault kind: slams
  requests into an engine/router until a target backlog is reached.

Faults corrupt only what crosses the reporting seam. Replaying the same
frozen `Request` on a healthy replica therefore reproduces the fault-free
outputs bit-identically — the property `serve.router.Router` relies on for
re-routing (and that the chaos tests assert).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Hashable, List, Optional, Sequence, Tuple

import numpy as np

from .api import (ModelRunner, QueueFull, Request, Result, RunnerSession,
                  StepBudget, StepReport)

KINDS = ("wedge", "slow", "raise", "nan", "flood")


class FaultError(RuntimeError):
    """Raised by a `FaultySession.step` executing a ``raise`` fault."""


@dataclasses.dataclass(frozen=True)
class Fault:
    """One scheduled fault.

    kind:    'wedge' — step makes no progress (inner session not advanced);
             'slow'  — advance the injected clock by ``seconds`` before the
                       (otherwise normal) inner step;
             'raise' — raise `FaultError(message)` mid-step;
             'nan'   — poison the reported outputs of ``slot`` (all slots
                       when None) with NaN after a normal inner step;
             'flood' — no-op at the session seam; drivers query it via
                       `FaultPlan.active` and call `flood_queue`.
    start/stop: half-open step-index window [start, stop) in which the
             fault is active; ``stop=None`` means "from start onward".
    """
    kind: str
    start: int
    stop: Optional[int] = None
    slot: Optional[int] = None
    seconds: float = 1.0
    message: str = "injected fault"

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"choose from {KINDS}")

    def active_at(self, step: int) -> bool:
        return step >= self.start and (self.stop is None or step < self.stop)


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """An immutable schedule of `Fault`s, queried by step index."""

    faults: Tuple[Fault, ...] = ()

    def active(self, kind: str, step: int) -> Optional[Fault]:
        """First fault of ``kind`` active at ``step``, or None."""
        for f in self.faults:
            if f.kind == kind and f.active_at(step):
                return f
        return None

    @classmethod
    def parse(cls, spec: str) -> "FaultPlan":
        """Parse a compact plan spec.

        Grammar: ``fault(;fault)*`` where each fault is
        ``kind@start[-stop][:key=val(,key=val)*]`` — e.g.

            "wedge@3"                  wedge every step from 3 on
            "nan@5:slot=0"             NaN-poison slot 0 from step 5 on
            "slow@2-4:seconds=3.5"     steps 2 and 3 run 3.5 clock-s slow
            "wedge@3;nan@5:slot=1"     both
        """
        faults: List[Fault] = []
        for part in filter(None, (p.strip() for p in spec.split(";"))):
            head, _, opts = part.partition(":")
            kind, at, window = head.partition("@")
            if not at:
                raise ValueError(f"fault {part!r}: expected kind@start[-stop]")
            start_s, _, stop_s = window.partition("-")
            kwargs: Dict[str, Any] = {
                "kind": kind.strip(),
                "start": int(start_s),
                "stop": int(stop_s) if stop_s else None,
            }
            for kv in filter(None, (o.strip() for o in opts.split(","))):
                key, eq, val = kv.partition("=")
                if not eq:
                    raise ValueError(f"fault {part!r}: bad option {kv!r}")
                if key == "slot":
                    kwargs["slot"] = int(val)
                elif key == "seconds":
                    kwargs["seconds"] = float(val)
                elif key == "message":
                    kwargs["message"] = val
                else:
                    raise ValueError(f"fault {part!r}: unknown option {key!r}")
            faults.append(Fault(**kwargs))
        return cls(tuple(faults))


def parse_fleet_plan(spec: str) -> Dict[int, FaultPlan]:
    """Parse a per-replica plan spec: ``"1=wedge@3,2=nan@5:slot=0"``
    (replica index ``=`` plan; plans themselves use ``;`` separators, so
    ``,`` splits replicas). Used by ``launch/serve.py --fault-plan``."""
    plans: Dict[int, FaultPlan] = {}
    for part in filter(None, (p.strip() for p in spec.split(","))):
        idx_s, eq, plan_s = part.partition("=")
        if not eq:
            raise ValueError(f"fleet plan {part!r}: expected IDX=PLAN")
        idx = int(idx_s)
        merged = plans.get(idx, FaultPlan()).faults
        plans[idx] = FaultPlan(merged + FaultPlan.parse(plan_s).faults)
    return plans


class TickClock:
    """A manually advanced engine clock (seconds start at 0.0).

    Unlike `serve.core.StepClock` it is not tied to an engine's step count:
    a router shares one TickClock across all replicas, and the ``slow``
    fault advances it mid-step so latency faults show up in the measured
    step seconds deterministically."""

    def __init__(self, t: float = 0.0):
        self.t = float(t)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        self.t += float(dt)
        return self.t


# -- NaN poisoning ------------------------------------------------------------

def poison(value):
    """A NaN-poisoned copy of ``value``, preserving its shape: numbers
    become NaN, arrays are NaN-filled, containers recurse. Non-numeric
    leaves (strings, bools, None) pass through — the point is to corrupt
    the numeric payload the way a bad kernel would, not the metadata."""
    if isinstance(value, bool) or value is None or isinstance(value, (str, bytes)):
        return value
    if isinstance(value, (int, float)):
        return float("nan")
    if isinstance(value, dict):
        return {k: poison(v) for k, v in value.items()}
    if isinstance(value, tuple):
        return tuple(poison(v) for v in value)
    if isinstance(value, list):
        return [poison(v) for v in value]
    if hasattr(value, "dtype"):
        arr = np.asarray(value)
        if np.issubdtype(arr.dtype, np.floating) or \
                np.issubdtype(arr.dtype, np.complexfloating):
            return np.full_like(arr, np.nan)
        return np.full(arr.shape, np.nan, dtype=np.float32)
    return value


def _poison_report(report: StepReport, slot: Optional[int]) -> StepReport:
    """Poison the reported outputs of ``slot`` (all slots when None)."""
    progress = dict(report.progress)
    finished = dict(report.finished)
    targets = [slot] if slot is not None else list(progress) + list(finished)
    for idx in targets:
        prog = progress.get(idx)
        if prog is not None and prog.emitted:
            progress[idx] = dataclasses.replace(
                prog, emitted=tuple(poison(e) for e in prog.emitted))
        res = finished.get(idx)
        if res is not None:
            finished[idx] = dataclasses.replace(res, outputs=poison(res.outputs))
    return StepReport(finished=finished, progress=progress, cost=report.cost)


# -- the wrapper runner -------------------------------------------------------

class FaultySession:
    """`RunnerSession` wrapper applying a `FaultPlan` at each step.

    Keeps its own step index (0-based, incremented on every ``step()`` call
    whether or not the inner session ran) so plans are phrased in the
    replica's local step count. Only the *reported* outputs are corrupted;
    the inner session's state stays clean — ``cancel`` yields the inner
    session's untouched partial result.
    """

    def __init__(self, inner: RunnerSession, plan: FaultPlan,
                 clock: Optional[TickClock] = None):
        self.inner = inner
        self.plan = plan
        self.clock = clock
        self.step_idx = 0

    def admit(self, slot: int, request: Request) -> Optional[Result]:
        return self.inner.admit(slot, request)

    def cancel(self, slot: int) -> Result:
        return self.inner.cancel(slot)

    def step(self, budget: StepBudget) -> StepReport:
        idx = self.step_idx
        self.step_idx += 1
        fault = self.plan.active("raise", idx)
        if fault is not None:
            raise FaultError(f"{fault.message} (step {idx})")
        if self.plan.active("wedge", idx) is not None:
            # no progress, inner untouched: the heartbeat failure mode
            return StepReport(cost={"units": 0})
        fault = self.plan.active("slow", idx)
        if fault is not None and self.clock is not None:
            self.clock.advance(fault.seconds)
        report = self.inner.step(budget)
        fault = self.plan.active("nan", idx)
        if fault is not None:
            report = _poison_report(report, fault.slot)
        return report


class FaultyRunner:
    """`ModelRunner` wrapper: delegates everything, opens `FaultySession`s.

    One plan per runner; a fresh wrapper per replica gives each replica its
    own schedule (`parse_fleet_plan`). With an empty plan the wrapper is
    transparent — `serve.router.make_router` wraps every replica uniformly
    so replica behavior differs only by plan.
    """

    def __init__(self, inner: ModelRunner, plan: Optional[FaultPlan] = None,
                 clock: Optional[TickClock] = None):
        self.inner = inner
        self.plan = plan if plan is not None else FaultPlan()
        self.clock = clock

    def bucket_key(self, request: Request) -> Hashable:
        return self.inner.bucket_key(request)

    def filler(self, request: Request) -> Request:
        return self.inner.filler(request)

    def run(self, batch: Sequence[Request]) -> Sequence[Result]:
        return self.inner.run(batch)

    def session_key(self, request: Request) -> Hashable:
        return self.inner.session_key(request)

    def open_session(self, slots: int) -> FaultySession:
        return FaultySession(self.inner.open_session(slots), self.plan,
                             self.clock)


def flood_queue(target, payload, *, count: Optional[int] = None,
                priority: int = 0, **options) -> List[int]:
    """Drive-side implementation of the ``flood`` fault: submit copies of
    ``payload`` until ``target`` stops admitting (its queue is full / the
    router starts shedding) or ``count`` submissions went in. ``target`` is
    anything with ``submit(payload, **options)`` — an `EngineCore` (stops at
    `QueueFull`) or a `serve.router.Router` (never raises; stops after
    ``count``, which is required then). Returns the submitted request ids."""
    if count is None:
        if not hasattr(target, "config"):
            raise ValueError("flood_queue(count=None) needs a QueueFull-"
                             "raising target; pass count= for routers")
        count = math.inf
    rids: List[int] = []
    while len(rids) < count:
        try:
            rids.append(target.submit(payload, priority=priority, **options))
        except QueueFull:
            break
    return rids
