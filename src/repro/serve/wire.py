"""Versioned wire protocol for the serving control plane.

PR 6 made the engine message-shaped (`submit/poll/cancel/poll_partial`) and
the router's supervision transport-agnostic; this module makes the implicit
in-process call contract *explicit*: a frozen message schema plus a codec
that round-trips every value the control plane moves — `Request` payloads
(token lists, numpy images), `Result` outputs/stats (nested dicts, tuples,
NaN/Inf from the numerics probe), and streamed partials — bit-exactly.
`serve.worker` speaks this protocol over a pipe; `serve.router`'s
`SubprocessTransport` is the client side.

Design rules:

* **No pickle.** Frames are length-prefixed JSON with a small set of tagged
  value types. A worker is a subprocess we supervise, not a peer we trust
  with arbitrary code objects — and refusing pickle keeps the protocol
  implementable from any language.
* **Bit-exact round trips.** numpy arrays travel as
  ``{dtype, shape, base64(raw bytes)}`` so every payload and every stats
  tensor decodes to the same bits (NaN payload patterns included); floats
  ride JSON's repr round-trip (exact for float64); tuples are tagged so
  ``marker`` et al. come back as tuples, not lists. This is what lets the
  router assert replayed outputs bit-identical across process boundaries.
* **Versioned.** Every frame carries ``PROTOCOL_VERSION``; `unpack` refuses
  a mismatched peer with a `ProtocolError` naming both versions. The
  worker handshake (`HelloMsg` -> `ReadyMsg`) therefore fails fast and
  loudly instead of mis-decoding messages mid-flight.

Framing: ``!I`` big-endian length prefix + JSON body (``allow_nan=True`` —
NaN/Infinity literals are part of the contract; both ends are Python today
and the tagged-ndarray path covers them for any future non-Python peer).
"""
from __future__ import annotations

import base64
import dataclasses
import json
import struct
from typing import Any, ClassVar, Dict, Mapping, Optional, Tuple, Type

import numpy as np

from .api import Request, Result

#: bump on any incompatible change to the message set or the codec.
#: v2: `HelloMsg.obs` opt-in + `HeartbeatMsg.telemetry` (observability
#: increments piggybacking on the step reply). Both are default-valued —
#: same-build peers always agree, and the version stamp keeps a v1 peer
#: from half-decoding a v2 stream.
PROTOCOL_VERSION = 2

#: refuse frames larger than this (corrupted length prefix guard)
MAX_FRAME_BYTES = 1 << 30

_HEADER = struct.Struct("!I")

_TAG_ND = "__nd__"        # numpy array / scalar: [dtype.str, shape, b64 bytes]
_TAG_TUPLE = "__tuple__"  # tuple: [items...]
_TAG_BYTES = "__bytes__"  # bytes: b64 string
_TAG_MAP = "__map__"      # mapping with non-string (or tag-like) keys: [[k, v]...]
_TAGS = (_TAG_ND, _TAG_TUPLE, _TAG_BYTES, _TAG_MAP)


class ProtocolError(RuntimeError):
    """A frame violated the wire contract: version mismatch, unknown
    message type or value tag, truncated frame, or an unencodable value."""


# ---------------------------------------------------------------------------
# value codec
# ---------------------------------------------------------------------------

def encode_value(value: Any) -> Any:
    """Encode one Python value into the JSON-able tagged form.

    Supported: None, bool, int, float (NaN/Inf included), str, bytes,
    list, tuple, dict/Mapping (any encodable keys), numpy arrays and
    numpy scalars. Anything else raises `ProtocolError` — the control
    plane refuses to guess at a serialization.
    """
    if value is None or isinstance(value, (bool, int, str)):
        return value
    if isinstance(value, float):
        return value
    if isinstance(value, np.ndarray):
        # ascontiguousarray promotes 0-d to (1,): take the shape first so
        # numpy scalars round-trip as true 0-d arrays
        raw = base64.b64encode(
            np.ascontiguousarray(value).tobytes()).decode("ascii")
        return {_TAG_ND: [value.dtype.str, list(value.shape), raw]}
    if isinstance(value, np.generic):
        # scalars keep their dtype via the 0-d array form
        return encode_value(np.asarray(value))
    if (hasattr(value, "__array__") and hasattr(value, "dtype")
            and hasattr(value, "shape")):
        # duck-typed array (e.g. a jax device array): np.asarray is a
        # bit-exact device->host transfer, so payloads submitted as device
        # arrays cross the wire losslessly
        return encode_value(np.asarray(value))
    if isinstance(value, (bytes, bytearray)):
        return {_TAG_BYTES: base64.b64encode(bytes(value)).decode("ascii")}
    if isinstance(value, tuple):
        return {_TAG_TUPLE: [encode_value(v) for v in value]}
    if isinstance(value, list):
        return [encode_value(v) for v in value]
    if isinstance(value, Mapping):
        keys = list(value.keys())
        plain = all(isinstance(k, str) and not k.startswith("__") for k in keys)
        if plain:
            return {k: encode_value(v) for k, v in value.items()}
        # non-string or tag-like keys: escape into an explicit pair list
        return {_TAG_MAP: [[encode_value(k), encode_value(v)]
                           for k, v in value.items()]}
    raise ProtocolError(
        f"cannot encode {type(value).__name__!r} on the wire: the control "
        f"plane only moves JSON scalars, bytes, lists/tuples, mappings and "
        f"numpy arrays")


def decode_value(value: Any) -> Any:
    """Inverse of `encode_value`. Unknown tags raise `ProtocolError`."""
    if value is None or isinstance(value, (bool, int, float, str)):
        return value
    if isinstance(value, list):
        return [decode_value(v) for v in value]
    if isinstance(value, dict):
        if len(value) == 1:
            (key, body), = value.items()
            if key == _TAG_ND:
                dtype, shape, raw = body
                arr = np.frombuffer(base64.b64decode(raw), dtype=np.dtype(dtype))
                arr = arr.reshape([int(s) for s in shape]).copy()
                return arr
            if key == _TAG_TUPLE:
                return tuple(decode_value(v) for v in body)
            if key == _TAG_BYTES:
                return base64.b64decode(body)
            if key == _TAG_MAP:
                return {decode_value(k): decode_value(v) for k, v in body}
            if isinstance(key, str) and key.startswith("__"):
                raise ProtocolError(f"unknown wire value tag {key!r} "
                                    f"(peer newer than v{PROTOCOL_VERSION}?)")
        return {k: decode_value(v) for k, v in value.items()}
    raise ProtocolError(f"cannot decode wire value of type {type(value).__name__!r}")


# ---------------------------------------------------------------------------
# message schema
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class HelloMsg:
    """Parent -> worker handshake opener. ``runner`` is the wire form of a
    `serve.worker.RunnerSpec`; ``config`` the `api.EngineConfig` fields.
    The frame's version field *is* the version check — a mismatched worker
    never gets as far as reading these fields.

    obs: when True the worker attaches a `repro.obs.Observability` bundle
    to its engine and ships telemetry increments on every heartbeat
    (v2, default off — the observability plane is strictly opt-in)."""
    TYPE: ClassVar[str] = "hello"
    runner: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    config: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    obs: bool = False


@dataclasses.dataclass(frozen=True)
class ReadyMsg:
    """Worker -> parent handshake close: the engine is built and serving."""
    TYPE: ClassVar[str] = "ready"
    pid: int = 0
    workload: str = ""


@dataclasses.dataclass(frozen=True)
class ErrorMsg:
    """Worker -> parent fatal report (bad handshake, unknown runner kind).
    The worker exits after sending one."""
    TYPE: ClassVar[str] = "error"
    error: str = ""


@dataclasses.dataclass(frozen=True)
class SubmitMsg:
    """Parent -> worker: admit one request. Fields are exactly the canonical
    `api.SubmitSpec` shape — the single submit surface `EngineCore.submit`
    and `Router.submit` both parse into."""
    TYPE: ClassVar[str] = "submit"
    payload: Any = None
    deadline_s: Optional[float] = None
    priority: int = 0
    options: Mapping[str, Any] = dataclasses.field(default_factory=dict)

    @classmethod
    def from_spec(cls, spec: "SubmitSpec") -> "SubmitMsg":
        return cls(payload=spec.payload, deadline_s=spec.deadline_s,
                   priority=spec.priority, options=dict(spec.options))

    def to_spec(self) -> "SubmitSpec":
        from .api import SubmitSpec
        return SubmitSpec.make(self.payload, deadline_s=self.deadline_s,
                               priority=self.priority,
                               options=dict(self.options))


@dataclasses.dataclass(frozen=True)
class AckMsg:
    """Worker -> parent terminal reply for submit/poll/cancel requests.
    ``rid`` is the worker-local request id on successful submit."""
    TYPE: ClassVar[str] = "ack"
    ok: bool = True
    rid: int = -1
    error: str = ""


@dataclasses.dataclass(frozen=True)
class PollMsg:
    """Parent -> worker: fetch the `Result` for ``rid`` if retired."""
    TYPE: ClassVar[str] = "poll"
    rid: int = -1


@dataclasses.dataclass(frozen=True)
class CancelMsg:
    """Parent -> worker: cancel ``rid`` (queued or resident)."""
    TYPE: ClassVar[str] = "cancel"
    rid: int = -1
    status: str = "cancelled"


@dataclasses.dataclass(frozen=True)
class StepMsg:
    """Parent -> worker: advance the engine one step. The worker replies
    with any newly available `PartialMsg`/`ResultMsg` pushes followed by
    exactly one `HeartbeatMsg` echoing ``seq``."""
    TYPE: ClassVar[str] = "step"
    seq: int = 0


@dataclasses.dataclass(frozen=True)
class ResultMsg:
    """Worker -> parent push: one retired request's `api.Result`."""
    TYPE: ClassVar[str] = "result"
    rid: int = -1
    outputs: Any = None
    stats: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    status: str = "ok"

    @classmethod
    def from_result(cls, rid: int, result: Result) -> "ResultMsg":
        return cls(rid=rid, outputs=result.outputs,
                   stats=dict(result.stats), status=result.status)

    def to_result(self) -> Result:
        return Result(request_id=self.rid, outputs=self.outputs,
                      stats=dict(self.stats), status=self.status)


@dataclasses.dataclass(frozen=True)
class PartialMsg:
    """Worker -> parent push: streamed partial outputs for ``rid`` — the
    same items `EngineCore.poll_partial` would have returned in-process."""
    TYPE: ClassVar[str] = "partial"
    rid: int = -1
    items: Tuple = ()


@dataclasses.dataclass(frozen=True)
class HeartbeatMsg:
    """Worker -> parent: terminal reply to every `StepMsg` — the engine
    vitals the router's supervision reads each step.

    marker:      `EngineCore._progress_marker()` — (retired, work_units,
                 decode_tokens, queue_len); an unchanged marker across
                 ``wedge_patience`` supervised steps condemns the replica.
    failed:      cumulative numerics-screen failures (`EngineCore._failed`);
                 a delta trips the router's NaN probe.
    cost_finite: whether the last step's reported cost was NaN/Inf-free —
                 the second half of the numerics probe.
    in_flight /  queue-depth signals the router's placement reads.
    pending:
    stats:       the full `EngineCore.stats()` mapping (fleet dashboards);
                 supervision only needs the scalar fields above.
    telemetry:   observability increment (v2, None unless `HelloMsg.obs`):
                 ``{spans, metrics, frames[, dumps]}`` from
                 `repro.obs.Observability.wire_telemetry` — newly closed
                 trace spans, the current metrics snapshot, a recorder
                 frame tail (postmortem cushion if the worker dies before
                 its next heartbeat) and any fresh recorder dumps.
    """
    TYPE: ClassVar[str] = "heartbeat"
    seq: int = 0
    marker: Tuple = ()
    failed: int = 0
    cost_finite: bool = True
    in_flight: int = 0
    pending: int = 0
    stats: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    telemetry: Any = None


@dataclasses.dataclass(frozen=True)
class ShutdownMsg:
    """Parent -> worker: exit cleanly after the current message."""
    TYPE: ClassVar[str] = "shutdown"


MESSAGE_TYPES: Dict[str, Type] = {
    cls.TYPE: cls
    for cls in (HelloMsg, ReadyMsg, ErrorMsg, SubmitMsg, AckMsg, PollMsg,
                CancelMsg, StepMsg, ResultMsg, PartialMsg, HeartbeatMsg,
                ShutdownMsg)
}


# ---------------------------------------------------------------------------
# pack / unpack + framing
# ---------------------------------------------------------------------------

def pack(msg: Any, *, version: Optional[int] = None) -> bytes:
    """Serialize one message to a frame body. ``version`` overrides the
    stamped protocol version (tests use it to provoke the mismatch path)."""
    cls = type(msg)
    if getattr(cls, "TYPE", None) not in MESSAGE_TYPES:
        raise ProtocolError(f"not a wire message: {cls.__name__}")
    fields = {f.name: encode_value(getattr(msg, f.name))
              for f in dataclasses.fields(cls)}
    body = {"v": PROTOCOL_VERSION if version is None else int(version),
            "t": cls.TYPE, "f": fields}
    return json.dumps(body, allow_nan=True, separators=(",", ":")).encode("utf-8")


def unpack(data: bytes) -> Any:
    """Deserialize one frame body. Rejects version mismatches and unknown
    message types with `ProtocolError` — the handshake's failure mode."""
    try:
        body = json.loads(data.decode("utf-8"))
    except (ValueError, UnicodeDecodeError) as e:
        raise ProtocolError(f"undecodable wire frame: {e}") from e
    if not isinstance(body, dict) or not {"v", "t", "f"} <= set(body):
        raise ProtocolError("malformed wire frame: missing v/t/f envelope")
    version = body["v"]
    if version != PROTOCOL_VERSION:
        raise ProtocolError(
            f"protocol version mismatch: peer speaks v{version}, this "
            f"process speaks v{PROTOCOL_VERSION}; refusing to talk to a "
            f"mismatched peer (upgrade both ends to the same repro build)")
    cls = MESSAGE_TYPES.get(body["t"])
    if cls is None:
        raise ProtocolError(f"unknown wire message type {body['t']!r}")
    known = {f.name for f in dataclasses.fields(cls)}
    fields = body["f"]
    if not isinstance(fields, dict) or not set(fields) <= known:
        extra = sorted(set(fields) - known) if isinstance(fields, dict) else fields
        raise ProtocolError(f"unknown fields {extra} for {body['t']!r} frame")
    return cls(**{k: decode_value(v) for k, v in fields.items()})


def write_frame(stream, msg: Any, *, version: Optional[int] = None) -> None:
    """Write one length-prefixed frame and flush."""
    data = pack(msg, version=version)
    stream.write(_HEADER.pack(len(data)))
    stream.write(data)
    stream.flush()


def _read_exact(stream, n: int) -> Optional[bytes]:
    """Read exactly n bytes; None on clean EOF at a frame boundary."""
    chunks = []
    got = 0
    while got < n:
        chunk = stream.read(n - got)
        if not chunk:
            if got == 0:
                return None
            raise ProtocolError(
                f"truncated wire frame: peer closed mid-frame "
                f"({got}/{n} bytes)")
        chunks.append(chunk)
        got += len(chunk)
    return b"".join(chunks)


def read_frame(stream) -> Optional[Any]:
    """Read one frame; None on clean EOF (peer closed between frames)."""
    header = _read_exact(stream, _HEADER.size)
    if header is None:
        return None
    (length,) = _HEADER.unpack(header)
    if length > MAX_FRAME_BYTES:
        raise ProtocolError(f"wire frame length {length} exceeds "
                            f"{MAX_FRAME_BYTES} (corrupted stream?)")
    data = _read_exact(stream, length)
    if data is None:
        raise ProtocolError("truncated wire frame: peer closed after header")
    return unpack(data)


# ---------------------------------------------------------------------------
# Request / Result round-trip helpers
# ---------------------------------------------------------------------------

def request_to_wire(request: Request) -> Mapping[str, Any]:
    """Full frozen `Request` -> wire mapping (codec tests + drain logs).
    The live control plane moves `SubmitMsg` instead — workers stamp their
    own request ids and arrival clocks."""
    return {
        "request_id": request.request_id,
        "payload": encode_value(request.payload),
        "options": encode_value(dict(request.options)),
        "deadline_s": request.deadline_s,
        "priority": request.priority,
        "arrival_s": request.arrival_s,
    }


def request_from_wire(data: Mapping[str, Any]) -> Request:
    return Request(request_id=int(data["request_id"]),
                   payload=decode_value(data["payload"]),
                   options=decode_value(data["options"]),
                   deadline_s=data["deadline_s"],
                   priority=int(data["priority"]),
                   arrival_s=float(data["arrival_s"]))


def result_to_wire(result: Result) -> Mapping[str, Any]:
    return {
        "request_id": result.request_id,
        "outputs": encode_value(result.outputs),
        "stats": encode_value(dict(result.stats)),
        "status": result.status,
    }


def result_from_wire(data: Mapping[str, Any]) -> Result:
    return Result(request_id=int(data["request_id"]),
                  outputs=decode_value(data["outputs"]),
                  stats=decode_value(data["stats"]),
                  status=str(data["status"]))
