"""LM runner: prefill-scan + greedy decode behind the `ModelRunner` protocol.

This is the old `ServeEngine` hot path refactored into a pluggable runner,
with the ragged-prompt prefill bug fixed. The seed engine teacher-forced
*every* request through the batch's max prompt length, so shorter prompts
consumed pad zeros into their KV caches / recurrent state and started
decoding from a pad-conditioned distribution. Here the prefill scan carries a
per-request active mask: a request's caches only advance while the scan
position is inside its own prompt (`decode_step(..., active=...)` freezes KV
slots and recurrent state row-wise), its first generated token is captured at
its own last prompt position, and decode runs with a per-request position
vector — numerics per request are identical to serving it alone.

Bucketing (``run`` / batch admission): prompts are padded to `prompt_bucket`
multiples, and the bucket key is (padded prompt length, max_new_tokens), so
each distinct bucket compiles the prefill scan once and batches only
compatible requests.

Continuous admission (``open_session``): the same per-row masking machinery,
generalized from "ragged prompts in one batch" to "requests joining a live
batch at arbitrary steps". An `_LMSession` holds one KV cache / recurrent
state of width ``slots``; every session step is ONE launch in which each
occupied slot consumes its own next token(s) at its own position(s) — a
budgeted *chunk* of prompt tokens while prefilling (teacher-forced, argmax
discarded until the last prompt position), its previously generated token
while decoding. The per-step work is set by the engine's `api.StepBudget`:
with ``chunk == 1`` every step is one `decode_step` (token-by-token
prefill, the PR-3 behavior); with ``chunk > 1`` prefilling rows consume up
to ``chunk`` prompt tokens via `transformer.decode_chunk` — C sequential
masked decode_steps fused in one jitted scan, with resident decode rows
riding along at ``take == 1`` — so a long prompt stops holding goodput
down for its whole prefill. Free slots ride along with ``active=False``
(caches frozen, outputs ignored), and a newly freed slot's recurrent state
is reset row-wise before reuse (`transformer.reset_cache_rows`; KV entries
are position-masked so they need no reset). Because every launch is
row-independent and chunking only regroups the same masked per-token
updates, a request admitted mid-stream sees exactly the numerics a solo
run would give it — bit-identical outputs for every chunk size, which the
tests assert.
"""
from __future__ import annotations

import functools
from typing import Dict, Hashable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ...configs.base import ArchConfig
from ...core.quant import fake_quant
from ...core.tiling import round_up
from ...models import transformer as tf
from ..api import (PAD_REQUEST_ID, Request, Result, SlotProgress, StepBudget,
                   StepReport)


def quantized_lm_params(params, bits: int):
    """Fake-quant view of the LM weight matrices (norms / biases untouched)."""
    def walk(path, x):
        key = jax.tree_util.keystr(path)
        if x.ndim >= 2 and (".w" in key or "w_" in key) and "norm" not in key:
            return fake_quant(x, bits, None)
        return x
    return jax.tree_util.tree_map_with_path(walk, params)


class LMRunner:
    """Greedy batched generation over the unified LM (`ModelRunner`)."""

    def __init__(self, cfg: ArchConfig, params, *, max_seq: int = 512,
                 quant_bits: int = 0, prompt_bucket: int = 8):
        self.cfg = cfg
        self.max_seq = max_seq
        self.prompt_bucket = prompt_bucket
        self.quant_bits = quant_bits
        # quantized once at construction: serving never re-quantizes, so a
        # variant registry can hold one fp32 and one int4 runner over the
        # same raw params with no per-request quantization cost
        self.params = quantized_lm_params(params, quant_bits) if quant_bits else params

        @jax.jit
        def step(params, cache, tokens, pos_vec):
            """One greedy decode step at per-request positions [B]."""
            logits, cache = tf.decode_step(params, cache, {"tokens": tokens},
                                           pos_vec, cfg)
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            return nxt[:, None], cache            # [B, 1] — feeds the next step

        @jax.jit
        def masked_step(params, cache, tokens, pos_vec, active):
            """One mixed prefill/decode step for a live session: every row
            consumes its own token at its own position; active=False rows
            (free slots) freeze their caches."""
            logits, cache = tf.decode_step(params, cache, {"tokens": tokens},
                                           pos_vec, cfg, active=active)
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            return nxt, cache                     # [B] greedy picks

        @jax.jit
        def chunk_step(params, cache, tokens, pos0, take, active):
            """One chunked mixed prefill/decode step: every row consumes its
            own ragged token chunk at its own positions (decode rows take 1;
            see `transformer.decode_chunk`). Greedy picks per column."""
            return tf.decode_chunk(params, cache, tokens, pos0, take, cfg,
                                   active=active)

        @jax.jit
        def prefill(params, cache, toks, lens):
            """Masked teacher-forced prefill: one jit'd scan over the prompt
            block. Rows past their own prompt length freeze their caches, and
            each row's first decode token is read off at its own last prompt
            position — ragged prompts decode bit-identically to solo runs."""

            def body(carry, xs):
                cache, first = carry
                tok, p = xs                       # tok [B], p scalar position
                logits, cache = tf.decode_step(
                    params, cache, {"tokens": tok[:, None]}, p, cfg,
                    active=p < lens)
                nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
                first = jnp.where(p == lens - 1, nxt, first)
                return (cache, first), None

            plen = toks.shape[1]
            positions = jnp.arange(plen, dtype=jnp.int32)
            first0 = jnp.zeros((toks.shape[0],), jnp.int32)
            (cache, first), _ = jax.lax.scan(body, (cache, first0),
                                             (toks.T, positions))
            return first[:, None], cache          # [B, 1] — first decode input

        self._step = step
        self._masked_step = masked_step
        self._chunk_step = chunk_step
        self._prefill = prefill

    @property
    def precision(self) -> str:
        """Active weight numerics, as recorded on every `Result.stats`."""
        return f"int{self.quant_bits}" if self.quant_bits else "fp32"

    @property
    def wbytes_per(self) -> float:
        """Bytes per weight at the active precision (4.0 fp32, 0.5 int4)."""
        return self.quant_bits / 8.0 if self.quant_bits else 4.0

    # -- ModelRunner protocol ------------------------------------------------

    def _padded_len(self, prompt: Sequence[int]) -> int:
        return round_up(max(len(prompt), 1), self.prompt_bucket)

    def bucket_key(self, request: Request) -> Hashable:
        return (self._padded_len(request.payload),
                int(request.options.get("max_new_tokens", 0)))

    def filler(self, request: Request) -> Request:
        # zero-length prompt: never active in the prefill mask, decode output
        # discarded by the engine
        return Request(PAD_REQUEST_ID, [], dict(request.options))

    def run(self, batch: Sequence[Request]) -> List[Result]:
        prompts = [list(r.payload) for r in batch]
        num_tokens = int(batch[0].options.get("max_new_tokens", 0))
        plen = self._padded_len(max(prompts, key=len) if prompts else [0])
        assert plen + num_tokens <= self.max_seq, (
            f"prompt bucket {plen} + {num_tokens} new tokens exceeds "
            f"max_seq {self.max_seq}")

        b = len(batch)
        toks = jnp.zeros((b, plen), jnp.int32)
        for i, p in enumerate(prompts):
            if p:
                toks = toks.at[i, :len(p)].set(jnp.array(p, jnp.int32))
        lens = jnp.array([len(p) for p in prompts], jnp.int32)

        cache = tf.init_cache(self.cfg, b, self.max_seq)
        cur, cache = self._prefill(self.params, cache, toks, lens)
        out = [list(p) for p in prompts]
        for k in range(num_tokens):
            pos_vec = lens + k                   # per-request decode position
            for i in range(b):
                out[i].append(int(cur[i, 0]))
            cur, cache = self._step(self.params, cache, cur, pos_vec)

        return [
            Result(r.request_id, out[i], stats={
                "prompt_len": len(prompts[i]),
                "padded_len": plen,
                "new_tokens": num_tokens,
                "precision": self.precision,
                "wbytes_per": self.wbytes_per,
            })
            for i, r in enumerate(batch)
        ]

    # -- continuous admission ------------------------------------------------

    def session_key(self, request: Request) -> Hashable:
        # any prompt/budget that fits max_seq can join a live LM session:
        # slots prefill/decode independently, so there is nothing to bucket
        return ("lm", self.max_seq)

    def open_session(self, slots: int) -> "_LMSession":
        return _LMSession(self, slots)


class _LMSession:
    """A live width-``slots`` decode batch requests join between tokens.

    Per-slot python state (prompt, emitted tokens, position, budget) steers
    one shared jitted launch per engine step — `decode_step` when every row
    takes one token, `decode_chunk` when the budget lets prefilling rows
    consume a chunk; the device state is the session-wide KV cache /
    recurrent state. See the module docstring for the equivalence argument.
    """

    def __init__(self, runner: LMRunner, slots: int):
        self.runner = runner
        self.slots = slots
        self._fresh = tf.init_cache(runner.cfg, slots, runner.max_seq)
        self.cache = self._fresh
        self.req: List[Optional[Request]] = [None] * slots
        self.prompt: List[List[int]] = [[] for _ in range(slots)]
        self.out: List[List[int]] = [[] for _ in range(slots)]
        self.pos = [0] * slots        # next position this slot consumes
        self.budget = [0] * slots
        self.next_tok = [0] * slots   # token the slot feeds next step
        self.prefill_chunks = [0] * slots  # steps that consumed prompt tokens
        self.steps_in = [0] * slots   # steps since admission
        self.ttft = [0] * slots       # steps through the first emitted token
        self._stale: set = set()      # slots whose past occupant touched state

    def _result(self, i: int, status: str = "ok") -> Result:
        req = self.req[i]
        plen = len(self.prompt[i])
        # continuous admission feeds prompts unpadded — `Result` documents
        # padded_len == prompt_len. Enforce the invariant behind it: the
        # outputs open with the prompt exactly as submitted (no bucket
        # padding ever leaked into the stream) and the slot consumed no
        # token position past its own prompt + emissions.
        assert self.out[i][:plen] == self.prompt[i], (self.out[i], self.prompt[i])
        return Result(req.request_id, self.out[i], stats={
            "prompt_len": plen,
            "padded_len": plen,
            "new_tokens": self.budget[i],
            "prefill_chunks": self.prefill_chunks[i],
            "ttft_steps": self.ttft[i],
            "precision": self.runner.precision,
            "wbytes_per": self.runner.wbytes_per,
        }, status=status)

    def admit(self, slot: int, request: Request) -> Optional[Result]:
        assert self.req[slot] is None, f"slot {slot} busy"
        prompt = [int(t) for t in request.payload]
        budget = int(request.options.get("max_new_tokens", 0))
        assert len(prompt) + budget <= self.runner.max_seq, (
            f"prompt {len(prompt)} + {budget} new tokens exceeds "
            f"max_seq {self.runner.max_seq}")
        self.req[slot] = request
        self.prompt[slot] = prompt
        self.out[slot] = list(prompt)
        self.pos[slot] = 0
        self.budget[slot] = budget
        self.prefill_chunks[slot] = 0
        self.steps_in[slot] = 0
        self.ttft[slot] = 0
        if budget == 0:               # nothing to generate: done on arrival
            res = self._result(slot)
            self.req[slot] = None
            return res
        if prompt:
            self.next_tok[slot] = prompt[0]
        else:
            # batch-path parity: an empty prompt's first "generated" token is
            # the argmax placeholder 0 the scan prefill leaves behind (its
            # rows are never active, first0 is zeros); decode continues from
            # it at position 0
            self.out[slot].append(0)
            self.next_tok[slot] = 0
            if budget <= 1:
                res = self._result(slot)
                self.req[slot] = None
                return res
        return None

    def cancel(self, slot: int) -> Result:
        """Reclaim ``slot`` mid-flight. Neighbours are untouched (every
        launch is row-independent); the evicted row's cache is re-zeroed
        lazily before the slot's next occupant, exactly like a normal
        completion."""
        assert self.req[slot] is not None, f"slot {slot} empty"
        res = self._result(slot, status="cancelled")
        self.req[slot] = None
        self._stale.add(slot)         # its prefill/decode advanced the state
        return res

    def _takes(self, occupied: List[int], budget: StepBudget) -> Dict[int, int]:
        """Tokens each occupied slot consumes this step: decode slots take
        exactly one; prefilling slots take up to their per-slot allowance
        (never past their own prompt end). A total-units cap trims the
        prefill extras in slot order, never below one token per slot."""
        takes: Dict[int, int] = {}
        for i in occupied:
            remaining = len(self.prompt[i]) - self.pos[i]
            takes[i] = min(budget.for_slot(i), remaining) if remaining > 1 else 1
        if budget.units is not None:
            total = sum(takes.values())
            cap = max(int(budget.units), len(occupied))
            for i in occupied:
                if total <= cap:
                    break
                cut = min(takes[i] - 1, total - cap)
                takes[i] -= cut
                total -= cut
        return takes

    def step(self, budget: StepBudget = StepBudget()) -> StepReport:
        occupied = [i for i in range(self.slots) if self.req[i] is not None]
        if not occupied:
            return StepReport()
        # re-zero state rows whose previous occupant advanced them, all in
        # one pass (KV entries are position-masked and would not need this;
        # rglru/xlstm recurrent state is cumulative and does). Fresh slots
        # skip it entirely.
        stale = [i for i in occupied if i in self._stale]
        if stale:
            keep = np.ones(self.slots, bool)
            keep[stale] = False
            self.cache = tf.reset_cache_rows(self.cache, self._fresh,
                                             jnp.asarray(keep))
            self._stale.difference_update(stale)

        takes = self._takes(occupied, budget)
        width = max(takes.values())
        if width > 1:
            # pow2-bucket the launch width: every distinct width is its own
            # XLA compile, and scheduler budget splits can request arbitrary
            # chunks — bucketing bounds the compile set to log2(max chunk)
            # kernels. Extra columns ride along fully masked (take < width),
            # so numerics are unchanged.
            width = 1 << (width - 1).bit_length()
        pos_vec = jnp.asarray(self.pos, jnp.int32)
        active = jnp.asarray([self.req[i] is not None for i in range(self.slots)])
        if width == 1:
            # all rows take one token: the PR-3 single-token launch
            tokens = jnp.asarray(
                [[self.next_tok[i]] for i in range(self.slots)], jnp.int32)
            nxt, self.cache = self.runner._masked_step(
                self.runner.params, self.cache, tokens, pos_vec, active)
            picks_dev, cols = nxt, {i: 0 for i in occupied}
        else:
            # ragged chunk: row i consumes tokens[i, :take[i]] — its own
            # prompt slice while prefilling, its pending token at column 0
            # while decoding (take == 1; later columns masked)
            buf = np.zeros((self.slots, width), np.int32)
            take_vec = np.zeros(self.slots, np.int32)
            for i in occupied:
                t = takes[i]
                take_vec[i] = t
                p, prompt = self.pos[i], self.prompt[i]
                for j in range(t):
                    buf[i, j] = prompt[p + j] if p + j < len(prompt) \
                        else self.next_tok[i]
            picks_dev, self.cache = self.runner._chunk_step(
                self.runner.params, self.cache, jnp.asarray(buf), pos_vec,
                jnp.asarray(take_vec), active)
            cols = {i: takes[i] - 1 for i in occupied}

        finished: Dict[int, Result] = {}
        progress: Dict[int, SlotProgress] = {}
        picks = None                  # fetched lazily: prefill-only steps skip it
        prompt_toks = decode_toks = 0
        for i in occupied:
            t = takes[i]
            p = self.pos[i]
            plen = len(self.prompt[i])
            was_prefill = p < plen
            self.pos[i] += t
            self.steps_in[i] += 1
            if was_prefill:
                self.prefill_chunks[i] += 1
                prompt_toks += min(t, plen - p)
            emitted = ()
            if self.pos[i] < plen:    # still prefilling: argmax discarded
                self.next_tok[i] = self.prompt[i][self.pos[i]]
            else:
                if picks is None:
                    picks = np.asarray(picks_dev)
                # pos crossed (or sits past) the prompt end: the pick at the
                # row's last consumed column is a generated token
                tok = int(picks[i, cols[i]] if picks.ndim == 2 else picks[i])
                self.out[i].append(tok)
                self.next_tok[i] = tok
                emitted = (tok,)
                decode_toks += 1
                if self.ttft[i] == 0:
                    self.ttft[i] = self.steps_in[i]
            done = len(self.out[i]) - plen >= self.budget[i]
            progress[i] = SlotProgress(
                request_id=self.req[i].request_id,
                phase="decode" if self.pos[i] >= plen else "prefill",
                units_done=min(self.pos[i], plen) + max(0, len(self.out[i]) - plen),
                units_total=plen + self.budget[i],
                emitted=emitted)
            if done:
                finished[i] = self._result(i)
                self.req[i] = None
                self._stale.add(i)    # its decode steps advanced the state
        cost = {"units": sum(takes.values()), "prompt_tokens": prompt_toks,
                "decode_tokens": decode_toks}
        return StepReport(finished=finished, progress=progress, cost=cost)
