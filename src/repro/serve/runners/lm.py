"""LM runner: prefill-scan + greedy decode behind the `ModelRunner` protocol.

This is the old `ServeEngine` hot path refactored into a pluggable runner,
with the ragged-prompt prefill bug fixed. The seed engine teacher-forced
*every* request through the batch's max prompt length, so shorter prompts
consumed pad zeros into their KV caches / recurrent state and started
decoding from a pad-conditioned distribution. Here the prefill scan carries a
per-request active mask: a request's caches only advance while the scan
position is inside its own prompt (`decode_step(..., active=...)` freezes KV
slots and recurrent state row-wise), its first generated token is captured at
its own last prompt position, and decode runs with a per-request position
vector — numerics per request are identical to serving it alone.

Bucketing (``run`` / batch admission): prompts are padded to `prompt_bucket`
multiples, and the bucket key is (padded prompt length, max_new_tokens), so
each distinct bucket compiles the prefill scan once and batches only
compatible requests.

Continuous admission (``open_session``): the same per-row masking machinery,
generalized from "ragged prompts in one batch" to "requests joining a live
batch at arbitrary steps". An `_LMSession` holds one KV cache / recurrent
state of width ``slots``; every session step is ONE launch in which each
occupied slot consumes its own next token(s) at its own position(s) — a
budgeted *chunk* of prompt tokens while prefilling (teacher-forced, argmax
discarded until the last prompt position), its previously generated token
while decoding. The per-step work is set by the engine's `api.StepBudget`:
with ``chunk == 1`` every step is one `decode_step` (token-by-token
prefill, the PR-3 behavior); with ``chunk > 1`` prefilling rows consume up
to ``chunk`` prompt tokens via `transformer.decode_chunk` — C sequential
masked decode_steps fused in one jitted scan, with resident decode rows
riding along at ``take == 1`` — so a long prompt stops holding goodput
down for its whole prefill. Free slots ride along with ``active=False``
(caches frozen, outputs ignored), and a newly freed slot's recurrent state
is reset row-wise before reuse (`transformer.reset_cache_rows`; KV entries
are position-masked so they need no reset). Because every launch is
row-independent and chunking only regroups the same masked per-token
updates, a request admitted mid-stream sees exactly the numerics a solo
run would give it — bit-identical outputs for every chunk size, which the
tests assert.

Speculative decode (``speculate_k > 0``) reuses the same chunk launch as
the *verify* primitive: a pure-decode row whose proposer
(`serve.speculative`, n-gram prompt lookup by default) offers K draft
tokens feeds ``[pending, d1..dK]`` at ``take == K+1`` and reads K+1
next-token selections back from the one launch its slot-mates prefill and
plain-decode in; the longest draft prefix matching the model's own
selections is accepted plus the corrected token at the first mismatch, the
row's position advances by accepted+1, and KV entries written at rejected
columns are zeroed (`transformer.rollback_cache_rows`) so the cache stays
bit-identical to a never-speculated session. Speculation is gated to
attention-only architectures: recurrent blocks hold cumulative state and
local attention a ring buffer, neither of which rolls back positionally.

Token selection is greedy argmax by default, or the per-request sampling
layer (`serve.sampling`: temperature/top-k/top-p with a per-request seed,
deterministic per (seed, generation index) so a position samples the same
token inside a verify launch as it would one-token-at-a-time). Drafts only
change how many positions one launch advances — never which tokens come
out: speculative output is bit-identical to plain decode for greedy and
sampled requests alike.
"""
from __future__ import annotations

import functools
from typing import Dict, Hashable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ...configs.base import ArchConfig
from ...core.quant import fake_quant
from ...core.tiling import round_up
from ...models import transformer as tf
from .. import sampling as sampling_mod
from ..api import (PAD_REQUEST_ID, Request, Result, SlotProgress, StepBudget,
                   StepReport)
from ..sampling import SamplingParams
from ..speculative import NGramProposer, Proposer

#: block kinds whose decode cache is a position-indexed KV cache — the only
#: ones speculative rollback can restore exactly (see module docstring)
_SPEC_SAFE_KINDS = ("attn_mlp", "attn_moe")


def quantized_lm_params(params, bits: int):
    """Fake-quant view of the LM weight matrices (norms / biases untouched)."""
    def walk(path, x):
        key = jax.tree_util.keystr(path)
        if x.ndim >= 2 and (".w" in key or "w_" in key) and "norm" not in key:
            return fake_quant(x, bits, None)
        return x
    return jax.tree_util.tree_map_with_path(walk, params)


class LMRunner:
    """Greedy batched generation over the unified LM (`ModelRunner`)."""

    def __init__(self, cfg: ArchConfig, params, *, max_seq: int = 512,
                 quant_bits: int = 0, prompt_bucket: int = 8,
                 speculate_k: int = 0, proposer: Optional[Proposer] = None):
        self.cfg = cfg
        self.max_seq = max_seq
        self.prompt_bucket = prompt_bucket
        self.quant_bits = quant_bits
        # speculative decode: sessions draft up to speculate_k tokens per
        # pure-decode row and verify them in the chunk launch. Only safe
        # when every block's cache is position-indexed KV (rollback zeroes
        # the rejected positions exactly; recurrent/ring-buffer state has
        # no positional undo).
        self.speculate_k = int(speculate_k)
        if self.speculate_k:
            unsupported = (set(cfg.pattern) | set(cfg.tail)) - set(_SPEC_SAFE_KINDS)
            assert not unsupported, (
                f"speculate_k={speculate_k} needs position-indexed KV "
                f"rollback; block kinds {sorted(unsupported)} hold "
                f"recurrent or ring-buffer state that cannot roll back")
        self.proposer: Proposer = proposer if proposer is not None \
            else NGramProposer()
        # quantized once at construction: serving never re-quantizes, so a
        # variant registry can hold one fp32 and one int4 runner over the
        # same raw params with no per-request quantization cost
        self.params = quantized_lm_params(params, quant_bits) if quant_bits else params

        @jax.jit
        def step(params, cache, tokens, pos_vec):
            """One greedy decode step at per-request positions [B]."""
            logits, cache = tf.decode_step(params, cache, {"tokens": tokens},
                                           pos_vec, cfg)
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            return nxt[:, None], cache            # [B, 1] — feeds the next step

        @jax.jit
        def masked_step(params, cache, tokens, pos_vec, active):
            """One mixed prefill/decode step for a live session: every row
            consumes its own token at its own position; active=False rows
            (free slots) freeze their caches. Returns greedy picks [B] plus
            the full next-token logits [B, V] — the device keeps both; the
            session only transfers logits when a row samples or tracks
            logprobs, so the pure-greedy path pays nothing for them."""
            logits, cache = tf.decode_step(params, cache, {"tokens": tokens},
                                           pos_vec, cfg, active=active)
            last = logits[:, -1]
            nxt = jnp.argmax(last, axis=-1).astype(jnp.int32)
            return nxt, last, cache

        @jax.jit
        def chunk_step(params, cache, tokens, pos0, take, active):
            """One chunked mixed prefill/decode step: every row consumes its
            own ragged token chunk at its own positions (decode rows take 1,
            speculative rows 1 + draft length; see `transformer.decode_chunk`).
            Greedy picks and logits per column."""
            return tf.decode_chunk(params, cache, tokens, pos0, take, cfg,
                                   active=active)

        @jax.jit
        def rollback(cache, keep_len, rows):
            """Zero KV entries at positions >= keep_len for the masked rows:
            the speculative-decode rollback (`transformer.rollback_cache_rows`).
            One launch per step, only when a draft was rejected."""
            return tf.rollback_cache_rows(cache, keep_len, rows)

        @jax.jit
        def prefill(params, cache, toks, lens):
            """Masked teacher-forced prefill: one jit'd scan over the prompt
            block. Rows past their own prompt length freeze their caches, and
            each row's first decode token is read off at its own last prompt
            position — ragged prompts decode bit-identically to solo runs."""

            def body(carry, xs):
                cache, first = carry
                tok, p = xs                       # tok [B], p scalar position
                logits, cache = tf.decode_step(
                    params, cache, {"tokens": tok[:, None]}, p, cfg,
                    active=p < lens)
                nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
                first = jnp.where(p == lens - 1, nxt, first)
                return (cache, first), None

            plen = toks.shape[1]
            positions = jnp.arange(plen, dtype=jnp.int32)
            first0 = jnp.zeros((toks.shape[0],), jnp.int32)
            (cache, first), _ = jax.lax.scan(body, (cache, first0),
                                             (toks.T, positions))
            return first[:, None], cache          # [B, 1] — first decode input

        self._step = step
        self._masked_step = masked_step
        self._chunk_step = chunk_step
        self._rollback = rollback
        self._prefill = prefill

    @property
    def precision(self) -> str:
        """Active weight numerics, as recorded on every `Result.stats`."""
        return f"int{self.quant_bits}" if self.quant_bits else "fp32"

    @property
    def wbytes_per(self) -> float:
        """Bytes per weight at the active precision (4.0 fp32, 0.5 int4)."""
        return self.quant_bits / 8.0 if self.quant_bits else 4.0

    # -- ModelRunner protocol ------------------------------------------------

    def _padded_len(self, prompt: Sequence[int]) -> int:
        return round_up(max(len(prompt), 1), self.prompt_bucket)

    def bucket_key(self, request: Request) -> Hashable:
        return (self._padded_len(request.payload),
                int(request.options.get("max_new_tokens", 0)))

    def filler(self, request: Request) -> Request:
        # zero-length prompt: never active in the prefill mask, decode output
        # discarded by the engine
        return Request(PAD_REQUEST_ID, [], dict(request.options))

    def run(self, batch: Sequence[Request]) -> List[Result]:
        for r in batch:
            bad = sorted(set(r.options) & set(SamplingParams.KEYS))
            if not r.is_pad and bad:
                raise ValueError(
                    f"request {r.request_id} carries sampling options {bad}; "
                    "the run-to-completion batch path is greedy-only — use "
                    "EngineConfig.admission='continuous'")
        prompts = [list(r.payload) for r in batch]
        num_tokens = int(batch[0].options.get("max_new_tokens", 0))
        plen = self._padded_len(max(prompts, key=len) if prompts else [0])
        assert plen + num_tokens <= self.max_seq, (
            f"prompt bucket {plen} + {num_tokens} new tokens exceeds "
            f"max_seq {self.max_seq}")

        b = len(batch)
        toks = jnp.zeros((b, plen), jnp.int32)
        for i, p in enumerate(prompts):
            if p:
                toks = toks.at[i, :len(p)].set(jnp.array(p, jnp.int32))
        lens = jnp.array([len(p) for p in prompts], jnp.int32)

        cache = tf.init_cache(self.cfg, b, self.max_seq)
        cur, cache = self._prefill(self.params, cache, toks, lens)
        out = [list(p) for p in prompts]
        for k in range(num_tokens):
            pos_vec = lens + k                   # per-request decode position
            for i in range(b):
                out[i].append(int(cur[i, 0]))
            cur, cache = self._step(self.params, cache, cur, pos_vec)

        return [
            Result(r.request_id, out[i], stats={
                "prompt_len": len(prompts[i]),
                "padded_len": plen,
                "new_tokens": num_tokens,
                "precision": self.precision,
                "wbytes_per": self.wbytes_per,
            })
            for i, r in enumerate(batch)
        ]

    # -- continuous admission ------------------------------------------------

    def session_key(self, request: Request) -> Hashable:
        # any prompt/budget that fits max_seq can join a live LM session:
        # slots prefill/decode independently, so there is nothing to bucket
        return ("lm", self.max_seq)

    def open_session(self, slots: int) -> "_LMSession":
        return _LMSession(self, slots)


class _LMSession:
    """A live width-``slots`` decode batch requests join between tokens.

    Per-slot python state (prompt, emitted tokens, position, budget) steers
    one shared jitted launch per engine step — `decode_step` when every row
    takes one token, `decode_chunk` when the budget lets prefilling rows
    consume a chunk; the device state is the session-wide KV cache /
    recurrent state. See the module docstring for the equivalence argument.
    """

    def __init__(self, runner: LMRunner, slots: int):
        self.runner = runner
        self.slots = slots
        self._fresh = tf.init_cache(runner.cfg, slots, runner.max_seq)
        self.cache = self._fresh
        self.req: List[Optional[Request]] = [None] * slots
        self.prompt: List[List[int]] = [[] for _ in range(slots)]
        self.out: List[List[int]] = [[] for _ in range(slots)]
        self.pos = [0] * slots        # next position this slot consumes
        self.budget = [0] * slots
        self.next_tok = [0] * slots   # token the slot feeds next step
        self.prefill_chunks = [0] * slots  # steps that consumed prompt tokens
        self.steps_in = [0] * slots   # steps since admission
        self.ttft = [0] * slots       # steps through the first emitted token
        # per-slot sampling config (None = pure greedy, zero-cost default)
        # and the logprob trace for slots that track it
        self.sampling: List[Optional[SamplingParams]] = [None] * slots
        self.logprobs: List[List[float]] = [[] for _ in range(slots)]
        # speculative-decode accounting: accepted + rejected == drafted,
        # per slot (the property the test battery sums exactly)
        self.drafted = [0] * slots
        self.accepted = [0] * slots
        self.rejected = [0] * slots
        self._stale: set = set()      # slots whose past occupant touched state

    def _result(self, i: int, status: str = "ok") -> Result:
        req = self.req[i]
        plen = len(self.prompt[i])
        # continuous admission feeds prompts unpadded — `Result` documents
        # padded_len == prompt_len. Enforce the invariant behind it: the
        # outputs open with the prompt exactly as submitted (no bucket
        # padding ever leaked into the stream) and the slot consumed no
        # token position past its own prompt + emissions.
        assert self.out[i][:plen] == self.prompt[i], (self.out[i], self.prompt[i])
        stats = {
            "prompt_len": plen,
            "padded_len": plen,
            "new_tokens": self.budget[i],
            "prefill_chunks": self.prefill_chunks[i],
            "ttft_steps": self.ttft[i],
            "precision": self.runner.precision,
            "wbytes_per": self.runner.wbytes_per,
            # speculative accounting (all zero when speculation is off):
            # drafted == accepted + rejected by construction
            "drafted_tokens": self.drafted[i],
            "accepted_tokens": self.accepted[i],
            "rejected_tokens": self.rejected[i],
        }
        sp = self.sampling[i]
        if sp is not None and sp.track_logprobs:
            # one raw-distribution log_softmax value per generated token,
            # in emission order (`serve.sampling` — the empty-prompt argmax
            # placeholder is forced, recorded as logprob 0.0)
            stats["logprobs"] = list(self.logprobs[i])
        return Result(req.request_id, self.out[i], stats=stats, status=status)

    def admit(self, slot: int, request: Request) -> Optional[Result]:
        assert self.req[slot] is None, f"slot {slot} busy"
        prompt = [int(t) for t in request.payload]
        budget = int(request.options.get("max_new_tokens", 0))
        assert len(prompt) + budget <= self.runner.max_seq, (
            f"prompt {len(prompt)} + {budget} new tokens exceeds "
            f"max_seq {self.runner.max_seq}")
        self.req[slot] = request
        self.prompt[slot] = prompt
        self.out[slot] = list(prompt)
        self.pos[slot] = 0
        self.budget[slot] = budget
        self.prefill_chunks[slot] = 0
        self.steps_in[slot] = 0
        self.ttft[slot] = 0
        self.sampling[slot] = SamplingParams.from_options(request.options)
        self.logprobs[slot] = []
        self.drafted[slot] = 0
        self.accepted[slot] = 0
        self.rejected[slot] = 0
        if budget == 0:               # nothing to generate: done on arrival
            res = self._result(slot)
            self.req[slot] = None
            return res
        if prompt:
            self.next_tok[slot] = prompt[0]
        else:
            # batch-path parity: an empty prompt's first "generated" token is
            # the argmax placeholder 0 the scan prefill leaves behind (its
            # rows are never active, first0 is zeros); decode continues from
            # it at position 0. The placeholder is forced, not selected, so
            # a logprob-tracking slot records 0.0 (probability one) for it.
            self.out[slot].append(0)
            self.next_tok[slot] = 0
            sp = self.sampling[slot]
            if sp is not None and sp.track_logprobs:
                self.logprobs[slot].append(0.0)
            if budget <= 1:
                res = self._result(slot)
                self.req[slot] = None
                return res
        return None

    def cancel(self, slot: int) -> Result:
        """Reclaim ``slot`` mid-flight. Neighbours are untouched (every
        launch is row-independent); the evicted row's cache is re-zeroed
        lazily before the slot's next occupant, exactly like a normal
        completion."""
        assert self.req[slot] is not None, f"slot {slot} empty"
        res = self._result(slot, status="cancelled")
        self.req[slot] = None
        self._stale.add(slot)         # its prefill/decode advanced the state
        return res

    def _draft_k(self, i: int) -> int:
        """Draft allowance for slot ``i`` this step: 0 unless the slot is a
        pure-decode row (position past its prompt end — crossing rows still
        owe a prompt token) with at least two budgeted tokens left. The
        clamp to ``remaining - 1`` keeps every verify launch inside both the
        decode budget (it emits at most accepted+1 <= k+1 <= remaining
        tokens) and ``max_seq`` (admit() bounds prompt+budget)."""
        if self.runner.speculate_k <= 0 or self.pos[i] < len(self.prompt[i]):
            return 0
        remaining = self.budget[i] - (len(self.out[i]) - len(self.prompt[i]))
        return max(0, min(self.runner.speculate_k, remaining - 1))

    def _plan(self, occupied: List[int], budget: StepBudget
              ) -> "tuple[Dict[int, int], Dict[int, List[int]]]":
        """Tokens each occupied slot consumes this step, plus draft
        proposals: decode slots take one, speculative decode slots one plus
        their draft, prefilling slots up to their per-slot allowance (never
        past their own prompt end). A total-units cap trims the extras —
        prefill chunk and draft tail alike — in slot order, never below one
        token per slot."""
        takes: Dict[int, int] = {}
        drafts: Dict[int, List[int]] = {}
        for i in occupied:
            remaining = len(self.prompt[i]) - self.pos[i]
            if remaining > 1:
                takes[i] = min(budget.for_slot(i), remaining)
                continue
            takes[i] = 1
            k = self._draft_k(i)
            if k > 0:
                draft = [int(t) for t in
                         self.runner.proposer.propose(self.out[i], k)][:k]
                assert all(0 <= t < self.runner.cfg.vocab for t in draft), draft
                if draft:
                    drafts[i] = draft
                    takes[i] = 1 + len(draft)
        if budget.units is not None:
            total = sum(takes.values())
            cap = max(int(budget.units), len(occupied))
            for i in occupied:
                if total <= cap:
                    break
                cut = min(takes[i] - 1, total - cap)
                takes[i] -= cut
                total -= cut
                if i in drafts:
                    drafts[i] = drafts[i][:takes[i] - 1]
                    if not drafts[i]:
                        del drafts[i]
        return takes, drafts

    def step(self, budget: StepBudget = StepBudget()) -> StepReport:
        occupied = [i for i in range(self.slots) if self.req[i] is not None]
        if not occupied:
            return StepReport()
        # re-zero state rows whose previous occupant advanced them, all in
        # one pass (KV entries are position-masked and would not need this;
        # rglru/xlstm recurrent state is cumulative and does). Fresh slots
        # skip it entirely.
        stale = [i for i in occupied if i in self._stale]
        if stale:
            keep = np.ones(self.slots, bool)
            keep[stale] = False
            self.cache = tf.reset_cache_rows(self.cache, self._fresh,
                                             jnp.asarray(keep))
            self._stale.difference_update(stale)

        takes, drafts = self._plan(occupied, budget)
        width = max(takes.values())
        if width > 1:
            # pow2-bucket the launch width: every distinct width is its own
            # XLA compile, and scheduler budget splits can request arbitrary
            # chunks — bucketing bounds the compile set to log2(max chunk)
            # kernels. Extra columns ride along fully masked (take < width),
            # so numerics are unchanged.
            width = 1 << (width - 1).bit_length()
        pos_vec = jnp.asarray(self.pos, jnp.int32)
        active = jnp.asarray([self.req[i] is not None for i in range(self.slots)])
        chunked = width > 1
        if not chunked:
            # all rows take one token: the PR-3 single-token launch
            tokens = jnp.asarray(
                [[self.next_tok[i]] for i in range(self.slots)], jnp.int32)
            picks_dev, logits_dev, self.cache = self.runner._masked_step(
                self.runner.params, self.cache, tokens, pos_vec, active)
        else:
            # ragged chunk: row i consumes tokens[i, :take[i]] — its own
            # prompt slice while prefilling, its pending token at column 0
            # (plus its draft at columns 1..k while speculating) while
            # decoding; later columns masked
            buf = np.zeros((self.slots, width), np.int32)
            take_vec = np.zeros(self.slots, np.int32)
            for i in occupied:
                t = takes[i]
                take_vec[i] = t
                p, prompt = self.pos[i], self.prompt[i]
                d = drafts.get(i)
                for j in range(t):
                    if p + j < len(prompt):
                        buf[i, j] = prompt[p + j]
                    elif d is not None and j > 0:
                        buf[i, j] = d[j - 1]
                    else:
                        buf[i, j] = self.next_tok[i]
            picks_dev, logits_dev, self.cache = self.runner._chunk_step(
                self.runner.params, self.cache, jnp.asarray(buf), pos_vec,
                jnp.asarray(take_vec), active)

        # device->host transfers are lazy: prefill-only steps fetch nothing,
        # pure-greedy steps fetch picks only — logits move to host only when
        # some row samples or tracks logprobs this step
        fetched: Dict[str, Optional[np.ndarray]] = {"picks": None, "logits": None}

        def pick_at(row: int, col: int) -> int:
            if fetched["picks"] is None:
                fetched["picks"] = np.asarray(picks_dev)
            arr = fetched["picks"]
            return int(arr[row, col] if chunked else arr[row])

        def logits_at(row: int, col: int) -> np.ndarray:
            if fetched["logits"] is None:
                fetched["logits"] = np.asarray(logits_dev)
            arr = fetched["logits"]
            return arr[row, col] if chunked else arr[row]

        def select(row: int, col: int, index: int):
            """(token, logprob|None) the model selects at launch column
            ``col`` for generation index ``index`` of slot ``row`` — greedy
            argmax straight off the device picks, or the seed-deterministic
            sampling layer. The speculative accept test compares draft
            tokens against exactly these selections, so acceptance can
            never change the emitted stream."""
            sp = self.sampling[row]
            if sp is None or not sp.track_logprobs:
                return pick_at(row, col), None
            if sp.greedy:            # logprobs requested on the greedy path
                tok = pick_at(row, col)
                return tok, float(
                    sampling_mod.log_softmax(logits_at(row, col))[tok])
            return sampling_mod.sample(logits_at(row, col), sp, index)

        finished: Dict[int, Result] = {}
        progress: Dict[int, SlotProgress] = {}
        prompt_toks = decode_toks = 0
        drafted_toks = accepted_toks = 0
        rollback_rows: List[int] = []
        for i in occupied:
            t = takes[i]
            p = self.pos[i]
            plen = len(self.prompt[i])
            was_prefill = p < plen
            self.steps_in[i] += 1
            if was_prefill:
                self.prefill_chunks[i] += 1
                prompt_toks += min(t, plen - p)
            emitted = ()
            if p + t < plen:          # still prefilling: argmax discarded
                self.pos[i] = p + t
                self.next_tok[i] = self.prompt[i][self.pos[i]]
            else:
                # pos crossed (or sits past) the prompt end: selections at
                # the row's consumed columns are generated tokens
                sp = self.sampling[i]
                gen0 = len(self.out[i]) - plen   # generation index base
                d = drafts.get(i)
                toks: List[int] = []
                lps: List[Optional[float]] = []
                if d is None:
                    # plain decode or a prefill chunk crossing the prompt
                    # end: all t columns were consumed (t - 1 of them
                    # prompt tokens), the last column's selection is the
                    # one generated token
                    tok, lp = select(i, t - 1, gen0)
                    toks.append(tok)
                    lps.append(lp)
                    self.pos[i] = p + t
                else:
                    # verify: accept the longest draft prefix matching the
                    # model's own selections, then the corrected (or bonus)
                    # token at the stop column — emitted == accepted + 1
                    for j in range(t):
                        tok, lp = select(i, j, gen0 + j)
                        toks.append(tok)
                        lps.append(lp)
                        if not (j < len(d) and tok == d[j]):
                            break
                    acc = len(toks) - 1
                    self.drafted[i] += len(d)
                    self.accepted[i] += acc
                    self.rejected[i] += len(d) - acc
                    drafted_toks += len(d)
                    accepted_toks += acc
                    if acc < len(d):
                        # rejected suffix: KV entries were written at the
                        # dead columns; roll them back after the loop
                        rollback_rows.append(i)
                    # consumed columns: the pending token plus the accepted
                    # draft prefix — the corrected/bonus token is emitted
                    # but not yet consumed (it feeds the next step)
                    self.pos[i] = p + len(toks)
                self.out[i].extend(toks)
                self.next_tok[i] = toks[-1]
                if sp is not None and sp.track_logprobs:
                    self.logprobs[i].extend(lps)
                emitted = tuple(toks)
                decode_toks += len(toks)
                if self.ttft[i] == 0:
                    self.ttft[i] = self.steps_in[i]
            done = len(self.out[i]) - plen >= self.budget[i]
            progress[i] = SlotProgress(
                request_id=self.req[i].request_id,
                phase="decode" if self.pos[i] >= plen else "prefill",
                units_done=min(self.pos[i], plen) + max(0, len(self.out[i]) - plen),
                units_total=plen + self.budget[i],
                emitted=emitted)
            if done:
                finished[i] = self._result(i)
                self.req[i] = None
                self._stale.add(i)    # its decode steps advanced the state
        if rollback_rows:
            # zero the KV entries at rejected positions so the cache is
            # bit-identical to a never-speculated session's (one launch for
            # all rolled-back rows; rows not listed are untouched)
            keep_len = np.zeros(self.slots, np.int32)
            mask = np.zeros(self.slots, bool)
            for i in rollback_rows:
                mask[i] = True
                keep_len[i] = self.pos[i]
            self.cache = self.runner._rollback(
                self.cache, jnp.asarray(keep_len), jnp.asarray(mask))
        cost = {"units": sum(takes.values()), "prompt_tokens": prompt_toks,
                "decode_tokens": decode_toks, "drafted_tokens": drafted_toks,
                "accepted_tokens": accepted_toks}
        return StepReport(finished=finished, progress=progress, cost=cost)
