"""LM runner: prefill-scan + greedy decode behind the `ModelRunner` protocol.

This is the old `ServeEngine` hot path refactored into a pluggable runner,
with the ragged-prompt prefill bug fixed. The seed engine teacher-forced
*every* request through the batch's max prompt length, so shorter prompts
consumed pad zeros into their KV caches / recurrent state and started
decoding from a pad-conditioned distribution. Here the prefill scan carries a
per-request active mask: a request's caches only advance while the scan
position is inside its own prompt (`decode_step(..., active=...)` freezes KV
slots and recurrent state row-wise), its first generated token is captured at
its own last prompt position, and decode runs with a per-request position
vector — numerics per request are identical to serving it alone.

Bucketing: prompts are padded to `prompt_bucket` multiples, and the bucket
key is (padded prompt length, max_new_tokens), so each distinct bucket
compiles the prefill scan once and batches only compatible requests.
"""
from __future__ import annotations

import functools
from typing import Dict, Hashable, List, Sequence

import jax
import jax.numpy as jnp

from ...configs.base import ArchConfig
from ...core.quant import fake_quant
from ...core.tiling import round_up
from ...models import transformer as tf
from ..api import PAD_REQUEST_ID, Request, Result


def quantized_lm_params(params, bits: int):
    """Fake-quant view of the LM weight matrices (norms / biases untouched)."""
    def walk(path, x):
        key = jax.tree_util.keystr(path)
        if x.ndim >= 2 and (".w" in key or "w_" in key) and "norm" not in key:
            return fake_quant(x, bits, None)
        return x
    return jax.tree_util.tree_map_with_path(walk, params)


class LMRunner:
    """Greedy batched generation over the unified LM (`ModelRunner`)."""

    def __init__(self, cfg: ArchConfig, params, *, max_seq: int = 512,
                 quant_bits: int = 0, prompt_bucket: int = 8):
        self.cfg = cfg
        self.max_seq = max_seq
        self.prompt_bucket = prompt_bucket
        self.params = quantized_lm_params(params, quant_bits) if quant_bits else params

        @jax.jit
        def step(params, cache, tokens, pos_vec):
            """One greedy decode step at per-request positions [B]."""
            logits, cache = tf.decode_step(params, cache, {"tokens": tokens},
                                           pos_vec, cfg)
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            return nxt[:, None], cache            # [B, 1] — feeds the next step

        @jax.jit
        def prefill(params, cache, toks, lens):
            """Masked teacher-forced prefill: one jit'd scan over the prompt
            block. Rows past their own prompt length freeze their caches, and
            each row's first decode token is read off at its own last prompt
            position — ragged prompts decode bit-identically to solo runs."""

            def body(carry, xs):
                cache, first = carry
                tok, p = xs                       # tok [B], p scalar position
                logits, cache = tf.decode_step(
                    params, cache, {"tokens": tok[:, None]}, p, cfg,
                    active=p < lens)
                nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
                first = jnp.where(p == lens - 1, nxt, first)
                return (cache, first), None

            plen = toks.shape[1]
            positions = jnp.arange(plen, dtype=jnp.int32)
            first0 = jnp.zeros((toks.shape[0],), jnp.int32)
            (cache, first), _ = jax.lax.scan(body, (cache, first0),
                                             (toks.T, positions))
            return first[:, None], cache          # [B, 1] — first decode input

        self._step = step
        self._prefill = prefill

    # -- ModelRunner protocol ------------------------------------------------

    def _padded_len(self, prompt: Sequence[int]) -> int:
        return round_up(max(len(prompt), 1), self.prompt_bucket)

    def bucket_key(self, request: Request) -> Hashable:
        return (self._padded_len(request.payload),
                int(request.options.get("max_new_tokens", 0)))

    def filler(self, request: Request) -> Request:
        # zero-length prompt: never active in the prefill mask, decode output
        # discarded by the engine
        return Request(PAD_REQUEST_ID, [], dict(request.options))

    def run(self, batch: Sequence[Request]) -> List[Result]:
        prompts = [list(r.payload) for r in batch]
        num_tokens = int(batch[0].options.get("max_new_tokens", 0))
        plen = self._padded_len(max(prompts, key=len) if prompts else [0])
        assert plen + num_tokens <= self.max_seq, (
            f"prompt bucket {plen} + {num_tokens} new tokens exceeds "
            f"max_seq {self.max_seq}")

        b = len(batch)
        toks = jnp.zeros((b, plen), jnp.int32)
        for i, p in enumerate(prompts):
            if p:
                toks = toks.at[i, :len(p)].set(jnp.array(p, jnp.int32))
        lens = jnp.array([len(p) for p in prompts], jnp.int32)

        cache = tf.init_cache(self.cfg, b, self.max_seq)
        cur, cache = self._prefill(self.params, cache, toks, lens)
        out = [list(p) for p in prompts]
        for k in range(num_tokens):
            pos_vec = lens + k                   # per-request decode position
            for i in range(b):
                out[i].append(int(cur[i, 0]))
            cur, cache = self._step(self.params, cache, cur, pos_vec)

        return [
            Result(r.request_id, out[i], stats={
                "prompt_len": len(prompts[i]),
                "padded_len": plen,
                "new_tokens": num_tokens,
            })
            for i, r in enumerate(batch)
        ]
