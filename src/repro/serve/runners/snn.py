"""SNN runner: batched spiking-VGG9 inference behind the `ModelRunner` protocol.

Wraps `models.vgg9.vgg9_infer_hybrid` — the fused dense-core + sparse-core
serving graph — under a `core.hybrid.plan_vgg9_inference` plan sized to the
engine's fixed slot count, so every batch reuses one compiled graph. Image
requests are stacked into the slot batch (zero images fill empty slots; all
layers are row-independent, so real rows are bit-identical to a direct
`vgg9_infer_hybrid` call on the same batch), and the fused pipeline's
occupancy/skip counters are split back out per request:

* spike counts — the per-image input/output sums the fused graph measures
  ([B] vectors; 0/1 spikes make the split exact);
* tile-skip rates — each request's rows of the folded [T*B·H·W, K] matmul
  re-tiled at the layer's block size, i.e. the skip rate the occupancy map
  would deliver if the request were served alone (a tile straddling two
  images never bills the silent one);
* paper-model energy — Eq. 3 workloads built from each request's *measured*
  input-spike counts, priced with the plan's NC allocation and the FPGA
  power model (`core.energy.energy_per_image`).

Data-mesh sharding: under an ambient compute mesh (`dist.context`) whose
``'data'`` axis divides the slot count, `run` switches to
`vgg9_infer_hybrid_sharded` — the folded [T*B·H·W, K] matmuls split across
devices, weights replicated, and the per-shard occupancy counters are
re-assembled so every per-request stat (skip rate, spike counts, energy) is
identical to the single-device run. `EngineCore` needs no changes: sharding
is a runner concern, engaged by wrapping engine stepping in
``compute_mesh(mesh)``.
"""
from __future__ import annotations

from typing import Dict, Hashable, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from ...core.energy import analytical_energy_per_image, energy_per_image
from ...core.hybrid import HybridPlan, plan_vgg9_inference
from ...core.workload import (conv_workload, dense_input_workload, fc_workload)
from ...dist.context import current_mesh
from ...models.vgg9 import (VGG9Config, conv_names, vgg9_infer_hybrid,
                            vgg9_infer_hybrid_sharded)
from ..api import (PAD_REQUEST_ID, Request, Result, SlotProgress, StepBudget,
                   StepReport)


def _per_request_skip(row_occ: np.ndarray, block_m: int, rows: int,
                      rows_per_slice: int, batch: int) -> np.ndarray:
    """Split a folded layer's occupancy back out per request.

    row_occ: [M_pad, K/bk] 0/1 spike occupancy at (row x k-tile) granularity,
    rows ordered (t*batch + b)*rows_per_slice + pixel. For each request we
    gather *its own* rows (in folded order — the order a solo run would fold
    them) and re-tile them at the layer's block_m: the returned skip rate is
    the fraction of (block_m x block_k) tiles the occupancy map would skip if
    the request were served alone with the same kernel plan. This makes the
    per-request number independent of who shares a straddled tile — a silent
    request reports exactly 1.0 next to a dense neighbour — which is the
    intrinsic sparsity signal a co-batching scheduler needs.
    """
    kt = row_occ.shape[1]
    owner = (np.arange(rows) // rows_per_slice) % batch  # folded slice -> request
    skip = np.zeros(batch)
    for b in range(batch):
        rb = row_occ[:rows][owner == b]                  # [T*rows_per_slice, kt]
        pad = (-len(rb)) % block_m
        if pad:
            rb = np.concatenate([rb, np.zeros((pad, kt), rb.dtype)])
        occ = rb.reshape(-1, block_m, kt).any(axis=1)
        skip[b] = 1.0 - occ.sum() / occ.size
    return skip


def _per_timestep_occupancy(row_occ: np.ndarray, rows: int,
                            rows_per_slice: int, batch: int) -> np.ndarray:
    """Per-request per-timestep active-row fraction, [T, B].

    Rows of the folded matmul are ordered (t*batch + b)*rows_per_slice +
    pixel, so slicing the 0/1 row occupancy back out by (t, b) gives each
    request's sparsity *trace over timesteps* — the per-timestep stat the
    engine streams through `poll_partial` while a request is in flight.
    """
    active = row_occ[:rows].any(axis=1).astype(np.float64)
    t = rows // (batch * rows_per_slice)
    return active.reshape(t, batch, rows_per_slice).mean(axis=2)


class SNNRunner:
    """Fixed-slot spiking-VGG9 serving (`ModelRunner`)."""

    def __init__(self, cfg: VGG9Config, params, *, interpret: bool = True):
        self.cfg = cfg
        self.params = params
        self.interpret = interpret
        self._plans: Dict[int, HybridPlan] = {}

    def plan(self, batch: int) -> HybridPlan:
        """The inference plan for a slot count (cached: plans are static jit
        arguments, so one plan per batch size means one compiled graph)."""
        if batch not in self._plans:
            self._plans[batch] = plan_vgg9_inference(self.cfg, batch)
        return self._plans[batch]

    # -- ModelRunner protocol ------------------------------------------------

    def bucket_key(self, request: Request) -> Hashable:
        return tuple(np.shape(request.payload))

    def filler(self, request: Request) -> Request:
        return Request(PAD_REQUEST_ID, jnp.zeros_like(jnp.asarray(request.payload)))

    def _data_shards(self, n: int) -> int:
        """How many ways to split a slot batch: the ambient mesh's 'data'
        axis size when it divides the batch, else 1 (unsharded)."""
        mesh = current_mesh()
        if mesh is None or "data" not in mesh.axis_names:
            return 1
        ndev = int(mesh.shape["data"])
        return ndev if ndev > 1 and n % ndev == 0 else 1

    def _run_unsharded(self, images, n: int):
        plan = self.plan(n)
        logits, counts, stats = vgg9_infer_hybrid(
            self.params, images, self.cfg, interpret=self.interpret,
            plan=plan, return_stats=True)
        batch_skip = {k: float(v["skip_rate"]) for k, v in stats.items()
                      if "skip_rate" in v}
        out_spikes = {k: np.asarray(v["out_spikes_per_image"], np.float64)
                      for k, v in stats.items()}
        in_spikes = {k: np.asarray(v["in_spikes_per_image"], np.float64)
                     for k, v in stats.items() if "in_spikes_per_image" in v}

        per_req_skip: Dict[str, np.ndarray] = {}
        ts_occ: Dict[str, np.ndarray] = {}
        for name, st in stats.items():
            if "occ_map" not in st:
                continue
            ks = plan.layer(name).kernel
            t = self.cfg.timesteps
            rps = ks.m // (t * n)
            row_occ = np.asarray(st["row_occ"])
            per_req_skip[name] = _per_request_skip(
                row_occ, int(st["block_m"]), int(st["rows"]),
                rows_per_slice=rps, batch=n)
            ts_occ[name] = _per_timestep_occupancy(
                row_occ, int(st["rows"]), rows_per_slice=rps, batch=n)
        return (np.asarray(logits), batch_skip, out_spikes, in_spikes,
                per_req_skip, ts_occ)

    def _run_sharded(self, images, n: int, ndev: int):
        """Split the slot batch over the data mesh (`vgg9_infer_hybrid_sharded`)
        and re-assemble per-request counters from the per-shard stats.

        Per-image spike vectors come back shard-concatenated (already global);
        occupancy maps come back stacked per shard, so per-request skip rates
        are computed shard-by-shard — device ``d`` owns requests
        ``[d*n/ndev, (d+1)*n/ndev)`` — and written into the global vector.
        The numbers match the unsharded run exactly: rows_per_slice and the
        128-row sparse M tile are batch-size-invariant, so re-tiling a
        request's own rows gives the same served-alone skip rate."""
        mesh = current_mesh()
        b_local = n // ndev
        plan = self.plan(b_local)
        logits, counts, stats = vgg9_infer_hybrid_sharded(
            self.params, images, self.cfg, mesh=mesh, interpret=self.interpret,
            plan=plan, return_stats=True)
        batch_skip = {k: float(np.mean(np.asarray(v["skip_rate"])))
                      for k, v in stats.items() if "skip_rate" in v}
        out_spikes = {k: np.asarray(v["out_spikes_per_image"], np.float64)
                      for k, v in stats.items()}
        in_spikes = {k: np.asarray(v["in_spikes_per_image"], np.float64)
                     for k, v in stats.items() if "in_spikes_per_image" in v}

        per_req_skip: Dict[str, np.ndarray] = {}
        ts_occ: Dict[str, np.ndarray] = {}
        t = self.cfg.timesteps
        for name, st in stats.items():
            if "occ_map" not in st:
                continue
            ks = plan.layer(name).kernel
            rps = ks.m // (t * b_local)
            row_occ = np.asarray(st["row_occ"])
            skip = np.zeros(n)
            occ_t = np.zeros((t, n))
            for d in range(ndev):
                sl = slice(d * b_local, (d + 1) * b_local)
                rows_d = int(np.asarray(st["rows"])[d])
                skip[sl] = _per_request_skip(
                    row_occ[d], int(np.asarray(st["block_m"])[d]), rows_d,
                    rows_per_slice=rps, batch=b_local)
                occ_t[:, sl] = _per_timestep_occupancy(
                    row_occ[d], rows_d, rows_per_slice=rps, batch=b_local)
            per_req_skip[name] = skip
            ts_occ[name] = occ_t
        return (np.asarray(logits), batch_skip, out_spikes, in_spikes,
                per_req_skip, ts_occ)

    def run(self, batch: Sequence[Request]) -> List[Result]:
        images = jnp.stack([jnp.asarray(r.payload) for r in batch])
        n = len(batch)
        ndev = self._data_shards(n)
        if ndev > 1:
            logits, batch_skip, out_spikes, in_spikes, per_req_skip, ts_occ = \
                self._run_sharded(images, n, ndev)
        else:
            logits, batch_skip, out_spikes, in_spikes, per_req_skip, ts_occ = \
                self._run_unsharded(images, n)

        # energy is priced with the full-slot-count plan in both modes so a
        # request's Eq. 3 estimate doesn't change with the device count
        plan = self.plan(n)
        energies = [self._energy_estimate(plan, {k: v[i] for k, v in in_spikes.items()})
                    for i in range(n)]

        # batch-context cost: Eq. 3 priced on the batch's *total* measured
        # spikes (pad slots are zero images and contribute nothing). A
        # request's served_energy_j — its share of the batch it actually rode
        # in — is what a sparsity-aware scheduler improves for sparse
        # requests: co-batched with dense stragglers, the batch total (and
        # therefore the share) is dominated by the straggler's spikes.
        n_real = sum(1 for r in batch if not r.is_pad) or 1
        batch_est = self._energy_estimate(
            plan, {k: float(v.sum()) for k, v in in_spikes.items()})
        batch_stats = {
            "batch_energy_j": batch_est["energy_j"],
            "batch_latency_s": batch_est["latency_s"],
            "batch_real": n_real,
            "served_energy_j": batch_est["energy_j"] / n_real,
            # the analytical (per-op) model's view of the same share, so
            # serving records always carry both cost models side by side
            "served_energy_analytical_j":
                batch_est["energy_analytical_j"] / n_real,
            # active numerics: which weight precision served this request
            "precision": self.precision,
            "wbytes_per": self.wbytes_per,
        }

        results = []
        for i, req in enumerate(batch):
            results.append(Result(req.request_id, logits[i], stats={
                "skip_rate": {k: float(v[i]) for k, v in per_req_skip.items()},
                "batch_skip_rate": batch_skip,
                "out_spikes": {k: float(v[i]) for k, v in out_spikes.items()},
                "in_spikes": {k: float(v[i]) for k, v in in_spikes.items()},
                "spike_total": float(sum(v[i] for v in out_spikes.values())),
                "ts_occupancy": {k: [float(x) for x in v[:, i]]
                                 for k, v in ts_occ.items()},
                **energies[i],
                **batch_stats,
            }))
        return results

    # -- continuous admission ------------------------------------------------

    def session_key(self, request: Request) -> Hashable:
        # one compiled fused graph per image shape: only same-shape images
        # may share a live session's slot batch
        return tuple(np.shape(request.payload))

    def open_session(self, slots: int) -> "_SNNSession":
        return _SNNSession(self, slots)

    # -- paper-model energy --------------------------------------------------

    def _energy_estimate(self, plan: HybridPlan, in_spikes: Dict[str, float]) -> Dict[str, float]:
        """Eq. 3 workloads from one request's measured input spikes, priced
        with the plan's NC allocation and the calibrated FPGA power model."""
        cfg = self.cfg
        convs = cfg.conv_channels
        t = cfg.timesteps
        hw = cfg.img_hw
        n_mp = sum(1 for s in cfg.stages if s == "MP")
        flat = (hw // (2 ** n_mp)) ** 2 * convs[-1]
        wbytes_per = 0.5 if cfg.quant_bits == 4 else 4.0
        precision = "int4" if cfg.quant_bits == 4 else "fp32"

        workloads = [dense_input_workload("conv0", hw, hw, convs[0], t)]
        weight_bytes = [9 * cfg.in_ch * convs[0] * wbytes_per]
        cin = convs[0]
        for i, name in enumerate(conv_names(cfg)[1:], start=1):
            workloads.append(conv_workload(name, convs[i], 9, in_spikes[name]))
            weight_bytes.append(9 * cin * convs[i] * wbytes_per)
            cin = convs[i]
        for name, d_in, d_out in (("fc0", flat, cfg.fc_dim),
                                  ("fc1", cfg.fc_dim, cfg.population)):
            workloads.append(fc_workload(name, d_out, in_spikes[name]))
            weight_bytes.append(d_in * d_out * wbytes_per)

        est = energy_per_image(workloads, plan.cores(), weight_bytes, precision)
        ana = analytical_energy_per_image(workloads, precision)
        return {"energy_j": est["energy_j"], "latency_s": est["latency_s"],
                "energy_analytical_j": ana["energy_j"]}

    @property
    def precision(self) -> str:
        return "int4" if self.cfg.quant_bits == 4 else "fp32"

    @property
    def wbytes_per(self) -> float:
        return 0.5 if self.cfg.quant_bits == 4 else 4.0


class _SNNSession:
    """Slot-refill session: each engine step runs one fused T-timestep batch.

    The spiking VGG9 is feedforward over a fixed timestep window, so a
    request occupies its slot for exactly one step — "continuous admission"
    for this workload means freed (zero-image padding) slots are refilled
    with real queued work at every step boundary instead of only between
    run-to-completion batches. Execution reuses `SNNRunner.run` on the full
    slot width (free slots become zero-image fillers), so row-independence
    keeps mid-stream-admitted requests bit-identical to solo runs.
    """

    def __init__(self, runner: SNNRunner, slots: int):
        self.runner = runner
        self.slots = slots
        self.req: List[Optional[Request]] = [None] * slots

    def admit(self, slot: int, request: Request) -> Optional[Result]:
        assert self.req[slot] is None, f"slot {slot} busy"
        self.req[slot] = request
        return None

    def cancel(self, slot: int) -> Result:
        """An SNN request holds no device state between steps (the fused
        graph runs whole); cancellation just frees the slot."""
        assert self.req[slot] is not None, f"slot {slot} empty"
        req = self.req[slot]
        self.req[slot] = None
        return Result(req.request_id, None, stats={}, status="cancelled")

    def step(self, budget: StepBudget = StepBudget()) -> StepReport:
        """One fused T-timestep batch. The SNN's work unit is the timestep;
        the fused graph always spends all T per occupied slot (a partial-T
        graph would be a different compilation), so the budget is reported
        as cost rather than enforced. Each finished request's per-timestep
        sparsity trace (input-row occupancy per mapped layer) is emitted as
        T partial entries for `EngineCore.poll_partial`."""
        occupied = [i for i in range(self.slots) if self.req[i] is not None]
        if not occupied:
            return StepReport()
        ref = self.req[occupied[0]]
        batch = [self.req[i] if self.req[i] is not None
                 else self.runner.filler(ref) for i in range(self.slots)]
        results = self.runner.run(batch)
        t = self.runner.cfg.timesteps
        finished = {}
        progress = {}
        for i in occupied:
            res = results[i]
            trace = res.stats.get("ts_occupancy", {})
            emitted = tuple({layer: vals[k] for layer, vals in trace.items()}
                            for k in range(t))
            progress[i] = SlotProgress(
                request_id=res.request_id, phase="infer",
                units_done=t, units_total=t, emitted=emitted)
            finished[i] = res
            self.req[i] = None
        return StepReport(finished=finished, progress=progress,
                          cost={"units": t * len(occupied), "timesteps": t})
