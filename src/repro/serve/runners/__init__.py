"""Pluggable workload runners for the unified serving engine."""
from .lm import LMRunner
from .snn import SNNRunner

__all__ = ["LMRunner", "SNNRunner"]
