"""Self-speculation draft proposal for LM serving (no second model).

Speculative decoding splits token generation into a cheap *draft* and an
exact *verify*: a proposer guesses the next K tokens, the target model
scores all K+1 positions in ONE launch, and the longest prefix of the draft
that matches the model's own selections is accepted — plus the model's
corrected token at the first mismatch (so every verify launch emits between
1 and K+1 tokens). The output stream is bit-identical to plain decode by
construction: every emitted token is the model's own pick at its position;
the draft only decides how many positions one launch advances.

This module is the *draft* half. The verify half is the existing
`transformer.decode_chunk` ragged multi-token launch — the serving session
(`runners.lm._LMSession`) feeds a drafting row ``[pending, d1..dK]`` with
``take == K+1`` and reads K+1 next-token distributions back, alongside
slot-mates that are prefilling or plain-decoding in the same launch.

`NGramProposer` is self-speculation via prompt lookup (the draft-model-free
scheme): find the most recent earlier occurrence of the request's own
trailing n-gram and propose the tokens that followed it. Repetitive
structure — code, templated text, the token loops small models fall into —
yields high accept rates for free; on non-repetitive streams the proposer
returns no draft and the row decodes plainly (speculation never costs
correctness, only wasted verify columns).

Proposers are pluggable (`Proposer` protocol) so the test battery can drive
adversarial drafts (all-wrong / all-right / partially-right / empty) through
the same acceptance/rollback machinery, and a future small draft model can
slot in without touching the session.
"""
from __future__ import annotations

from typing import List, Protocol, Sequence, runtime_checkable


@runtime_checkable
class Proposer(Protocol):
    """Draft source for self-speculative decode."""

    def propose(self, history: Sequence[int], k: int) -> List[int]:
        """Up to ``k`` draft tokens continuing ``history`` (the request's
        prompt + everything emitted so far). An empty list means "no
        guess" — the row falls back to plain one-token decode this step.
        Returned ids must be valid vocabulary tokens: they are fed through
        the embedding in the verify launch."""
        ...


class NGramProposer:
    """Prompt-lookup drafting: continue the most recent match of the
    trailing n-gram.

    For n from ``max_ngram`` down to ``min_ngram``: take the history's last
    n tokens, scan backwards for the most recent earlier occurrence of that
    n-gram, and propose the (up to k) tokens that followed it. Longer
    n-grams are preferred — a longer matched context predicts the
    continuation better; the most recent match is preferred over older ones
    for the same reason. No match at any n => no draft.
    """

    def __init__(self, *, max_ngram: int = 3, min_ngram: int = 1,
                 max_k: int = 8):
        assert 1 <= min_ngram <= max_ngram, (min_ngram, max_ngram)
        assert max_k >= 1, max_k
        self.max_ngram = max_ngram
        self.min_ngram = min_ngram
        self.max_k = max_k

    def propose(self, history: Sequence[int], k: int) -> List[int]:
        k = min(int(k), self.max_k)
        n_hist = len(history)
        if k <= 0 or n_hist < self.min_ngram + 1:
            return []
        for n in range(min(self.max_ngram, n_hist - 1), self.min_ngram - 1, -1):
            suffix = tuple(history[n_hist - n:])
            # most recent occurrence whose continuation lies inside history
            for start in range(n_hist - n - 1, -1, -1):
                if tuple(history[start:start + n]) == suffix:
                    cont = history[start + n:start + n + k]
                    return [int(t) for t in cont]
        return []
