"""Sampling layer for LM serving: temperature / top-k / top-p + logprobs.

Replaces greedy-only decode with the batched sampling contract production
engines expose (cf. the lmdeploy `sampling_utils` surface the ROADMAP names):
per-request ``temperature`` / ``top_k`` / ``top_p`` knobs, a per-request
PRNG ``seed``, and the sampled token's logprob surfaced on `api.Result`.

Determinism is the design center, not an afterthought. Serving correctness
elsewhere in this stack leans on *replay*: the router re-routes in-flight
requests off faulted replicas by resubmitting the frozen `Request` and
asserting bit-identical outputs (`serve.router`), and the speculative
decoder (`serve.speculative`) must sample the same token whether a position
is reached one-token-at-a-time or inside a K-token verify launch. Both
demand that the sampled token at generation index ``i`` be a pure function
of ``(request seed, i, logits)`` — never of engine state, step grouping, or
how many times the request has been partially executed. `token_rng`
therefore derives an independent generator per (seed, index) pair from a
`numpy.random.SeedSequence`; no RNG state is carried between tokens.

All math here is float64 numpy on host — this is the *selection* layer over
device logits, sized [vocab] per emitted token, and doubles as the reference
the differential tests (`tests/test_sampling.py`) check against.

Filter semantics (applied in this order, standard contract):

1. temperature — logits / T. ``T == 0`` is exact greedy argmax (no RNG).
2. top_k       — keep the k highest logits (ties broken toward lower token
                 ids, stable); 0 disables.
3. top_p       — keep the smallest prefix of the sorted distribution whose
                 cumulative probability reaches p (the crossing token is
                 kept; the top token always survives); 1.0 disables.

The surfaced logprob is ``log_softmax(raw logits)[token]`` — the model's
own distribution, *before* temperature/filtering, so downstream consumers
(rescoring, accept-rate analysis) see calibrated values regardless of the
sampling knobs.
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar, Mapping, Optional, Tuple

import numpy as np

#: request-option keys this layer owns; presence of any of them on a
#: `Request.options` opts the request into the sampling path
OPTION_KEYS = ("temperature", "top_k", "top_p", "seed", "logprobs")


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling configuration, parsed from `Request.options`.

    temperature: 0.0 (default) is exact greedy argmax — bit-identical to a
                 request that never opted into sampling. > 0 samples.
    top_k:       keep only the k highest logits before sampling; 0 = all.
    top_p:       nucleus filtering — keep the smallest probability mass
                 >= top_p; 1.0 = all.
    seed:        per-request PRNG seed. The token sampled at generation
                 index i depends only on (seed, i, logits), so replays and
                 speculative verification reproduce the stream exactly.
    logprobs:    surface per-token logprobs on `Result.stats` even for
                 greedy requests (sampled requests always surface them;
                 greedy ones only on request, because it forces a logits
                 transfer the argmax path otherwise skips).
    """

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0
    logprobs: bool = False

    KEYS: ClassVar[Tuple[str, ...]] = OPTION_KEYS

    def __post_init__(self):
        assert self.temperature >= 0.0, f"temperature {self.temperature} < 0"
        assert self.top_k >= 0, f"top_k {self.top_k} < 0"
        assert 0.0 < self.top_p <= 1.0, f"top_p {self.top_p} not in (0, 1]"

    @property
    def greedy(self) -> bool:
        """True when selection is argmax (temperature 0): no RNG involved."""
        return self.temperature == 0.0

    @property
    def track_logprobs(self) -> bool:
        """Whether the session must fetch logits for this request every
        step: sampled requests always (selection needs the distribution),
        greedy ones only when logprobs were explicitly requested."""
        return (not self.greedy) or self.logprobs

    @classmethod
    def from_options(cls, options: Mapping) -> Optional["SamplingParams"]:
        """Parse request options; None when the request never opted in
        (pure greedy decode, no logprob tracking — the zero-cost default).

        Ported onto the validated `api.RequestOptions` surface: parsing
        happens at the submit boundary's rules (unknown sampling values
        raise there, not here), and this is now just the opt-in view.
        """
        if not any(k in options for k in cls.KEYS):
            return None
        from .api import RequestOptions
        parsed = RequestOptions.parse(
            {k: v for k, v in options.items() if k in cls.KEYS})
        return parsed.sampling


def _check_option_key_registry():
    # the submit-boundary validator (api.OPTION_SPECS) must know every key
    # this layer reads, or a valid sampling request would be rejected at
    # submit; checked at import so the two registries cannot drift.
    from .api import SAMPLING_OPTION_KEYS
    assert SAMPLING_OPTION_KEYS == OPTION_KEYS, (
        SAMPLING_OPTION_KEYS, OPTION_KEYS)


_check_option_key_registry()


def log_softmax(logits: np.ndarray) -> np.ndarray:
    """Numerically stable log-softmax over the last axis, in float64."""
    x = np.asarray(logits, np.float64)
    x = x - x.max(axis=-1, keepdims=True)
    return x - np.log(np.exp(x).sum(axis=-1, keepdims=True))


def apply_top_k(logits: np.ndarray, k: int) -> np.ndarray:
    """Mask all but the k highest logits to -inf. Ties at the boundary
    break toward lower token ids (stable sort), so the kept set is a pure
    function of the logits — required for cross-run determinism."""
    x = np.asarray(logits, np.float64)
    if k <= 0 or k >= x.size:
        return x
    order = np.argsort(-x, kind="stable")
    out = np.full_like(x, -np.inf)
    out[order[:k]] = x[order[:k]]
    return out


def apply_top_p(logits: np.ndarray, p: float) -> np.ndarray:
    """Nucleus filter: keep the smallest prefix of the probability-sorted
    distribution whose cumulative mass reaches ``p`` (the crossing token is
    kept, so the top token always survives). -inf entries (e.g. from a
    prior top-k pass) stay masked."""
    x = np.asarray(logits, np.float64)
    if p >= 1.0:
        return x
    order = np.argsort(-x, kind="stable")
    finite = np.isfinite(x[order])
    shifted = np.where(finite, x[order] - x[order[0]], -np.inf)
    probs = np.exp(shifted)
    probs = np.where(finite, probs, 0.0)
    probs = probs / probs.sum()
    cum = np.cumsum(probs)
    cutoff = int(np.searchsorted(cum, p, side="left")) + 1
    out = np.full_like(x, -np.inf)
    keep = order[:cutoff]
    out[keep] = x[keep]
    return out


def token_rng(seed: int, index: int) -> np.random.Generator:
    """Independent generator for one (request seed, generation index) pair.

    No state flows between tokens: the stream is a pure function of the
    pair, so replays, engine restarts, and speculative verify launches all
    reproduce the same draw for the same position.
    """
    entropy = (int(seed) & 0xFFFFFFFFFFFFFFFF, int(index))
    return np.random.default_rng(np.random.SeedSequence(entropy))


def sample(logits: np.ndarray, params: SamplingParams,
           index: int) -> Tuple[int, float]:
    """Select one token from ``logits`` [vocab] at generation ``index``.

    Returns (token, logprob) where logprob is taken from the *raw*
    distribution (see module docstring). temperature == 0 is exact argmax —
    the same tie-break (first maximum) as the device greedy path.
    """
    lsm = log_softmax(logits)
    if params.greedy:
        tok = int(np.argmax(np.asarray(logits)))
        return tok, float(lsm[tok])
    x = np.asarray(logits, np.float64) / params.temperature
    x = apply_top_k(x, params.top_k)
    x = apply_top_p(x, params.top_p)
    finite = np.isfinite(x)
    shifted = np.where(finite, x - x[finite].max(), -np.inf)
    probs = np.where(finite, np.exp(shifted), 0.0)
    probs = probs / probs.sum()
    tok = int(token_rng(params.seed, index).choice(probs.size, p=probs))
    return tok, float(lsm[tok])
