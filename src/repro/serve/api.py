"""Workload-agnostic serving API: Request/Result dataclasses + runner protocol.

The paper's hybrid architecture is an inference *serving* design: a dense
core plus sparse event-driven cores fed by a stream of inputs. This module is
the software seam for that design — one request/result vocabulary shared by
every workload the engine can serve (today: the unified LM and the spiking
VGG9), so the scheduler (`serve.core.EngineCore`) never needs to know what a
payload is.

Sparsity-aware co-design (Aliyev et al., arXiv:2408.14437) requires the
software stack to surface *per-request* sparsity to the scheduler; `Result`
therefore carries per-request stats next to the outputs: tile-skip rates of
the occupancy-mapped kernels, spike counts, and the paper-model energy
estimate for SNN requests; prompt/decode accounting for LM requests.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Hashable, Mapping, Protocol, Sequence, runtime_checkable

# Request id used for the filler requests that pad a batch to the full slot
# count. Results for pad slots are dropped by the engine, never surfaced.
PAD_REQUEST_ID = -1


@dataclasses.dataclass(frozen=True)
class Request:
    """One admitted unit of work.

    payload is workload-defined: a token-id list for the LM runner, an
    [H, W, C] image for the SNN runner. options carry per-request knobs the
    runner understands (e.g. ``max_new_tokens`` for the LM).
    """
    request_id: int
    payload: Any
    options: Mapping[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def is_pad(self) -> bool:
        return self.request_id < 0


@dataclasses.dataclass(frozen=True)
class Result:
    """Outputs *and* per-request stats for one completed request.

    outputs: generated token list (LM) or class logits (SNN).
    stats:   flat mapping of per-request measurements. SNN results include
             ``skip_rate`` / ``batch_skip_rate`` (per layer), ``out_spikes``
             / ``in_spikes`` (per layer), ``spike_total``, and the FPGA-model
             ``energy_j`` / ``latency_s`` estimate; LM results include
             ``prompt_len``, ``padded_len``, ``new_tokens``.
    """
    request_id: int
    outputs: Any
    stats: Mapping[str, Any] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Scheduler configuration shared by all workloads.

    slots:     fixed batch width. Every runner invocation sees exactly this
               many requests (short batches are padded with runner fillers) —
               the static-shape contract that keeps TPU serving free of
               per-batch recompilation.
    max_queue: admission bound; `submit` past it raises ``QueueFull``.
    """
    slots: int = 8
    max_queue: int = 256


class QueueFull(RuntimeError):
    """Raised by `EngineCore.submit` when the admission queue is at capacity."""


@runtime_checkable
class ModelRunner(Protocol):
    """What a workload must provide to be served by `EngineCore`.

    The engine owns admission, bucketing, slot lifecycle and result routing;
    the runner owns tensors. ``run`` is handed a batch of exactly
    ``EngineConfig.slots`` requests whose ``bucket_key`` all match and must
    return one `Result` per request, in order (pad results included; the
    engine drops them).
    """

    def bucket_key(self, request: Request) -> Hashable:
        """Requests are only batched together when their keys are equal
        (e.g. padded prompt length + decode budget for the LM, image shape
        for the SNN): the padding/bucketing contract of the scheduler."""
        ...

    def filler(self, request: Request) -> Request:
        """A `PAD_REQUEST_ID` request compatible with ``request``'s bucket,
        used by the engine to pad short batches to the full slot count."""
        ...

    def run(self, batch: Sequence[Request]) -> Sequence[Result]:
        """Execute one fixed-slot batch."""
        ...
