"""Workload-agnostic serving API: Request/Result dataclasses + runner protocol.

The paper's hybrid architecture is an inference *serving* design: a dense
core plus sparse event-driven cores fed by a stream of inputs. This module is
the software seam for that design — one request/result vocabulary shared by
every workload the engine can serve (today: the unified LM and the spiking
VGG9), so the scheduler (`serve.core.EngineCore`) never needs to know what a
payload is.

Sparsity-aware co-design (Aliyev et al., arXiv:2408.14437) requires the
software stack to surface *per-request* sparsity to the scheduler; `Result`
therefore carries per-request stats next to the outputs: tile-skip rates of
the occupancy-mapped kernels, spike counts, and the paper-model energy
estimate for SNN requests; prompt/decode accounting for LM requests.
"""
from __future__ import annotations

import dataclasses
from typing import (Any, Hashable, Mapping, Optional, Protocol, Sequence,
                    runtime_checkable)

# Request id used for the filler requests that pad a batch to the full slot
# count. Results for pad slots are dropped by the engine, never surfaced.
PAD_REQUEST_ID = -1


@dataclasses.dataclass(frozen=True)
class Request:
    """One admitted unit of work.

    payload is workload-defined: a token-id list for the LM runner, an
    [H, W, C] image for the SNN runner. options carry per-request knobs the
    runner understands (e.g. ``max_new_tokens`` for the LM).
    """
    request_id: int
    payload: Any
    options: Mapping[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def is_pad(self) -> bool:
        return self.request_id < 0


@dataclasses.dataclass(frozen=True)
class Result:
    """Outputs *and* per-request stats for one completed request.

    outputs: generated token list (LM) or class logits (SNN).
    stats:   flat mapping of per-request measurements.

    SNN result stats (see `runners.snn.SNNRunner`):

    ``skip_rate``        per-layer dict, each value in [0, 1]: the fraction of
                         (block_m x block_k) spike tiles the occupancy map
                         would skip if this request were served *alone* with
                         the same kernel plan (the request's own rows of the
                         folded [T*B*H*W, K] matmul, re-tiled at the layer's
                         block_m). The intrinsic sparsity signal schedulers
                         co-batch on; independent of slot-mates.
    ``batch_skip_rate``  per-layer dict: the skip rate the kernel actually
                         measured for the *whole* batch this request was
                         served in. The gap to ``skip_rate`` is the
                         co-batching penalty (dense neighbours un-skipping
                         tiles that straddle requests).
    ``in_spikes`` /      per-layer dicts: this request's input/output spike
    ``out_spikes``       *counts* (events over all T timesteps; spikes are
                         0/1, so the per-request split of the batch totals is
                         exact). ``spike_total``: sum of ``out_spikes``.
    ``energy_j``         paper Eq. 3 / §V-C dynamic energy estimate for this
                         request served alone, in joules — per-layer FPGA
                         power x per-layer latency from the request's
                         *measured* input-spike workloads, priced with the
                         plan's NC allocation. ``latency_s``: the matching
                         sum-of-layer-latencies estimate, in seconds.
    ``batch_energy_j`` / Eq. 3 energy (J) / latency (s) of the whole batch
    ``batch_latency_s``  this request was served in (workloads = batch total
                         spikes). ``batch_real``: how many non-pad requests
                         shared the batch. ``served_energy_j`` =
                         ``batch_energy_j / batch_real``: this request's
                         share of the energy of the batch it actually rode
                         in — the quantity a sparsity-aware scheduler
                         improves for sparse requests by not co-batching
                         them with dense stragglers.

    LM result stats: ``prompt_len`` (tokens), ``padded_len`` (prompt length
    after bucket padding; equals ``prompt_len`` under continuous admission,
    which feeds prompts unpadded), ``new_tokens`` (decode budget).
    """
    request_id: int
    outputs: Any
    stats: Mapping[str, Any] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Scheduler configuration shared by all workloads.

    slots:     fixed batch width. Every runner invocation sees exactly this
               many requests (short batches are padded with runner fillers) —
               the static-shape contract that keeps TPU serving free of
               per-batch recompilation.
    max_queue: admission bound; `submit` past it raises ``QueueFull``.
    admission: 'continuous' (default) — step-level admission: each
               `EngineCore.step` first refills freed slots from the queue,
               then advances the live runner session one iteration (one
               decode token for the LM, one fused batch for the SNN), so new
               requests join between iterations instead of waiting for the
               current batch to drain. 'batch' — the PR-2 run-to-completion
               policy: one `step` forms one same-bucket batch and runs it to
               completion.
    scheduler: batch-composition policy name, resolved by
               `scheduler.make_scheduler`: 'fifo' (arrival order) or
               'sparsity' (co-batch by observed/predicted tile-skip rate,
               EWMA-learned from per-request `Result` stats).
    """
    slots: int = 8
    max_queue: int = 256
    admission: str = "continuous"
    scheduler: str = "fifo"


class QueueFull(RuntimeError):
    """Raised by `EngineCore.submit` when the admission queue is at capacity."""


@runtime_checkable
class ModelRunner(Protocol):
    """What a workload must provide to be served by `EngineCore`.

    The engine owns admission, bucketing, slot lifecycle and result routing;
    the runner owns tensors. ``run`` is handed a batch of exactly
    ``EngineConfig.slots`` requests whose ``bucket_key`` all match and must
    return one `Result` per request, in order (pad results included; the
    engine drops them).
    """

    def bucket_key(self, request: Request) -> Hashable:
        """Requests are only batched together when their keys are equal
        (e.g. padded prompt length + decode budget for the LM, image shape
        for the SNN): the padding/bucketing contract of the scheduler."""
        ...

    def filler(self, request: Request) -> Request:
        """A `PAD_REQUEST_ID` request compatible with ``request``'s bucket,
        used by the engine to pad short batches to the full slot count."""
        ...

    def run(self, batch: Sequence[Request]) -> Sequence[Result]:
        """Execute one fixed-slot batch."""
        ...

    # -- continuous admission (step-level serving) ---------------------------

    def session_key(self, request: Request) -> Hashable:
        """Compatibility key for *joining a live session*. Coarser than
        ``bucket_key``: the LM accepts any prompt/decode budget that fits
        ``max_seq`` into a running session (slots free and fill
        independently), so its key is constant; the SNN key is the image
        shape (one compiled fused graph per shape)."""
        ...

    def open_session(self, slots: int) -> "RunnerSession":
        """Start a live fixed-slot session for continuous admission."""
        ...


@runtime_checkable
class RunnerSession(Protocol):
    """A live fixed-width batch the engine admits into between iterations.

    The engine drives the session as: ``admit`` requests into free slot
    indices, then ``step`` to advance every occupied slot by one iteration
    (one decode token for the LM; one fused T-timestep batch for the SNN).
    Slots the engine never admitted into are the runner's problem to pad
    (inactive rows for the LM, zero images for the SNN) — the engine only
    guarantees it will not reuse a slot index before the session reported
    the previous occupant finished.
    """

    def admit(self, slot: int, request: Request) -> Optional[Result]:
        """Place ``request`` in slot index ``slot``. May complete degenerate
        requests immediately (e.g. ``max_new_tokens=0``) by returning their
        `Result`; returns None when the request will run in coming steps."""
        ...

    def step(self) -> Mapping[int, Result]:
        """Advance every occupied slot one iteration; returns results for
        the slots that finished this step (their indices are free again)."""
        ...
