"""Workload-agnostic serving API: Request/Result dataclasses + runner protocol.

The paper's hybrid architecture is an inference *serving* design: a dense
core plus sparse event-driven cores fed by a stream of inputs. This module is
the software seam for that design — one request/result vocabulary shared by
every workload the engine can serve (today: the unified LM and the spiking
VGG9), so the scheduler (`serve.core.EngineCore`) never needs to know what a
payload is.

Sparsity-aware co-design (Aliyev et al., arXiv:2408.14437) requires the
software stack to surface *per-request* sparsity to the scheduler; `Result`
therefore carries per-request stats next to the outputs: tile-skip rates of
the occupancy-mapped kernels, spike counts, and the paper-model energy
estimate for SNN requests; prompt/decode accounting for LM requests.
"""
from __future__ import annotations

import dataclasses
from typing import (Any, Hashable, Mapping, Optional, Protocol, Sequence,
                    Tuple, runtime_checkable)

# Request id used for the filler requests that pad a batch to the full slot
# count. Results for pad slots are dropped by the engine, never surfaced.
PAD_REQUEST_ID = -1


@dataclasses.dataclass(frozen=True)
class Request:
    """One admitted unit of work.

    payload is workload-defined: a token-id list for the LM runner, an
    [H, W, C] image for the SNN runner. options carry per-request knobs the
    runner understands: ``max_new_tokens`` for the LM, plus the sampling
    keys the continuous-admission LM runner parses into
    `serve.sampling.SamplingParams` — ``temperature`` (0.0 = greedy),
    ``top_k``, ``top_p``, ``seed`` (per-request PRNG seed; the token at
    generation index i is a pure function of (seed, i, logits), so router
    replay and engine restarts reproduce the stream bit-identically) and
    ``logprobs`` (surface per-token logprobs even for greedy requests).
    Options ride the frozen Request through queue, drain and re-route
    untouched, which is what makes replay determinism possible.

    deadline_s/priority are scheduler-facing lifecycle knobs (first-class,
    not options, because the engine itself acts on them):

    deadline_s: latency SLO in engine-clock seconds *relative to submission*.
                A request past ``arrival_s + deadline_s`` at a step boundary
                is retired with ``Result.status == 'expired'`` (queued or
                resident; residents surface their partial progress). None =
                no deadline.
    priority:   strict admission class for deadline-aware schedulers;
                higher wins over any deadline in a lower class, and the
                tightest deadline wins within a class. Ignored by
                FIFO/sparsity.
    arrival_s:  engine-clock timestamp stamped by `EngineCore.submit` —
                the reference point for ``deadline_s``.
    """
    request_id: int
    payload: Any
    options: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    deadline_s: Optional[float] = None
    priority: int = 0
    arrival_s: float = 0.0

    @property
    def is_pad(self) -> bool:
        return self.request_id < 0

    @property
    def deadline_at(self) -> Optional[float]:
        """Absolute engine-clock deadline, or None."""
        if self.deadline_s is None:
            return None
        return self.arrival_s + self.deadline_s


def _is_int(value: Any) -> bool:
    return isinstance(value, int) and not isinstance(value, bool)


def _is_num(value: Any) -> bool:
    return isinstance(value, (int, float)) and not isinstance(value, bool)


#: validator table for every option key any layer of the stack reads.
#: (predicate, human-readable expectation) — the single place a new
#: per-request knob gets registered so it is accepted at submit() and at
#: the wire boundary.
OPTION_SPECS: Mapping[str, Any] = {
    # LM decode budget
    "max_new_tokens": (lambda v: _is_int(v) and v >= 0, "int >= 0"),
    # sampling layer (serve.sampling.SamplingParams)
    "temperature": (lambda v: _is_num(v) and v >= 0.0, "number >= 0"),
    "top_k": (lambda v: _is_int(v) and v >= 0, "int >= 0"),
    "top_p": (lambda v: _is_num(v) and 0.0 < v <= 1.0, "number in (0, 1]"),
    "seed": (lambda v: _is_int(v), "int"),
    "logprobs": (lambda v: isinstance(v, bool), "bool"),
    # precision control (serve.precision)
    "pin_precision": (lambda v: v in ("fp32", "int4"),
                      "'fp32' or 'int4'"),
    # scheduler hints (serve.scheduler)
    "source": (lambda v: isinstance(v, str), "str"),
    "skip_hint": (lambda v: _is_num(v) and 0.0 <= v <= 1.0,
                  "number in [0, 1]"),
}

#: option keys that opt a request into the sampling path (mirrors
#: `serve.sampling.OPTION_KEYS`; asserted equal there)
SAMPLING_OPTION_KEYS = ("temperature", "top_k", "top_p", "seed", "logprobs")


@dataclasses.dataclass(frozen=True)
class RequestOptions:
    """Validated view of `Request.options`, parsed once at the submit
    boundary.

    Sampling, speculation, precision and the schedulers all read raw
    option dicts; before this class each consumed its keys ad-hoc, so a
    typo'd or ill-typed option surfaced (if ever) mid-step, deep inside a
    runner. `parse` is the single choke point: `EngineCore.submit`,
    `Router.submit` and the wire boundary (`serve.worker`) all call it, so
    unknown keys and ill-typed values fail *at submission* with a message
    naming the key — and a request that made it into the queue is known
    parseable by every downstream consumer.

    ``present`` records which keys the caller actually passed. That
    preservation matters: `serve.sampling.SamplingParams.from_options`
    returns None when *no* sampling key was passed (the zero-cost greedy
    path that never fetches logits), so "absent" and "present with the
    default value" are observably different requests.
    """
    max_new_tokens: int = 0
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0
    logprobs: bool = False
    pin_precision: Optional[str] = None
    source: Optional[str] = None
    skip_hint: Optional[float] = None
    present: Tuple[str, ...] = ()

    KEYS = tuple(OPTION_SPECS)

    @classmethod
    def parse(cls, options: Optional[Mapping[str, Any]]) -> "RequestOptions":
        """Validate a raw option mapping; raises ValueError on unknown
        keys or ill-typed/out-of-range values."""
        options = options or {}
        unknown = sorted(set(options) - set(OPTION_SPECS))
        if unknown:
            raise ValueError(
                f"unknown request option(s) {unknown}; known options: "
                f"{sorted(OPTION_SPECS)}")
        for key, value in options.items():
            ok, expect = OPTION_SPECS[key]
            if not ok(value):
                raise ValueError(
                    f"request option {key!r}={value!r} invalid: expected "
                    f"{expect}")
        fields = {k: options[k] for k in options}
        # numeric knobs normalize to their canonical python type
        if "temperature" in fields:
            fields["temperature"] = float(fields["temperature"])
        if "top_p" in fields:
            fields["top_p"] = float(fields["top_p"])
        if "skip_hint" in fields:
            fields["skip_hint"] = float(fields["skip_hint"])
        return cls(present=tuple(sorted(options)), **fields)

    @property
    def sampling(self):
        """`serve.sampling.SamplingParams` when any sampling key was
        present, else None — the `SamplingParams.from_options` contract,
        ported here so the opt-in semantics live with the validation."""
        if not any(k in self.present for k in SAMPLING_OPTION_KEYS):
            return None
        from .sampling import SamplingParams
        return SamplingParams(temperature=self.temperature, top_k=self.top_k,
                              top_p=self.top_p, seed=self.seed,
                              logprobs=self.logprobs)


def validate_options(options: Optional[Mapping[str, Any]]) -> Mapping[str, Any]:
    """Validate and return ``options`` (convenience over
    `RequestOptions.parse` for call sites that keep the raw mapping)."""
    RequestOptions.parse(options)
    return dict(options or {})


@dataclasses.dataclass(frozen=True)
class SubmitSpec:
    """The one canonical submit shape.

    `EngineCore.submit` and `Router.submit` used to duplicate the same
    ``(payload, *, deadline_s, priority, **options)`` kwarg list; both now
    parse into this spec, and the wire `SubmitMsg` serializes exactly
    these fields — one shape for in-process calls, the router's replay
    log, and the subprocess control plane.
    """
    payload: Any
    deadline_s: Optional[float] = None
    priority: int = 0
    options: Mapping[str, Any] = dataclasses.field(default_factory=dict)

    @classmethod
    def make(cls, payload: Any, *, deadline_s: Optional[float] = None,
             priority: int = 0, options: Optional[Mapping[str, Any]] = None,
             **extra: Any) -> "SubmitSpec":
        """Build + validate a spec from the submit kwarg surface. Option
        keys may come as an explicit ``options=`` mapping, as loose
        keyword arguments, or both (loose kwargs win on conflict)."""
        merged = dict(options or {})
        merged.update(extra)
        if deadline_s is not None:
            deadline_s = float(deadline_s)
            if deadline_s < 0:
                raise ValueError(f"deadline_s {deadline_s} < 0")
        return cls(payload=payload, deadline_s=deadline_s,
                   priority=int(priority),
                   options=validate_options(merged))


@dataclasses.dataclass(frozen=True)
class Result:
    """Outputs *and* per-request stats for one completed request.

    outputs: generated token list (LM) or class logits (SNN).
    stats:   flat mapping of per-request measurements.

    SNN result stats (see `runners.snn.SNNRunner`):

    ``skip_rate``        per-layer dict, each value in [0, 1]: the fraction of
                         (block_m x block_k) spike tiles the occupancy map
                         would skip if this request were served *alone* with
                         the same kernel plan (the request's own rows of the
                         folded [T*B*H*W, K] matmul, re-tiled at the layer's
                         block_m). The intrinsic sparsity signal schedulers
                         co-batch on; independent of slot-mates.
    ``batch_skip_rate``  per-layer dict: the skip rate the kernel actually
                         measured for the *whole* batch this request was
                         served in. The gap to ``skip_rate`` is the
                         co-batching penalty (dense neighbours un-skipping
                         tiles that straddle requests).
    ``in_spikes`` /      per-layer dicts: this request's input/output spike
    ``out_spikes``       *counts* (events over all T timesteps; spikes are
                         0/1, so the per-request split of the batch totals is
                         exact). ``spike_total``: sum of ``out_spikes``.
    ``energy_j``         paper Eq. 3 / §V-C dynamic energy estimate for this
                         request served alone, in joules — per-layer FPGA
                         power x per-layer latency from the request's
                         *measured* input-spike workloads, priced with the
                         plan's NC allocation. ``latency_s``: the matching
                         sum-of-layer-latencies estimate, in seconds.
    ``batch_energy_j`` / Eq. 3 energy (J) / latency (s) of the whole batch
    ``batch_latency_s``  this request was served in (workloads = batch total
                         spikes). ``batch_real``: how many non-pad requests
                         shared the batch. ``served_energy_j`` =
                         ``batch_energy_j / batch_real``: this request's
                         share of the energy of the batch it actually rode
                         in — the quantity a sparsity-aware scheduler
                         improves for sparse requests by not co-batching
                         them with dense stragglers.
    ``energy_analytical_j`` / the same two quantities under the *analytical*
    ``served_energy_analytical_j`` per-op cost model
                         (`core.energy.analytical_energy_per_image`):
                         bottom-up op counting instead of FPGA power x
                         latency. Reported side by side with the Eq. 3
                         figures so the two models' disagreement on any
                         request is measurable.

    ``ts_occupancy``     per-layer dict of length-T lists: the fraction of
                         this request's folded matmul rows that carried at
                         least one spike at each timestep — the per-timestep
                         sparsity trace streamed through
                         `EngineCore.poll_partial` while a request is being
                         served.

    LM result stats: ``prompt_len`` (tokens), ``padded_len`` (prompt length
    after bucket padding; the continuous-admission runner feeds prompts
    unpadded and *asserts* ``padded_len == prompt_len``), ``new_tokens``
    (decode budget), ``prefill_chunks`` (session steps that consumed at
    least one prompt token — ``ceil(prompt_len / chunk)`` under chunked
    prefill), ``ttft_steps`` (session steps from admission through the step
    that emitted the first generated token). Speculative-decode accounting
    (always present under continuous admission): ``drafted_tokens`` /
    ``accepted_tokens`` / ``rejected_tokens``, with accepted + rejected ==
    drafted exactly. Requests that opted into logprob tracking
    (``temperature > 0`` or ``logprobs: True``) also carry ``logprobs``:
    one ``log_softmax(raw logits)[token]`` per generated token.

    Both runners additionally stamp the active numerics on every result:
    ``precision`` ('fp32' or 'int4' — under adaptive serving, the variant
    this request was *actually* served at) and ``wbytes_per`` (bytes per
    weight at that precision: 4.0 fp32, 0.5 int4).

    status: lifecycle outcome —

    ``'ok'``        ran to completion.
    ``'cancelled'`` caller `EngineCore.cancel`.
    ``'expired'``   deadline passed before completion (queued or resident).
    ``'failed'``    the engine's numerics screen caught NaN/Inf in the
                    slot's step outputs and retired the request before the
                    poison could propagate (`EngineConfig.numerics_screen`),
                    or a supervised router exhausted the request's retry
                    budget re-routing it off faulted replicas
                    (`serve.router.Router`).
    ``'rejected'``  shed under sustained overload before ever running — the
                    router's explicit alternative to silently blowing the
                    deadline of everything behind it (`serve.router`).

    Non-'ok' results carry whatever partial outputs/stats the runner had
    produced ('rejected' requests never ran, so they carry none).
    """
    request_id: int
    outputs: Any
    stats: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    status: str = "ok"


@dataclasses.dataclass(frozen=True)
class StepBudget:
    """How much work one `RunnerSession.step` may perform, in workload-native
    units (LM: prompt+decode tokens; SNN: timesteps of the fused graph).

    Decoupling the work a step performs from the wall-clock step itself is
    the decoupled-processing-time idea (arXiv:2311.14447) applied to the
    serving seam: the scheduler spends budget where latency matters.

    units:    total units the whole step may consume (all slots summed), or
              None for no cap. Sessions never starve a slot below one unit —
              the cap trims *extra* prefill allowance, slot-index order.
    chunk:    default per-slot prefill allowance: how many prompt tokens a
              prefilling LM slot may consume this step (decode slots always
              consume exactly one). 1 reproduces token-by-token prefill.
    per_slot: optional per-slot overrides of ``chunk`` — the scheduler's
              budget *split* (e.g. boost the slot racing a deadline).
    """
    units: Optional[int] = None
    chunk: int = 1
    per_slot: Optional[Mapping[int, int]] = None

    def for_slot(self, slot: int) -> int:
        """Prefill allowance for one slot index (always >= 1)."""
        if self.per_slot is not None and slot in self.per_slot:
            return max(1, int(self.per_slot[slot]))
        return max(1, int(self.chunk))


@dataclasses.dataclass(frozen=True)
class SlotProgress:
    """One occupied slot's progress after a session step.

    phase:       workload-defined label ('prefill' | 'decode' for the LM,
                 'infer' for the SNN).
    units_done / consumed vs total work in the budget's units (LM: prompt +
    units_total: budgeted decode tokens; SNN: timesteps).
    emitted:     partial outputs produced *this step* — new tokens for the
                 LM, per-timestep sparsity stats for the SNN. The engine
                 accumulates these per request for `EngineCore.poll_partial`.
    """
    request_id: int
    phase: str
    units_done: int
    units_total: int
    emitted: tuple = ()


@dataclasses.dataclass(frozen=True)
class StepReport:
    """What one `RunnerSession.step` actually did.

    finished: results for the slots that completed this step (their slot
              indices are free again) — the old ``step()`` return value.
    progress: per-occupied-slot `SlotProgress` (finished slots included, so
              their last partials are not lost).
    cost:     measured cost of the step in workload-native units, e.g.
              ``{'units': 9, 'prompt_tokens': 8, 'decode_tokens': 1}`` (LM)
              or ``{'units': 8, 'timesteps': 4}`` (SNN). LM semantics:
              ``units`` is forward work (token positions processed),
              ``prompt_tokens`` the prompt tokens consumed out of it, and
              ``decode_tokens`` the tokens *emitted* — on the step that
              consumes a row's last prompt token the same forward pass
              also emits its first decode token, so ``prompt_tokens +
              decode_tokens`` may exceed ``units``. Under speculative
              decode the LM also reports ``drafted_tokens`` /
              ``accepted_tokens`` for the step, and ``decode_tokens``
              counts every emitted token (accepted draft prefix + the
              corrected/bonus token per speculating row) — so
              decode-tokens-per-step is the goodput headline speculation
              moves, while ``units`` still prices the forward work spent
              to get them. Schedulers fold these, with the
              engine-measured wall seconds, into their cost models
              (`SLOScheduler`).
    """
    finished: Mapping[int, Result] = dataclasses.field(default_factory=dict)
    progress: Mapping[int, SlotProgress] = dataclasses.field(default_factory=dict)
    cost: Mapping[str, float] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Scheduler configuration shared by all workloads.

    slots:     fixed batch width. Every runner invocation sees exactly this
               many requests (short batches are padded with runner fillers) —
               the static-shape contract that keeps TPU serving free of
               per-batch recompilation.
    max_queue: admission bound; `submit` past it raises ``QueueFull``.
    admission: 'continuous' (default) — step-level admission: each
               `EngineCore.step` first refills freed slots from the queue,
               then advances the live runner session one iteration (one
               decode token for the LM, one fused batch for the SNN), so new
               requests join between iterations instead of waiting for the
               current batch to drain. 'batch' — the PR-2 run-to-completion
               policy: one `step` forms one same-bucket batch and runs it to
               completion.
    scheduler: batch-composition policy name, resolved by
               `scheduler.make_scheduler`: 'fifo' (arrival order),
               'sparsity' (co-batch by observed/predicted tile-skip rate,
               EWMA-learned from per-request `Result` stats), or 'slo'
               (deadline/priority admission + per-step budget split;
               composes over an inner policy — 'slo:sparsity').
    prefill_chunk: default `StepBudget.chunk` for continuous admission —
               prompt tokens a joining LM request prefills per engine step,
               interleaved with resident decode rows in the same launch.
               1 reproduces token-by-token prefill; larger values stop long
               prompts from holding goodput down for their whole prefill.
               Bit-identical outputs for any value (chunking only regroups
               the same masked per-token launches).
    max_idle_steps: stall guard for `EngineCore.run_until_complete` — after
               this many *consecutive* steps in which no slot made progress
               (no work units consumed, nothing retired, nothing admitted)
               the drain raises `EngineStalled` with diagnostics instead of
               spinning forever on a wedged session. 0 disables the guard
               (the pre-fault-tolerance behavior); per-call override via
               ``run_until_complete(max_idle_steps=...)``.
    numerics_screen: screen every step's emitted partials and finished
               results for NaN/Inf; a poisoned slot is retired with
               ``status='failed'`` (partials preserved) instead of feeding
               the poison onward or corrupting batchmates' steps.
    precision: weight-numerics policy for precision-capable runners
               (`serve.precision.PrecisionRunner`): '' (default) leaves the
               runner's native numerics untouched; 'fp32'/'int4' pin every
               unpinned request to that variant; 'adaptive' lets the
               per-request `PrecisionController` choose from EWMA sparsity
               estimates, SLO slack and the accuracy budget. Requests with
               ``options['pin_precision']`` are never switched in any mode.
               Setting this on a runner without ``set_precision`` raises at
               engine construction.
    """
    slots: int = 8
    max_queue: int = 256
    admission: str = "continuous"
    scheduler: str = "fifo"
    prefill_chunk: int = 1
    max_idle_steps: int = 1000
    numerics_screen: bool = True
    precision: str = ""


class QueueFull(RuntimeError):
    """Raised by `EngineCore.submit` when the admission queue is at capacity."""


class EngineStalled(RuntimeError):
    """Raised by `EngineCore.run_until_complete` when no slot has made
    progress for `EngineConfig.max_idle_steps` consecutive steps — the
    wedged-session failure mode surfaced as a diagnosis instead of an
    infinite spin. The message carries the stalled residents and queue
    depth; a supervising router catches the same condition earlier via its
    per-step heartbeat (`serve.router.Router`)."""


@runtime_checkable
class ModelRunner(Protocol):
    """What a workload must provide to be served by `EngineCore`.

    The engine owns admission, bucketing, slot lifecycle and result routing;
    the runner owns tensors. ``run`` is handed a batch of exactly
    ``EngineConfig.slots`` requests whose ``bucket_key`` all match and must
    return one `Result` per request, in order (pad results included; the
    engine drops them).
    """

    def bucket_key(self, request: Request) -> Hashable:
        """Requests are only batched together when their keys are equal
        (e.g. padded prompt length + decode budget for the LM, image shape
        for the SNN): the padding/bucketing contract of the scheduler."""
        ...

    def filler(self, request: Request) -> Request:
        """A `PAD_REQUEST_ID` request compatible with ``request``'s bucket,
        used by the engine to pad short batches to the full slot count."""
        ...

    def run(self, batch: Sequence[Request]) -> Sequence[Result]:
        """Execute one fixed-slot batch."""
        ...

    # -- continuous admission (step-level serving) ---------------------------

    def session_key(self, request: Request) -> Hashable:
        """Compatibility key for *joining a live session*. Coarser than
        ``bucket_key``: the LM accepts any prompt/decode budget that fits
        ``max_seq`` into a running session (slots free and fill
        independently), so its key is constant; the SNN key is the image
        shape (one compiled fused graph per shape)."""
        ...

    def open_session(self, slots: int) -> "RunnerSession":
        """Start a live fixed-slot session for continuous admission."""
        ...


@runtime_checkable
class RunnerSession(Protocol):
    """A live fixed-width batch the engine admits into between steps.

    The engine drives the session as: ``admit`` requests into free slot
    indices, then ``step(budget)`` to advance every occupied slot by up to
    the budgeted amount of work (prompt/decode tokens for the LM; one fused
    T-timestep batch for the SNN). Slots the engine never admitted into are
    the runner's problem to pad (inactive rows for the LM, zero images for
    the SNN) — the engine only guarantees it will not reuse a slot index
    before the session reported (or ``cancel`` reclaimed) the previous
    occupant.
    """

    def admit(self, slot: int, request: Request) -> Optional[Result]:
        """Place ``request`` in slot index ``slot``. May complete degenerate
        requests immediately (e.g. ``max_new_tokens=0``) by returning their
        `Result`; returns None when the request will run in coming steps."""
        ...

    def step(self, budget: StepBudget) -> StepReport:
        """Advance every occupied slot by up to ``budget`` work; returns a
        `StepReport` with finished results, per-slot progress + partial
        outputs, and the step's measured cost."""
        ...

    def cancel(self, slot: int) -> Result:
        """Reclaim ``slot`` without perturbing its neighbours; returns a
        partial `Result` (outputs so far, ``status='cancelled'``) for the
        evicted occupant. The slot index is free for reuse afterwards."""
        ...
