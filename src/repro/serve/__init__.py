"""Unified serving: one engine core, pluggable LM and SNN runners.

See README.md in this directory for the Request/Result/Runner API.
"""
from .api import (EngineConfig, ModelRunner, PAD_REQUEST_ID, QueueFull,
                  Request, Result, RunnerSession)
from .core import EngineCore
from .engine import ServeEngine
from .scheduler import (FIFOScheduler, Scheduler, SparsityAwareScheduler,
                        make_scheduler)

__all__ = [
    "EngineConfig", "EngineCore", "FIFOScheduler", "ModelRunner",
    "PAD_REQUEST_ID", "QueueFull", "Request", "Result", "RunnerSession",
    "Scheduler", "ServeEngine", "SparsityAwareScheduler", "make_scheduler",
]
