"""Unified serving: one engine core, pluggable LM and SNN runners, and a
fault-tolerant multi-replica router.

See README.md in this directory for the Request/Result/Runner API and the
failure model.
"""
from .api import (EngineConfig, EngineStalled, ModelRunner, PAD_REQUEST_ID,
                  QueueFull, Request, Result, RunnerSession, SlotProgress,
                  StepBudget, StepReport)
from .core import EngineCore, StepClock, all_finite
from .faults import (Fault, FaultError, FaultPlan, FaultyRunner, TickClock,
                     flood_queue, parse_fleet_plan)
from .precision import (PrecisionController, PrecisionDecision,
                        PrecisionRunner, VariantRegistry, bind_controller,
                        make_lm_variants, make_snn_pricer, make_snn_variants)
from .router import Router, make_router
from .scheduler import (FIFOScheduler, Scheduler, SLOScheduler,
                        SparsityAwareScheduler, make_scheduler)

__all__ = [
    "EngineConfig", "EngineCore", "EngineStalled", "FIFOScheduler", "Fault",
    "FaultError", "FaultPlan", "FaultyRunner", "ModelRunner",
    "PAD_REQUEST_ID", "PrecisionController", "PrecisionDecision",
    "PrecisionRunner", "QueueFull", "Request", "Result", "Router",
    "RunnerSession", "SLOScheduler", "Scheduler", "SlotProgress",
    "SparsityAwareScheduler", "StepBudget", "StepClock", "StepReport",
    "TickClock", "VariantRegistry", "all_finite", "bind_controller",
    "flood_queue", "make_lm_variants", "make_router", "make_scheduler",
    "make_snn_pricer", "make_snn_variants", "parse_fleet_plan",
]
