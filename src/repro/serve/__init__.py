"""Unified serving: one engine core, pluggable LM and SNN runners.

See README.md in this directory for the Request/Result/Runner API.
"""
from .api import (EngineConfig, ModelRunner, PAD_REQUEST_ID, QueueFull,
                  Request, Result, RunnerSession, SlotProgress, StepBudget,
                  StepReport)
from .core import EngineCore, StepClock
from .engine import ServeEngine
from .scheduler import (FIFOScheduler, Scheduler, SLOScheduler,
                        SparsityAwareScheduler, make_scheduler)

__all__ = [
    "EngineConfig", "EngineCore", "FIFOScheduler", "ModelRunner",
    "PAD_REQUEST_ID", "QueueFull", "Request", "Result", "RunnerSession",
    "SLOScheduler", "Scheduler", "ServeEngine", "SlotProgress",
    "SparsityAwareScheduler", "StepBudget", "StepClock", "StepReport",
    "make_scheduler",
]
