"""Unified serving: one engine core, pluggable LM and SNN runners.

See README.md in this directory for the Request/Result/Runner API.
"""
from .api import (EngineConfig, ModelRunner, PAD_REQUEST_ID, QueueFull,
                  Request, Result)
from .core import EngineCore
from .engine import ServeEngine

__all__ = [
    "EngineConfig", "EngineCore", "ModelRunner", "PAD_REQUEST_ID",
    "QueueFull", "Request", "Result", "ServeEngine",
]
