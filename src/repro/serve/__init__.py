"""Unified serving: one engine core, pluggable LM and SNN runners, a
fault-tolerant multi-replica router, and a versioned wire protocol for
running replicas as worker subprocesses.

See README.md in this directory for the Request/Result/Runner API, the
failure model, and the process-fleet deployment mode.
"""
from .api import (EngineConfig, EngineStalled, ModelRunner, PAD_REQUEST_ID,
                  QueueFull, Request, RequestOptions, Result, RunnerSession,
                  SlotProgress, StepBudget, StepReport, SubmitSpec,
                  validate_options)
from .core import EngineCore, StepClock, all_finite
from .faults import (Fault, FaultError, FaultPlan, FaultyRunner, TickClock,
                     flood_queue, parse_fleet_plan)
from .precision import (PrecisionController, PrecisionDecision,
                        PrecisionRunner, VariantRegistry, bind_controller,
                        make_lm_variants, make_snn_pricer, make_snn_variants)
from .router import (InProcTransport, Router, Transport, TransportError,
                     make_router, make_worker_fleet)
from .scheduler import (FIFOScheduler, Scheduler, SLOScheduler,
                        SparsityAwareScheduler, make_scheduler)
from .wire import PROTOCOL_VERSION, ProtocolError
from .worker import RunnerSpec, SubprocessTransport, WorkerDied, build_runner

__all__ = [
    "EngineConfig", "EngineCore", "EngineStalled", "FIFOScheduler", "Fault",
    "FaultError", "FaultPlan", "FaultyRunner", "InProcTransport",
    "ModelRunner", "PAD_REQUEST_ID", "PROTOCOL_VERSION",
    "PrecisionController", "PrecisionDecision", "PrecisionRunner",
    "ProtocolError", "QueueFull", "Request", "RequestOptions", "Result",
    "Router", "RunnerSession", "RunnerSpec", "SLOScheduler", "Scheduler",
    "SlotProgress", "SparsityAwareScheduler", "StepBudget", "StepClock",
    "StepReport", "SubmitSpec", "SubprocessTransport", "TickClock",
    "Transport", "TransportError", "VariantRegistry", "WorkerDied",
    "all_finite", "bind_controller", "build_runner", "flood_queue",
    "make_lm_variants", "make_router", "make_scheduler", "make_snn_pricer",
    "make_snn_variants", "make_worker_fleet", "parse_fleet_plan",
    "validate_options",
]
