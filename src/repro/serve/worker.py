"""Subprocess worker harness: one `EngineCore` + runner per process.

This is the second deployment mode of the serving stack. The in-process
fleet (`serve.router.make_router`) shares one Python interpreter; a worker
fleet (`serve.router.make_worker_fleet`, `launch/serve.py --workers N`)
hosts each replica's engine in its own subprocess and drives it over the
versioned wire protocol (`serve.wire`) on a stdin/stdout pipe. Process
isolation is what the ROADMAP's fleet-scale item needs: a worker that
wedges, poisons its numerics, or dies outright (kill -9) cannot take the
router down with it — the pipe breaks, the transport raises
`router.TransportError`, and supervision drains + replays exactly as it
would for an in-process fault.

**Determinism across the process boundary.** A runner holds jitted state
that cannot (and should not) travel over a pipe, so workers are built from
a `RunnerSpec` — a wire-encodable recipe (workload kind, architecture
config, PRNG seed) from which parent and worker construct *identical*
runners: same `PRNGKey`-derived params, same greedy decode, therefore
bit-identical outputs whether a request runs in-process, in a worker, or
is replayed on a different worker after its first one was killed
mid-stream. That is the property the chaos benches assert.

**Protocol shape.** Every parent request gets zero or more push frames
(`PartialMsg`/`ResultMsg` for newly available outputs) followed by exactly
one terminal reply:

    HelloMsg    -> ReadyMsg            (handshake; version-checked)
    SubmitMsg   -> AckMsg              (rid on ok; QueueFull/ValueError text)
    StepMsg     -> pushes + HeartbeatMsg (progress marker + numerics probe)
    PollMsg     -> pushes + AckMsg
    CancelMsg   -> pushes + AckMsg
    ShutdownMsg -> AckMsg, then exit

Heartbeats piggyback on step replies — the router never pays an extra
round trip for supervision. Fatal worker-side errors emit one `ErrorMsg`
and exit; the parent surfaces them as a dead transport.

The worker's real stdout file descriptor is reserved for protocol frames;
fd 1 is re-pointed at stderr on startup so stray library prints cannot
corrupt the stream.
"""
from __future__ import annotations

import dataclasses
import os
import select
import subprocess
import sys
import time
from pathlib import Path
from typing import Any, Dict, List, Mapping, Optional, Set, Tuple

from . import wire
from .api import (PAD_REQUEST_ID, EngineConfig, QueueFull, Request, Result,
                  SlotProgress, StepBudget, StepReport, SubmitSpec)
from .core import EngineCore, all_finite
from .router import TransportError
from .wire import (AckMsg, CancelMsg, ErrorMsg, HeartbeatMsg, HelloMsg,
                   PartialMsg, PollMsg, ProtocolError, ReadyMsg, ResultMsg,
                   ShutdownMsg, StepMsg, SubmitMsg)


class WorkerDied(TransportError):
    """The worker subprocess is gone or unresponsive: closed pipe, fatal
    `ErrorMsg`, or a step that outlived the transport timeout. The router
    condemns the replica and replays its in-flight requests elsewhere."""


# ---------------------------------------------------------------------------
# RunnerSpec: a wire-encodable recipe for building a runner
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RunnerSpec:
    """Deterministic runner recipe both ends of the wire can execute.

    kind:        'lm' (transformer LM), 'snn' (spiking VGG9), or 'stub'
                 (a tiny jax-free arithmetic runner for protocol tests).
    arch:        architecture-config fields (`configs.base.ArchConfig` for
                 'lm', `configs.vgg9_snn.VGG9Config` for 'snn') as a plain
                 mapping — `dataclasses.asdict` of the config.
    seed:        `PRNGKey` seed for parameter init. Same spec -> same
                 params -> bit-identical greedy outputs in every process.
    max_seq / quant_bits / speculate_k: `runners.lm.LMRunner` knobs.
    interpret:   run SNN kernels in interpret mode (CPU CI).
    """
    kind: str
    arch: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    seed: int = 0
    max_seq: int = 64
    quant_bits: int = 0
    speculate_k: int = 0
    interpret: bool = True

    def to_wire(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_wire(cls, data: Mapping[str, Any]) -> "RunnerSpec":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = sorted(set(data) - known)
        if unknown:
            raise ProtocolError(f"unknown RunnerSpec fields {unknown}")
        return cls(**{k: v for k, v in data.items()})


def lm_spec(cfg, *, seed: int = 0, max_seq: int = 64, quant_bits: int = 0,
            speculate_k: int = 0) -> RunnerSpec:
    """Spec for an `LMRunner` over ``cfg`` (an `ArchConfig`)."""
    return RunnerSpec(kind="lm", arch=dataclasses.asdict(cfg), seed=seed,
                      max_seq=max_seq, quant_bits=quant_bits,
                      speculate_k=speculate_k)


def snn_spec(cfg, *, seed: int = 0, interpret: bool = True) -> RunnerSpec:
    """Spec for an `SNNRunner` over ``cfg`` (a `VGG9Config`)."""
    return RunnerSpec(kind="snn", arch=dataclasses.asdict(cfg), seed=seed,
                      interpret=interpret)


def build_runner(spec: RunnerSpec):
    """Construct the runner a spec describes (used by workers *and* by
    in-process reference runs asserting cross-process bit-identity)."""
    if spec.kind == "stub":
        return _StubRunner()
    if spec.kind == "lm":
        import jax

        from ..configs.base import ArchConfig
        from ..models import transformer as tf
        from .runners.lm import LMRunner
        cfg = ArchConfig(**dict(spec.arch))
        params = tf.init_params(jax.random.PRNGKey(spec.seed), cfg)
        return LMRunner(cfg, params, max_seq=spec.max_seq,
                        quant_bits=spec.quant_bits,
                        speculate_k=spec.speculate_k)
    if spec.kind == "snn":
        import jax

        from ..configs.vgg9_snn import VGG9Config
        from ..models.vgg9 import init_vgg9
        from .runners.snn import SNNRunner
        cfg = VGG9Config(**dict(spec.arch))
        params = init_vgg9(jax.random.PRNGKey(spec.seed), cfg)
        return SNNRunner(cfg, params, interpret=spec.interpret)
    raise ProtocolError(f"unknown RunnerSpec.kind {spec.kind!r} "
                        f"(known: lm, snn, stub)")


# ---------------------------------------------------------------------------
# stub runner: deterministic, jax-free — protocol tests without jit cost
# ---------------------------------------------------------------------------

class _StubSession:
    def __init__(self, slots: int):
        self.rows: List[Optional[list]] = [None] * slots

    def admit(self, slot: int, request: Request) -> Optional[Result]:
        payload = request.payload if isinstance(request.payload, Mapping) else {}
        steps = int(payload.get("steps", 1))
        if steps <= 0:
            return Result(request.request_id, ("done", 0), {"steps": 0})
        self.rows[slot] = [request, steps, 0]
        return None

    def step(self, budget: StepBudget) -> StepReport:
        finished: Dict[int, Result] = {}
        progress: Dict[int, SlotProgress] = {}
        units = 0
        for slot, row in enumerate(self.rows):
            if row is None:
                continue
            request, total, done = row
            done += 1
            row[2] = done
            units += 1
            progress[slot] = SlotProgress(request.request_id, "stub", done,
                                          total, (("tick", done),))
            if done >= total:
                finished[slot] = Result(request.request_id, ("done", done),
                                        {"steps": done})
                self.rows[slot] = None
        return StepReport(finished, progress, {"units": units})

    def cancel(self, slot: int) -> Result:
        request, _total, done = self.rows[slot]
        self.rows[slot] = None
        return Result(request.request_id, ("done", done), {"steps": done},
                      "cancelled")


class _StubRunner:
    """Minimal deterministic `ModelRunner`: a request runs for
    ``payload['steps']`` session steps and finishes with outputs
    ``('done', steps)``. Keeps worker protocol tests free of jax import
    and jit-compile cost."""

    def bucket_key(self, request: Request):
        return "stub"

    def session_key(self, request: Request):
        return "stub"

    def filler(self, request: Request) -> Request:
        return Request(PAD_REQUEST_ID, {"steps": 1})

    def run(self, batch):
        return [Result(r.request_id, ("done", 1), {"steps": 1})
                for r in batch]

    def open_session(self, slots: int) -> _StubSession:
        return _StubSession(slots)


# ---------------------------------------------------------------------------
# worker side: the subprocess main loop
# ---------------------------------------------------------------------------

def _heartbeat(core: EngineCore, seq: int) -> HeartbeatMsg:
    report = core.last_report
    telemetry = core.obs.wire_telemetry() if core.obs is not None else None
    return HeartbeatMsg(seq=seq, marker=core._progress_marker(),
                        failed=core._failed,
                        cost_finite=report is None or all_finite(report.cost),
                        in_flight=core.in_flight(), pending=core.pending(),
                        stats=core.stats(), telemetry=telemetry)


def serve_connection(rfile, wfile) -> int:
    """Speak the worker side of the protocol until shutdown/EOF.

    Returns a process exit code. Factored off `main` so tests can run a
    worker over arbitrary byte streams (e.g. `io.BytesIO` pairs).
    """
    def send(msg) -> None:
        wire.write_frame(wfile, msg)

    try:
        hello = wire.read_frame(rfile)
    except ProtocolError as e:
        # version mismatch or garbage on the pipe: report and refuse
        send(ErrorMsg(error=f"handshake failed: {e}"))
        return 2
    if hello is None:
        return 0                        # parent vanished before handshake
    if not isinstance(hello, HelloMsg):
        send(ErrorMsg(error=f"expected hello, got {type(hello).__name__}"))
        return 2
    try:
        spec = RunnerSpec.from_wire(hello.runner)
        config = EngineConfig(**dict(hello.config))
        obs = None
        if hello.obs:
            from ..obs import Observability
            obs = Observability()
        core = EngineCore(build_runner(spec), config, obs=obs)
    except Exception as e:              # bad spec/config: refuse loudly
        send(ErrorMsg(error=f"worker build failed: {e!r}"))
        return 2
    send(ReadyMsg(pid=os.getpid(), workload=spec.kind))

    live: Set[int] = set()              # rids with no ResultMsg pushed yet

    def push_new(rids) -> None:
        """Push partials/results that became available for ``rids``."""
        for rid in sorted(rids):
            items = core.poll_partial(rid)
            if items:
                send(PartialMsg(rid=rid, items=tuple(items)))
        for rid in sorted(rids):
            res = core.poll(rid)
            if res is not None:
                send(ResultMsg.from_result(rid, res))
                live.discard(rid)

    while True:
        try:
            msg = wire.read_frame(rfile)
        except ProtocolError as e:
            send(ErrorMsg(error=f"bad frame: {e}"))
            return 2
        if msg is None:                 # parent closed the pipe: we're done
            return 0
        try:
            if isinstance(msg, SubmitMsg):
                try:
                    rid = core.submit_spec(msg.to_spec())
                except QueueFull as e:
                    send(AckMsg(ok=False, error=f"QueueFull: {e}"))
                except ValueError as e:
                    send(AckMsg(ok=False, error=f"ValueError: {e}"))
                else:
                    live.add(rid)
                    send(AckMsg(ok=True, rid=rid))
            elif isinstance(msg, StepMsg):
                if core.in_flight() > 0 or core.pending() > 0:
                    core.step()
                push_new(set(live))
                send(_heartbeat(core, msg.seq))
            elif isinstance(msg, PollMsg):
                was_live = msg.rid in live
                push_new({msg.rid})
                send(AckMsg(ok=was_live and msg.rid not in live, rid=msg.rid))
            elif isinstance(msg, CancelMsg):
                ok = core.cancel(msg.rid, status=msg.status)
                push_new({msg.rid})
                send(AckMsg(ok=ok, rid=msg.rid))
            elif isinstance(msg, ShutdownMsg):
                send(AckMsg(ok=True))
                return 0
            else:
                send(ErrorMsg(error=f"unexpected {type(msg).__name__}"))
                return 2
        except Exception as e:          # engine/runner fault: die loudly —
            # the parent condemns this replica and replays elsewhere,
            # exactly the in-process step-raised path
            send(ErrorMsg(error=f"worker fault: {e!r}"))
            return 3


def main() -> int:
    # Reserve the real stdout fd for protocol frames and re-point fd 1 at
    # stderr, so library prints (jax logs etc.) cannot corrupt the stream.
    proto_in = sys.stdin.buffer
    proto_out = os.fdopen(os.dup(sys.stdout.fileno()), "wb")
    os.dup2(sys.stderr.fileno(), sys.stdout.fileno())
    try:
        return serve_connection(proto_in, proto_out)
    except BrokenPipeError:
        return 0                        # parent died mid-reply


# ---------------------------------------------------------------------------
# parent side: SubprocessTransport
# ---------------------------------------------------------------------------

class SubprocessTransport:
    """`router.Transport` over a worker subprocess.

    Spawns ``python -m repro.serve.worker``, performs the version-checked
    handshake, and maps the transport surface onto wire round trips:
    `step()` is one `StepMsg` -> pushes + `HeartbeatMsg` exchange (the
    heartbeat caches the progress marker / numerics-probe fields the
    router's between-step probes read), `submit_spec` is a `SubmitMsg` ->
    `AckMsg` exchange re-raising `QueueFull`/`ValueError` from the worker's
    submit boundary. Results and partials arrive as pushes during step and
    cancel exchanges and are served to `poll`/`poll_partial` from local
    caches — after a worker dies, whatever it already delivered remains
    salvageable, and `step`/`submit_spec` raise `WorkerDied` so the router
    condemns the replica.
    """

    def __init__(self, spec: RunnerSpec, config: EngineConfig = EngineConfig(),
                 *, step_timeout_s: float = 120.0,
                 handshake_timeout_s: float = 300.0,
                 python: str = sys.executable, obs: bool = False,
                 _hello_version: Optional[int] = None):
        self.spec = spec
        self.config = config
        self.clock = time.monotonic
        self.step_timeout_s = step_timeout_s
        self.pid: Optional[int] = None
        self._dead: Optional[str] = None
        self._seq = 0
        self._hb: Optional[HeartbeatMsg] = None
        self._results: Dict[int, Result] = {}
        self._partials: Dict[int, List[Any]] = {}
        self._live: Set[int] = set()    # submitted, no terminal result yet
        #: telemetry accumulated from heartbeats when the hello asked the
        #: worker to observe. Spans accumulate (each heartbeat ships the
        #: increment); metrics/frames are replaced by the newest snapshot —
        #: so the *last* heartbeat before a crash is the postmortem source.
        self.obs = obs
        self._spans: List[Dict[str, Any]] = []
        self._metrics: Dict[str, Any] = {}
        self._frames: List[Dict[str, Any]] = []
        self._dumps: List[Dict[str, Any]] = []
        env = dict(os.environ)
        src_root = str(Path(__file__).resolve().parents[2])
        env["PYTHONPATH"] = src_root + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
        # spawn via -c (not -m): the package __init__ already imports this
        # module, and runpy warns when re-executing an imported module
        boot = "import sys; from repro.serve.worker import main; sys.exit(main())"
        self.proc = subprocess.Popen(
            [python, "-c", boot],
            stdin=subprocess.PIPE, stdout=subprocess.PIPE, bufsize=0, env=env)
        try:
            self._send(HelloMsg(runner=spec.to_wire(),
                                config=dataclasses.asdict(config), obs=obs),
                       version=_hello_version)
            reply = self._recv(handshake_timeout_s)
        except TransportError:
            self._reap()
            raise
        except ProtocolError:
            self._mark_dead("handshake version mismatch")
            self._reap()
            raise
        if isinstance(reply, ErrorMsg):
            self._mark_dead(reply.error)
            self._reap()
            raise ProtocolError(f"worker rejected handshake: {reply.error}")
        if not isinstance(reply, ReadyMsg):
            self._mark_dead(f"unexpected handshake reply {type(reply).__name__}")
            self._reap()
            raise ProtocolError(self._dead)
        self.pid = reply.pid

    # -- low-level I/O -------------------------------------------------------

    def _send(self, msg, *, version: Optional[int] = None) -> None:
        try:
            wire.write_frame(self.proc.stdin, msg, version=version)
        except (BrokenPipeError, OSError) as e:
            self._mark_dead(f"pipe to worker broke: {e}")
            raise WorkerDied(self._dead) from e

    def _read_exact(self, n: int, timeout: float) -> bytes:
        deadline = time.monotonic() + timeout
        fd = self.proc.stdout.fileno()
        buf = b""
        while len(buf) < n:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                self._mark_dead(
                    f"worker pid {self.pid} unresponsive for {timeout:.0f}s")
                raise WorkerDied(self._dead)
            ready, _, _ = select.select([fd], [], [], min(remaining, 1.0))
            if not ready:
                continue
            chunk = os.read(fd, n - len(buf))
            if not chunk:
                code = self.proc.poll()
                self._mark_dead(f"worker pid {self.pid} closed its pipe "
                                f"(exit code {code})")
                raise WorkerDied(self._dead)
            buf += chunk
        return buf

    def _recv(self, timeout: float):
        header = self._read_exact(wire._HEADER.size, timeout)
        (length,) = wire._HEADER.unpack(header)
        if length > wire.MAX_FRAME_BYTES:
            self._mark_dead(f"oversized frame ({length} bytes) from worker")
            raise WorkerDied(self._dead)
        return wire.unpack(self._read_exact(length, timeout))

    def _rpc(self, msg, timeout: Optional[float] = None):
        """One request -> (pushes cached) -> terminal reply."""
        if self._dead:
            raise WorkerDied(self._dead)
        self._send(msg)
        while True:
            reply = self._recv(timeout if timeout is not None
                               else self.step_timeout_s)
            if isinstance(reply, PartialMsg):
                self._partials.setdefault(reply.rid, []).extend(reply.items)
            elif isinstance(reply, ResultMsg):
                self._results[reply.rid] = reply.to_result()
                self._live.discard(reply.rid)
            elif isinstance(reply, ErrorMsg):
                self._mark_dead(f"worker reported: {reply.error}")
                raise WorkerDied(self._dead)
            else:
                return reply

    def _mark_dead(self, reason: str) -> None:
        if self._dead is None:
            self._dead = reason

    def _reap(self) -> None:
        if self.proc.poll() is None:
            self.proc.kill()
        try:
            self.proc.wait(timeout=10)
        except subprocess.TimeoutExpired:
            pass
        for stream in (self.proc.stdin, self.proc.stdout):
            try:
                stream.close()
            except OSError:
                pass

    # -- Transport surface ---------------------------------------------------

    def submit_spec(self, spec: SubmitSpec) -> int:
        reply = self._rpc(SubmitMsg.from_spec(spec))
        if not isinstance(reply, AckMsg):
            self._mark_dead(f"bad submit reply {type(reply).__name__}")
            raise WorkerDied(self._dead)
        if reply.ok:
            self._live.add(reply.rid)
            return reply.rid
        if reply.error.startswith("QueueFull"):
            raise QueueFull(reply.error)
        raise ValueError(reply.error)

    def step(self) -> None:
        self._seq += 1
        reply = self._rpc(StepMsg(seq=self._seq))
        if not isinstance(reply, HeartbeatMsg):
            self._mark_dead(f"bad step reply {type(reply).__name__}")
            raise WorkerDied(self._dead)
        self._hb = reply
        telemetry = reply.telemetry
        if telemetry:
            self._spans.extend(telemetry.get("spans") or ())
            if telemetry.get("metrics") is not None:
                self._metrics = telemetry["metrics"]
            if telemetry.get("frames") is not None:
                self._frames = list(telemetry["frames"])
            self._dumps.extend(telemetry.get("dumps") or ())

    def poll(self, request_id: int) -> Optional[Result]:
        return self._results.pop(request_id, None)

    def poll_partial(self, request_id: int) -> List[Any]:
        return self._partials.pop(request_id, [])

    def cancel(self, request_id: int, *, status: str = "cancelled") -> bool:
        if self._dead:
            return False            # nothing to reclaim from a dead worker
        try:
            reply = self._rpc(CancelMsg(rid=request_id, status=status))
        except TransportError:
            return False
        return isinstance(reply, AckMsg) and reply.ok

    def progress_marker(self) -> Tuple[int, int, int, int]:
        return tuple(self._hb.marker) if self._hb else (0, 0, 0, 0)

    def failed_count(self) -> int:
        return self._hb.failed if self._hb else 0

    def cost_finite(self) -> bool:
        return self._hb.cost_finite if self._hb else True

    def in_flight(self) -> int:
        # local liveness, not the stale heartbeat: the router must see a
        # freshly submitted request as work even before the first step
        return len(self._live)

    def pending(self) -> int:
        return self._hb.pending if self._hb else 0

    def stats(self) -> Dict[str, Any]:
        stats = dict(self._hb.stats) if self._hb else {}
        stats["worker_pid"] = self.pid
        stats["worker_dead"] = self._dead
        return stats

    def max_idle_steps(self) -> int:
        return self.config.max_idle_steps

    # -- observability surface (probed by the router via getattr) ------------

    def telemetry(self) -> Dict[str, Any]:
        """Everything this transport has learned from worker heartbeats:
        closed spans (accumulated), the latest metrics snapshot, the latest
        recorder frame tail, and every recorder dump. Spans still open in
        the worker at death are lost — the frame tail is the cushion."""
        return {"spans": list(self._spans), "metrics": dict(self._metrics),
                "frames": list(self._frames), "dumps": list(self._dumps)}

    def recorder_dump(self, reason: str) -> Optional[Dict[str, Any]]:
        """Parent-side postmortem from the last heartbeat's frame tail —
        the `WorkerDied` path, where the worker can no longer dump for
        itself. None when the hello never asked the worker to observe."""
        if not self.obs:
            return None
        dump = {"reason": reason,
                "step": self._frames[-1]["step"] if self._frames else None,
                "frames": list(self._frames), "notes": [],
                "worker_pid": self.pid}
        self._dumps.append(dump)
        return dump

    def kill(self) -> None:
        """SIGKILL the worker (chaos harness). The transport does *not*
        mark itself dead — discovery happens through the protocol, the way
        a real crash would surface."""
        self.proc.kill()

    def close(self) -> None:
        if self._dead is None and self.proc.poll() is None:
            try:
                self._rpc(ShutdownMsg(), timeout=10.0)
                self.proc.wait(timeout=10)
            except (TransportError, ProtocolError,
                    subprocess.TimeoutExpired):
                pass
        self._reap()


if __name__ == "__main__":
    sys.exit(main())
