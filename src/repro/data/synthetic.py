"""Synthetic datasets (container is offline — see DESIGN.md §7).

Image task: class-conditional oriented Gabor-like textures at CIFAR geometry
(32x32x3) — learnable structure so the quantization-sparsity study trains to
non-trivial accuracy. Token task: order-k Markov streams with class-dependent
transition matrices (next-token-predictable).

Everything is *stateless and step-keyed*: batch(step) is a pure function of
(seed, step), which makes restarts/stragglers reproduce the exact data order
(fault-tolerance requirement).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def image_batch(seed: int, step: int, batch: int, *, num_classes: int = 10,
                hw: int = 32, dtype=jnp.float32):
    """Class-conditional Gabor textures + noise. Returns {images, labels}."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    labels = jax.random.randint(k1, (batch,), 0, num_classes)

    # per-class orientation/frequency/phase
    theta = labels.astype(jnp.float32) / num_classes * jnp.pi
    freq = 2.0 + (labels % 3).astype(jnp.float32) * 1.5
    yy, xx = jnp.meshgrid(jnp.linspace(-1, 1, hw), jnp.linspace(-1, 1, hw), indexing="ij")
    phase = jax.random.uniform(k2, (batch, 1, 1)) * 2 * jnp.pi
    proj = (xx[None] * jnp.cos(theta)[:, None, None]
            + yy[None] * jnp.sin(theta)[:, None, None])
    pattern = jnp.sin(proj * freq[:, None, None] * jnp.pi + phase) * 0.5 + 0.5
    # class-dependent colour mix
    colour = jax.nn.one_hot(labels % 3, 3) * 0.6 + 0.2
    imgs = pattern[..., None] * colour[:, None, None, :]
    imgs = imgs + jax.random.normal(k3, imgs.shape) * 0.08
    shift = jax.random.uniform(k4, (batch, 1, 1, 1)) * 0.1
    return {"images": jnp.clip(imgs + shift, 0, 1).astype(dtype),
            "labels": labels}


def token_batch(seed: int, step: int, batch: int, seq_len: int, vocab: int):
    """Markov-ish token streams: tokens[t+1] = f(tokens[t]) with noise.

    Returns {tokens, labels} where labels are next tokens (teacher forcing).
    """
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    k1, k2 = jax.random.split(key)
    # deterministic affine walk per row + uniform noise
    start = jax.random.randint(k1, (batch, 1), 0, vocab)
    stride = jax.random.randint(k2, (batch, 1), 1, 7)
    pos = jnp.arange(seq_len + 1)[None]
    stream = (start + stride * pos) % vocab
    noise_key = jax.random.fold_in(key, 7)
    flip = jax.random.bernoulli(noise_key, 0.05, stream.shape)
    rand = jax.random.randint(jax.random.fold_in(key, 8), stream.shape, 0, vocab)
    stream = jnp.where(flip, rand, stream)
    return {"tokens": stream[:, :-1].astype(jnp.int32),
            "labels": stream[:, 1:].astype(jnp.int32)}
