"""Sharded, deterministic, prefetching data pipeline.

Batches are pure functions of (seed, step) (see synthetic.py), generated
host-side and placed onto the mesh with the batch axis sharded over
('pod','data'). Because generation is stateless, any restart or elastic
re-mesh reproduces the exact global data order from the step counter alone —
no data-loader checkpointing needed, and straggler hosts cannot desynchronize
the stream.

A small background-thread prefetcher overlaps host-side generation with
device compute (double buffering).
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Dict, Iterator, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


class DataPipeline:
    def __init__(self, make_batch: Callable[[int], Dict], mesh: Optional[Mesh] = None,
                 batch_spec: Optional[P] = None, prefetch: int = 2):
        """make_batch: step -> host batch pytree."""
        self.make_batch = make_batch
        self.mesh = mesh
        self.batch_spec = batch_spec
        self.prefetch = prefetch

    def _place(self, batch):
        if self.mesh is None:
            return batch
        sh = NamedSharding(self.mesh, self.batch_spec or P())
        return jax.tree.map(lambda x: jax.device_put(x, sh), batch)

    def __call__(self, start_step: int = 0) -> Iterator:
        q: "queue.Queue" = queue.Queue(maxsize=self.prefetch)
        stop = threading.Event()

        def worker():
            step = start_step
            while not stop.is_set():
                try:
                    q.put((step, self.make_batch(step)), timeout=0.5)
                    step += 1
                except queue.Full:
                    continue

        t = threading.Thread(target=worker, daemon=True)
        t.start()
        try:
            while True:
                step, batch = q.get()
                yield step, self._place(batch)
        finally:
            stop.set()
