"""phi-3-vision-4.2b [vlm]: 32L d3072 32H (MHA kv=32) d_ff 8192 vocab 32064.

[hf:microsoft/Phi-3-vision-128k-instruct; hf]. Phi-3-mini backbone + CLIP
image tower. Backbone only per assignment: the CLIP tower is a stub —
input_specs() provides 1024 precomputed patch embeddings (d=1024) projected
and prepended to the text tokens. SwiGLU MLP.
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="phi-3-vision-4.2b", family="vlm",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32, head_dim=96,
    d_ff=8192, vocab=32064, mlp_act="swiglu",
    frontend="vision", n_frontend_tokens=1024, d_frontend=1024,
))
