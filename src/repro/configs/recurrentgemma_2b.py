"""recurrentgemma-2b [hybrid]: 26L d2560 10H (MQA kv=1) d_ff 7680 vocab 256000.

[arXiv:2402.19427; hf]. Griffin: RG-LRU recurrent blocks + local attention
(window 2048), pattern (rglru, rglru, local_attn) x 8 with a 2-recurrent-layer
tail (26 = 3*8 + 2). GeGLU MLP. Sub-quadratic => runs long_500k.
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, head_dim=256,
    d_ff=7680, vocab=256000, mlp_act="geglu",
    pattern=("rglru", "rglru", "local_attn"), tail=("rglru", "rglru"),
    window=2048, d_rnn=2560, conv_width=4,
    tie_embeddings=True, supports_long=True,
))
