"""qwen1.5-4b [dense]: 40L d2560 20H (kv=20, MHA) d_ff 6912 vocab 151936.

[hf:Qwen/Qwen1.5-*; hf]. QKV bias (the Qwen signature), SwiGLU MLP.
20 heads do not divide the 16-way model axis — GSPMD pads; see DESIGN.md §4.
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen1.5-4b", family="dense",
    n_layers=40, d_model=2560, n_heads=20, n_kv_heads=20, head_dim=128,
    d_ff=6912, vocab=151936, mlp_act="swiglu", qkv_bias=True,
))
