"""musicgen-large [audio]: 48L d2048 32H (MHA kv=32) d_ff 8192 vocab 2048.

[arXiv:2306.05284; hf]. Decoder-only over EnCodec tokens (vocab 2048 codes).
Backbone only per assignment: the EnCodec tokenizer and T5 text conditioner
are stubs — input_specs() provides 64 precomputed conditioning embeddings
(d=1024) prepended to the token sequence.
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab=2048, mlp_act="gelu",
    frontend="audio", n_frontend_tokens=64, d_frontend=1024,
))
