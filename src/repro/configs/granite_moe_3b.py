"""granite-moe-3b-a800m [moe]: 32L d1536 24H (GQA kv=8) vocab 49155 (padded
to 49408 = 16*3088 so the vocab dim shards; MaxText-style padding),
MoE 40 experts top-8 with expert d_ff 512, every layer MoE.

[hf:ibm-granite/granite-3.0-*; hf]. 40 experts do not divide the 16-way
model axis — expert GEMMs fall back to TP over the hidden dim (DESIGN.md §4).
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, head_dim=64,
    d_ff=512, vocab=49408, mlp_act="swiglu",
    pattern=("attn_moe",),
    n_experts=40, top_k=8, moe_d_ff=512, n_experts_padded=48,
))
