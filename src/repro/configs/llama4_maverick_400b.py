"""llama4-maverick-400b-a17b [moe]: 48L d5120 40H (GQA kv=8) dense d_ff 8192
vocab 202048, MoE 128 experts top-1, interleaved (every other layer MoE)
with a shared expert — 397B total / ~17B active, matching the 400b-a17b
budget. [hf:meta-llama/Llama-4-*; unverified].

Adafactor optimizer (ZeRO-1 AdamW states for 400B exceed the per-chip HBM
budget at 512 chips; see DESIGN.md §5).
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab=202048, mlp_act="swiglu",
    pattern=("attn_mlp", "attn_moe"),
    n_experts=128, top_k=1, moe_d_ff=8192, shared_expert=True,
    optimizer="adafactor", fsdp_experts=True,
))
