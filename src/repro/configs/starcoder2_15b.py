"""starcoder2-15b [dense]: 40L d6144 48H (GQA kv=4) d_ff 24576 vocab 49152.

[arXiv:2402.19173; hf]. GQA + RoPE, GELU MLP, linear biases on QKV.
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="starcoder2-15b", family="dense",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=4, head_dim=128,
    d_ff=24576, vocab=49152, mlp_act="gelu", qkv_bias=True,
))
