"""xlstm-125m [ssm]: 12L d768 4H vocab 50304, alternating mLSTM/sLSTM blocks
(d_ff=0: no MLPs). [arXiv:2405.04517; unverified].

Pure recurrence => O(1)-state decode, runs long_500k.
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="xlstm-125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4, head_dim=192,
    d_ff=0, vocab=50304, mlp_act="gelu",
    pattern=("mlstm", "slstm"),
    tie_embeddings=True, supports_long=True,
))
