"""The paper's own model configs: spiking VGG9 for CIFAR10/CIFAR100/SVHN.

Population sizes and LIF hyperparameters follow §V-A: P=1000 (CIFAR10/SVHN),
P=5000 (CIFAR100), beta=0.15, theta=0.5, T=2 direct coding (the paper's
best operating point), T=25 for the rate-coding comparison.

The published LW core allocations (Fig. 4) are kept for the energy-model
benchmarks.
"""
import dataclasses

from ..models.vgg9 import VGG9Config

CIFAR10 = VGG9Config(num_classes=10, population=1000)
CIFAR100 = VGG9Config(num_classes=100, population=5000)
SVHN = VGG9Config(num_classes=10, population=1000)

CIFAR10_INT4 = VGG9Config(num_classes=10, population=1000, quant_bits=4)
CIFAR100_INT4 = VGG9Config(num_classes=100, population=5000, quant_bits=4)
SVHN_INT4 = VGG9Config(num_classes=10, population=1000, quant_bits=4)

RATE_CIFAR10 = VGG9Config(num_classes=10, population=1000, coding="rate",
                          timesteps=25, quant_bits=4)

# Reduced config for CPU smoke tests / CI: same family, tiny dims.
TINY = VGG9Config(
    num_classes=4, population=64, timesteps=2, img_hw=16,
    stages=(8, 12, "MP", 16, 16, "MP"), fc_dim=32,
)
TINY_INT4 = dataclasses.replace(TINY, quant_bits=4)

# Paper Fig. 4 lightweight NC allocations (9 entries: dense core + 7 sparse
# conv layers + FC), used by the energy benchmarks.
LW_ALLOCATIONS = {
    "svhn": (1, 7, 1, 8, 2, 4, 14, 1, 2),
    "cifar10": (1, 8, 4, 18, 6, 6, 20, 2, 1),
    "cifar100": (1, 7, 3, 12, 4, 18, 16, 4, 1),
}
PERF2_CIFAR100 = (1, 28, 12, 54, 16, 72, 70, 19, 4)  # Table I configuration
