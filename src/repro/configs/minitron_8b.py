"""minitron-8b [dense]: 32L d4096 32H (GQA kv=8) d_ff 16384 vocab 256000.

[arXiv:2407.14679; hf]. Pruned Nemotron: squared-ReLU MLP (ungated),
large vocab (sentencepiece 256k).
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="minitron-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=16384, vocab=256000, mlp_act="relu2",
))
