"""Config registry: `get_arch(name)` / `all_archs()` + shape cells."""
from .base import ArchConfig, ShapeConfig, SHAPES, get_arch, all_archs, shape_applicable

_LOADED = False

ARCH_MODULES = (
    "granite_34b", "starcoder2_15b", "qwen1_5_4b", "minitron_8b",
    "recurrentgemma_2b", "musicgen_large", "phi_3_vision_4_2b",
    "llama4_maverick_400b", "granite_moe_3b", "xlstm_125m",
)


def _load_all():
    global _LOADED
    if _LOADED:
        return
    import importlib
    for m in ARCH_MODULES:
        importlib.import_module(f".{m}", __package__)
    _LOADED = True
