"""granite-34b [dense]: 88L d6144 48H (GQA kv=1) d_ff 24576 vocab 49152.

[arXiv:2405.04324; hf]. Code model; multi-query attention (kv=1), 4x GELU
MLP (matches the 34B parameter count; a gated MLP would land at ~46B).
RMSNorm+RoPE standardization noted in DESIGN.md.
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="granite-34b", family="dense",
    n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1, head_dim=128,
    d_ff=24576, vocab=49152, mlp_act="gelu",
))
