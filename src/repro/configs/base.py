"""Config system: architecture configs + input-shape registry.

Every assigned architecture is an `ArchConfig`; shapes are the four assigned
input-shape cells. Configs are plain frozen dataclasses — hashable, usable as
jit static args, and independent of jax device state.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    qkv_bias: bool = False
    mlp_act: str = "swiglu"          # swiglu | gelu | relu2
    pattern: Tuple[str, ...] = ("attn_mlp",)   # block kinds per scanned period
    tail: Tuple[str, ...] = ()       # unscanned leftover layers (pattern remainder)
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    shared_expert: bool = False
    capacity_factor: float = 1.25
    n_experts_padded: int = 0        # pad experts so EP shards the 16-way axis
                                     # (padded experts are router-masked to -inf)
    fsdp_experts: bool = False       # store expert weights sharded over 'data'
                                     # too (FSDP), gathered per layer at use
    # Recurrent / local attention
    window: int = 0                  # sliding-window size for 'local_attn' blocks
    d_rnn: int = 0
    conv_width: int = 4
    # Positional / numerics
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    frontend: str = ""               # '' | 'vision' | 'audio' (stub frontends)
    n_frontend_tokens: int = 0       # patches/frames prepended to the sequence
    d_frontend: int = 0              # stub embedding dim before projection
    # Execution
    dtype: str = "bfloat16"
    q_chunk: int = 512
    kv_chunk: int = 2048
    mlstm_chunk: int = 256
    unroll_chunks: bool = False      # dry-run cost lowering (EXPERIMENTS.md)
    attn_f32_streams: bool = False   # True = pre-optimization baseline (§Perf)
    sp_blocks: bool = True           # Megatron-SP: seq-shard every block output
                                     # (turns activation all-reduces into RS+AG)
    grad_dtype: str = ""             # e.g. "bfloat16": cast grads before the
                                     # cross-replica reduce (halves AR wire bytes)
    remat: str = "full"              # none | full  (activation checkpointing per period)
    optimizer: str = "adamw"         # adamw | adafactor
    supports_long: bool = False      # sub-quadratic -> long_500k cell runs

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_periods(self) -> int:
        body = self.n_layers - len(self.tail)
        assert body % len(self.pattern) == 0, (self.name, body, self.pattern)
        return body // len(self.pattern)

    def with_(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


_REGISTRY: Dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    from . import _load_all  # late import: populate registry
    _load_all()
    return _REGISTRY[name]


def all_archs() -> Dict[str, ArchConfig]:
    from . import _load_all
    _load_all()
    return dict(_REGISTRY)


def shape_applicable(cfg: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Is this (arch x shape) cell runnable? Returns (ok, reason-if-not)."""
    if shape.name == "long_500k" and not cfg.supports_long:
        return False, "full quadratic attention; 512k decode skipped per DESIGN.md §4"
    return True, ""
