"""Jitted public wrapper for the occupancy-gated spiking convolution."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .ref import im2col
from .spike_conv import spike_matmul


def _pad_to(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    pad = (-x.shape[axis]) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(
    jax.jit,
    static_argnames=("padding", "block_m", "block_k", "block_n", "gate", "interpret"),
)
def spike_conv2d(
    spikes: jax.Array,
    weights: jax.Array,
    *,
    padding: str = "SAME",
    block_m: int = 256,
    block_k: int = 128,
    block_n: int = 128,
    gate: bool = True,
    interpret: bool = False,
) -> jax.Array:
    """Event-driven spiking conv: [B,H,W,Cin] x [KH,KW,Cin,Cout] -> [B,H,W,Cout].

    Inference-path kernel (forward only). The training path uses the XLA
    convolution with identical numerics (see ref.conv_ref).
    """
    b, h, w, cin = spikes.shape
    kh, kw, _, cout = weights.shape
    patches = im2col(spikes, kh, kw, padding)            # [M, K]
    w2d = weights.reshape(kh * kw * cin, cout)           # [K, N]

    m, k = patches.shape
    block_m = min(block_m, _round_up(m))
    block_k = min(block_k, _round_up(k))
    block_n = min(block_n, _round_up(cout))
    patches = _pad_to(_pad_to(patches, 0, block_m), 1, block_k)
    w2d = _pad_to(_pad_to(w2d, 0, block_k), 1, block_n)

    out = spike_matmul(
        patches, w2d,
        block_m=block_m, block_k=block_k, block_n=block_n,
        gate=gate, interpret=interpret,
    )
    out = out[:m, :cout]
    oh, ow = (h, w) if padding == "SAME" else (h - kh + 1, w - kw + 1)
    return out.reshape(b, oh, ow, cout)


def _round_up(x: int, multiple: int = 128) -> int:
    return ((x + multiple - 1) // multiple) * multiple
