"""Public wrappers for the occupancy-gated spiking convolution.

Two entry points:

* ``spike_conv2d``        — the original kernel: the occupancy test runs
                            *inside* the matmul kernel (`jnp.any` per tile),
                            so every tile is DMA'd into VMEM just to discover
                            it is empty. Kept as the comparison baseline.
* ``spike_conv2d_mapped`` — the fused-pipeline kernel: a cheap precompute
                            pass reduces the binary spike tensor to a
                            [M/bm, K/bk] int32 occupancy map that is scalar-
                            prefetched into the kernel, so empty tiles skip
                            the VMEM load *and* the MXU dot. Returns the
                            measured tile-skip stats alongside the output.

Both wrappers count their kernel launches in ``KERNEL_LAUNCHES`` (python-call
granularity: inside an enclosing ``jax.jit`` the count is per *trace*, i.e.
launches baked into the executed graph — the quantity the fused-pipeline
benchmark reports).
"""
from __future__ import annotations

import collections
import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ...core.tiling import round_up as _round_up
from .ref import im2col
from .spike_conv import spike_matmul, spike_matmul_mapped

# name -> number of gated-matmul launches issued (per trace when jitted).
KERNEL_LAUNCHES: collections.Counter = collections.Counter()


def reset_launch_counts() -> None:
    KERNEL_LAUNCHES.clear()


def launch_counts() -> Dict[str, int]:
    return dict(KERNEL_LAUNCHES)


def _pad_to(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    pad = (-x.shape[axis]) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# ---------------------------------------------------------------------------
# Occupancy-map precompute
# ---------------------------------------------------------------------------

def occupancy_map(patches: jax.Array, block_m: int, block_k: int) -> jax.Array:
    """[M, K] binary spikes -> [M/bm, K/bk] int32 map: 1 iff the tile spikes.

    One cheap VPU reduction over the spike tensor; its output is the paper's
    per-event work list collapsed to the tile granularity the TPU can skip at.
    """
    m, k = patches.shape
    assert m % block_m == 0 and k % block_k == 0, ((m, k), (block_m, block_k))
    tiles = patches.reshape(m // block_m, block_m, k // block_k, block_k)
    return jnp.any(tiles != 0, axis=(1, 3)).astype(jnp.int32)


def skip_load_indices(occupancy: jax.Array) -> jax.Array:
    """For each (i, kk): the largest occupied k-tile index <= kk (0 if none).

    Feeding this through the kernel's index maps keeps the block index
    constant across runs of empty tiles, which makes their DMA a no-op
    (Pallas elides a fetch whose index equals the previous grid step's).
    """
    nk = occupancy.shape[1]
    kk = jnp.arange(nk, dtype=jnp.int32)[None, :]
    last = jax.lax.associative_scan(
        jnp.maximum, jnp.where(occupancy != 0, kk, -1), axis=1)
    return jnp.maximum(last, 0).astype(jnp.int32)


# ---------------------------------------------------------------------------
# In-kernel-gated wrapper (baseline)
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit,
    static_argnames=("padding", "block_m", "block_k", "block_n", "gate", "interpret"),
)
def _spike_conv2d_impl(
    spikes: jax.Array,
    weights: jax.Array,
    *,
    padding: str,
    block_m: int,
    block_k: int,
    block_n: int,
    gate: bool,
    interpret: bool,
) -> jax.Array:
    b, h, w, cin = spikes.shape
    kh, kw, _, cout = weights.shape
    patches = im2col(spikes, kh, kw, padding)            # [M, K]
    w2d = weights.reshape(kh * kw * cin, cout)           # [K, N]

    m, k = patches.shape
    block_m = min(block_m, _round_up(m))
    block_k = min(block_k, _round_up(k))
    block_n = min(block_n, _round_up(cout))
    patches = _pad_to(_pad_to(patches, 0, block_m), 1, block_k)
    w2d = _pad_to(_pad_to(w2d, 0, block_k), 1, block_n)

    out = spike_matmul(
        patches, w2d,
        block_m=block_m, block_k=block_k, block_n=block_n,
        gate=gate, interpret=interpret,
    )
    out = out[:m, :cout]
    oh, ow = (h, w) if padding == "SAME" else (h - kh + 1, w - kw + 1)
    return out.reshape(b, oh, ow, cout)


def spike_conv2d(
    spikes: jax.Array,
    weights: jax.Array,
    *,
    padding: str = "SAME",
    block_m: int = 256,
    block_k: int = 128,
    block_n: int = 128,
    gate: bool = True,
    interpret: bool = False,
) -> jax.Array:
    """Event-driven spiking conv: [B,H,W,Cin] x [KH,KW,Cin,Cout] -> [B,H,W,Cout].

    Inference-path kernel (forward only). The training path uses the XLA
    convolution with identical numerics (see ref.conv_ref).
    """
    KERNEL_LAUNCHES["spike_matmul"] += 1
    return _spike_conv2d_impl(
        spikes, weights, padding=padding,
        block_m=block_m, block_k=block_k, block_n=block_n,
        gate=gate, interpret=interpret)


# ---------------------------------------------------------------------------
# Occupancy-mapped wrapper (fused pipeline)
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit,
    static_argnames=("padding", "block_m", "block_k", "block_n", "gate", "interpret"),
)
def _spike_conv2d_mapped_impl(
    spikes: jax.Array,
    weights: jax.Array,
    *,
    padding: str,
    block_m: int,
    block_k: int,
    block_n: int,
    gate: bool,
    interpret: bool,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    b, h, w, cin = spikes.shape
    kh, kw, _, cout = weights.shape
    patches = im2col(spikes, kh, kw, padding)            # [M, K]
    w2d = weights.reshape(kh * kw * cin, cout)           # [K, N]

    m, k = patches.shape
    block_m = min(block_m, _round_up(m))
    block_k = min(block_k, _round_up(k))
    block_n = min(block_n, _round_up(cout))
    patches = _pad_to(_pad_to(patches, 0, block_m), 1, block_k)
    w2d = _pad_to(_pad_to(w2d, 0, block_k), 1, block_n)

    occ = occupancy_map(patches, block_m, block_k)
    if not gate:
        occ = jnp.ones_like(occ)
    lidx = skip_load_indices(occ)

    out = spike_matmul_mapped(
        patches, w2d, occ, lidx,
        block_m=block_m, block_k=block_k, block_n=block_n,
        interpret=interpret,
    )
    out = out[:m, :cout]
    oh, ow = (h, w) if padding == "SAME" else (h - kh + 1, w - kw + 1)

    tiles_total = jnp.asarray(occ.size, jnp.float32)
    tiles_occupied = occ.sum().astype(jnp.float32)
    stats = {
        "tiles_total": tiles_total,
        "tiles_occupied": tiles_occupied,
        "skip_rate": (tiles_total - tiles_occupied) / tiles_total,
        # raw maps + the clamped tile geometry, so callers (the serving
        # engine) can attribute tile skips back to individual requests in a
        # folded [T*B·H·W, K] batch: occ_map at (block_m x block_k) tile
        # granularity, row_occ at (row x block_k) granularity (who actually
        # spiked inside a tile that straddles two requests)
        "occ_map": occ,
        "row_occ": jnp.any(
            patches.reshape(patches.shape[0], occ.shape[1], block_k) != 0,
            axis=2).astype(jnp.int8),
        "block_m": jnp.int32(block_m),
        "rows": jnp.int32(m),
    }
    return out.reshape(b, oh, ow, cout), stats


def spike_conv2d_mapped(
    spikes: jax.Array,
    weights: jax.Array,
    *,
    padding: str = "SAME",
    block_m: int = 256,
    block_k: int = 128,
    block_n: int = 128,
    gate: bool = True,
    interpret: bool = False,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Occupancy-mapped spiking conv -> (output, tile-skip stats).

    Same numerics as ``spike_conv2d``; the batch axis may carry folded
    timesteps ([T*B, H, W, Cin]) — the fused pipeline's one-launch-per-layer
    form.

    The returned stats dict measures this launch's skippable work at two
    granularities (all shapes refer to the padded im2col matmul
    [M_pad, K_pad] with the block sizes *after* clamping to the padded
    problem):

    ``tiles_total`` /      scalar f32 counts of (block_m x block_k) spike
    ``tiles_occupied``     tiles overall / containing at least one spike.
    ``skip_rate``          scalar f32 in [0, 1]: fraction of tiles whose
                           VMEM DMA + MXU dot the kernel skipped,
                           ``1 - tiles_occupied / tiles_total``.
    ``occ_map``            int32 [M_pad/block_m, K_pad/block_k]: the
                           scalar-prefetched occupancy map itself — 1 iff
                           the tile spikes (all-ones when ``gate=False``).
    ``row_occ``            int8 [M_pad, K_pad/block_k]: occupancy at
                           (row x k-tile) granularity — which *rows* inside
                           a tile actually spiked. Callers that fold many
                           requests into M (the serving engine) use this to
                           attribute skips to individual requests: a tile
                           straddling two images is billed only to the rows
                           that spiked (see `serve.runners.snn`).
    ``block_m`` / ``rows`` int32: the clamped M tile size and the *unpadded*
                           row count M, so row_occ rows past ``rows`` (pure
                           padding) can be dropped before re-tiling.
    """
    KERNEL_LAUNCHES["spike_matmul_mapped"] += 1
    return _spike_conv2d_mapped_impl(
        spikes, weights, padding=padding,
        block_m=block_m, block_k=block_k, block_n=block_n,
        gate=gate, interpret=interpret)
