"""Pure-jnp oracles for the sparse spiking convolution.

Two references:
  * conv_ref        — dense convolution via lax.conv_general_dilated (the
                      numerical ground truth).
  * event_conv_ref  — the paper's event-driven semantics made explicit:
                      every spike at (b, y, x, c) scatter-accumulates the
                      filter column into the 3x3 neighbourhood of membrane
                      potentials, exactly like the FPGA Address Generation +
                      Accum routines. Used to prove event-driven == dense.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def conv_ref(spikes: jax.Array, weights: jax.Array, padding: str = "SAME") -> jax.Array:
    """spikes [B,H,W,Cin] x weights [KH,KW,Cin,Cout] -> [B,H,W,Cout] (fp32)."""
    return jax.lax.conv_general_dilated(
        spikes.astype(jnp.float32),
        weights.astype(jnp.float32),
        window_strides=(1, 1),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def im2col(spikes: jax.Array, kh: int, kw: int, padding: str = "SAME") -> jax.Array:
    """Extract [B*H*W, KH*KW*Cin] patches matching conv_ref's SAME layout."""
    b, h, w, c = spikes.shape
    if padding == "SAME":
        ph, pw = (kh - 1) // 2, (kw - 1) // 2
        x = jnp.pad(spikes, ((0, 0), (ph, kh - 1 - ph), (pw, kw - 1 - pw), (0, 0)))
        oh, ow = h, w
    else:  # VALID
        x = spikes
        oh, ow = h - kh + 1, w - kw + 1
    patches = []
    for dy in range(kh):
        for dx in range(kw):
            patches.append(x[:, dy:dy + oh, dx:dx + ow, :])
    # [B, OH, OW, KH*KW, C] -> [B*OH*OW, KH*KW*C]
    stacked = jnp.stack(patches, axis=3)
    return stacked.reshape(b * oh * ow, kh * kw * c)


def matmul_ref(patches: jax.Array, weights2d: jax.Array) -> jax.Array:
    return jnp.dot(patches.astype(jnp.float32), weights2d.astype(jnp.float32))


def event_conv_ref(spikes: jax.Array, weights: jax.Array) -> jax.Array:
    """Event-driven scatter-accumulate semantics (paper Fig. 3), SAME padding.

    For each input spike, add the filter taps into the affected output
    neighbourhood — implemented as a gather formulation per output site for
    tractability, mathematically identical to the FPGA scatter pipeline.
    """
    b, h, w, cin = spikes.shape
    kh, kw, _, cout = weights.shape
    ph, pw = (kh - 1) // 2, (kw - 1) // 2
    padded = jnp.pad(spikes, ((0, 0), (ph, kh - 1 - ph), (pw, kw - 1 - pw), (0, 0)))
    out = jnp.zeros((b, h, w, cout), jnp.float32)
    # Sum over filter taps: out[y, x] += s[y+dy, x+dx] * w[dy, dx]
    for dy in range(kh):
        for dx in range(kw):
            s = padded[:, dy:dy + h, dx:dx + w, :].astype(jnp.float32)
            out = out + jnp.einsum("bhwc,cn->bhwn", s, weights[dy, dx].astype(jnp.float32))
    return out
