"""Occupancy-gated spiking convolution kernel (sparse core, paper §IV-B).

TPU adaptation of the paper's event-driven sparse core: instead of a priority
encoder popping one spike per cycle, spikes stay binary inside dense
(block_m x block_k) VMEM tiles and the kernel *skips the MXU dot for any tile
containing zero spikes* (`@pl.when`). Event granularity 1 -> tile granularity,
which is the skip granularity the TPU memory/compute hierarchy can exploit.

The convolution itself is expressed as an im2col matmul (done by ops.py):
    patches [M, K] @ weights [K, N] -> currents [M, N]
with M = B*H_out*W_out, K = KH*KW*C_in, N = C_out. Because spike activations
are binary, the dot is effectively a masked column-sum of the weights; the
MXU executes it as a matmul, and zero tiles are skipped entirely.

Accumulation is fp32 in-place in the output block across the K grid dimension
(k is the innermost, sequential grid axis).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_M = 256
DEFAULT_BLOCK_K = 128
DEFAULT_BLOCK_N = 128


def _spike_matmul_kernel(x_ref, w_ref, o_ref, *, gate: bool):
    """One (i, j, k) grid step: o[i,j] += x[i,k] @ w[k,j], gated on occupancy."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]

    def _accumulate():
        o_ref[...] += jnp.dot(
            x, w_ref[...], preferred_element_type=jnp.float32
        ).astype(o_ref.dtype)

    if gate:
        # Tile-level occupancy gate: the block-granular analogue of the
        # paper's per-event skipping. On TPU this saves the MXU issue and
        # the partial-sum write for all-zero spike tiles.
        has_spike = jnp.any(x != 0)
        pl.when(has_spike)(_accumulate)
    else:
        _accumulate()


def spike_matmul(
    patches: jax.Array,
    weights: jax.Array,
    *,
    block_m: int = DEFAULT_BLOCK_M,
    block_k: int = DEFAULT_BLOCK_K,
    block_n: int = DEFAULT_BLOCK_N,
    gate: bool = True,
    interpret: bool = False,
) -> jax.Array:
    """patches [M, K] (binary spikes) @ weights [K, N] -> [M, N] fp32.

    M, K, N must be multiples of the block sizes (ops.py pads).
    """
    m, k = patches.shape
    k2, n = weights.shape
    assert k == k2, (patches.shape, weights.shape)
    assert m % block_m == 0 and k % block_k == 0 and n % block_n == 0, (
        (m, k, n), (block_m, block_k, block_n))

    grid = (m // block_m, n // block_n, k // block_k)
    return pl.pallas_call(
        functools.partial(_spike_matmul_kernel, gate=gate),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(patches, weights)


# ---------------------------------------------------------------------------
# Occupancy-mapped variant: the gate moves out of the kernel body
# ---------------------------------------------------------------------------

def _spike_matmul_mapped_kernel(occ_ref, lidx_ref, x_ref, w_ref, o_ref):
    """Grid step gated by the *prefetched* occupancy map.

    `occ_ref[i, kk]` decides whether this (block_m x block_k) spike tile
    contributes. The in-kernel `jnp.any` test of the plain `spike_matmul` is
    gone: empty tiles skip the MXU dot, and — because the index maps route
    their loads through `lidx_ref` (the last occupied k-tile) — the VMEM DMA
    for both the spike tile and the weight tile is elided too (Pallas skips a
    fetch whose block index equals the previous grid step's).
    """
    i = pl.program_id(0)
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(occ_ref[i, kk] != 0)
    def _accumulate():
        o_ref[...] += jnp.dot(
            x_ref[...], w_ref[...], preferred_element_type=jnp.float32
        ).astype(o_ref.dtype)


def spike_matmul_mapped(
    patches: jax.Array,
    weights: jax.Array,
    occupancy: jax.Array,
    load_idx: jax.Array,
    *,
    block_m: int = DEFAULT_BLOCK_M,
    block_k: int = DEFAULT_BLOCK_K,
    block_n: int = DEFAULT_BLOCK_N,
    interpret: bool = False,
) -> jax.Array:
    """patches [M, K] @ weights [K, N] -> [M, N] fp32, gated by a precomputed
    [M/block_m, K/block_k] occupancy map (see ops.occupancy_map).

    `load_idx[i, kk]` must be the largest occupied k-tile index <= kk for row
    block i (0 when none) — ops.skip_load_indices computes it. It keeps the
    input/weight block index constant across runs of empty tiles so the
    pipeline issues no DMA for them.
    """
    m, k = patches.shape
    k2, n = weights.shape
    assert k == k2, (patches.shape, weights.shape)
    assert m % block_m == 0 and k % block_k == 0 and n % block_n == 0, (
        (m, k, n), (block_m, block_k, block_n))
    nm, nk = m // block_m, k // block_k
    assert occupancy.shape == (nm, nk) == load_idx.shape, (
        occupancy.shape, load_idx.shape, (nm, nk))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(nm, n // block_n, nk),
        in_specs=[
            pl.BlockSpec((block_m, block_k),
                         lambda i, j, kk, occ, lidx: (i, lidx[i, kk])),
            pl.BlockSpec((block_k, block_n),
                         lambda i, j, kk, occ, lidx: (lidx[i, kk], j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n),
                               lambda i, j, kk, occ, lidx: (i, j)),
    )
    return pl.pallas_call(
        _spike_matmul_mapped_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(occupancy, load_idx, patches, weights)
