"""Occupancy-gated spiking convolution kernel (sparse core, paper §IV-B).

TPU adaptation of the paper's event-driven sparse core: instead of a priority
encoder popping one spike per cycle, spikes stay binary inside dense
(block_m x block_k) VMEM tiles and the kernel *skips the MXU dot for any tile
containing zero spikes* (`@pl.when`). Event granularity 1 -> tile granularity,
which is the skip granularity the TPU memory/compute hierarchy can exploit.

The convolution itself is expressed as an im2col matmul (done by ops.py):
    patches [M, K] @ weights [K, N] -> currents [M, N]
with M = B*H_out*W_out, K = KH*KW*C_in, N = C_out. Because spike activations
are binary, the dot is effectively a masked column-sum of the weights; the
MXU executes it as a matmul, and zero tiles are skipped entirely.

Accumulation is fp32 in-place in the output block across the K grid dimension
(k is the innermost, sequential grid axis).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_M = 256
DEFAULT_BLOCK_K = 128
DEFAULT_BLOCK_N = 128


def _spike_matmul_kernel(x_ref, w_ref, o_ref, *, gate: bool):
    """One (i, j, k) grid step: o[i,j] += x[i,k] @ w[k,j], gated on occupancy."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]

    def _accumulate():
        o_ref[...] += jnp.dot(
            x, w_ref[...], preferred_element_type=jnp.float32
        ).astype(o_ref.dtype)

    if gate:
        # Tile-level occupancy gate: the block-granular analogue of the
        # paper's per-event skipping. On TPU this saves the MXU issue and
        # the partial-sum write for all-zero spike tiles.
        has_spike = jnp.any(x != 0)
        pl.when(has_spike)(_accumulate)
    else:
        _accumulate()


def spike_matmul(
    patches: jax.Array,
    weights: jax.Array,
    *,
    block_m: int = DEFAULT_BLOCK_M,
    block_k: int = DEFAULT_BLOCK_K,
    block_n: int = DEFAULT_BLOCK_N,
    gate: bool = True,
    interpret: bool = False,
) -> jax.Array:
    """patches [M, K] (binary spikes) @ weights [K, N] -> [M, N] fp32.

    M, K, N must be multiples of the block sizes (ops.py pads).
    """
    m, k = patches.shape
    k2, n = weights.shape
    assert k == k2, (patches.shape, weights.shape)
    assert m % block_m == 0 and k % block_k == 0 and n % block_n == 0, (
        (m, k, n), (block_m, block_k, block_n))

    grid = (m // block_m, n // block_n, k // block_k)
    return pl.pallas_call(
        functools.partial(_spike_matmul_kernel, gate=gate),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(patches, weights)
