"""Pure-jnp oracle for the W4A16 int4 matmul."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...core.quant import QTensor, dequantize


def int4_matmul_ref(x: jax.Array, qt: QTensor) -> jax.Array:
    """Dequantize-to-fp32 then matmul — the numerical ground truth."""
    w = dequantize(qt, jnp.float32)
    return jnp.dot(x.astype(jnp.float32), w)
