"""Jitted public wrapper: W4A16 linear layer over a QTensor."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...core.quant import QTensor
from ...core.tiling import round_up as _round_up
from .int4_matmul import int4_matmul


@functools.partial(jax.jit, static_argnames=("block_m", "block_k", "block_n", "interpret"))
def w4a16_linear(
    x: jax.Array,
    qt: QTensor,
    *,
    block_m: int = 256,
    block_k: int = 512,
    block_n: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """x [..., K] @ int4-packed qt (logical [K, N]) -> [..., N] fp32.

    Pads M/K/N to block multiples; the packed layout (2 channels/byte along N)
    matches core.quant.pack_int4.
    """
    k_logical, n_logical = qt.shape
    lead = x.shape[:-1]
    x2 = x.reshape(-1, k_logical)
    m = x2.shape[0]

    bm = min(block_m, _round_up(m, 8))
    bk = min(block_k, _round_up(k_logical, 128))
    bn = min(block_n, _round_up(n_logical, 128))
    bn += bn % 2  # packed axis needs even blocks

    x2 = jnp.pad(x2, ((0, (-m) % bm), (0, (-k_logical) % bk)))
    packed = jnp.pad(qt.packed, ((0, (-k_logical) % bk), (0, (-(n_logical // 2)) % (bn // 2))))
    scale = jnp.broadcast_to(qt.scale.reshape(1, -1), (1, n_logical)).astype(jnp.float32)
    scale = jnp.pad(scale, ((0, 0), (0, (-n_logical) % bn)))

    out = int4_matmul(x2, packed, scale, block_m=bm, block_k=bk, block_n=bn, interpret=interpret)
    return out[:m, :n_logical].reshape(*lead, n_logical)


def _round_up(x: int, multiple: int) -> int:
    return ((x + multiple - 1) // multiple) * multiple
