"""W4A16 matmul kernel: packed-int4 weights dequantized in VMEM (paper §IV-D).

The FPGA design dequantizes int4 weights with shift-and-add constant
multipliers to avoid DSP blocks; the TPU analogue is keeping dequantization
*inside the kernel* so HBM traffic is int4 (2 values/byte) rather than
fp32/bf16 — a 4-8x reduction in the weight-streaming term, which is what
dominates memory-bound serving (decode) steps.

Layout: weights are packed along N (two output channels per byte):
    packed [K, N//2] int8, logical w[k, 2j] = low nibble, w[k, 2j+1] = high.
Per-output-channel scales [1, N] are applied to the fp32 accumulator in the
final K step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _unpack_nibbles(packed: jax.Array) -> jax.Array:
    """int8 [bk, bn//2] -> int8-valued [-8, 7] array [bk, bn] (interleaved)."""
    lo = (packed & 0xF).astype(jnp.int8)
    hi = ((packed >> 4) & 0xF).astype(jnp.int8)
    lo = jnp.where(lo >= 8, lo - 16, lo)
    hi = jnp.where(hi >= 8, hi - 16, hi)
    bk, bn2 = packed.shape
    return jnp.stack([lo, hi], axis=-1).reshape(bk, bn2 * 2)


def _int4_matmul_kernel(x_ref, wp_ref, scale_ref, o_ref):
    k = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = _unpack_nibbles(wp_ref[...]).astype(x_ref.dtype)
    o_ref[...] += jnp.dot(x_ref[...], w, preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _finish():
        o_ref[...] = o_ref[...] * scale_ref[...]


def int4_matmul(
    x: jax.Array,
    packed: jax.Array,
    scale: jax.Array,
    *,
    block_m: int = 256,
    block_k: int = 512,
    block_n: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """x [M, K] @ dequant(packed [K, N//2], scale [1, N]) -> [M, N] fp32."""
    m, k = x.shape
    k2, n2 = packed.shape
    n = n2 * 2
    assert k == k2, (x.shape, packed.shape)
    assert m % block_m == 0 and k % block_k == 0 and n % block_n == 0

    grid = (m // block_m, n // block_n, k // block_k)
    return pl.pallas_call(
        _int4_matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n // 2), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, block_n), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, packed, scale)
