"""Pure-jnp oracle for the fused flash-attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def causal_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """q/k/v [BH, S, hd] -> [BH, S, hd], standard masked softmax attention."""
    bh, s, hd = q.shape
    scores = jnp.einsum("bqh,bkh->bqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / (hd ** 0.5)
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask[None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bqk,bkh->bqh", w, v.astype(jnp.float32)).astype(q.dtype)
