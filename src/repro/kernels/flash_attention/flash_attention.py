"""Fused causal flash-attention kernel (Pallas TPU).

The §Perf decomposition shows the dominant HBM term for dense-transformer
training is the attention score chain: an unfused [Cq, Ck] f32 score tensor
crosses HBM ~7x per chunk (mask, max, exp, sum, two matmul operand reads,
cast). This kernel keeps the entire online-softmax pipeline in VMEM: HBM
traffic collapses to Q/K/V/O block streams — the flash-attention bound.

Grid: (batch*kv_heads*groups, nq, nk), innermost nk sequential. The running
max/denominator (m, l) and the output accumulator live in output refs blocked
per (bh, i) — the same accumulate-in-output pattern as kernels/spike_conv.

Causal block skipping: a kv block entirely in the future of the q block is
skipped with @pl.when — zero MXU issue and zero VMEM traffic for ~half the
blocks. This is the same *structural* gating the paper's sparse cores apply
to spike events, applied to the causal mask (DESIGN.md §2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *,
                  bq: int, bk: int, scale: float):
    i = pl.program_id(1)
    kk = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kk == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = i * bq
    k_start = kk * bk

    @pl.when(k_start <= q_start + bq - 1)      # causal block skip
    def _block():
        q = q_ref[0].astype(jnp.float32) * scale        # [bq, hd]
        k = k_ref[0].astype(jnp.float32)                # [bk, hd]
        v = v_ref[0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # [bq, bk]
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(kpos <= qpos, s, NEG_INF)

        m_prev = m_ref[0]                                # [bq]
        l_prev = l_ref[0]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + p.sum(axis=-1)
        o_new = o_ref[0] * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[0] = m_new
        l_ref[0] = l_new
        o_ref[0] = o_new

    @pl.when(kk == nk - 1)
    def _finish():
        o_ref[0] = o_ref[0] / jnp.maximum(l_ref[0], 1e-30)[:, None]


def flash_attention_fwd(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    block_q: int = 256, block_k: int = 256, interpret: bool = False,
) -> jax.Array:
    """Causal attention. q/k/v: [BH, S, hd] (kv already broadcast to q heads).

    Returns o [BH, S, hd] (f32 accumulation, cast to q.dtype).
    """
    bh, s, hd = q.shape
    assert k.shape == v.shape == (bh, s, hd)
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0
    grid = (bh, s // block_q, s // block_k)
    scale = 1.0 / (hd ** 0.5)

    kernel = functools.partial(_flash_kernel, bq=block_q, bk=block_k, scale=scale)
    o, m, l = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, kk: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, kk: (b, kk, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, kk: (b, kk, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, kk: (b, i, 0)),
            pl.BlockSpec((1, block_q), lambda b, i, kk: (b, i)),
            pl.BlockSpec((1, block_q), lambda b, i, kk: (b, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, hd), jnp.float32),
            jax.ShapeDtypeStruct((bh, s), jnp.float32),
            jax.ShapeDtypeStruct((bh, s), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return o.astype(q.dtype)
