"""Jitted GQA wrapper for the fused flash-attention kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_fwd


@functools.partial(jax.jit, static_argnames=("block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    block_q: int = 256, block_k: int = 256,
                    interpret: bool = False) -> jax.Array:
    """GQA causal attention: q [B,S,H,hd], k/v [B,S,KV,hd] -> [B,S,H,hd]."""
    b, s, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    # broadcast kv heads to q heads and fold (B, H) into one grid axis
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), g, axis=1).reshape(b * h, s, hd)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), g, axis=1).reshape(b * h, s, hd)
    o = flash_attention_fwd(qf, kf, vf, block_q=block_q, block_k=block_k,
                            interpret=interpret)
    return o.reshape(b, h, s, hd).transpose(0, 2, 1, 3)
