"""Pure-jnp oracle for the fused LIF step — delegates to core.lif."""
from __future__ import annotations

import jax

from ...core.lif import LIFParams, lif_step


def lif_step_ref(u: jax.Array, current: jax.Array, prev_spike: jax.Array, *, beta: float, theta: float):
    p = LIFParams(beta=beta, theta=theta)
    return lif_step(u, current, prev_spike, p)
