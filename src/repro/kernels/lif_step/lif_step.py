"""Fused element-wise LIF update kernel (VPU path).

One timestep of paper Eq. 1-2 for a whole membrane tensor:
    u' = beta * u + current - s_prev * theta ;  s = (u' > theta)
Fusing the decay, integration, soft reset, and threshold into one VMEM pass
avoids three HBM round-trips per timestep — the serving-path hot loop for
spiking layers (the training path uses the autodiff-friendly jnp version in
core.lif).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lif_step_kernel(u_ref, i_ref, s_ref, u_out_ref, s_out_ref, *, beta, theta):
    u = beta * u_ref[...] + i_ref[...] - s_ref[...] * theta
    u_out_ref[...] = u
    s_out_ref[...] = (u > theta).astype(u.dtype)


def lif_step_fused(
    u: jax.Array,
    current: jax.Array,
    prev_spike: jax.Array,
    *,
    beta: float,
    theta: float,
    block_r: int = 256,
    block_c: int = 512,
    interpret: bool = False,
):
    """u, current, prev_spike: [R, C] -> (u_next, spike). R%block_r==C%block_c==0."""
    r, c = u.shape
    assert r % block_r == 0 and c % block_c == 0, ((r, c), (block_r, block_c))
    grid = (r // block_r, c // block_c)
    spec = pl.BlockSpec((block_r, block_c), lambda i, j: (i, j))
    kernel = functools.partial(_lif_step_kernel, beta=beta, theta=theta)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((r, c), u.dtype),
            jax.ShapeDtypeStruct((r, c), u.dtype),
        ],
        interpret=interpret,
    )(u, current, prev_spike)


# ---------------------------------------------------------------------------
# Conv-epilogue variant: bias add folded into the same VMEM pass
# ---------------------------------------------------------------------------

def _lif_epilogue_kernel(u_ref, i_ref, s_ref, b_ref, u_out_ref, s_out_ref, *, beta, theta):
    """Bias add + decay + soft reset + threshold in one pass.

    The bias is the conv/FC epilogue that the gated matmul deliberately does
    not apply (its output tiles are revisited across the k grid axis);
    folding it here means the currents take no extra HBM round-trip between
    the matmul and the LIF nonlinearity.
    """
    u = beta * u_ref[...] + (i_ref[...] + b_ref[...]) - s_ref[...] * theta
    u_out_ref[...] = u
    s_out_ref[...] = (u > theta).astype(u.dtype)


def lif_epilogue_fused(
    u: jax.Array,
    current: jax.Array,
    prev_spike: jax.Array,
    bias: jax.Array,
    *,
    beta: float,
    theta: float,
    block_r: int = 256,
    block_c: int = 512,
    interpret: bool = False,
):
    """u, current, prev_spike: [R, C]; bias: [1, C] -> (u_next, spike)."""
    r, c = u.shape
    assert bias.shape == (1, c), (bias.shape, c)
    assert r % block_r == 0 and c % block_c == 0, ((r, c), (block_r, block_c))
    grid = (r // block_r, c // block_c)
    spec = pl.BlockSpec((block_r, block_c), lambda i, j: (i, j))
    bias_spec = pl.BlockSpec((1, block_c), lambda i, j: (0, j))
    kernel = functools.partial(_lif_epilogue_kernel, beta=beta, theta=theta)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec, spec, spec, bias_spec],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((r, c), u.dtype),
            jax.ShapeDtypeStruct((r, c), u.dtype),
        ],
        interpret=interpret,
    )(u, current, prev_spike, bias)
