"""Jitted wrapper for the fused LIF step over arbitrary-shaped tensors."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...core.tiling import round_up as _round_up
from .lif_step import lif_epilogue_fused, lif_step_fused


@functools.partial(jax.jit, static_argnames=("beta", "theta", "interpret"))
def lif_update(
    u: jax.Array,
    current: jax.Array,
    prev_spike: jax.Array,
    *,
    beta: float = 0.15,
    theta: float = 0.5,
    interpret: bool = False,
):
    """Fused LIF update for any shape: flattens to 2D, pads to VPU tiles."""
    shape = u.shape
    flat = u.reshape(-1)
    n = flat.shape[0]
    cols = 512
    rows = -(-n // cols)
    block_r = min(256, ((rows + 7) // 8) * 8)
    rows_padded = -(-rows // block_r) * block_r

    def prep(x):
        x = x.reshape(-1)
        x = jnp.pad(x, (0, rows * cols - n))
        x = x.reshape(rows, cols)
        return jnp.pad(x, ((0, rows_padded - rows), (0, 0)))

    u2, i2, s2 = prep(u), prep(current), prep(prev_spike)
    u_next, s = lif_step_fused(
        u2, i2, s2, beta=beta, theta=theta,
        block_r=block_r, block_c=cols, interpret=interpret,
    )
    return (
        u_next.reshape(-1)[:n].reshape(shape),
        s.reshape(-1)[:n].reshape(shape),
    )


@functools.partial(jax.jit, static_argnames=("beta", "theta", "interpret"))
def lif_epilogue(
    u: jax.Array,
    current: jax.Array,
    prev_spike: jax.Array,
    bias: jax.Array,
    *,
    beta: float = 0.15,
    theta: float = 0.5,
    interpret: bool = False,
):
    """Fused conv-epilogue LIF update over channel-major tensors.

    u, current, prev_spike: [..., N]; bias: [N] broadcast over leading dims.
    Unlike `lif_update` (which flattens away the channel axis), the layout is
    kept 2D [rows, N] so the per-channel bias rides in the same VMEM pass as
    decay + soft reset + threshold — the epilogue of the gated spike matmul.
    """
    shape = u.shape
    n = shape[-1]
    assert bias.shape == (n,), (bias.shape, n)
    rows = 1
    for d in shape[:-1]:
        rows *= d

    block_c = min(512, _round_up(n, 128))
    cpad = (-n) % block_c
    block_r = min(256, ((rows + 7) // 8) * 8)
    rpad = (-rows) % block_r

    def prep(x):
        return jnp.pad(x.reshape(rows, n), ((0, rpad), (0, cpad)))

    u2, i2, s2 = prep(u), prep(current), prep(prev_spike)
    b2 = jnp.pad(bias.astype(u.dtype), (0, cpad)).reshape(1, -1)
    u_next, s = lif_epilogue_fused(
        u2, i2, s2, b2, beta=beta, theta=theta,
        block_r=block_r, block_c=block_c, interpret=interpret,
    )
    return (
        u_next[:rows, :n].reshape(shape),
        s[:rows, :n].reshape(shape),
    )
