"""Jitted wrapper for the fused LIF step over arbitrary-shaped tensors."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .lif_step import lif_step_fused


@functools.partial(jax.jit, static_argnames=("beta", "theta", "interpret"))
def lif_update(
    u: jax.Array,
    current: jax.Array,
    prev_spike: jax.Array,
    *,
    beta: float = 0.15,
    theta: float = 0.5,
    interpret: bool = False,
):
    """Fused LIF update for any shape: flattens to 2D, pads to VPU tiles."""
    shape = u.shape
    flat = u.reshape(-1)
    n = flat.shape[0]
    cols = 512
    rows = -(-n // cols)
    block_r = min(256, ((rows + 7) // 8) * 8)
    rows_padded = -(-rows // block_r) * block_r

    def prep(x):
        x = x.reshape(-1)
        x = jnp.pad(x, (0, rows * cols - n))
        x = x.reshape(rows, cols)
        return jnp.pad(x, ((0, rows_padded - rows), (0, 0)))

    u2, i2, s2 = prep(u), prep(current), prep(prev_spike)
    u_next, s = lif_step_fused(
        u2, i2, s2, beta=beta, theta=theta,
        block_r=block_r, block_c=cols, interpret=interpret,
    )
    return (
        u_next.reshape(-1)[:n].reshape(shape),
        s.reshape(-1)[:n].reshape(shape),
    )
