"""Dense-core kernel: input-layer convolution fused with LIF over T timesteps.

TPU adaptation of the paper's weight-stationary dense core (27-PE systolic
array for the 3-channel, 3x3-filter input layer). On TPU the weight matrix
[K=27(pad), N=C_out] stays resident in VMEM across the whole M grid
(weight-stationary <=> block residency), the im2col'd image patches stream
through the MXU, and the LIF dynamics for all T timesteps are fused into the
epilogue.

Direct coding presents the *same* image every timestep, so the convolution is
computed once and the T-step LIF recurrence runs on the in-register current:
    u[t+1] = beta * u[t] + I - s[t-1] * theta ;  s[t] = u[t+1] > theta
(paper Eq. 1-2). This hoisting is bit-exact vs. per-timestep recompute and is
one of the beyond-paper wins recorded in EXPERIMENTS.md.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dense_conv_lif_kernel(x_ref, w_ref, b_ref, s_ref, u_ref, *, num_steps, beta, theta):
    """Grid step (i, j): currents = x[i] @ w[:, j] + bias[j]; run T LIF steps."""
    current = jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    ) + b_ref[...]

    u = jnp.zeros_like(current)
    s = jnp.zeros_like(current)
    for t in range(num_steps):  # T is small (2-8) and static: unrolled
        u = beta * u + current - s * theta
        s = (u > theta).astype(current.dtype)
        s_ref[t, ...] = s
    u_ref[...] = u


def dense_conv_lif(
    patches: jax.Array,
    weights: jax.Array,
    bias: jax.Array,
    *,
    num_steps: int,
    beta: float,
    theta: float,
    block_m: int = 256,
    block_n: int = 128,
    interpret: bool = False,
):
    """[M, K] patches x [K, N] weights (+bias [N]) -> spikes [T, M, N], u [M, N].

    K is the full (padded) im2col depth — a single K block, since the input
    layer has K = 27 (3 channels x 3x3 filter), the same observation that
    sized the paper's 27-PE array.
    """
    m, k = patches.shape
    k2, n = weights.shape
    assert k == k2 and m % block_m == 0 and n % block_n == 0
    grid = (m // block_m, n // block_n)

    kernel = functools.partial(
        _dense_conv_lif_kernel, num_steps=num_steps, beta=beta, theta=theta
    )
    spikes, u = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, block_n), lambda i, j: (0, j)),   # weight-stationary
            pl.BlockSpec((1, block_n), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((num_steps, block_m, block_n), lambda i, j: (0, i, j)),
            pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((num_steps, m, n), jnp.float32),
            jax.ShapeDtypeStruct((m, n), jnp.float32),
        ],
        interpret=interpret,
    )(patches, weights, bias.reshape(1, n))
    return spikes, u
