"""Jitted public wrapper for the dense-core fused conv+LIF (input layer)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...core.tiling import round_up as _round_up
from ..spike_conv.ref import im2col
from .dense_conv_lif import dense_conv_lif


@functools.partial(
    jax.jit,
    static_argnames=("num_steps", "beta", "theta", "block_m", "block_n", "interpret"),
)
def input_layer_conv_lif(
    image: jax.Array,
    weights: jax.Array,
    bias: jax.Array,
    *,
    num_steps: int,
    beta: float = 0.15,
    theta: float = 0.5,
    block_m: int = 256,
    block_n: int = 128,
    interpret: bool = False,
):
    """Direct-coded input layer: [B,H,W,3] image -> spikes [T,B,H,W,Cout].

    Computes the convolution once (direct coding repeats the image each
    timestep) and runs the T-step LIF recurrence fused in the kernel.
    """
    b, h, w, cin = image.shape
    kh, kw, _, cout = weights.shape
    patches = im2col(image, kh, kw, "SAME")            # [M, K], K = kh*kw*cin
    w2d = weights.reshape(kh * kw * cin, cout)

    m, k = patches.shape
    block_m = min(block_m, _round_up(m))
    block_n = min(block_n, _round_up(cout))
    # pad K to a lane multiple, M/N to block multiples
    kpad = _round_up(k, 128)
    patches = jnp.pad(patches, ((0, (-m) % block_m), (0, kpad - k)))
    w2d = jnp.pad(w2d, ((0, kpad - k), (0, (-cout) % block_n)))
    bias_p = jnp.pad(bias.astype(jnp.float32), (0, (-cout) % block_n))

    spikes, u = dense_conv_lif(
        patches, w2d, bias_p,
        num_steps=num_steps, beta=beta, theta=theta,
        block_m=block_m, block_n=block_n, interpret=interpret,
    )
    spikes = spikes[:, :m, :cout].reshape(num_steps, b, h, w, cout)
    u = u[:m, :cout].reshape(b, h, w, cout)
    return spikes, u
