"""Jitted public wrapper for the dense-core fused conv+LIF (input layer).

Launch configuration (block_m/block_n) comes from the caller — in the serving
pipeline that is the layer's `KernelSpec` chosen by
`core.hybrid.plan_vgg9_inference`, not hard-coded heuristics. Launches are
counted in ``KERNEL_LAUNCHES`` with the same trace-time semantics as the
spike_conv counters, and the clamped block shapes of each launch are recorded
in ``LAUNCH_LOG`` so tests/benchmarks can assert the plan actually drives the
kernel.
"""
from __future__ import annotations

import collections
import functools
from typing import Dict, List

import jax
import jax.numpy as jnp

from ...core.tiling import round_up as _round_up
from ..spike_conv.ref import im2col
from .dense_conv_lif import dense_conv_lif

# name -> number of dense-core launches issued (per trace when jitted).
KERNEL_LAUNCHES: collections.Counter = collections.Counter()
# clamped launch configurations, in issue order (cleared with the counter)
LAUNCH_LOG: List[Dict[str, int]] = []


def reset_launch_counts() -> None:
    KERNEL_LAUNCHES.clear()
    LAUNCH_LOG.clear()


def launch_counts() -> Dict[str, int]:
    return dict(KERNEL_LAUNCHES)


@functools.partial(
    jax.jit,
    static_argnames=("num_steps", "beta", "theta", "block_m", "block_n", "interpret"),
)
def _input_layer_conv_lif_impl(
    image: jax.Array,
    weights: jax.Array,
    bias: jax.Array,
    *,
    num_steps: int,
    beta: float,
    theta: float,
    block_m: int,
    block_n: int,
    interpret: bool,
):
    b, h, w, cin = image.shape
    kh, kw, _, cout = weights.shape
    patches = im2col(image, kh, kw, "SAME")            # [M, K], K = kh*kw*cin
    w2d = weights.reshape(kh * kw * cin, cout)

    m, k = patches.shape
    # pad K to a lane multiple, M/N to block multiples
    kpad = _round_up(k, 128)
    patches = jnp.pad(patches, ((0, (-m) % block_m), (0, kpad - k)))
    w2d = jnp.pad(w2d, ((0, kpad - k), (0, (-cout) % block_n)))
    bias_p = jnp.pad(bias.astype(jnp.float32), (0, (-cout) % block_n))

    spikes, u = dense_conv_lif(
        patches, w2d, bias_p,
        num_steps=num_steps, beta=beta, theta=theta,
        block_m=block_m, block_n=block_n, interpret=interpret,
    )
    spikes = spikes[:, :m, :cout].reshape(num_steps, b, h, w, cout)
    u = u[:m, :cout].reshape(b, h, w, cout)
    return spikes, u


def input_layer_conv_lif(
    image: jax.Array,
    weights: jax.Array,
    bias: jax.Array,
    *,
    num_steps: int,
    beta: float = 0.15,
    theta: float = 0.5,
    block_m: int = 256,
    block_n: int = 128,
    interpret: bool = False,
):
    """Direct-coded input layer: [B,H,W,3] image -> spikes [T,B,H,W,Cout].

    Computes the convolution once (direct coding repeats the image each
    timestep) and runs the T-step LIF recurrence fused in the kernel.
    Block sizes are clamped to the padded problem size before launch.
    """
    b, h, w, _ = image.shape
    cout = weights.shape[-1]
    block_m = min(block_m, _round_up(b * h * w))
    block_n = min(block_n, _round_up(cout))
    KERNEL_LAUNCHES["dense_conv_lif"] += 1
    LAUNCH_LOG.append({"block_m": block_m, "block_n": block_n})
    return _input_layer_conv_lif_impl(
        image, weights, bias,
        num_steps=num_steps, beta=beta, theta=theta,
        block_m=block_m, block_n=block_n, interpret=interpret)
