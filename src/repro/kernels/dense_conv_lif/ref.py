"""Pure-jnp oracle for the fused dense-core conv + LIF kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dense_conv_lif_ref(
    patches: jax.Array,
    weights: jax.Array,
    bias: jax.Array,
    *,
    num_steps: int,
    beta: float,
    theta: float,
):
    """Reference: conv-as-matmul once, then T explicit LIF steps (Eq. 1-2)."""
    current = jnp.dot(patches.astype(jnp.float32), weights.astype(jnp.float32)) + bias
    u = jnp.zeros_like(current)
    s = jnp.zeros_like(current)
    spikes = []
    for _ in range(num_steps):
        u = beta * u + current - s * theta
        s = (u > theta).astype(current.dtype)
        spikes.append(s)
    return jnp.stack(spikes), u
