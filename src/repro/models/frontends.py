"""Stub modality frontends (per assignment: backbone only, frontend = STUB).

The audio (EnCodec) and vision (CLIP) encoders are external to the assigned
backbones; `input_specs()` provides precomputed frame/patch embeddings. These
helpers generate matching ShapeDtypeStructs (dry-run) and synthetic arrays
(smoke tests). The backbone projects them with `embed.w_front`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig


def frontend_spec(cfg: ArchConfig, batch: int, dtype=jnp.float32):
    """ShapeDtypeStruct for the precomputed frontend embeddings."""
    if not cfg.frontend:
        return None
    return jax.ShapeDtypeStruct((batch, cfg.n_frontend_tokens, cfg.d_frontend), dtype)


def synth_frontend(key, cfg: ArchConfig, batch: int, dtype=jnp.float32):
    if not cfg.frontend:
        return None
    return jax.random.normal(key, (batch, cfg.n_frontend_tokens, cfg.d_frontend), dtype) * 0.02
