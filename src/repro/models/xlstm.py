"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory) + sLSTM (scalar).

mLSTM recurrence (per head, stabilized, state stored pre-scaled by exp(-m)):

    m_t = max(lf_t + m_{t-1}, li_t)
    C_t = exp(lf_t + m_{t-1} - m_t) C_{t-1} + exp(li_t - m_t) k_t v_t^T
    n_t = exp(lf_t + m_{t-1} - m_t) n_{t-1} + exp(li_t - m_t) k_t
    h_t = o_t * (q_t C_t) / max(|q_t . n_t|, exp(-m_t))

Training/prefill uses an exact *chunkwise-parallel* form: within a chunk the
decay matrix D_ij = exp(F_i - F_j + li_j) is applied to a masked quadratic
(attention-like, MXU-friendly) score, across chunks the (C, n, m) state is
carried by lax.scan. Per-position stabilizers are computed in closed form
(m_i = F_i + max(m_prev, cummax_j(li_j - F_j))) so the chunked path is
bit-compatible with the sequential recurrence (tests assert this).

sLSTM has hidden-state feedback in its gates (true recurrence, not
parallelizable); it runs as a lax.scan over time with block-diagonal
per-head recurrent weights.

Both are leaky-integrator relatives of the paper's LIF neuron (DESIGN.md §4):
mLSTM's forget gate is a learned, input-dependent beta.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .layers import dense_init

NEG = -1e30


# ===========================================================================
# mLSTM
# ===========================================================================

def mlstm_init(key, d: int, n_heads: int, dtype) -> Dict:
    d_in = 2 * d
    ks = jax.random.split(key, 8)
    return {
        "w_up": dense_init(ks[0], d, d_in, dtype),
        "w_gate": dense_init(ks[1], d, d_in, dtype),
        "wq": dense_init(ks[2], d_in, d_in, dtype),
        "wk": dense_init(ks[3], d_in, d_in, dtype),
        "wv": dense_init(ks[4], d_in, d_in, dtype),
        "w_if": dense_init(ks[5], d_in, 2 * n_heads, dtype),   # input/forget gate logits
        "w_down": dense_init(ks[6], d_in, d, dtype),
        "b_f": jnp.full((n_heads,), 3.0, jnp.float32),          # forget bias -> long memory
    }


def _mlstm_qkv_gates(p: Dict, x: jax.Array, n_heads: int):
    b, s, _ = x.shape
    u = x @ p["w_up"]
    d_in = u.shape[-1]
    hd = d_in // n_heads
    q = (u @ p["wq"]).reshape(b, s, n_heads, hd) / math.sqrt(hd)
    k = (u @ p["wk"]).reshape(b, s, n_heads, hd) / math.sqrt(hd)
    v = (u @ p["wv"]).reshape(b, s, n_heads, hd)
    gates = (u @ p["w_if"]).astype(jnp.float32).reshape(b, s, n_heads, 2)
    li = gates[..., 0]                                          # log input gate (exp gating)
    lf = jax.nn.log_sigmoid(gates[..., 1] + p["b_f"])           # log forget gate
    gate_out = jax.nn.silu(x @ p["w_gate"])
    return q, k, v, li, lf, gate_out, u


def mlstm_block(p: Dict, x: jax.Array, n_heads: int, chunk: int = 256,
                unroll: bool = False) -> jax.Array:
    """Chunkwise-parallel mLSTM over [B, S, d].

    unroll=True replaces the chunk scan with a Python loop (dry-run cost
    lowering; see EXPERIMENTS.md §Methodology)."""
    b, s, d = x.shape
    q, k, v, li, lf, gate_out, _ = _mlstm_qkv_gates(p, x, n_heads)
    hd = q.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    # reshape to [nc, B, H, L, ...] for scan over chunks
    def rc(a, feat):
        a = a.reshape(b, nc, chunk, n_heads, *feat)
        return jnp.moveaxis(jnp.moveaxis(a, 1, 0), 3, 2)        # [nc, B, H, L, feat]

    qc = rc(q.astype(jnp.float32), (hd,))
    kc = rc(k.astype(jnp.float32), (hd,))
    vc = rc(v.astype(jnp.float32), (hd,))
    lic = rc(li, ())
    lfc = rc(lf, ())

    def chunk_body(carry, xs):
        C, n, m = carry                                         # [B,H,hd,hd], [B,H,hd], [B,H]
        qi, ki, vi, lii, lfi = xs
        F = jnp.cumsum(lfi, axis=-1)                            # [B,H,L] inclusive cumsum
        # per-position stabilizer (exact sequential m): m_i = F_i + max(m_prev, cummax(li_j - F_j))
        g = jnp.maximum(m[..., None], jax.lax.cummax(lii - F, axis=2))
        m_i = F + g                                             # [B,H,L]
        # inter-chunk: qi against carried state, decay exp(F_i + m_prev - m_i)
        inter_w = jnp.exp(F + m[..., None] - m_i)               # [B,H,L]
        h_inter = jnp.einsum("bhlq,bhqd->bhld", qi * inter_w[..., None], C)
        n_inter = jnp.einsum("bhlq,bhq->bhl", qi * inter_w[..., None], n)
        # intra-chunk: D_ij = exp(F_i - F_j + li_j - m_i) masked causal
        D = F[..., :, None] - F[..., None, :] + lii[..., None, :] - m_i[..., :, None]
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        D = jnp.where(mask[None, None], D, NEG)
        sc = jnp.einsum("bhld,bhjd->bhlj", qi, ki) * jnp.exp(D)
        h_intra = jnp.einsum("bhlj,bhjd->bhld", sc, vi)
        # normalizer: n_i = sum_j D_ij (q_i . k_j) + inter term
        n_intra = jnp.sum(sc, axis=-1)
        num = h_inter + h_intra                                 # [B,H,L,hd]
        den = n_inter + n_intra                                 # [B,H,L]
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_i))[..., None]
        # state update to end of chunk
        F_tot = F[..., -1]
        m_next = jnp.maximum(m + F_tot, jnp.max(F_tot[..., None] - F + lii, axis=-1))
        decay_state = jnp.exp(m + F_tot - m_next)
        w_j = jnp.exp(F_tot[..., None] - F + lii - m_next[..., None])  # [B,H,L]
        C_next = decay_state[..., None, None] * C + jnp.einsum("bhjd,bhje->bhde", ki * w_j[..., None], vi)
        n_next = decay_state[..., None] * n + jnp.sum(ki * w_j[..., None], axis=2)
        return (C_next, n_next, m_next), h

    C0 = jnp.zeros((b, n_heads, hd, hd), jnp.float32)
    n0 = jnp.zeros((b, n_heads, hd), jnp.float32)
    m0 = jnp.full((b, n_heads), NEG, jnp.float32)
    if unroll:
        carry = (C0, n0, m0)
        hs_list = []
        for ci in range(nc):
            carry, h_i = chunk_body(carry, (qc[ci], kc[ci], vc[ci], lic[ci], lfc[ci]))
            hs_list.append(h_i)
        hs = jnp.stack(hs_list)
    else:
        _, hs = jax.lax.scan(chunk_body, (C0, n0, m0), (qc, kc, vc, lic, lfc))
    # hs: [nc, B, H, L, hd] -> [B, nc, L, H, hd] -> [B, S, H*hd]
    h = jnp.moveaxis(hs, 0, 1).transpose(0, 1, 3, 2, 4).reshape(b, s, n_heads * hd)
    out = (h.astype(x.dtype) * gate_out) @ p["w_down"]
    return out


def mlstm_init_state(batch: int, d: int, n_heads: int) -> Dict[str, jax.Array]:
    hd = 2 * d // n_heads
    return {
        "C": jnp.zeros((batch, n_heads, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, n_heads, hd), jnp.float32),
        "m": jnp.full((batch, n_heads), NEG, jnp.float32),
    }


def mlstm_block_decode(p: Dict, x: jax.Array, state: Dict, n_heads: int) -> Tuple[jax.Array, Dict]:
    """One-token mLSTM update. x: [B, 1, d]."""
    b = x.shape[0]
    q, k, v, li, lf, gate_out, _ = _mlstm_qkv_gates(p, x, n_heads)
    q, k, v = (a[:, 0].astype(jnp.float32) for a in (q, k, v))   # [B,H,hd]
    li, lf = li[:, 0], lf[:, 0]                                  # [B,H]
    C, n, m = state["C"], state["n"], state["m"]
    m_t = jnp.maximum(lf + m, li)
    dec = jnp.exp(lf + m - m_t)[..., None]
    inp = jnp.exp(li - m_t)[..., None]
    C_t = dec[..., None] * C + inp[..., None] * (k[..., :, None] * v[..., None, :])
    n_t = dec * n + inp * k
    num = jnp.einsum("bhq,bhqd->bhd", q, C_t)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhq,bhq->bh", q, n_t)), jnp.exp(-m_t))
    h = (num / den[..., None]).reshape(b, 1, -1).astype(x.dtype)
    out = (h * gate_out) @ p["w_down"]
    return out, {"C": C_t, "n": n_t, "m": m_t}


# ===========================================================================
# sLSTM
# ===========================================================================

def slstm_init(key, d: int, n_heads: int, dtype) -> Dict:
    ks = jax.random.split(key, 4)
    hd = d // n_heads
    r = jax.random.normal(ks[1], (4, n_heads, hd, hd), jnp.float32) * (0.02 / math.sqrt(hd))
    return {
        "w_in": dense_init(ks[0], d, 4 * d, dtype),              # z, i, f, o pre-activations
        "r": r.astype(dtype),                                    # recurrent block-diagonal
        "w_out": dense_init(ks[2], d, d, dtype),
        "b": jnp.concatenate([jnp.zeros((2 * d,)), jnp.full((d,), 3.0), jnp.zeros((d,))]).astype(jnp.float32),
    }


def _slstm_step(p: Dict, n_heads: int, carry, wx_t):
    """carry: (c, n, m, h) each [B, d] (fp32); wx_t: [B, 4d] input projection."""
    c, n, m, h = carry
    b, d = c.shape
    hd = d // n_heads
    hh = h.reshape(b, n_heads, hd)
    rec = jnp.einsum("bhk,ghkl->bghl", hh, p["r"].astype(jnp.float32)).reshape(b, 4 * d)
    pre = wx_t.astype(jnp.float32) + rec + p["b"]
    z = jnp.tanh(pre[:, 0:d])
    li = pre[:, d:2 * d]                                          # exp input gate (log domain)
    lf = jax.nn.log_sigmoid(pre[:, 2 * d:3 * d])
    o = jax.nn.sigmoid(pre[:, 3 * d:4 * d])
    m_t = jnp.maximum(lf + m, li)
    dec = jnp.exp(lf + m - m_t)
    inp = jnp.exp(li - m_t)
    c_t = dec * c + inp * z
    n_t = dec * n + inp
    h_t = o * c_t / jnp.maximum(n_t, jnp.exp(-m_t))
    return (c_t, n_t, m_t, h_t), h_t


def slstm_block(p: Dict, x: jax.Array, n_heads: int) -> jax.Array:
    """Sequential sLSTM over [B, S, d] (true recurrence; lax.scan over time)."""
    b, s, d = x.shape
    wx = (x @ p["w_in"]).astype(jnp.float32)                      # [B, S, 4d]
    c0 = jnp.zeros((b, d), jnp.float32)
    m0 = jnp.full((b, d), NEG, jnp.float32)
    carry0 = (c0, c0, m0, c0)
    step = lambda carry, wx_t: _slstm_step(p, n_heads, carry, wx_t)
    _, hs = jax.lax.scan(step, carry0, jnp.moveaxis(wx, 1, 0))
    h = jnp.moveaxis(hs, 0, 1).astype(x.dtype)                    # [B, S, d]
    return h @ p["w_out"]


def slstm_init_state(batch: int, d: int) -> Dict[str, jax.Array]:
    z = jnp.zeros((batch, d), jnp.float32)
    return {"c": z, "n": z, "m": jnp.full((batch, d), NEG, jnp.float32), "h": z}


def slstm_block_decode(p: Dict, x: jax.Array, state: Dict, n_heads: int) -> Tuple[jax.Array, Dict]:
    wx = (x[:, 0] @ p["w_in"]).astype(jnp.float32)
    carry = (state["c"], state["n"], state["m"], state["h"])
    (c, n, m, h), h_out = _slstm_step(p, n_heads, carry, wx)
    out = (h_out[:, None].astype(x.dtype)) @ p["w_out"]
    return out, {"c": c, "n": n, "m": m, "h": h}
