"""Mixture-of-Experts layer: sort-based routing + capacity grouped GEMM.

The hybrid dense/sparse insight of the paper maps structurally onto MoE: the
router is the event generator and experts are event-gated compute — work is
spent only where tokens are routed, the LM-scale analogue of event-driven
execution (DESIGN.md §4).

Implementation (TPU-canonical, GShard/MaxText-style dropped-token capacity):
  1. top-k route, flatten to T*k (token, expert) pairs, sort by expert id;
  2. gather each expert's contiguous rows into a fixed-capacity buffer
     [E, C, d] (C = T*k/E * capacity_factor; overflow rows dropped — the
     bounded-imbalance contract that keeps step shapes static at scale);
  3. three batched GEMMs `ecd,edf->ecf` on the MXU;
  4. masked scatter-back + gate-weighted combine.

`jax.lax.ragged_dot` was rejected: its CPU lowering materializes a dense
[E, T, ff] mask tensor (40 GiB/buffer at the production shapes); the
capacity formulation is also what real TPU MoE stacks ship.

Under an ambient compute mesh (dist.context), routing runs shard-locally via
shard_map (manual over DP axes, auto over 'model') so the sort/gather/scatter
never leave the data-parallel shard.

A Switch-style load-balancing auxiliary loss is returned alongside.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .layers import dense_init, mlp_apply, mlp_init


def moe_init(key, d: int, n_experts: int, d_ff_e: int, act: str, dtype,
             shared_expert: bool = False, d_ff_shared: int = 0,
             n_experts_padded: int = 0) -> Dict:
    n_experts = max(n_experts_padded, n_experts)  # padded experts router-masked
    ks = jax.random.split(key, 5)
    n_mats = 3 if act in ("swiglu", "geglu") else 2
    experts = {
        "w_in": jax.vmap(lambda k: dense_init(k, d, d_ff_e, dtype))(jax.random.split(ks[0], n_experts)),
        "w_out": jax.vmap(lambda k: dense_init(k, d_ff_e, d, dtype))(jax.random.split(ks[1], n_experts)),
    }
    if n_mats == 3:
        experts["w_gate"] = jax.vmap(lambda k: dense_init(k, d, d_ff_e, dtype))(
            jax.random.split(ks[2], n_experts))
    p = {"w_router": dense_init(ks[3], d, n_experts, dtype), "experts": experts}
    if shared_expert:
        p["shared"] = mlp_init(ks[4], d, d_ff_shared or d_ff_e, act, dtype)
    return p


def moe_apply(p: Dict, x: jax.Array, *, top_k: int, act: str, n_experts: int,
              capacity_factor: float = 1.25, unroll: bool = False,
              n_experts_padded: int = 0,
              fsdp_experts: bool = False) -> Tuple[jax.Array, jax.Array]:
    """x: [B, S, d] -> (y [B, S, d], aux_loss scalar)."""
    n_valid = n_experts
    n_experts = max(n_experts_padded, n_experts)
    from ..dist.context import current_mesh
    mesh = current_mesh()
    if mesh is not None and fsdp_experts:
        # FSDP gather: expert weights are stored 'data'-sharded on the expert
        # axis (dist.sharding.param_spec); constrain to the compute layout —
        # expert axis gathered, d_ff kept 'model'-sharded (column-parallel, so
        # the gather never crosses the tensor-parallel axis) — here so GSPMD
        # inserts one all-gather per layer (overlappable), instead of keeping
        # a full replica resident.
        from jax.sharding import NamedSharding, PartitionSpec as P
        from ..dist.sharding import _repair

        def _gather_spec(path, leaf):
            # mirror param_spec's matrix layout: w_out is row-parallel
            # ('model' on d_ff, dim -2); w_in/w_gate are column-parallel
            # ('model' on d_ff, the last dim) — only the expert axis moves.
            name = str(getattr(path[-1], "key", path[-1]))
            tp_dim = len(leaf.shape) - (2 if name == "w_out" else 1)
            axes = [None] * len(leaf.shape)
            axes[tp_dim] = "model"
            return jax.lax.with_sharding_constraint(
                leaf, NamedSharding(mesh, P(*_repair(axes, tuple(leaf.shape), mesh))))

        p = dict(p)
        p["experts"] = jax.tree_util.tree_map_with_path(_gather_spec, p["experts"])
    from ..dist import compat as _compat
    if (mesh is not None and "data" in mesh.axis_names
            # partially-auto shard_map (manual dp, auto 'model') trips a
            # fatal SPMD-partitioner check on the old XLA the compat shims
            # target; there, tensor-parallel MoE falls back to pure GSPMD
            and not (_compat.SHIMMED and "model" in mesh.axis_names
                     and mesh.shape["model"] > 1)):
        from jax.sharding import PartitionSpec as P
        dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        ndp = 1
        for a in dp:
            ndp *= mesh.shape[a]
        if x.shape[0] % ndp == 0 and x.shape[0] >= ndp:
            pspec = jax.tree.map(lambda _: P(), p)
            dtype = x.dtype
            # f32 at the shard_map boundary: the replicated-param grad psum
            # otherwise lowers to a bf16 all-reduce, which trips an XLA-CPU
            # promotion-pass bug in this container (TPU target unaffected).
            p32 = jax.tree.map(lambda a: a.astype(jnp.float32), p)

            def local(p_, x_):
                p_ = jax.tree.map(lambda a: a.astype(dtype), p_)
                y, aux = _moe_core(p_, x_, top_k=top_k, act=act,
                                   n_experts=n_experts, n_valid=n_valid,
                                   capacity_factor=capacity_factor, unroll=unroll)
                return y, jax.lax.pmean(aux, dp)

            return jax.shard_map(
                local, mesh=mesh,
                in_specs=(pspec, P(dp, None, None)),
                out_specs=(P(dp, None, None), P()),
                axis_names=set(dp), check_vma=False,
            )(p32, x)
    return _moe_core(p, x, top_k=top_k, act=act, n_experts=n_experts,
                     n_valid=n_valid, capacity_factor=capacity_factor, unroll=unroll)


def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m


def _moe_core(p: Dict, x: jax.Array, *, top_k: int, act: str, n_experts: int,
              n_valid: int, capacity_factor: float,
              unroll: bool) -> Tuple[jax.Array, jax.Array]:
    b, s, d = x.shape
    xt = x.reshape(-1, d)
    t = xt.shape[0]
    rows = t * top_k
    capacity = min(_round_up(int(rows / n_valid * capacity_factor) + 1, 8), rows)

    logits = (xt @ p["w_router"]).astype(jnp.float32)          # [T, E]
    if n_valid < n_experts:                                    # mask padded experts
        pad_mask = jnp.arange(n_experts) >= n_valid
        logits = jnp.where(pad_mask[None], -1e30, logits)
    gate_vals, idx = jax.lax.top_k(logits, top_k)              # [T, k]
    if top_k == 1:
        weights = jax.nn.sigmoid(gate_vals)                    # keep router gradient
    else:
        weights = jax.nn.softmax(gate_vals, axis=-1)

    flat_expert = idx.reshape(-1)                              # [T*k]
    token_idx = jnp.repeat(jnp.arange(t), top_k)               # [T*k]
    order = jnp.argsort(flat_expert)                           # int keys: cheap VJP
    sorted_expert = flat_expert[order]
    src_token = token_idx[order]
    group_sizes = jnp.bincount(flat_expert, length=n_experts).astype(jnp.int32)
    offsets = jnp.cumsum(group_sizes) - group_sizes            # [E]

    # rank of each sorted row within its expert; rows >= capacity are dropped
    rank = jnp.arange(rows, dtype=jnp.int32) - offsets[sorted_expert]
    valid = rank < capacity

    xs = xt[src_token]                                         # [T*k, d] sorted
    # pad so dynamic_slice never clamps (offset + capacity can exceed rows)
    xs_pad = jnp.pad(xs, ((0, capacity), (0, 0)))

    def gather_expert(e):
        blk = jax.lax.dynamic_slice(xs_pad, (offsets[e], 0), (capacity, d))
        mask = (jnp.arange(capacity, dtype=jnp.int32) < group_sizes[e])
        return blk * mask[:, None].astype(blk.dtype)

    # vmap (not a Python loop): lowers to one batched gather, which HLO cost
    # analysis charges once — an unrolled loop charges the full xs operand per
    # expert (48x bytes inflation in the dry-run accounting)
    xe = jax.vmap(gather_expert)(jnp.arange(n_experts, dtype=jnp.int32))

    h = jnp.einsum("ecd,edf->ecf", xe, p["experts"]["w_in"])   # [E, C, ff]
    if act in ("swiglu", "geglu"):
        hg = jnp.einsum("ecd,edf->ecf", xe, p["experts"]["w_gate"])
        h = (jax.nn.silu(hg) if act == "swiglu" else jax.nn.gelu(hg)) * h
    elif act == "gelu":
        h = jax.nn.gelu(h)
    elif act == "relu2":
        h = jnp.square(jax.nn.relu(h))
    oe = jnp.einsum("ecf,efd->ecd", h, p["experts"]["w_out"])  # [E, C, d]

    # scatter back: sorted row i reads oe[expert_i, rank_i] when valid
    out_rows = oe[sorted_expert, jnp.clip(rank, 0, capacity - 1)]
    gate = (weights.reshape(-1)[order] * valid).astype(xt.dtype)   # [T*k] bf16
    contrib = out_rows.astype(xt.dtype) * gate[:, None]
    y = jnp.zeros_like(xt).at[src_token].add(contrib)

    if "shared" in p:
        y = y + mlp_apply(p["shared"], xt, act)

    # Switch-style load-balancing loss: E * sum_e f_e * p_e
    router_probs = jax.nn.softmax(logits, axis=-1)             # [T, E]
    frac_tokens = jnp.mean(
        (jax.nn.one_hot(idx, n_experts, dtype=jnp.float32)).sum(1), axis=0)
    frac_probs = jnp.mean(router_probs, axis=0)
    aux = n_experts * jnp.sum(frac_tokens / top_k * frac_probs)
    return y.reshape(b, s, d), aux
