"""Unified decoder LM covering all assigned architecture families.

The network is a sequence of *periods*: a fixed pattern of block kinds
(e.g. llama4-maverick = [attn_mlp, attn_moe], recurrentgemma =
[rglru, rglru, local_attn]) scanned with `jax.lax.scan` over stacked
per-period parameters, so compiled HLO size is independent of depth — a
requirement for compiling 48-88 layer models on one host. Pattern
remainders live in an unscanned `tail`.

Block kinds:
    attn_mlp   — GQA attention + MLP (dense transformers, musicgen, phi-3)
    attn_moe   — GQA attention + mixture-of-experts (+ optional shared MLP)
    local_attn — sliding-window GQA attention + MLP (recurrentgemma)
    rglru      — RG-LRU recurrent block + MLP (recurrentgemma)
    mlstm      — xLSTM matrix-memory block (no MLP)
    slstm      — xLSTM scalar-memory block (no MLP)

Three entry points per model: `train_loss` (next-token CE + MoE aux),
`prefill_step` (logits + filled caches) and `decode_step` (one token against
caches). Caches are pytrees stacked along the period axis so the decode path
scans them in lock-step with the parameters.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeConfig
from .attention import (attention_block, attention_decode, attn_init, init_kv_cache)
from .layers import dense_init, embed_init, mlp_apply, mlp_init, rmsnorm, rmsnorm_init
from .moe import moe_apply, moe_init
from .rglru import (rglru_block, rglru_block_decode, rglru_init, rglru_init_state)
from .xlstm import (mlstm_block, mlstm_block_decode, mlstm_init, mlstm_init_state,
                    slstm_block, slstm_block_decode, slstm_init, slstm_init_state)


def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


# ===========================================================================
# Parameter init
# ===========================================================================

def init_block(key, cfg: ArchConfig, kind: str) -> Dict[str, Any]:
    dt = _dtype(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"norm1": rmsnorm_init(d, dt)}
    if kind in ("attn_mlp", "attn_moe", "local_attn"):
        p["attn"] = attn_init(ks[0], d, cfg.n_heads, cfg.n_kv_heads, cfg.hd, cfg.qkv_bias, dt)
        p["norm2"] = rmsnorm_init(d, dt)
        if kind == "attn_moe":
            p["moe"] = moe_init(ks[1], d, cfg.n_experts, cfg.moe_d_ff or cfg.d_ff,
                                cfg.mlp_act, dt, cfg.shared_expert, cfg.d_ff,
                                n_experts_padded=cfg.n_experts_padded)
        else:
            p["mlp"] = mlp_init(ks[1], d, cfg.d_ff, cfg.mlp_act, dt)
    elif kind == "rglru":
        p["rglru"] = rglru_init(ks[0], d, cfg.d_rnn or d, cfg.conv_width, dt)
        p["norm2"] = rmsnorm_init(d, dt)
        p["mlp"] = mlp_init(ks[1], d, cfg.d_ff, cfg.mlp_act, dt)
    elif kind == "mlstm":
        p["mlstm"] = mlstm_init(ks[0], d, cfg.n_heads, dt)
    elif kind == "slstm":
        p["slstm"] = slstm_init(ks[0], d, cfg.n_heads, dt)
    else:
        raise ValueError(kind)
    return p


def init_params(key, cfg: ArchConfig) -> Dict[str, Any]:
    dt = _dtype(cfg)
    keys = jax.random.split(key, 6)
    params: Dict[str, Any] = {
        "embed": {"w_tok": embed_init(keys[0], cfg.vocab, cfg.d_model, dt)},
        "final_norm": rmsnorm_init(cfg.d_model, dt),
    }
    if cfg.frontend:
        params["embed"]["w_front"] = dense_init(keys[3], cfg.d_frontend, cfg.d_model, dt)
    if not cfg.tie_embeddings:
        params["lm_head"] = {"w": dense_init(keys[1], cfg.d_model, cfg.vocab, dt)}

    period_keys = jax.random.split(keys[2], cfg.n_periods)
    periods = {}
    for si, kind in enumerate(cfg.pattern):
        slot_keys = jax.vmap(lambda k, i=si: jax.random.fold_in(k, i))(period_keys)
        periods[f"slot{si}"] = jax.vmap(lambda k, kd=kind: init_block(k, cfg, kd))(slot_keys)
    params["periods"] = periods

    tail_keys = jax.random.split(keys[4], max(len(cfg.tail), 1))
    params["tail"] = tuple(init_block(tail_keys[i], cfg, kind) for i, kind in enumerate(cfg.tail))
    return params


# ===========================================================================
# Forward blocks
# ===========================================================================

def _apply_block(kind: str, p: Dict, x: jax.Array, cfg: ArchConfig) -> Tuple[jax.Array, jax.Array]:
    """Residual block application. Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = rmsnorm(x, p["norm1"], cfg.norm_eps)
    if kind in ("attn_mlp", "attn_moe", "local_attn"):
        window = cfg.window if kind == "local_attn" else 0
        x = x + attention_block(
            p["attn"], h, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.hd, rope_theta=cfg.rope_theta, window=window,
            q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk, unroll=cfg.unroll_chunks,
            f32_streams=cfg.attn_f32_streams)
        h2 = rmsnorm(x, p["norm2"], cfg.norm_eps)
        if kind == "attn_moe":
            y, aux = moe_apply(p["moe"], h2, top_k=cfg.top_k, act=cfg.mlp_act,
                               n_experts=cfg.n_experts,
                               capacity_factor=cfg.capacity_factor,
                               unroll=cfg.unroll_chunks,
                               n_experts_padded=cfg.n_experts_padded,
                               fsdp_experts=cfg.fsdp_experts)
            x = x + y
        else:
            x = x + mlp_apply(p["mlp"], h2, cfg.mlp_act)
        if cfg.sp_blocks:
            x = _seq_shard(x)
    elif kind == "rglru":
        x = x + rglru_block(p["rglru"], h)
        x = x + mlp_apply(p["mlp"], rmsnorm(x, p["norm2"], cfg.norm_eps), cfg.mlp_act)
    elif kind == "mlstm":
        x = x + mlstm_block(p["mlstm"], h, cfg.n_heads, cfg.mlstm_chunk,
                            unroll=cfg.unroll_chunks)
    elif kind == "slstm":
        x = x + slstm_block(p["slstm"], h, cfg.n_heads)
    else:
        raise ValueError(kind)
    return x, aux


def _embed(params: Dict, batch: Dict, cfg: ArchConfig) -> jax.Array:
    from ..dist.sharding import shard_cotangents
    params = dict(params, embed=shard_cotangents(params["embed"]))
    x = params["embed"]["w_tok"][batch["tokens"]]
    if cfg.frontend:
        front = batch["frontend_embeds"].astype(x.dtype) @ params["embed"]["w_front"]
        x = jnp.concatenate([front, x], axis=1)
    return x


def _unembed(params: Dict, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    if cfg.tie_embeddings:
        logits = x @ params["embed"]["w_tok"].T
    else:
        logits = x @ params["lm_head"]["w"]
    return _vocab_shard(logits)


def _vocab_shard(logits: jax.Array) -> jax.Array:
    """Keep logits vocab-sharded over 'model' (GSPMD drops the sharding on
    the way into the loss otherwise, replicating a [B,S,V] fp32 tensor)."""
    from ..dist.context import current_mesh
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = current_mesh()
    if mesh is None or "model" not in mesh.axis_names or logits.ndim != 3:
        return logits
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    ndp = 1
    for a in dp:
        ndp *= mesh.shape[a]
    if logits.shape[0] % ndp or logits.shape[-1] % mesh.shape["model"]:
        return logits
    return jax.lax.with_sharding_constraint(
        logits, NamedSharding(mesh, P(dp, None, "model")))


def _seq_shard(x: jax.Array) -> jax.Array:
    """Sequence-shard [B, S, d] activations over the 'model' axis (SP).

    Applied at period boundaries so the per-period activation checkpoints the
    backward scan stores are 1/TP the size; GSPMD all-gathers the sequence
    where a block genuinely needs it (attention) and reduce-scatters after.
    No-op without an ambient mesh.
    """
    from ..dist.context import current_mesh
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = current_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return x
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    ndp = 1
    for a in dp:
        ndp *= mesh.shape[a]
    tp = mesh.shape["model"]
    if x.ndim != 3 or x.shape[0] % ndp or x.shape[1] % tp:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(dp, "model", None)))


def forward(params: Dict, batch: Dict, cfg: ArchConfig) -> Tuple[jax.Array, jax.Array]:
    """Full forward: batch {tokens [B,S], frontend_embeds?} -> (logits, aux)."""
    x = _embed(params, batch, cfg)

    def period_fn(carry, slot_params):
        x, aux = carry
        x = _seq_shard(x)
        from ..dist.sharding import shard_cotangents
        slot_params = shard_cotangents(slot_params)
        for si, kind in enumerate(cfg.pattern):
            x, a = _apply_block(kind, slot_params[f"slot{si}"], x, cfg)
            aux = aux + a
        return (x, aux), None

    if cfg.remat == "full":
        period_fn = jax.checkpoint(period_fn)

    (x, aux), _ = jax.lax.scan(period_fn, (x, jnp.zeros((), jnp.float32)), params["periods"])
    for i, kind in enumerate(cfg.tail):
        x, a = _apply_block(kind, params["tail"][i], x, cfg)
        aux = aux + a
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return _unembed(params, x, cfg), aux


def train_loss(params: Dict, batch: Dict, cfg: ArchConfig, aux_weight: float = 0.01) -> jax.Array:
    """Next-token cross-entropy (+ MoE load-balance aux)."""
    logits, aux = forward(params, batch, cfg)
    labels = batch["labels"]
    if cfg.frontend:  # frontend tokens carry no labels
        logits = logits[:, cfg.n_frontend_tokens:]
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ce = jnp.mean(logz - gold)
    return ce + aux_weight * aux


# ===========================================================================
# Serving: cache init, prefill, decode
# ===========================================================================

def _init_block_cache(kind: str, cfg: ArchConfig, batch: int, seq_len: int, dt) -> Dict:
    if kind in ("attn_mlp", "attn_moe"):
        return init_kv_cache(batch, seq_len, cfg.n_kv_heads, cfg.hd, dt)
    if kind == "local_attn":
        return init_kv_cache(batch, min(cfg.window, seq_len), cfg.n_kv_heads, cfg.hd, dt)
    if kind == "rglru":
        return rglru_init_state(batch, cfg.d_rnn or cfg.d_model, cfg.conv_width, dt)
    if kind == "mlstm":
        return mlstm_init_state(batch, cfg.d_model, cfg.n_heads)
    if kind == "slstm":
        return slstm_init_state(batch, cfg.d_model)
    raise ValueError(kind)


def init_cache(cfg: ArchConfig, batch: int, seq_len: int) -> Dict[str, Any]:
    dt = _dtype(cfg)
    periods = {}
    for si, kind in enumerate(cfg.pattern):
        one = _init_block_cache(kind, cfg, batch, seq_len, dt)
        periods[f"slot{si}"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.n_periods,) + a.shape), one)
    tail = tuple(_init_block_cache(kind, cfg, batch, seq_len, dt) for kind in cfg.tail)
    return {"periods": periods, "tail": tail}


def reset_cache_rows(cache: Dict[str, Any], fresh: Dict[str, Any],
                     keep: jax.Array) -> Dict[str, Any]:
    """Reset per-request cache rows to their freshly-initialized state.

    cache/fresh: pytrees from `init_cache` (period leaves are
    [n_periods, B, ...], tail leaves [B, ...]); keep: bool [B] — rows with
    keep=False are replaced by the corresponding ``fresh`` rows. Continuous
    serving uses this when a finished request's slot is re-admitted: KV
    caches are position-masked so stale entries are never attended, but
    recurrent state (rglru/xlstm) is cumulative and must be re-zeroed for
    the slot's next occupant.
    """
    def sel(axis):
        def f(c, fr):
            shape = [1] * c.ndim
            shape[axis] = keep.shape[0]
            return jnp.where(keep.reshape(shape), c, fr)
        return f
    return {"periods": jax.tree.map(sel(1), cache["periods"], fresh["periods"]),
            "tail": jax.tree.map(sel(0), cache["tail"], fresh["tail"])}


def _freeze_state_rows(new_state, old_state, active: jax.Array):
    """Keep ``old_state`` rows where ``active`` is False (recurrent-state
    leaves are [B, ...]; small, so a full select is cheap)."""
    def sel(n, o):
        return jnp.where(active.reshape((active.shape[0],) + (1,) * (n.ndim - 1)), n, o)
    return jax.tree.map(sel, new_state, old_state)


def _decode_block(kind: str, p: Dict, x: jax.Array, cache: Dict, pos: jax.Array,
                  cfg: ArchConfig, active: Optional[jax.Array] = None) -> Tuple[jax.Array, Dict]:
    h = rmsnorm(x, p["norm1"], cfg.norm_eps)
    if kind in ("attn_mlp", "attn_moe", "local_attn"):
        window = cfg.window if kind == "local_attn" else 0
        y, cache = attention_decode(
            p["attn"], h, cache, pos, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.hd, rope_theta=cfg.rope_theta, window=window, active=active)
        x = x + y
        h2 = rmsnorm(x, p["norm2"], cfg.norm_eps)
        if kind == "attn_moe":
            y2, _ = moe_apply(p["moe"], h2, top_k=cfg.top_k, act=cfg.mlp_act,
                              n_experts=cfg.n_experts,
                              capacity_factor=cfg.capacity_factor,
                              unroll=cfg.unroll_chunks,
                              n_experts_padded=cfg.n_experts_padded,
                              fsdp_experts=cfg.fsdp_experts)
            x = x + y2
        else:
            x = x + mlp_apply(p["mlp"], h2, cfg.mlp_act)
    elif kind == "rglru":
        prev = cache
        y, cache = rglru_block_decode(p["rglru"], h, cache)
        x = x + y
        x = x + mlp_apply(p["mlp"], rmsnorm(x, p["norm2"], cfg.norm_eps), cfg.mlp_act)
        if active is not None:
            cache = _freeze_state_rows(cache, prev, active)
    elif kind == "mlstm":
        prev = cache
        y, cache = mlstm_block_decode(p["mlstm"], h, cache, cfg.n_heads)
        x = x + y
        if active is not None:
            cache = _freeze_state_rows(cache, prev, active)
    elif kind == "slstm":
        prev = cache
        y, cache = slstm_block_decode(p["slstm"], h, cache, cfg.n_heads)
        x = x + y
        if active is not None:
            cache = _freeze_state_rows(cache, prev, active)
    else:
        raise ValueError(kind)
    return x, cache


def decode_step(params: Dict, cache: Dict, batch: Dict, pos: jax.Array,
                cfg: ArchConfig, active: Optional[jax.Array] = None) -> Tuple[jax.Array, Dict]:
    """One-token decode. batch {tokens [B,1]}; pos: scalar int32 position
    shared by the batch, or an int32 [B] vector of per-request positions
    (attention rotates/writes/attends per row; recurrent blocks are
    position-free).

    active: optional bool [B] per-request cache freeze — rows with
    active=False advance *no* cache (KV writes or recurrent state). The
    serving engine's ragged prefill uses this so requests whose prompt has
    already been fully consumed are not teacher-forced on pad tokens
    (KV caches mask only the written slot; recurrent state is selected
    row-wise)."""
    x = params["embed"]["w_tok"][batch["tokens"]]

    def period_fn(carry, xs):
        x = carry
        slot_params, slot_cache = xs
        new_cache = {}
        for si, kind in enumerate(cfg.pattern):
            x, c = _decode_block(kind, slot_params[f"slot{si}"], x,
                                 slot_cache[f"slot{si}"], pos, cfg, active)
            new_cache[f"slot{si}"] = c
        return x, new_cache

    x, new_period_cache = jax.lax.scan(period_fn, x, (params["periods"], cache["periods"]))
    new_tail = []
    for i, kind in enumerate(cfg.tail):
        x, c = _decode_block(kind, params["tail"][i], x, cache["tail"][i], pos, cfg, active)
        new_tail.append(c)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = _unembed(params, x, cfg)
    return logits, {"periods": new_period_cache, "tail": tuple(new_tail)}


def decode_chunk(params: Dict, cache: Dict, tokens: jax.Array, pos0: jax.Array,
                 take: jax.Array, cfg: ArchConfig,
                 active: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, jax.Array, Dict]:
    """Chunk-masked multi-token decode: per-row ragged token chunks.

    tokens: int32 [B, C] — row i consumes ``tokens[i, :take[i]]`` at
    positions ``pos0[i] .. pos0[i] + take[i] - 1``; columns at or past
    ``take[i]`` are masked out for that row (caches frozen, outputs
    ignored), so rows with different chunk lengths share one launch. This
    is the serving engine's chunked prefill: a joining prompt consumes a
    scheduler-sized chunk of prompt tokens in the same call its slot-mates
    decode their single token in (their ``take`` is 1). It is also the
    speculative-decode verify primitive: a drafting row feeds its pending
    token plus K drafted tokens and reads K+1 next-token distributions
    back from one launch (`serve.speculative`).

    Semantically this IS C sequential `decode_step` calls with per-column
    active masks, fused into one jitted scan — bit-identity with the
    token-by-token path holds by construction for every chunk size.

    Returns (picks [B, C] int32 greedy argmax per consumed column,
    logits [B, C, V] the full next-token distribution at every consumed
    column — rows read their own entries at columns ``< take[i]``; masked
    columns carry garbage — and the updated cache).
    """
    b, c = tokens.shape
    pos0 = jnp.asarray(pos0, jnp.int32)
    take = jnp.asarray(take, jnp.int32)
    base = jnp.ones((b,), bool) if active is None else active

    def body(cache, xs):
        t, tok = xs                              # t scalar column, tok [B]
        act = base & (t < take)
        logits, cache = decode_step(params, cache, {"tokens": tok[:, None]},
                                    pos0 + t, cfg, active=act)
        last = logits[:, -1]                     # [B, V]
        return cache, (jnp.argmax(last, axis=-1).astype(jnp.int32), last)

    cache, (picks, logits) = jax.lax.scan(
        body, cache, (jnp.arange(c, dtype=jnp.int32), tokens.T))
    # scan stacks per-column outputs on the leading axis: [C, B] / [C, B, V]
    return picks.T, jnp.swapaxes(logits, 0, 1), cache


def rollback_cache_rows(cache: Dict, keep_len: jax.Array,
                        rows: jax.Array) -> Dict:
    """Zero KV-cache entries at positions ``>= keep_len[b]`` for masked rows.

    The speculative-decode rollback: a verify launch writes K+1 KV entries
    per drafting row, but only the accepted prefix belongs to the real
    sequence. Zeroing the rejected suffix restores the exact state a
    never-speculated session would hold (`init_kv_cache` zeros; non-windowed
    attention writes at slot == pos and masks ``idx <= pos``, so absolute
    positions index the cache directly).

    Only valid for architectures whose blocks all carry position-indexed KV
    caches — plain attention (``attn_mlp`` / ``attn_moe``). Recurrent blocks
    (rglru/mlstm/slstm) hold cumulative state and ``local_attn`` uses a ring
    buffer; neither can be rolled back positionally (`serve.runners.lm`
    gates speculation off for them).

    keep_len: int32 [B] — first position to zero, per row.
    rows:     bool [B] — rows to roll back; False rows are untouched.
    """
    keep_len = jnp.asarray(keep_len, jnp.int32)
    rows = jnp.asarray(rows, bool)

    def cut(batch_axis):
        def f(leaf):
            seq = leaf.shape[batch_axis + 1]
            idx = jnp.arange(seq, dtype=jnp.int32)
            keep = (~rows[:, None]) | (idx[None, :] < keep_len[:, None])
            shape = [1] * leaf.ndim
            shape[batch_axis] = keep_len.shape[0]
            shape[batch_axis + 1] = seq
            return jnp.where(keep.reshape(shape), leaf, jnp.zeros_like(leaf))
        return f

    return {"periods": jax.tree.map(cut(1), cache["periods"]),
            "tail": jax.tree.map(cut(0), cache["tail"])}


def prefill_step(params: Dict, batch: Dict, cfg: ArchConfig) -> Tuple[jax.Array, jax.Array]:
    """Prefill: forward over the prompt, returning last-position logits.

    (Cache extraction during prefill shares the forward path; for the
    dry-run shape cells the lowered artifact is the full forward — decode
    cells exercise the cache-consuming path.)
    """
    logits, aux = forward(params, batch, cfg)
    return logits[:, -1:], aux
