"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

The RG-LRU is a gated leaky integrator:

    r_t = sigmoid(W_a x_t);  i_t = sigmoid(W_x x_t)
    a_t = a ** (c * r_t)         (a = sigmoid(Lambda), c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

i.e. the paper's LIF Eq. 1 without threshold/reset (DESIGN.md §4) — the same
leaky-integration machinery, here with learned per-channel, per-step decay.

Training/prefill uses `jax.lax.associative_scan` (parallel prefix scan over
(a, b) pairs) — the TPU-parallel form; decode is the O(1) recurrent update.
The block follows Griffin: two branches (conv1d -> RG-LRU) x (linear ->
GeLU), multiplied, then projected back to d_model.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .layers import dense_init

_C = 8.0
_MIN_LOG = -11.0  # Lambda init so a ~ [0.9, 0.999]


def rglru_init(key, d: int, d_rnn: int, conv_width: int, dtype) -> Dict:
    ks = jax.random.split(key, 7)
    return {
        "w_x": dense_init(ks[0], d, d_rnn, dtype),          # recurrent branch in-proj
        "w_y": dense_init(ks[1], d, d_rnn, dtype),          # gate branch in-proj
        "w_out": dense_init(ks[2], d_rnn, d, dtype),
        "w_conv": (jax.random.normal(ks[3], (conv_width, d_rnn), jnp.float32) * 0.02).astype(dtype),
        "w_a": dense_init(ks[4], d_rnn, d_rnn, dtype),      # recurrence gate
        "w_i": dense_init(ks[5], d_rnn, d_rnn, dtype),      # input gate
        "lam": (jnp.linspace(0.9, 0.999, d_rnn)).astype(jnp.float32),  # a = sigmoid-free direct decay
    }


def _causal_conv1d(x: jax.Array, w: jax.Array) -> jax.Array:
    """x [B, S, C], w [W, C] depthwise causal conv."""
    width = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(width):
        out = out + pad[:, i:i + x.shape[1], :] * w[i][None, None, :]
    return out


def _gates(p: Dict, u: jax.Array):
    r = jax.nn.sigmoid(u @ p["w_a"])
    i = jax.nn.sigmoid(u @ p["w_i"])
    a0 = jnp.clip(p["lam"], 1e-4, 1 - 1e-4).astype(jnp.float32)
    log_a = _C * r.astype(jnp.float32) * jnp.log(a0)         # [B, S, d_rnn]
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-9)) * (i * u).astype(jnp.float32)
    return a, b


def rglru_scan(p: Dict, u: jax.Array) -> jax.Array:
    """Parallel prefix scan over the full sequence. u: [B, S, d_rnn]."""
    a, b = _gates(p, u)

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(u.dtype)


def rglru_block(p: Dict, x: jax.Array) -> jax.Array:
    """Full Griffin recurrent block over [B, S, d] (pre-normed input)."""
    u = x @ p["w_x"]
    u = _causal_conv1d(u, p["w_conv"])
    h = rglru_scan(p, u)
    gate = jax.nn.gelu(x @ p["w_y"])
    return (h * gate) @ p["w_out"]


# ---------------------------------------------------------------------------
# Decode path: O(1) state update per token
# ---------------------------------------------------------------------------

def rglru_init_state(batch: int, d_rnn: int, conv_width: int, dtype) -> Dict[str, jax.Array]:
    return {
        "h": jnp.zeros((batch, d_rnn), jnp.float32),
        "conv": jnp.zeros((batch, conv_width - 1, d_rnn), dtype),  # trailing inputs
    }


def rglru_block_decode(p: Dict, x: jax.Array, state: Dict) -> Tuple[jax.Array, Dict]:
    """x: [B, 1, d]; returns ([B, 1, d], new state)."""
    u = x @ p["w_x"]                                         # [B, 1, d_rnn]
    hist = jnp.concatenate([state["conv"], u], axis=1)       # [B, W, d_rnn]
    w = p["w_conv"]
    u_conv = jnp.einsum("bwc,wc->bc", hist.astype(jnp.float32), w.astype(jnp.float32))[:, None, :]
    u_conv = u_conv.astype(x.dtype)
    a, b = _gates(p, u_conv)
    h = a[:, 0] * state["h"] + b[:, 0]
    gate = jax.nn.gelu(x @ p["w_y"])
    out = (h[:, None].astype(x.dtype) * gate) @ p["w_out"]
    return out, {"h": h, "conv": hist[:, 1:]}
