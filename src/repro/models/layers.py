"""Common model layers: norms, embeddings, RoPE, MLPs, initializers."""
from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Initializers (all take explicit keys; usable under jax.eval_shape)
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype) -> jax.Array:
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.truncated_normal(key, -2, 2, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype) -> jax.Array:
    return (jax.random.truncated_normal(key, -2, 2, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dtype)


def rmsnorm_init(d: int, dtype) -> jax.Array:
    return jnp.zeros((d,), dtype)  # scale stored as (1 + s)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """x: [B, S, H, hd]; positions: [B, S] (int). Rotates pairs (even, odd)."""
    b, s, h, hd = x.shape
    freqs = rope_freqs(hd, theta)                       # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [B, S, hd/2]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1 = x[..., 0::2].astype(jnp.float32)
    x2 = x[..., 1::2].astype(jnp.float32)
    r1 = x1 * cos - x2 * sin
    r2 = x1 * sin + x2 * cos
    out = jnp.stack([r1, r2], axis=-1).reshape(b, s, h, hd)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs (swiglu / gelu / relu2) — weights use 'w*' prefixes so QAT sees them
# ---------------------------------------------------------------------------

def mlp_init(key, d: int, d_ff: int, act: str, dtype) -> Dict[str, jax.Array]:
    ks = jax.random.split(key, 3)
    p = {"w_in": dense_init(ks[0], d, d_ff, dtype), "w_out": dense_init(ks[1], d_ff, d, dtype)}
    if act in ("swiglu", "geglu"):
        p["w_gate"] = dense_init(ks[2], d, d_ff, dtype)
    return p


def mlp_apply(p: Dict[str, jax.Array], x: jax.Array, act: str) -> jax.Array:
    h = x @ p["w_in"]
    if act == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * h
    elif act == "geglu":
        h = jax.nn.gelu(x @ p["w_gate"]) * h
    elif act == "gelu":
        h = jax.nn.gelu(h)
    elif act == "relu2":
        h = jnp.square(jax.nn.relu(h))
    else:
        raise ValueError(act)
    return h @ p["w_out"]
