"""Spiking VGG9 (paper §V-A) with hybrid dense/sparse execution.

Network: 64C3-112C3-MP2-192C3-216C3-MP2-480C3-504C3-560C3-MP2-FC(1064)-FC(P)
with LIF neurons after every conv/FC layer, population-coded output (P
neurons, class score = spike count over the class's neuron group), trained
with surrogate gradients (BPTT over T timesteps) and optional int4 QAT.

Execution paths:
  * training / eval  — pure-JAX (lax.conv), autodiff-friendly; direct coding
    hoists the input conv out of the timestep scan (bit-exact, the input is
    timestep-invariant — dense-core observation from the paper).
  * hybrid inference — dense core kernel (kernels/dense_conv_lif) for the
    input layer + occupancy-gated spike_conv kernels for the spiking layers;
    validated against the training path in tests.

Every forward returns per-layer spike counts (the Eq. 3 workload inputs and
the Fig. 1 quantization-sparsity measurements).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..core.coding import direct_code, rate_code
from ..core.lif import LIFParams, lif_step
from ..core.quant import fake_quant


@dataclasses.dataclass(frozen=True)
class VGG9Config:
    num_classes: int = 10
    population: int = 1000          # P output neurons (paper: 1000 / 5000)
    timesteps: int = 2
    beta: float = 0.15
    theta: float = 0.5
    coding: str = "direct"          # direct | rate
    quant_bits: int = 0             # 0 = fp32, 4 = int4 QAT (biases int8)
    img_hw: int = 32
    in_ch: int = 3
    stages: Tuple = (64, 112, "MP", 192, 216, "MP", 480, 504, 560, "MP")
    fc_dim: int = 1064
    hoist_input_conv: bool = True   # beyond-paper: reuse timestep-invariant conv
    surrogate_slope: float = 25.0

    @property
    def conv_channels(self):
        return [c for c in self.stages if c != "MP"]

    @property
    def lif(self) -> LIFParams:
        return LIFParams(self.beta, self.theta, self.surrogate_slope)


def conv_names(cfg: VGG9Config):
    return [f"conv{i}" for i in range(len(cfg.conv_channels))]


def init_vgg9(key, cfg: VGG9Config, dtype=jnp.float32) -> Dict:
    params = {}
    cin = cfg.in_ch
    keys = jax.random.split(key, len(cfg.conv_channels) + 2)
    for i, cout in enumerate(cfg.conv_channels):
        fan_in = 3 * 3 * cin
        params[f"conv{i}"] = {
            "w": (jax.random.normal(keys[i], (3, 3, cin, cout)) * (2.0 / fan_in) ** 0.5).astype(dtype),
            "b": jnp.zeros((cout,), dtype),
        }
        cin = cout
    n_mp = sum(1 for s in cfg.stages if s == "MP")
    hw = cfg.img_hw // (2 ** n_mp)
    flat = hw * hw * cfg.conv_channels[-1]
    params["fc0"] = {
        "w": (jax.random.normal(keys[-2], (flat, cfg.fc_dim)) * (1.0 / flat) ** 0.5).astype(dtype),
        "b": jnp.zeros((cfg.fc_dim,), dtype),
    }
    params["fc1"] = {
        "w": (jax.random.normal(keys[-1], (cfg.fc_dim, cfg.population)) * (1.0 / cfg.fc_dim) ** 0.5).astype(dtype),
        "b": jnp.zeros((cfg.population,), dtype),
    }
    return params


def quantized_view(params: Dict, cfg: VGG9Config) -> Dict:
    """QAT fake-quant view of the weights (paper §II-B): int-`quant_bits`
    weights, int8 biases, neuronal parameters untouched."""
    if cfg.quant_bits == 0:
        return params
    return jax.tree_util.tree_map_with_path(
        lambda path, x: fake_quant(x, cfg.quant_bits, None)
        if path[-1].key == "w" else fake_quant(x, 8, None),
        params)


def _conv(x, p):
    return jax.lax.conv_general_dilated(
        x, p["w"], (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")) + p["b"]


def _maxpool_spikes(s):
    """2x2 max-pool on binary spikes == OR gate over the window (paper §IV-B)."""
    return jax.lax.reduce_window(s, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


def vgg9_forward(params: Dict, images: jax.Array, cfg: VGG9Config, *,
                 rng: jax.Array | None = None) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """images [B,H,W,C] -> (logits [B,num_classes], spike counts per layer).

    BPTT-ready: the timestep loop is a lax.scan carrying membrane potentials
    and previous spikes for every LIF layer.
    """
    qp = quantized_view(params, cfg)
    lif = cfg.lif
    names = conv_names(cfg) + ["fc0", "fc1"]
    b = images.shape[0]

    # layer output shapes (for state init)
    shapes = {}
    hw = cfg.img_hw
    stage_of = []
    ci = 0
    for s in cfg.stages:
        if s == "MP":
            hw //= 2
            stage_of.append(("MP", None))
        else:
            shapes[f"conv{ci}"] = (b, hw, hw, s)
            stage_of.append(("conv", ci))
            ci += 1
    shapes["fc0"] = (b, cfg.fc_dim)
    shapes["fc1"] = (b, cfg.population)

    def zeros_state():
        return {n: (jnp.zeros(shapes[n], jnp.float32), jnp.zeros(shapes[n], jnp.float32))
                for n in names}

    if cfg.coding == "direct":
        if cfg.hoist_input_conv:
            input_current = _conv(images, qp["conv0"])   # computed once, reused T times
            currents_in = jnp.broadcast_to(input_current[None],
                                           (cfg.timesteps,) + input_current.shape)
        else:
            coded = direct_code(images, cfg.timesteps)
            currents_in = jax.vmap(lambda im: _conv(im, qp["conv0"]))(coded)
    else:  # rate coding: binary input spikes, conv0 acts as a sparse layer
        assert rng is not None, "rate coding needs an rng key"
        coded = rate_code(rng, images, cfg.timesteps)
        currents_in = jax.vmap(lambda sp: _conv(sp, qp["conv0"]))(coded)

    def timestep(carry, current0):
        state = carry
        new_state = {}
        counts = {}

        def fire(name, current):
            u, s_prev = state[name]
            u_next, s = lif_step(u, current, s_prev, lif)
            new_state[name] = (u_next, s)
            counts[name] = jnp.sum(s)
            return s

        s = fire("conv0", current0)
        ci = 1
        for kind, idx in stage_of:
            if kind == "MP":
                s = _maxpool_spikes(s)
            elif idx is not None and idx > 0:
                s = fire(f"conv{idx}", _conv(s, qp[f"conv{idx}"]))
        s = s.reshape(b, -1)
        s = fire("fc0", s @ qp["fc0"]["w"] + qp["fc0"]["b"])
        s_out = fire("fc1", s @ qp["fc1"]["w"] + qp["fc1"]["b"])
        return new_state, (s_out, counts)

    _, (out_spikes, counts) = jax.lax.scan(timestep, zeros_state(), currents_in)
    # population decoding: class score = total spikes in the class's group
    group = cfg.population // cfg.num_classes
    pop = out_spikes.sum(0)                                  # [B, P] spike counts over T
    logits = pop.reshape(b, cfg.num_classes, group).sum(-1) / (cfg.timesteps * group)
    total_counts = {k: counts[k].sum(0) for k in counts}  # scan stacked over T
    return logits, total_counts


def vgg9_loss(params: Dict, batch: Dict, cfg: VGG9Config, *, rng=None) -> jax.Array:
    logits, _ = vgg9_forward(params, batch["images"], cfg, rng=rng)
    labels = batch["labels"]
    logits = logits * 10.0  # population rates are in [0,1]; sharpen for CE
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)


# ---------------------------------------------------------------------------
# Hybrid kernel inference path (dense core + sparse cores)
# ---------------------------------------------------------------------------

def _stage_plan(cfg: VGG9Config):
    """[('MP', None) | ('conv', idx>0), ...] — the post-input-layer walk."""
    plan = []
    ci = 0
    for s in cfg.stages:
        if s == "MP":
            plan.append(("MP", None))
        else:
            if ci > 0:
                plan.append(("conv", ci))
            ci += 1
    return plan


@functools.partial(jax.jit, static_argnames=("cfg", "plan", "interpret", "with_stats"))
def _infer_hybrid_fused(params: Dict, images: jax.Array, *, cfg: VGG9Config,
                        plan, interpret: bool, with_stats: bool):
    """The fused serving graph. See vgg9_infer_hybrid for the contract.
    with_stats is static: the no-stats trace returns an empty stats dict, so
    XLA drops the occupancy/row maps and per-image reductions entirely."""
    from ..kernels.dense_conv_lif.ops import input_layer_conv_lif
    from ..kernels.lif_step.ops import lif_epilogue
    from ..kernels.spike_conv.ops import spike_conv2d_mapped

    qp = quantized_view(params, cfg)
    b = images.shape[0]
    t = cfg.timesteps

    # Dense core: input layer, conv once + T fused LIF steps (one launch).
    ks0 = plan.layer("conv0").kernel
    spikes, _ = input_layer_conv_lif(
        images, qp["conv0"]["w"], qp["conv0"]["b"],
        num_steps=t, beta=cfg.beta, theta=cfg.theta,
        block_m=ks0.block_m, block_n=ks0.block_n, interpret=interpret)
    counts = {"conv0": jnp.sum(spikes)}
    # stats carry per-layer tile-skip measurements *and* per-request spike
    # counts ([B] vectors) so the serving engine can split the folded batch's
    # counters back out per request. Spikes are 0/1 floats, so the per-image
    # sums recombine exactly to the scalar `counts`.
    stats: Dict[str, Dict[str, jax.Array]] = {}
    if with_stats:
        stats["conv0"] = {"out_spikes_per_image": spikes.sum(axis=(0, 2, 3, 4))}

    def lif_scan_fused(cur_t, bias):
        """lax.scan of the conv-epilogue LIF over [T, rows, N] currents."""
        u0 = jnp.zeros_like(cur_t[0])

        def step(carry, cur):
            u, s_prev = carry
            u, s = lif_epilogue(u, cur, s_prev, bias, beta=cfg.beta,
                                theta=cfg.theta, interpret=interpret)
            return (u, s), s

        _, s_seq = jax.lax.scan(step, (u0, jnp.zeros_like(u0)), cur_t)
        return s_seq                                     # [T, rows, N]

    # Sparse cores: timesteps folded into the batch — ONE occupancy-mapped
    # gated matmul launch per layer, then the sequential LIF recurrence.
    x = spikes.reshape((t * b,) + spikes.shape[2:])      # [T*B, H, W, C]
    for kind, idx in _stage_plan(cfg):
        if kind == "MP":
            x = _maxpool_spikes(x)
            continue
        name = f"conv{idx}"
        ks = plan.layer(name).kernel
        cur, st = spike_conv2d_mapped(
            x, qp[name]["w"],
            block_m=ks.block_m, block_k=ks.block_k, block_n=ks.block_n,
            gate=ks.gate, interpret=interpret)           # [T*B, H, W, Cout]
        _, h, w, cout = cur.shape
        s_seq = lif_scan_fused(cur.reshape(t, b * h * w, cout), qp[name]["b"])
        counts[name] = jnp.sum(s_seq)
        if with_stats:
            stats[name] = dict(
                st,
                in_spikes_per_image=x.reshape(t, b, -1).sum(axis=(0, 2)),  # Eq. 3 S
                out_spikes_per_image=s_seq.reshape(t, b, -1).sum(axis=(0, 2)),
            )
        x = s_seq.reshape(t * b, h, w, cout)

    # FC layers (sparse cores with URAM weights in the paper): same folding.
    flat = x.reshape(t * b, -1)
    for name in ("fc0", "fc1"):
        w2d = qp[name]["w"]
        in_per_image = flat.reshape(t, b, -1).sum(axis=(0, 2))
        cur = flat @ w2d                                 # one launch, bias in epilogue
        s_seq = lif_scan_fused(cur.reshape(t, b, w2d.shape[-1]), qp[name]["b"])
        counts[name] = jnp.sum(s_seq)
        if with_stats:
            stats[name] = {
                "in_spikes_per_image": in_per_image,
                "out_spikes_per_image": s_seq.sum(axis=(0, 2)),
            }
        flat = s_seq.reshape(t * b, -1)

    group = cfg.population // cfg.num_classes
    pop = s_seq.sum(0)                                   # [B, P] spike counts over T
    logits = pop.reshape(b, cfg.num_classes, group).sum(-1) / (t * group)
    return logits, counts, stats


def vgg9_infer_hybrid(params: Dict, images: jax.Array, cfg: VGG9Config, *,
                      interpret: bool = True, plan=None, return_stats: bool = False):
    """Fused inference via the TPU kernels: dense_conv_lif for the input
    layer, occupancy-mapped spike_conv + conv-epilogue LIF for the spiking
    layers. The whole graph is one jit (static `cfg`/`plan` hashing), with
    timesteps folded into the batch so every spiking layer issues a single
    gated-matmul launch instead of T.

    Direct coding only. Numerics match vgg9_forward (tests assert).
    Returns (logits, counts); with return_stats=True additionally returns the
    per-layer stats: tile-skip measurements (occupancy map included) of the
    occupancy-mapped kernels plus per-image input/output spike counts for
    every layer — the quantities the serving engine splits back out per
    request.
    """
    assert cfg.coding == "direct"
    if plan is None:
        from ..core.hybrid import plan_vgg9_inference
        plan = plan_vgg9_inference(cfg, images.shape[0])
    logits, counts, stats = _infer_hybrid_fused(
        params, images, cfg=cfg, plan=plan, interpret=interpret,
        with_stats=return_stats)
    if return_stats:
        return logits, counts, stats
    return logits, counts


_SHARDED_FNS: Dict = {}


def vgg9_infer_hybrid_sharded(params: Dict, images: jax.Array, cfg: VGG9Config, *,
                              mesh, axis: str = "data", interpret: bool = True,
                              plan=None, return_stats: bool = False):
    """Data-mesh sharded fused inference: the folded ``[T*B·H·W, K]`` spiking
    matmuls split over ``mesh``'s ``axis`` via ``shard_map``.

    Every layer of the fused graph is row-independent over the batch, so the
    global batch shards contiguously: device ``d`` serves images
    ``[d*B/ndev, (d+1)*B/ndev)`` with a *local* plan sized to ``B/ndev``
    slots, weights replicated. Logits are bit-identical to the unsharded
    graph (same per-row accumulation order; the plan only re-tiles M).

    Stat layout differs from `vgg9_infer_hybrid` so per-shard counters stay
    attributable (see `serve.runners.snn` for the consumer):

    * ``counts``  — per-layer ``[ndev]`` vectors (sum for the global count);
    * ``*_per_image`` stats — global ``[B]`` vectors (shard-concatenated);
    * every other stat leaf (``occ_map``, ``row_occ``, ``skip_rate``,
      ``block_m``, ``rows``, tile counts) — stacked with a leading ``[ndev]``
      device axis; ``row_occ[d]`` rows are in device ``d``'s local folded
      order.

    Args:
        mesh: a mesh whose ``axis`` divides the batch (``B % ndev == 0``).
        plan: optional `HybridPlan` sized to the *local* batch ``B/ndev``.
    """
    assert cfg.coding == "direct"
    b = images.shape[0]
    ndev = int(mesh.shape[axis])
    assert b % ndev == 0, f"batch {b} must divide the '{axis}' axis ({ndev})"
    b_local = b // ndev
    if plan is None:
        from ..core.hybrid import plan_vgg9_inference
        plan = plan_vgg9_inference(cfg, b_local)

    from jax.sharding import PartitionSpec as P

    key = (cfg, plan, mesh, axis, interpret, return_stats,
           images.shape, str(images.dtype))
    if key not in _SHARDED_FNS:
        def local_fn(p, im):
            logits, counts, stats = _infer_hybrid_fused(
                p, im, cfg=cfg, plan=plan, interpret=interpret,
                with_stats=return_stats)
            counts = {k: v.reshape(1) for k, v in counts.items()}
            stats = {
                name: {k: (v if k.endswith("_per_image") else v[None])
                       for k, v in st.items()}
                for name, st in stats.items()}
            return logits, counts, stats

        shape_local = jax.ShapeDtypeStruct((b_local,) + images.shape[1:],
                                           images.dtype)
        out_shapes = jax.eval_shape(local_fn, params, shape_local)
        out_specs = jax.tree.map(lambda _: P(axis), out_shapes)
        _SHARDED_FNS[key] = jax.jit(jax.shard_map(
            local_fn, mesh=mesh, in_specs=(P(), P(axis)),
            out_specs=out_specs, check_vma=False))
    logits, counts, stats = _SHARDED_FNS[key](params, images)
    if return_stats:
        return logits, counts, stats
    return logits, counts


def vgg9_infer_hybrid_unfused(params: Dict, images: jax.Array, cfg: VGG9Config, *,
                              interpret: bool = True) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """The pre-fusion pipeline: T separate in-kernel-gated spike_conv +
    lif_step launches per layer from a Python loop. Kept as the benchmark
    baseline for benchmarks/hybrid_pipeline.py."""
    from ..kernels.dense_conv_lif.ops import input_layer_conv_lif
    from ..kernels.spike_conv.ops import spike_conv2d
    from ..kernels.lif_step.ops import lif_update

    assert cfg.coding == "direct"
    qp = quantized_view(params, cfg)
    b = images.shape[0]

    # Dense core: input layer, conv once + T fused LIF steps
    spikes, _ = input_layer_conv_lif(
        images, qp["conv0"]["w"], qp["conv0"]["b"],
        num_steps=cfg.timesteps, beta=cfg.beta, theta=cfg.theta, interpret=interpret)
    counts = {"conv0": jnp.sum(spikes)}

    layer_in = spikes                                       # [T, B, H, W, C]
    for kind, idx in _stage_plan(cfg):
        if kind == "MP":
            layer_in = jax.vmap(_maxpool_spikes)(layer_in)
            continue
        name = f"conv{idx}"
        u = jnp.zeros(layer_in.shape[1:-1] + (qp[name]["w"].shape[-1],), jnp.float32)
        s_prev = jnp.zeros_like(u)
        outs = []
        for t in range(cfg.timesteps):
            cur = spike_conv2d(layer_in[t], qp[name]["w"], interpret=interpret) + qp[name]["b"]
            u, s_prev = lif_update(u, cur, s_prev, beta=cfg.beta, theta=cfg.theta,
                                   interpret=interpret)
            outs.append(s_prev)
        layer_in = jnp.stack(outs)
        counts[name] = jnp.sum(layer_in)

    # FC layers (sparse cores with URAM weights in the paper)
    flat = layer_in.reshape(cfg.timesteps, b, -1)
    for name in ("fc0", "fc1"):
        u = jnp.zeros((b, qp[name]["w"].shape[-1]), jnp.float32)
        s_prev = jnp.zeros_like(u)
        outs = []
        for t in range(cfg.timesteps):
            cur = flat[t] @ qp[name]["w"] + qp[name]["b"]
            u, s_prev = lif_update(u, cur, s_prev, beta=cfg.beta, theta=cfg.theta,
                                   interpret=interpret)
            outs.append(s_prev)
        flat = jnp.stack(outs)
        counts[name] = jnp.sum(flat)

    group = cfg.population // cfg.num_classes
    pop = flat.sum(0)
    logits = pop.reshape(b, cfg.num_classes, group).sum(-1) / (cfg.timesteps * group)
    return logits, counts
