"""GQA attention: chunked (flash-style) training/prefill path + KV-cache decode.

Memory-efficient attention in pure JAX: lax.scan over query chunks with an
online-softmax accumulator over KV chunks, so peak activation memory is
O(S * chunk) instead of O(S^2) — required for the 32k prefill shapes to
produce an honest memory analysis. Supports GQA (grouped KV heads), RoPE,
optional QKV bias (qwen1.5), and sliding-window masks (recurrentgemma local
attention).

Training/prefill positions are left-aligned and shared across the batch
(positions derived from iota; no padding mask). The decode path additionally
accepts a per-request position vector, which the serving engine uses for
ragged prompt lengths (each request rotates/writes/attends at its own
position — see serve/runners/lm.py).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import apply_rope, dense_init

NEG_INF = -1e30


def _largest_divisor_leq(n: int, cap: int) -> int:
    """Largest divisor of n that is <= cap (chunk-size selection)."""
    cap = min(cap, n)
    for d in range(cap, 0, -1):
        if n % d == 0:
            return d
    return 1


def attn_init(key, d: int, n_heads: int, n_kv_heads: int, head_dim: int,
              qkv_bias: bool, dtype) -> Dict[str, jax.Array]:
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, n_heads * head_dim, dtype),
        "wk": dense_init(ks[1], d, n_kv_heads * head_dim, dtype),
        "wv": dense_init(ks[2], d, n_kv_heads * head_dim, dtype),
        "wo": dense_init(ks[3], n_heads * head_dim, d, dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((n_kv_heads * head_dim,), dtype)
        p["bv"] = jnp.zeros((n_kv_heads * head_dim,), dtype)
    return p


def _project_qkv(p, x, n_heads, n_kv_heads, head_dim, rope_theta, positions):
    b, s, _ = x.shape
    q = x @ p["wq"] + (p["bq"] if "bq" in p else 0)
    k = x @ p["wk"] + (p["bk"] if "bk" in p else 0)
    v = x @ p["wv"] + (p["bv"] if "bv" in p else 0)
    q = q.reshape(b, s, n_heads, head_dim)
    k = k.reshape(b, s, n_kv_heads, head_dim)
    v = v.reshape(b, s, n_kv_heads, head_dim)
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)
    return q, k, v


def chunked_causal_attention(
    q: jax.Array, k: jax.Array, v: jax.Array,
    *, window: int = 0, q_chunk: int = 512, kv_chunk: int = 1024,
    unroll: bool = False, f32_streams: bool = False,
) -> jax.Array:
    """Online-softmax causal attention. q [B,S,H,hd], k/v [B,S,KV,hd].

    window > 0 restricts attention to the last `window` positions
    (sliding-window / local attention). S must divide by the chunk sizes
    (callers pad); chunks are clamped to S.

    unroll=True replaces the chunk scans with Python loops — used by the
    dry-run cost lowering so HLO cost_analysis sees every chunk (scan bodies
    are counted once by XLA; see EXPERIMENTS.md §Methodology).
    """
    b, s, h, hd = q.shape
    kv_heads = k.shape[2]
    g = h // kv_heads
    q_chunk = _largest_divisor_leq(s, q_chunk)
    kv_chunk = _largest_divisor_leq(s, kv_chunk)
    nq, nk = s // q_chunk, s // kv_chunk
    scale = 1.0 / math.sqrt(hd)

    # keep q/k/v streams in their native dtype (bf16 on TPU): the MXU takes
    # bf16 operands with f32 accumulation (preferred_element_type below), and
    # HBM traffic for the chunk streams halves vs upcasting here.
    # f32_streams=True reproduces the pre-optimization baseline (§Perf).
    sdt = jnp.float32 if f32_streams else q.dtype
    qr = (q.astype(jnp.float32) * scale).astype(sdt).reshape(
        b, nq, q_chunk, kv_heads, g, hd)
    kr = k.astype(sdt).reshape(b, nk, kv_chunk, kv_heads, hd)
    vr = v.astype(sdt).reshape(b, nk, kv_chunk, kv_heads, hd)
    # [nq, B, C, KV, G, hd] etc. so scan walks the chunk axis
    qr = jnp.moveaxis(qr, 1, 0)
    kr = jnp.moveaxis(kr, 1, 0)
    vr = jnp.moveaxis(vr, 1, 0)

    def q_body(_, q_in):
        qi, qc = q_in                              # index, [B, C, KV, G, hd]

        @jax.checkpoint
        def kv_body(carry, kv_in):
            m, l, acc = carry
            ki, kc, vc = kv_in
            qpos = qi * q_chunk + jax.lax.broadcasted_iota(jnp.int32, (q_chunk, kv_chunk), 0)
            kpos = ki * kv_chunk + jax.lax.broadcasted_iota(jnp.int32, (q_chunk, kv_chunk), 1)
            mask = kpos <= qpos
            if window > 0:
                mask &= kpos > qpos - window
            # scores: [B, KV, G, Cq, Ck] — f32 accumulation off bf16 operands
            sc = jnp.einsum("bqkgh,bskh->bkgqs", qc, kc,
                            preferred_element_type=jnp.float32)
            sc = jnp.where(mask[None, None, None], sc, NEG_INF)
            m_new = jnp.maximum(m, sc.max(axis=-1))
            p = jnp.exp(sc - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            # p in the value dtype for the PV matmul (standard flash practice;
            # exact for f32 models, halves the score read for bf16 models)
            pv = jnp.einsum("bkgqs,bskh->bkgqh", p.astype(vc.dtype), vc,
                            preferred_element_type=jnp.float32)
            acc_new = acc * alpha[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kv_heads, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kv_heads, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, kv_heads, g, q_chunk, hd), jnp.float32)
        if unroll:
            carry = (m0, l0, a0)
            for ki in range(nk):
                carry, _ = kv_body(carry, (jnp.asarray(ki), kr[ki], vr[ki]))
            m, l, acc = carry
        else:
            (m, l, acc), _ = jax.lax.scan(
                kv_body, (m0, l0, a0), (jnp.arange(nk), kr, vr))
        out = acc / jnp.maximum(l, 1e-30)[..., None]   # [B, KV, G, Cq, hd]
        return None, jnp.moveaxis(out, 3, 1)           # [B, Cq, KV, G, hd]

    if unroll:
        chunks = jnp.stack([q_body(None, (jnp.asarray(qi), qr[qi]))[1] for qi in range(nq)])
    else:
        _, chunks = jax.lax.scan(q_body, None, (jnp.arange(nq), qr))
    out = jnp.moveaxis(chunks, 0, 1).reshape(b, s, h, hd)  # [B, S, H, hd]
    return out.astype(q.dtype)


def attention_block(
    p: Dict[str, jax.Array], x: jax.Array, *,
    n_heads: int, n_kv_heads: int, head_dim: int,
    rope_theta: float, window: int = 0,
    q_chunk: int = 512, kv_chunk: int = 1024, unroll: bool = False,
    f32_streams: bool = False,
) -> jax.Array:
    """Full training/prefill attention over [B, S, d] (pre-normed input)."""
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    q, k, v = _project_qkv(p, x, n_heads, n_kv_heads, head_dim, rope_theta, positions)
    out = chunked_causal_attention(q, k, v, window=window, q_chunk=q_chunk,
                                   kv_chunk=kv_chunk, unroll=unroll,
                                   f32_streams=f32_streams)
    return out.reshape(b, s, n_heads * head_dim) @ p["wo"]


# ---------------------------------------------------------------------------
# Decode path (one new token against a KV cache)
# ---------------------------------------------------------------------------

def init_kv_cache(batch: int, max_seq: int, n_kv_heads: int, head_dim: int, dtype) -> Dict[str, jax.Array]:
    return {
        "k": jnp.zeros((batch, max_seq, n_kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, max_seq, n_kv_heads, head_dim), dtype),
    }


def attention_decode(
    p: Dict[str, jax.Array], x: jax.Array, cache: Dict[str, jax.Array], pos: jax.Array, *,
    n_heads: int, n_kv_heads: int, head_dim: int, rope_theta: float, window: int = 0,
    active: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: [B, 1, d] new-token activations; pos: scalar int32 position shared
    by the batch, or an int32 [B] vector of per-request positions (ragged
    serving: each request writes/attends/rotates at its own position).

    active: optional bool [B]; rows with active=False leave their cache slot
    untouched (the serving engine's ragged prefill masks requests whose
    prompt is already consumed). The select is applied to the single written
    slot, not the whole cache.

    For window > 0 the cache is a ring buffer of size `window` (cache slot =
    pos % window); otherwise the cache covers max_seq positions.
    """
    b = x.shape[0]
    max_s = cache["k"].shape[1]
    pos = jnp.asarray(pos, jnp.int32)
    pos_vec = jnp.broadcast_to(pos, (b,)) if pos.ndim == 0 else pos  # [B]
    positions = pos_vec[:, None]                                    # [B, 1]
    q, k_new, v_new = _project_qkv(p, x, n_heads, n_kv_heads, head_dim, rope_theta, positions)

    slot = pos_vec % max_s if window > 0 else pos_vec
    rows = jnp.arange(b)
    k_upd = k_new[:, 0].astype(cache["k"].dtype)        # [B, KV, hd]
    v_upd = v_new[:, 0].astype(cache["v"].dtype)
    if active is not None:
        keep = active[:, None, None]
        k_upd = jnp.where(keep, k_upd, cache["k"][rows, slot])
        v_upd = jnp.where(keep, v_upd, cache["v"][rows, slot])
    ck = cache["k"].at[rows, slot].set(k_upd)
    cv = cache["v"].at[rows, slot].set(v_upd)

    g = n_heads // n_kv_heads
    qh = q.reshape(b, n_kv_heads, g, head_dim).astype(jnp.float32) / math.sqrt(head_dim)
    sc = jnp.einsum("bkgh,bskh->bkgs", qh, ck.astype(jnp.float32))  # [B,KV,G,S]
    idx = jnp.arange(max_s)[None]                                   # [1, S]
    pv = pos_vec[:, None]                                           # [B, 1]
    if window > 0:
        # ring buffer: slot i holds absolute position derived from pos
        ph = pv % max_s
        abs_pos = jnp.where(idx <= ph, pv - ph + idx, pv - ph - max_s + idx)
        valid = (abs_pos >= 0) & (abs_pos <= pv) & (abs_pos > pv - max_s)
    else:
        valid = idx <= pv                                           # [B, S]
    sc = jnp.where(valid[:, None, None], sc, NEG_INF)
    w = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", w, cv.astype(jnp.float32))
    out = out.reshape(b, 1, n_heads * head_dim).astype(x.dtype) @ p["wo"]
    return out, {"k": ck, "v": cv}
