"""Request-lifecycle tracing: deterministic spans over the engine clock.

A trace is a list of `Span`s with parent/child ids covering one request's
life through the serving stack:

    request (root, opened at submit, closed with the terminal status)
      queued            submit -> admission (or straight to the terminal
                        status for requests retired from the queue)
      serve             admission -> retirement
        prefill-chunk   one span per engine step that consumed prompt
                        tokens for the request (== ``prefill_chunks``)
        decode|speculate|infer
                        one coalesced span per contiguous phase run
                        ('speculate' when the step's cost showed drafted
                        tokens, 'infer' for the SNN's fused step)

Timestamps are whatever clock the engine runs (`core.StepClock` /
`faults.TickClock` in tests and benches), recorded from values the engine
*already read* — the tracer never touches a clock itself, so attaching it
cannot perturb deadlines or scheduling (the no-perturbation contract
`tests/test_obs.py` asserts bit-identically).

Fleet traces: each replica traces locally; `Tracer.drain` hands closed
spans to the transport (in-process directly, over the wire via the
heartbeat's telemetry field) and `merge_traces` namespaces span ids by
replica label into one ordered trace for the whole run.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

#: terminal statuses a root span may close with (mirrors `api.Result.status`
#: plus the router-side 'rejected')
TERMINAL = ("ok", "cancelled", "expired", "failed", "rejected")


@dataclasses.dataclass
class Span:
    """One lifecycle span. ``start_s``/``end_s`` are engine-clock stamps;
    ``start_step``/``end_step`` engine step indices (router step indices
    for router-level spans)."""
    span_id: int
    parent_id: Optional[int]
    request_id: int
    name: str
    start_step: int
    start_s: float
    end_step: Optional[int] = None
    end_s: Optional[float] = None
    status: str = ""
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def closed(self) -> bool:
        return self.end_step is not None

    def to_dict(self) -> Dict[str, Any]:
        return {
            "span_id": self.span_id, "parent_id": self.parent_id,
            "request_id": self.request_id, "name": self.name,
            "start_step": self.start_step, "start_s": self.start_s,
            "end_step": self.end_step, "end_s": self.end_s,
            "status": self.status, "attrs": dict(self.attrs),
        }


class Tracer:
    """Per-engine (or per-router) span recorder.

    All methods take the clock value and step index as arguments — the
    caller passes readings it already made. Unknown request ids are
    ignored (a request may retire from the queue without ever being
    admitted, or a replica may join a trace mid-life after a re-route).
    """

    def __init__(self):
        self._next_id = 0
        self.spans: List[Span] = []          # every span, open or closed
        self._root: Dict[int, Span] = {}     # request_id -> open root
        self._serve: Dict[int, Span] = {}    # request_id -> open serve span
        self._queued: Dict[int, Span] = {}   # request_id -> open queued span
        self._phase: Dict[int, Span] = {}    # request_id -> open phase span
        self._drained = 0                    # spans[:_drained] already shipped

    def _open(self, name: str, rid: int, step: int, now: float,
              parent: Optional[Span] = None, **attrs: Any) -> Span:
        span = Span(self._next_id,
                    None if parent is None else parent.span_id,
                    rid, name, step, now, attrs=attrs)
        self._next_id += 1
        self.spans.append(span)
        return span

    @staticmethod
    def _close(span: Optional[Span], step: int, now: float,
               status: str = "") -> None:
        if span is not None and not span.closed:
            span.end_step = step
            span.end_s = now
            if status:
                span.status = status

    # -- lifecycle hooks ----------------------------------------------------

    def begin(self, rid: int, step: int, now: float, **attrs: Any) -> None:
        """Request submitted: open the root span and its 'queued' child."""
        root = self._open("request", rid, step, now, **attrs)
        self._root[rid] = root
        self._queued[rid] = self._open("queued", rid, step, now, parent=root)

    def admit(self, rid: int, step: int, now: float) -> None:
        """Request entered a slot: close 'queued', open 'serve'."""
        root = self._root.get(rid)
        if root is None:
            return
        self._close(self._queued.pop(rid, None), step, now)
        self._serve[rid] = self._open("serve", rid, step, now, parent=root)

    def phase(self, rid: int, name: str, step: int, now: float,
              units: int = 0) -> None:
        """One engine step advanced ``rid`` in phase ``name``.

        'prefill' records one closed 'prefill-chunk' span per step (the
        chunk structure is the point); other phases coalesce contiguous
        same-name runs into one span, closed lazily at the next phase flip
        or at retirement.
        """
        parent = self._serve.get(rid) or self._root.get(rid)
        if parent is None:
            return
        if name == "prefill":
            open_phase = self._phase.pop(rid, None)
            self._close(open_phase, step, now)
            chunk = self._open("prefill-chunk", rid, step, now, parent=parent,
                               units=units)
            self._close(chunk, step, now)
            return
        span = self._phase.get(rid)
        if span is not None and span.name == name:
            span.end_step = step        # provisional close: extended in place
            span.end_s = now
            span.attrs["units"] = span.attrs.get("units", 0) + units
            return
        self._close(span, step, now)
        self._phase[rid] = self._open(name, rid, step, now, parent=parent,
                                      units=units)

    def end(self, rid: int, status: str, step: int, now: float) -> None:
        """Request retired: close everything still open for it."""
        self._close(self._phase.pop(rid, None), step, now)
        self._close(self._queued.pop(rid, None), step, now)
        self._close(self._serve.pop(rid, None), step, now)
        self._close(self._root.pop(rid, None), step, now, status=status)

    # -- export -------------------------------------------------------------

    def export(self, *, closed_only: bool = False) -> List[Dict[str, Any]]:
        return [s.to_dict() for s in self.spans
                if not closed_only or s.closed]

    def drain(self) -> List[Dict[str, Any]]:
        """Closed spans not yet drained (wire telemetry: each heartbeat
        ships only the increment). Open spans stay until they close."""
        out = []
        kept = []
        for span in self.spans[self._drained:]:
            (out if span.closed else kept).append(span)
        self.spans = self.spans[:self._drained] + \
            [s for s in self.spans[self._drained:] if s.closed] + kept
        self._drained = len(self.spans) - len(kept)
        return [s.to_dict() for s in out]


def merge_traces(parts: Sequence[Tuple[Any, Sequence[Dict[str, Any]]]]
                 ) -> List[Dict[str, Any]]:
    """Merge per-replica span lists into one fleet trace.

    ``parts`` is ``[(label, spans), ...]``; span ids are namespaced to
    ``"<label>:<id>"`` strings (parent links rewritten alike) and every
    span gains a ``replica`` field, so ids from different replicas can
    never collide. Ordered by (start_step, replica, span id).
    """
    merged: List[Dict[str, Any]] = []
    for label, spans in parts:
        for span in spans:
            out = dict(span)
            out["replica"] = label
            out["span_id"] = f"{label}:{span['span_id']}"
            if span.get("parent_id") is not None:
                out["parent_id"] = f"{label}:{span['parent_id']}"
            merged.append(out)
    merged.sort(key=lambda s: (s["start_step"], str(s["replica"]),
                               s["span_id"]))
    return merged
