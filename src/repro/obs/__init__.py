"""Observability plane: tracing + typed metrics + flight recorder.

One `Observability` bundle rides an `EngineCore` (or a `Router`) and turns
the values the engine already computed into three artifacts:

* a request-lifecycle **trace** (`obs.trace.Tracer` — submit -> admit ->
  prefill-chunk* -> decode|speculate|infer -> terminal status),
* a typed **metrics** snapshot (`obs.metrics.MetricsRegistry` — goodput
  counters, queue gauges, step-seconds histograms, plus whatever the
  scheduler / precision controller publish through ``metrics_into``),
* a **flight recorder** ring (`obs.recorder.FlightRecorder` — the last N
  step frames + decisions, dumped on `EngineStalled`, numerics poison and
  `WorkerDied`).

The contract, tested property-style in ``tests/test_obs.py``: attached
vs. detached is **bit-identical** on every `Result` and every scheduler
decision. The hooks only *receive* values (clock readings, reports,
results) that the engine read anyway — nothing here calls a clock,
advances an RNG, or mutates engine state.

Hook order per engine step (see `serve/core.py`):

    on_submit(rid)  ->  on_admit(rids)  ->  on_step(report, ...)
        ->  on_retire(result) per retirement  ->  on_dump(reason) on faults

Fleet story: each replica owns one bundle; `wire_telemetry()` emits the
*increment* (newly closed spans, current metrics snapshot, fresh recorder
dumps) that worker heartbeats carry; the router folds replicas together
with `merge_traces` + `metrics.aggregate`.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Iterable, List, Optional, Sequence

from .metrics import (Counter, Gauge, Histogram, MetricsRegistry, aggregate,
                      to_prometheus)
from .recorder import FlightRecorder, summarize_report
from .trace import Span, Tracer, merge_traces

__all__ = [
    "Observability", "Tracer", "Span", "merge_traces",
    "MetricsRegistry", "Counter", "Gauge", "Histogram", "aggregate",
    "to_prometheus", "FlightRecorder", "summarize_report",
]

#: Result.stats keys summed into served-energy counters (both cost models)
_ENERGY_KEYS = (("served_energy_j", "precision_served_energy_eq3_j",
                 "Eq. 3 served energy of retired requests (J)"),
                ("served_energy_analytical_j",
                 "precision_served_energy_analytical_j",
                 "analytical per-op served energy of retired requests (J)"))


class Observability:
    """Bundle of tracer + metrics + recorder with engine-shaped hooks.

    Any pillar can be disabled (``trace=False``, ``metrics=False``,
    ``recorder=0``); hooks skip the missing pieces. ``attach_engine``
    registers pull collectors for the scheduler's and precision
    controller's ``metrics_into`` and remembers the controller so its
    per-request decisions land in the recorder's notes.
    """

    def __init__(self, *, trace: bool = True, metrics: bool = True,
                 recorder: int = 64):
        self.tracer: Optional[Tracer] = Tracer() if trace else None
        self.metrics: Optional[MetricsRegistry] = \
            MetricsRegistry() if metrics else None
        self.recorder: Optional[FlightRecorder] = \
            FlightRecorder(recorder) if recorder else None
        self._controller = None      # PrecisionController, if the engine has one
        self._decisions_seen = 0     # controller.decisions already noted
        self._dumps_shipped = 0      # recorder.dumps already sent over the wire
        self._units_seen: Dict[int, int] = {}   # rid -> last units_done

    # -- attachment ---------------------------------------------------------

    def attach_engine(self, core: Any) -> None:
        """Probe ``core`` for metric publishers; never mutates it."""
        if self.metrics is not None:
            publish = getattr(getattr(core, "scheduler", None),
                              "metrics_into", None)
            if callable(publish):
                self.metrics.collectors.append(
                    lambda reg, _p=publish: _p(reg))
        controller = getattr(getattr(core, "runner", None), "controller", None)
        if controller is not None:
            self._controller = controller
            publish = getattr(controller, "metrics_into", None)
            if self.metrics is not None and callable(publish):
                self.metrics.collectors.append(
                    lambda reg, _p=publish: _p(reg))

    # -- engine hooks -------------------------------------------------------

    def on_submit(self, rid: int, step: int, now: float,
                  **attrs: Any) -> None:
        if self.tracer is not None:
            self.tracer.begin(rid, step, now, **attrs)
        if self.metrics is not None:
            self.metrics.counter(
                "engine_submitted", "requests accepted into the queue").inc()

    def on_admit(self, rids: Sequence[int], step: int, now: float) -> None:
        if not rids:
            return
        if self.tracer is not None:
            for rid in rids:
                self.tracer.admit(rid, step, now)
        if self.metrics is not None:
            self.metrics.counter(
                "engine_admitted", "requests admitted into slots").inc(
                    len(rids))
        if self.recorder is not None:
            self.recorder.note(step, "admit", rids=list(rids))

    def on_step(self, report: Any, *, step: int, now: float, seconds: float,
                queue_len: int, occupied: int,
                poisoned: Iterable[int] = ()) -> None:
        """One engine step ran. ``step``/``now``/``seconds`` are the
        engine's own readings; ``poisoned`` the request ids whose slots
        failed the numerics screen this step."""
        cost = report.cost
        if self.recorder is not None:
            self.recorder.record(step, report, seconds=seconds,
                                 queue_len=queue_len, occupied=occupied)
            self._note_precision_decisions(step)
            poisoned = list(poisoned)
            if poisoned:
                self.recorder.note(step, "poison", rids=poisoned)
        if self.tracer is not None:
            speculated = cost.get("drafted_tokens", 0) > 0
            for prog in report.progress.values():
                rid = prog.request_id
                prev = self._units_seen.get(rid, 0)
                self._units_seen[rid] = prog.units_done
                emitted = len(prog.emitted)
                # prompt tokens consumed this step: the units advance not
                # explained by emissions. `SlotProgress.phase` flips to
                # 'decode' *on* the step that finishes the prompt, so the
                # delta — not the label — decides whether this step was a
                # prefill chunk (== the `prefill_chunks` stat).
                consumed = max(0, prog.units_done - prev - emitted)
                if consumed > 0:
                    self.tracer.phase(rid, "prefill", step, now,
                                      units=consumed)
                if emitted > 0:
                    name = ("speculate"
                            if speculated and prog.phase == "decode"
                            else prog.phase)
                    self.tracer.phase(rid, name, step, now, units=emitted)
        if self.metrics is not None:
            m = self.metrics
            m.counter("engine_steps", "engine steps executed").inc()
            for key, help in (("units", "budget units consumed"),
                              ("prompt_tokens", "prompt tokens prefilled"),
                              ("decode_tokens", "decode tokens emitted"),
                              ("drafted_tokens", "draft tokens proposed"),
                              ("accepted_tokens", "draft tokens accepted")):
                amount = float(cost.get(key, 0) or 0)
                if amount > 0:
                    m.counter(f"engine_{key}", help).inc(amount)
            m.gauge("engine_queue_depth", "waiting requests").set(queue_len)
            m.gauge("engine_occupied_slots", "slots holding a request").set(
                occupied)
            m.histogram("engine_step_seconds",
                        "wall seconds per engine step").observe(seconds)

    def on_retire(self, result: Any, step: int, now: float) -> None:
        """A request reached a terminal status (any of `trace.TERMINAL`)."""
        self._units_seen.pop(result.request_id, None)
        if self.tracer is not None:
            self.tracer.end(result.request_id, result.status, step, now)
        if self.metrics is not None:
            self.metrics.counter(
                f"engine_retired_{result.status}",
                f"requests retired with status={result.status}").inc()
            for stats_key, metric, help in _ENERGY_KEYS:
                joules = result.stats.get(stats_key)
                if joules is not None and math.isfinite(joules):
                    self.metrics.counter(metric, help).inc(float(joules))

    def on_dump(self, reason: str, step: int,
                **extra: Any) -> Optional[Dict[str, Any]]:
        """Fault boundary hit ('stalled' | 'numerics-poison' |
        'worker-died' | ...): freeze the recorder rings."""
        if self.metrics is not None:
            self.metrics.counter(
                "recorder_dumps", "flight-recorder postmortems taken").inc()
        if self.recorder is None:
            return None
        return self.recorder.dump(reason, step=step, extra=extra or None)

    def _note_precision_decisions(self, step: int) -> None:
        controller = self._controller
        if controller is None or self.recorder is None:
            return
        decisions = getattr(controller, "decisions", ())
        for decision in decisions[self._decisions_seen:]:
            self.recorder.note(step, "precision",
                               rid=decision.request_id,
                               precision=decision.precision,
                               reason=decision.reason)
        self._decisions_seen = len(decisions)

    # -- export -------------------------------------------------------------

    def wire_telemetry(self) -> Dict[str, Any]:
        """The per-heartbeat increment a worker ships to its parent:
        newly closed spans, the current metrics snapshot, fresh recorder
        dumps, and a short frame tail (postmortem cushion if the process
        dies before its next heartbeat)."""
        telemetry: Dict[str, Any] = {}
        if self.tracer is not None:
            telemetry["spans"] = self.tracer.drain()
        if self.metrics is not None:
            telemetry["metrics"] = self.metrics.snapshot()
        if self.recorder is not None:
            telemetry["frames"] = self.recorder.tail(16)
            fresh = self.recorder.dumps[self._dumps_shipped:]
            if fresh:
                telemetry["dumps"] = list(fresh)
            self._dumps_shipped = len(self.recorder.dumps)
        return telemetry

    def snapshot(self) -> Dict[str, Any]:
        """Everything, in place (in-process consumers / `--metrics`)."""
        out: Dict[str, Any] = {}
        if self.tracer is not None:
            out["trace"] = self.tracer.export()
        if self.metrics is not None:
            out["metrics"] = self.metrics.snapshot()
        if self.recorder is not None:
            out["dumps"] = list(self.recorder.dumps)
        return out
