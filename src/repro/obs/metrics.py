"""Typed metrics registry: counters, gauges, histograms + fleet aggregation.

The serving stack's measurements used to live in ad-hoc ``stats()`` dicts
(engine goodput, router drain counts, scheduler EWMAs, precision-controller
tallies) with no shared naming, typing, or export path. This registry is
that shared surface:

* **typed** — a name is registered once with one kind; re-registering it as
  a different kind raises (``engine_decode_tokens`` can never silently flip
  from counter to gauge between PRs).
* **pull-friendly** — ``snapshot()`` is a plain JSON-able dict; components
  that learn state privately (schedulers, the precision controller) expose
  a ``metrics_into(registry)`` hook called at snapshot time, so observing
  them costs nothing on the hot path and cannot perturb their decisions.
* **aggregable** — `aggregate` folds per-replica snapshots into one fleet
  snapshot (counters/histograms sum, gauges sum with a per-replica
  breakdown), and `to_prometheus` renders any snapshot in the Prometheus
  text exposition format for scrape-shaped consumers.

Naming convention: ``<component>_<quantity>[_<unit>]`` — e.g.
``engine_decode_tokens``, ``router_drains``, ``scheduler_skip_ewma``,
``precision_served_energy_j``. The full table lives in
``docs/architecture.md``.
"""
from __future__ import annotations

import json
import math
from typing import Any, Dict, List, Mapping, Optional, Sequence

#: default histogram bucket upper bounds (engine-clock seconds / work units)
DEFAULT_BUCKETS = (0.001, 0.005, 0.01, 0.05, 0.1, 0.5, 1.0, 5.0, 10.0)


class Counter:
    """Monotonically increasing total."""

    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name} cannot decrease "
                             f"({amount})")
        self.value += amount

    def snapshot(self) -> Dict[str, Any]:
        return {"kind": self.kind, "value": self.value, "help": self.help}


class Gauge:
    """Point-in-time value (queue depth, EWMA, occupancy)."""

    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)

    def snapshot(self) -> Dict[str, Any]:
        return {"kind": self.kind, "value": self.value, "help": self.help}


class Histogram:
    """Cumulative-bucket distribution (Prometheus semantics: each bucket
    counts observations <= its bound; +Inf is implicit via ``count``)."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets: Sequence[float] = DEFAULT_BUCKETS):
        self.name = name
        self.help = help
        self.bounds = tuple(sorted(float(b) for b in buckets))
        self.bucket_counts = [0] * len(self.bounds)
        self.count = 0
        self.sum = 0.0

    def observe(self, value: float) -> None:
        value = float(value)
        self.count += 1
        self.sum += value
        for i, bound in enumerate(self.bounds):
            if value <= bound:
                self.bucket_counts[i] += 1

    def snapshot(self) -> Dict[str, Any]:
        return {"kind": self.kind, "help": self.help,
                "count": self.count, "sum": self.sum,
                "buckets": {repr(b): c for b, c in
                            zip(self.bounds, self.bucket_counts)}}


class MetricsRegistry:
    """Get-or-create registry of typed metrics, keyed by name."""

    def __init__(self):
        self._metrics: Dict[str, Any] = {}
        #: callables ``fn(registry)`` run at the top of every ``snapshot()``
        #: — the pull hook stateful components (schedulers, the precision
        #: controller) use to publish their learned state without being
        #: touched on the hot path.
        self.collectors: List[Any] = []

    def _get(self, cls, name: str, help: str, **kwargs):
        metric = self._metrics.get(name)
        if metric is None:
            metric = cls(name, help, **kwargs)
            self._metrics[name] = metric
        elif not isinstance(metric, cls):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{metric.kind}, not {cls.kind}")
        return metric

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._get(Histogram, name, help, buckets=buckets)

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def snapshot(self) -> Dict[str, Dict[str, Any]]:
        """Run every collector, then export all metrics as one JSON-able
        mapping ``{name: {kind, value | count/sum/buckets, help}}``."""
        for collect in self.collectors:
            collect(self)
        return {name: m.snapshot()
                for name, m in sorted(self._metrics.items())}

    def to_json(self, **dump_kwargs: Any) -> str:
        return json.dumps(self.snapshot(), sort_keys=True, **dump_kwargs)

    def to_prometheus(self) -> str:
        return to_prometheus(self.snapshot())


def _fmt(value: float) -> str:
    if value != value:
        return "NaN"
    if value in (math.inf, -math.inf):
        return "+Inf" if value > 0 else "-Inf"
    if float(value).is_integer():
        return str(int(value))
    return repr(float(value))


def to_prometheus(snapshot: Mapping[str, Mapping[str, Any]],
                  labels: Optional[Mapping[str, str]] = None) -> str:
    """Render a snapshot (from `MetricsRegistry.snapshot` or `aggregate`)
    in the Prometheus text exposition format."""
    label_str = ""
    if labels:
        inner = ",".join(f'{k}="{v}"' for k, v in sorted(labels.items()))
        label_str = "{" + inner + "}"
    lines: List[str] = []
    for name, m in sorted(snapshot.items()):
        if m.get("help"):
            lines.append(f"# HELP {name} {m['help']}")
        lines.append(f"# TYPE {name} {m['kind']}")
        if m["kind"] == "histogram":
            for bound, count in m["buckets"].items():
                le = ('{le="%s"}' % bound) if not labels else \
                    label_str[:-1] + f',le="{bound}"}}'
                lines.append(f"{name}_bucket{le} {_fmt(count)}")
            inf_le = '{le="+Inf"}' if not labels else \
                label_str[:-1] + ',le="+Inf"}'
            lines.append(f"{name}_bucket{inf_le} {_fmt(m['count'])}")
            lines.append(f"{name}_sum{label_str} {_fmt(m['sum'])}")
            lines.append(f"{name}_count{label_str} {_fmt(m['count'])}")
        else:
            lines.append(f"{name}{label_str} {_fmt(m['value'])}")
    return "\n".join(lines) + "\n"


def aggregate(parts: Mapping[Any, Mapping[str, Mapping[str, Any]]]
              ) -> Dict[str, Dict[str, Any]]:
    """Fold per-replica snapshots into one fleet snapshot.

    Counters and histograms sum across replicas (totals are additive);
    gauges sum too (queue depths, occupancies and counts-as-gauges are
    additive fleet-wide) but additionally keep a ``per_replica`` breakdown
    so non-additive gauges (EWMAs) stay inspectable per replica.
    """
    out: Dict[str, Dict[str, Any]] = {}
    for label, snapshot in parts.items():
        for name, m in snapshot.items():
            agg = out.get(name)
            if agg is None:
                if m["kind"] == "histogram":
                    agg = {"kind": "histogram", "help": m.get("help", ""),
                           "count": 0, "sum": 0.0,
                           "buckets": {b: 0 for b in m["buckets"]}}
                else:
                    agg = {"kind": m["kind"], "help": m.get("help", ""),
                           "value": 0.0}
                    if m["kind"] == "gauge":
                        agg["per_replica"] = {}
                out[name] = agg
            if m["kind"] != agg["kind"]:
                raise TypeError(f"metric {name!r} is {m['kind']} on replica "
                                f"{label!r} but {agg['kind']} elsewhere")
            if m["kind"] == "histogram":
                agg["count"] += m["count"]
                agg["sum"] += m["sum"]
                for bound, count in m["buckets"].items():
                    agg["buckets"][bound] = agg["buckets"].get(bound, 0) + count
            else:
                agg["value"] += m["value"]
                if m["kind"] == "gauge":
                    agg["per_replica"][str(label)] = m["value"]
    return out
