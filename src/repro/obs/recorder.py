"""Flight recorder: a bounded ring of recent step activity per replica.

When a replica wedges, poisons its numerics, or its worker dies, the
interesting evidence is the handful of steps *before* the failure — the
`api.StepReport`s, the scheduler's admissions, and the precision
controller's choices that led up to it. The recorder keeps exactly that: a
``deque(maxlen=N)`` of summarized step frames plus a parallel ring of
decision notes, and a ``dump()`` that freezes both into a JSON-able
postmortem the router attaches to its ``drain_log``.

Frames are *summaries*, not the reports themselves: slot -> (request id,
phase, units) and the step's cost dict — no output tensors — so a frame is
cheap to keep, wire-encodable for worker heartbeats (NaN costs included;
the tagged codec round-trips them), and safe to hold after the engine
moved on. Recording is append-only on engine-owned values; the recorder
never reads engine state itself, preserving the no-perturbation contract.
"""
from __future__ import annotations

import collections
from typing import Any, Dict, List, Mapping, Optional


def summarize_report(report: Any) -> Dict[str, Any]:
    """`api.StepReport` -> JSON-able frame body (no output tensors)."""
    return {
        "cost": dict(report.cost),
        "finished": {int(idx): {"rid": res.request_id, "status": res.status}
                     for idx, res in report.finished.items()},
        "progress": {int(idx): {"rid": p.request_id, "phase": p.phase,
                                "done": p.units_done, "total": p.units_total}
                     for idx, p in report.progress.items()},
    }


class FlightRecorder:
    """Ring buffer of the last ``capacity`` step frames + decision notes.

    dumps: every postmortem produced so far (`dump` appends and returns) —
    the router lifts these into ``drain_log`` details; `EngineCore` dumps
    on `EngineStalled` and on a numerics-poison retirement.
    """

    def __init__(self, capacity: int = 64):
        self.capacity = int(capacity)
        self.frames: "collections.deque[Dict[str, Any]]" = \
            collections.deque(maxlen=self.capacity)
        self.notes: "collections.deque[Dict[str, Any]]" = \
            collections.deque(maxlen=self.capacity)
        self.dumps: List[Dict[str, Any]] = []

    def record(self, step: int, report: Any, *, seconds: float = 0.0,
               queue_len: int = 0, occupied: int = 0) -> None:
        """Capture one engine step's `StepReport` summary."""
        frame = summarize_report(report)
        frame.update(step=int(step), seconds=float(seconds),
                     queue=int(queue_len), occupied=int(occupied))
        self.frames.append(frame)

    def note(self, step: int, kind: str, **detail: Any) -> None:
        """Record one scheduler/precision decision (e.g. ``kind='admit'``
        with the admitted request ids, ``kind='precision'`` with the
        controller's choice + reason)."""
        self.notes.append({"step": int(step), "kind": kind, **detail})

    def tail(self, n: Optional[int] = None) -> List[Dict[str, Any]]:
        frames = list(self.frames)
        return frames if n is None else frames[-n:]

    def dump(self, reason: str, *, step: Optional[int] = None,
             extra: Optional[Mapping[str, Any]] = None) -> Dict[str, Any]:
        """Freeze the rings into one postmortem record."""
        record = {
            "reason": reason,
            "step": step if step is not None else (
                self.frames[-1]["step"] if self.frames else None),
            "frames": list(self.frames),
            "notes": list(self.notes),
        }
        if extra:
            record.update(dict(extra))
        self.dumps.append(record)
        return record
