"""Fault-injection harness + per-engine graceful degradation.

Covers the `serve.faults` layer in isolation (plan parsing, the wrapper
session's five fault kinds, NaN shape preservation), the engine-level
containment it exercises (`EngineStalled` instead of an infinite spin on a
wedged session; numerics screen retiring poisoned slots as ``'failed'``
with clean partials, batchmates bit-identical), and the slot-lifecycle
invariants: seeded random interleavings of submit/cancel/expire/fail under
random fault schedules never leak a slot or double-release one.

Everything here runs on the pure-python stub runner — no jax.
"""
import random

import pytest

from repro.serve.api import (EngineConfig, EngineStalled, Request,
                             StepBudget)
from repro.serve.core import EngineCore, StepClock, all_finite
from repro.serve.faults import (Fault, FaultError, FaultPlan, FaultyRunner,
                                TickClock, flood_queue, parse_fleet_plan,
                                poison)

from test_serve_continuous import StubRunner


def _core(runner=None, **cfg):
    cfg.setdefault("slots", 2)
    return EngineCore(runner if runner is not None else StubRunner(),
                      EngineConfig(**cfg), clock=StepClock())


# ---------------------------------------------------------------------------
# FaultPlan / parsing
# ---------------------------------------------------------------------------

def test_fault_plan_parse():
    plan = FaultPlan.parse("wedge@3;nan@5-7:slot=0;slow@2:seconds=3.5")
    assert plan.active("wedge", 2) is None
    assert plan.active("wedge", 3).kind == "wedge"      # open-ended
    assert plan.active("wedge", 99) is not None
    nan = plan.active("nan", 5)
    assert nan.slot == 0 and plan.active("nan", 6) is nan
    assert plan.active("nan", 7) is None                # half-open [5, 7)
    assert plan.active("slow", 2).seconds == 3.5
    assert plan.active("raise", 2) is None
    assert FaultPlan.parse("").faults == ()


def test_fault_plan_parse_rejects_garbage():
    with pytest.raises(ValueError):
        FaultPlan.parse("wedge3")                       # missing @
    with pytest.raises(ValueError):
        FaultPlan.parse("meteor@3")                     # unknown kind
    with pytest.raises(ValueError):
        FaultPlan.parse("nan@3:wat=1")                  # unknown option


def test_parse_fleet_plan():
    plans = parse_fleet_plan("1=wedge@3,2=nan@5:slot=0;raise@9")
    assert set(plans) == {1, 2}
    assert plans[1].active("wedge", 3) is not None
    assert plans[2].active("nan", 5).slot == 0
    assert plans[2].active("raise", 9) is not None
    with pytest.raises(ValueError):
        parse_fleet_plan("wedge@3")                     # missing IDX=


def test_tick_clock():
    clock = TickClock()
    assert clock() == 0.0
    clock.advance(2.5)
    assert clock() == 2.5


def test_poison_preserves_shape():
    np = pytest.importorskip("numpy")
    out = poison({"a": [1, 2.0], "b": ("x", 3), "c": np.ones((2, 2))})
    assert out["a"][0] != out["a"][0] and out["a"][1] != out["a"][1]  # NaN
    assert out["b"][0] == "x" and out["b"][1] != out["b"][1]
    assert out["c"].shape == (2, 2) and not all_finite(out["c"])
    assert all_finite(poison({"meta": "tag", "flag": True, "none": None}))


# ---------------------------------------------------------------------------
# FaultySession semantics
# ---------------------------------------------------------------------------

def _session(plan, clock=None, slots=2):
    runner = FaultyRunner(StubRunner(), FaultPlan.parse(plan), clock)
    return runner.open_session(slots)


def test_wedge_makes_no_progress_and_leaves_inner_untouched():
    sess = _session("wedge@1-3")
    sess.admit(0, Request(1, {"key": "a", "steps": 2}))
    r0 = sess.step(StepBudget())
    assert r0.progress[0].units_done == 1
    for _ in range(2):                                  # steps 1, 2: wedged
        rep = sess.step(StepBudget())
        assert not rep.progress and not rep.finished
        assert rep.cost == {"units": 0}
    rep = sess.step(StepBudget())                       # step 3: resumes
    assert 0 in rep.finished and rep.progress[0].units_done == 2


def test_raise_fault_raises():
    sess = _session("raise@1:message=boom")
    sess.admit(0, Request(1, {"key": "a", "steps": 3}))
    sess.step(StepBudget())
    with pytest.raises(FaultError, match="boom"):
        sess.step(StepBudget())


def test_slow_fault_advances_clock():
    clock = TickClock()
    sess = _session("slow@0-1:seconds=4.0", clock=clock)
    sess.admit(0, Request(1, {"key": "a", "steps": 2}))
    sess.step(StepBudget())
    assert clock() == 4.0                               # fault cost visible
    sess.step(StepBudget())
    assert clock() == 4.0                               # only step 0 slow


def test_nan_fault_poisons_only_target_slot():
    sess = _session("nan@0:slot=1")
    sess.admit(0, Request(1, {"key": "a", "steps": 2}))
    sess.admit(1, Request(2, {"key": "a", "steps": 2}))
    rep = sess.step(StepBudget())
    assert all_finite(rep.progress[0].emitted)          # slot 0 untouched
    assert not all_finite(rep.progress[1].emitted)
    # inner session state stays clean: a cancel yields an untouched partial
    res = sess.cancel(1)
    assert res.status == "cancelled" and all_finite(res.outputs)


def test_flood_queue_fills_to_capacity():
    core = _core(max_queue=5)
    rids = flood_queue(core, {"key": "a", "steps": 1})
    assert len(rids) == 5 and core.pending() == 5
    assert flood_queue(core, {"key": "a", "steps": 1}) == []


# ---------------------------------------------------------------------------
# Engine-level graceful degradation
# ---------------------------------------------------------------------------

def test_run_until_complete_raises_on_wedged_session():
    """Regression for the unbounded spin: a session that stops progressing
    must surface `EngineStalled` diagnostics, not loop forever."""
    runner = FaultyRunner(StubRunner(), FaultPlan.parse("wedge@1"))
    core = _core(runner, max_idle_steps=7)
    rid = core.submit({"key": "a", "steps": 5})
    with pytest.raises(EngineStalled, match="7 consecutive steps") as ei:
        core.run_until_complete()
    assert str(rid) in str(ei.value)                    # names the stuck rid


def test_run_until_complete_per_call_override_and_recovery():
    """The guard is per-call overridable, and a *transient* wedge shorter
    than the limit drains normally."""
    runner = FaultyRunner(StubRunner(), FaultPlan.parse("wedge@1-4"))
    core = _core(runner, max_idle_steps=2)
    core.submit({"key": "a", "steps": 2})
    results = core.run_until_complete(max_idle_steps=10)   # outlasts the wedge
    assert len(results) == 1
    assert next(iter(results.values())).status == "ok"


def test_numerics_screen_retires_poisoned_slot_as_failed():
    """NaN in a slot's step outputs: the request retires ``'failed'`` with
    its clean pre-poison partials; the batchmate's outputs are identical to
    a fault-free run."""
    clean = _core()
    a0 = clean.submit({"key": "a", "steps": 4})
    b0 = clean.submit({"key": "a", "steps": 4})
    ref = clean.run_until_complete()

    runner = FaultyRunner(StubRunner(), FaultPlan.parse("nan@2:slot=0"))
    core = _core(runner)
    a = core.submit({"key": "a", "steps": 4})           # slot 0: poisoned
    b = core.submit({"key": "a", "steps": 4})
    core.step()
    core.step()
    pre_poison = core.poll_partial(a)
    assert pre_poison == [1, 2] and all_finite(pre_poison)
    results = core.run_until_complete()
    assert results[a].status == "failed"
    assert results[b].status == "ok"
    assert results[b].outputs == ref[b0].outputs        # batchmate untouched
    assert core.stats()["failed"] == 1
    assert core.in_flight() == 0                        # slot reclaimed


def test_numerics_screen_never_streams_poison():
    runner = FaultyRunner(StubRunner(), FaultPlan.parse("nan@1"))
    core = _core(runner, slots=1)
    rid = core.submit({"key": "a", "steps": 3})
    core.step()                       # clean: emits 1
    core.step()                       # poisoned: retired, nothing streamed
    assert core.poll_partial(rid) == [1]
    assert core.poll(rid).status == "failed"


def test_numerics_screen_can_be_disabled():
    runner = FaultyRunner(StubRunner(), FaultPlan.parse("nan@0"))
    core = _core(runner, slots=1, numerics_screen=False)
    rid = core.submit({"key": "a", "steps": 1})
    results = core.run_until_complete()
    assert results[rid].status == "ok"                  # caller's problem now


# ---------------------------------------------------------------------------
# Slot-lifecycle invariants under random fault schedules
# ---------------------------------------------------------------------------

def _assert_slot_invariants(core, polled, submitted):
    occupied = [s.request_id for s in core.slots if s.request_id is not None]
    assert len(occupied) == len(set(occupied)), "slot holds duplicate rids"
    assert set(occupied) == set(core._resident), \
        "slot occupancy out of sync with resident map (leak/double-release)"
    assert core.in_flight() == len(core._resident)
    for rid in occupied:
        assert rid not in polled, f"rid {rid} resident after terminal result"
    assert set(polled) <= submitted


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_random_interleavings_never_leak_slots(seed):
    """Property-style: random interleavings of submit / cancel / deadline
    expiry / NaN-fault retirement, against a random fault schedule, keep
    `_Slot.acquire/release` accounting exact after every step — and every
    request ends with exactly one terminal result."""
    rng = random.Random(seed)
    faults = []
    for step in sorted(rng.sample(range(2, 40), 6)):
        faults.append(Fault("nan", step, stop=step + 1,
                            slot=rng.randrange(3)))
    if rng.random() < 0.5:
        w = rng.randrange(10, 30)
        faults.append(Fault("wedge", w, stop=w + rng.randrange(1, 4)))
    runner = FaultyRunner(StubRunner(), FaultPlan(tuple(faults)))
    core = _core(runner, slots=3, max_queue=16, max_idle_steps=0)

    submitted, polled = set(), {}
    live = []
    for _ in range(60):
        op = rng.random()
        if op < 0.45 and len(live) < 12:
            rid = core.submit(
                {"key": "a", "steps": rng.randrange(1, 5)},
                deadline_s=rng.choice([None, None, float(rng.randrange(1, 6))]))
            submitted.add(rid)
            live.append(rid)
        elif op < 0.6 and live:
            core.cancel(rng.choice(live))
        else:
            core.step()
        for rid in list(live):
            res = core.poll(rid)
            if res is not None:
                assert rid not in polled, "double terminal result"
                assert res.status in ("ok", "cancelled", "expired", "failed")
                polled[rid] = res
                live.remove(rid)
        _assert_slot_invariants(core, polled, submitted)

    results = core.run_until_complete()
    for rid, res in results.items():
        assert rid not in polled
        polled[rid] = res
    _assert_slot_invariants(core, polled, submitted)
    assert set(polled) == submitted                 # exactly-once, no losses
    admitted = {rid for _, group in core.admission_log for rid in group}
    assert sum(s.served for s in core.slots) == len(admitted), \
        "slot served-count disagrees with admissions (double-release?)"
