"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles.

All kernels run in interpret mode (CPU container; TPU is the target)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quant import quantize_int4
from repro.kernels.dense_conv_lif.ops import input_layer_conv_lif
from repro.kernels.dense_conv_lif.ref import dense_conv_lif_ref
from repro.kernels.int4_matmul.ops import w4a16_linear
from repro.kernels.int4_matmul.ref import int4_matmul_ref
from repro.kernels.lif_step.ops import lif_update
from repro.kernels.lif_step.ref import lif_step_ref
from repro.kernels.spike_conv.ops import spike_conv2d
from repro.kernels.spike_conv.ref import conv_ref, event_conv_ref, im2col

RNG = np.random.default_rng(0)


# ---------------------------------------------------------------------------
# spike_conv: occupancy-gated event-driven convolution
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape,cout", [
    ((1, 8, 8, 8), 16), ((2, 16, 16, 3), 32), ((1, 7, 9, 5), 13),
])
@pytest.mark.parametrize("density", [0.0, 0.1, 0.9])
def test_spike_conv_matches_dense_oracle(shape, cout, density):
    s = (RNG.random(shape) < density).astype(np.float32)
    w = RNG.normal(size=(3, 3, shape[-1], cout)).astype(np.float32)
    out = spike_conv2d(jnp.asarray(s), jnp.asarray(w), interpret=True)
    ref = conv_ref(jnp.asarray(s), jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_event_driven_semantics_equal_dense():
    """The paper's scatter-accumulate event semantics == dense conv."""
    s = (RNG.random((2, 10, 10, 4)) < 0.2).astype(np.float32)
    w = RNG.normal(size=(3, 3, 4, 8)).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(event_conv_ref(jnp.asarray(s), jnp.asarray(w))),
        np.asarray(conv_ref(jnp.asarray(s), jnp.asarray(w))), atol=1e-4)


def test_spike_conv_gate_on_off_identical():
    """Occupancy gating must not change results (only skip empty tiles)."""
    s = (RNG.random((1, 12, 12, 16)) < 0.05).astype(np.float32)
    s[:, 6:, :, :] = 0.0  # guarantee empty tiles
    w = RNG.normal(size=(3, 3, 16, 16)).astype(np.float32)
    a = spike_conv2d(jnp.asarray(s), jnp.asarray(w), gate=True, interpret=True)
    b = spike_conv2d(jnp.asarray(s), jnp.asarray(w), gate=False, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_spike_conv_all_zero_input():
    s = np.zeros((1, 8, 8, 8), np.float32)
    w = RNG.normal(size=(3, 3, 8, 8)).astype(np.float32)
    out = spike_conv2d(jnp.asarray(s), jnp.asarray(w), interpret=True)
    assert float(jnp.abs(out).max()) == 0.0


def test_im2col_matches_conv():
    x = RNG.normal(size=(2, 6, 6, 3)).astype(np.float32)
    w = RNG.normal(size=(3, 3, 3, 4)).astype(np.float32)
    patches = im2col(jnp.asarray(x), 3, 3, "SAME")
    out = (patches @ jnp.asarray(w.reshape(27, 4))).reshape(2, 6, 6, 4)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(conv_ref(jnp.asarray(x), jnp.asarray(w))),
                               atol=1e-4)


# ---------------------------------------------------------------------------
# dense_conv_lif: weight-stationary input layer + fused T-step LIF
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("num_steps", [1, 2, 4])
@pytest.mark.parametrize("cout", [16, 64])
def test_dense_conv_lif_matches_ref(num_steps, cout):
    img = RNG.normal(size=(2, 8, 8, 3)).astype(np.float32)
    w = (RNG.normal(size=(3, 3, 3, cout)) * 0.3).astype(np.float32)
    b = (RNG.normal(size=(cout,)) * 0.1).astype(np.float32)
    spk, u = input_layer_conv_lif(jnp.asarray(img), jnp.asarray(w), jnp.asarray(b),
                                  num_steps=num_steps, interpret=True)
    patches = im2col(jnp.asarray(img), 3, 3, "SAME")
    rs, ru = dense_conv_lif_ref(patches, jnp.asarray(w.reshape(27, cout)), jnp.asarray(b),
                                num_steps=num_steps, beta=0.15, theta=0.5)
    np.testing.assert_array_equal(np.asarray(spk).reshape(num_steps, -1, cout), np.asarray(rs))
    np.testing.assert_allclose(np.asarray(u).reshape(-1, cout), np.asarray(ru), atol=1e-5)


def test_dense_conv_lif_spikes_binary():
    img = RNG.normal(size=(1, 8, 8, 3)).astype(np.float32)
    w = RNG.normal(size=(3, 3, 3, 32)).astype(np.float32)
    spk, _ = input_layer_conv_lif(jnp.asarray(img), jnp.asarray(w), jnp.zeros(32),
                                  num_steps=3, interpret=True)
    assert set(np.unique(np.asarray(spk))) <= {0.0, 1.0}


# ---------------------------------------------------------------------------
# int4_matmul: W4A16 packed dequant matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [(4, 64, 32), (17, 96, 130), (128, 512, 256)])
def test_int4_matmul_matches_dequant_oracle(m, k, n):
    x = RNG.normal(size=(m, k)).astype(np.float32)
    w = RNG.normal(size=(k, n)).astype(np.float32)
    qt = quantize_int4(jnp.asarray(w), axis=-1)
    out = w4a16_linear(jnp.asarray(x), qt, interpret=True)
    ref = int4_matmul_ref(jnp.asarray(x), qt)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-3)


def test_int4_matmul_batched_input():
    x = RNG.normal(size=(2, 3, 64)).astype(np.float32)
    qt = quantize_int4(jnp.asarray(RNG.normal(size=(64, 48)).astype(np.float32)))
    out = w4a16_linear(jnp.asarray(x), qt, interpret=True)
    assert out.shape == (2, 3, 48)


# ---------------------------------------------------------------------------
# lif_step: fused elementwise LIF update
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(8,), (3, 7, 11), (2, 32, 32, 16)])
def test_lif_update_matches_core(shape):
    u = RNG.normal(size=shape).astype(np.float32)
    cur = RNG.normal(size=shape).astype(np.float32)
    sp = (RNG.random(shape) < 0.3).astype(np.float32)
    un, sn = lif_update(jnp.asarray(u), jnp.asarray(cur), jnp.asarray(sp), interpret=True)
    ur, sr = lif_step_ref(jnp.asarray(u), jnp.asarray(cur), jnp.asarray(sp),
                          beta=0.15, theta=0.5)
    np.testing.assert_allclose(np.asarray(un), np.asarray(ur), atol=1e-6)
    np.testing.assert_array_equal(np.asarray(sn), np.asarray(sr))
