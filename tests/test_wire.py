"""Wire codec + submit-boundary validation tests.

Property-style: random value trees (nested dicts/lists/tuples, numpy
arrays of several dtypes, NaN/Inf floats, bytes, non-string dict keys)
must survive a full pack -> bytes -> unpack round trip *bit-exactly* —
that property is what lets the router assert replayed outputs identical
across process boundaries. Plus the protocol's refusal paths: version
mismatch, unknown message types/fields/tags, truncated frames, and the
`RequestOptions` submit-boundary validation the wire shares with
`EngineCore.submit`.
"""
import io
import math
import random

import numpy as np
import pytest

from repro.serve import wire
from repro.serve.api import (Request, RequestOptions, Result, SubmitSpec,
                             validate_options)
from repro.serve.sampling import SamplingParams
from repro.serve.wire import (MESSAGE_TYPES, PROTOCOL_VERSION, AckMsg,
                              HeartbeatMsg, HelloMsg, PartialMsg, PollMsg,
                              ProtocolError, ResultMsg, StepMsg, SubmitMsg,
                              decode_value, encode_value, pack, read_frame,
                              request_from_wire, request_to_wire,
                              result_from_wire, result_to_wire, unpack,
                              write_frame)

# ---------------------------------------------------------------------------
# helpers: random trees + NaN-aware, dtype-exact deep equality
# ---------------------------------------------------------------------------

_DTYPES = (np.float32, np.float64, np.int32, np.int64, np.uint8, np.bool_)


def random_value(rng, depth=0):
    kinds = ["int", "float", "special", "str", "none", "bool", "bytes", "nd"]
    if depth < 3:
        kinds += ["list", "tuple", "dict", "oddmap"] * 2
    kind = rng.choice(kinds)
    if kind == "int":
        return rng.randrange(-(2 ** 40), 2 ** 40)
    if kind == "float":
        return rng.uniform(-1e12, 1e12)
    if kind == "special":
        return rng.choice([math.nan, math.inf, -math.inf, -0.0])
    if kind == "str":
        return "".join(rng.choice("abc_ é☃") for _ in range(rng.randrange(8)))
    if kind == "none":
        return None
    if kind == "bool":
        return rng.random() < 0.5
    if kind == "bytes":
        return bytes(rng.randrange(256) for _ in range(rng.randrange(12)))
    if kind == "nd":
        dtype = rng.choice(_DTYPES)
        shape = tuple(rng.randrange(1, 4) for _ in range(rng.randrange(3)))
        arr = np.array(rng.random()) * np.ones(shape)
        if np.issubdtype(dtype, np.floating) and rng.random() < 0.5:
            arr = arr * rng.choice([math.nan, math.inf, 1.0])
        return (arr * 100).astype(dtype)
    if kind == "list":
        return [random_value(rng, depth + 1) for _ in range(rng.randrange(4))]
    if kind == "tuple":
        return tuple(random_value(rng, depth + 1)
                     for _ in range(rng.randrange(4)))
    if kind == "dict":
        return {f"k{i}": random_value(rng, depth + 1)
                for i in range(rng.randrange(4))}
    # mapping that needs the __map__ escape: int and tag-like string keys
    return {rng.choice([rng.randrange(100), "__nd__", "__weird__"]):
            random_value(rng, depth + 1)}


def deep_equal(a, b):
    if isinstance(a, (np.ndarray, np.generic)) or isinstance(
            b, (np.ndarray, np.generic)):
        # the codec normalizes numpy scalars to their 0-d array form
        if not (isinstance(a, (np.ndarray, np.generic))
                and isinstance(b, (np.ndarray, np.generic))):
            return False
        a, b = np.asarray(a), np.asarray(b)
        return (a.dtype == b.dtype and a.shape == b.shape
                and a.tobytes() == b.tobytes())       # bit-exact, NaN-proof
    if isinstance(a, float) and isinstance(b, float):
        if math.isnan(a) or math.isnan(b):
            return math.isnan(a) and math.isnan(b)
        return a == b and math.copysign(1, a) == math.copysign(1, b)
    if type(a) is not type(b):
        return False
    if isinstance(a, (list, tuple)):
        return len(a) == len(b) and all(map(deep_equal, a, b))
    if isinstance(a, dict):
        return set(a) == set(b) and all(deep_equal(a[k], b[k]) for k in a)
    return a == b


def roundtrip(value):
    msg = unpack(pack(ResultMsg(rid=7, outputs=value, stats={"x": 1})))
    return msg.outputs


# ---------------------------------------------------------------------------
# codec round trips
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_random_trees_roundtrip_bit_exact(seed):
    rng = random.Random(seed)
    for _ in range(60):
        value = random_value(rng)
        assert deep_equal(roundtrip(value), value), value


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_request_and_result_roundtrip(seed):
    rng = random.Random(1000 + seed)
    request = Request(request_id=rng.randrange(100),
                      payload=random_value(rng),
                      options={"max_new_tokens": rng.randrange(8),
                               "seed": seed},
                      deadline_s=rng.choice([None, 12.5]),
                      priority=rng.randrange(-2, 3),
                      arrival_s=rng.random())
    back = request_from_wire(request_to_wire(request))
    assert back.request_id == request.request_id
    assert deep_equal(back.payload, request.payload)
    assert dict(back.options) == dict(request.options)
    assert back.deadline_s == request.deadline_s
    assert back.priority == request.priority

    result = Result(request_id=rng.randrange(100),
                    outputs=random_value(rng),
                    stats={"cost": {"flops": math.nan, "bytes": math.inf},
                           "probe": np.float32(3.25),
                           "tree": random_value(rng)},
                    status=rng.choice(["ok", "failed", "expired"]))
    back = result_from_wire(result_to_wire(result))
    assert back.request_id == result.request_id and back.status == result.status
    assert deep_equal(back.outputs, result.outputs)
    assert math.isnan(back.stats["cost"]["flops"])
    assert back.stats["cost"]["bytes"] == math.inf
    assert deep_equal(back.stats["tree"], result.stats["tree"])


def test_numpy_payload_bit_exact_including_nan_patterns():
    # two distinct NaN bit patterns must survive: the codec moves raw bytes
    raw = np.array([0x7FC00001, 0x7FC00002], dtype=np.uint32).view(np.float32)
    out = roundtrip(raw)
    assert out.tobytes() == raw.tobytes()


def test_every_message_type_roundtrips():
    for cls in MESSAGE_TYPES.values():
        msg = cls()
        assert unpack(pack(msg)) == msg
    # and with non-default content on the workhorses
    for msg in (SubmitMsg(payload=[1, 2], deadline_s=3.0, priority=-1,
                          options={"max_new_tokens": 4}),
                HeartbeatMsg(seq=9, marker=(1, 2, 3, 4), failed=1,
                             cost_finite=False, in_flight=2, pending=1,
                             stats={"ok": 3}),
                PartialMsg(rid=5, items=(("tok", 7), ("tok", 8))),
                AckMsg(ok=False, rid=3, error="QueueFull: full")):
        back = unpack(pack(msg))
        assert back == msg
        assert isinstance(back.__class__, type(msg.__class__))
    # tuples come back as tuples, not lists (marker identity matters)
    hb = unpack(pack(HeartbeatMsg(marker=(1, 2, 3, 4))))
    assert hb.marker == (1, 2, 3, 4) and isinstance(hb.marker, tuple)


# ---------------------------------------------------------------------------
# refusal paths
# ---------------------------------------------------------------------------

def test_version_mismatch_rejected_naming_both_versions():
    frame = pack(StepMsg(seq=1), version=PROTOCOL_VERSION + 41)
    with pytest.raises(ProtocolError) as exc:
        unpack(frame)
    assert f"v{PROTOCOL_VERSION + 41}" in str(exc.value)
    assert f"v{PROTOCOL_VERSION}" in str(exc.value)


def test_unknown_message_type_and_fields_rejected():
    bad = pack(PollMsg(rid=1)).replace(b'"poll"', b'"gossip"')
    with pytest.raises(ProtocolError, match="unknown wire message type"):
        unpack(bad)
    bad = pack(PollMsg(rid=1)).replace(b'"rid"', b'"rip"')
    with pytest.raises(ProtocolError, match="unknown fields"):
        unpack(bad)


def test_unknown_value_tag_and_unencodable_rejected():
    with pytest.raises(ProtocolError, match="unknown wire value tag"):
        decode_value({"__hologram__": [1, 2]})
    with pytest.raises(ProtocolError, match="cannot encode"):
        encode_value(object())
    with pytest.raises(ProtocolError, match="not a wire message"):
        pack(Request(0, []))


def test_framing_eof_and_truncation():
    buf = io.BytesIO()
    write_frame(buf, HelloMsg(runner={"kind": "stub"}))
    write_frame(buf, StepMsg(seq=2))
    data = buf.getvalue()
    stream = io.BytesIO(data)
    assert isinstance(read_frame(stream), HelloMsg)
    assert read_frame(stream) == StepMsg(seq=2)
    assert read_frame(stream) is None          # clean EOF between frames
    cut = io.BytesIO(data[:-3])                # second frame loses its tail
    assert isinstance(read_frame(cut), HelloMsg)
    with pytest.raises(ProtocolError, match="truncated"):
        read_frame(cut)                        # mid-frame EOF is loud


# ---------------------------------------------------------------------------
# submit-boundary validation (RequestOptions / SubmitSpec)
# ---------------------------------------------------------------------------

def test_request_options_rejects_unknown_and_ill_typed():
    with pytest.raises(ValueError, match=r"unknown request option\(s\).*bogus"):
        RequestOptions.parse({"bogus": 1})
    for key, value in [("max_new_tokens", -1), ("temperature", -0.5),
                       ("top_p", 0.0), ("top_p", 1.5), ("top_k", "many"),
                       ("logprobs", 1), ("pin_precision", "int8"),
                       ("skip_hint", 2.0), ("seed", 1.5)]:
        with pytest.raises(ValueError, match=key):
            RequestOptions.parse({key: value})
    opts = validate_options({"temperature": 1, "top_k": 3})
    assert opts == {"temperature": 1, "top_k": 3}


def test_request_options_present_tracking_drives_sampling_opt_in():
    assert RequestOptions.parse({}).sampling is None
    assert RequestOptions.parse({"max_new_tokens": 4}).sampling is None
    # present-with-default is observably different from absent
    params = RequestOptions.parse({"temperature": 0.0}).sampling
    assert params == SamplingParams()
    assert RequestOptions.parse({"logprobs": True}).sampling.track_logprobs


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_from_options_matches_request_options_sampling(seed):
    rng = random.Random(seed)
    for _ in range(40):
        opts = {}
        if rng.random() < 0.7:
            opts["temperature"] = rng.choice([0.0, 0.5, 1.0])
        if rng.random() < 0.5:
            opts["top_k"] = rng.randrange(5)
        if rng.random() < 0.5:
            opts["top_p"] = rng.choice([0.3, 1.0])
        if rng.random() < 0.3:
            opts["seed"] = rng.randrange(100)
        if rng.random() < 0.3:
            opts["logprobs"] = rng.random() < 0.5
        if rng.random() < 0.3:
            opts["max_new_tokens"] = rng.randrange(8)   # non-sampling key
        assert (SamplingParams.from_options(opts)
                == RequestOptions.parse(opts).sampling)


def test_submit_spec_merges_and_validates():
    spec = SubmitSpec.make([1, 2], deadline_s=3, priority=2,
                           options={"top_k": 1}, temperature=0.5)
    assert spec.deadline_s == 3.0 and spec.priority == 2
    assert spec.options == {"top_k": 1, "temperature": 0.5}
    # loose kwargs win on conflict
    assert SubmitSpec.make(0, options={"top_k": 1},
                           top_k=7).options["top_k"] == 7
    with pytest.raises(ValueError, match="deadline_s"):
        SubmitSpec.make(0, deadline_s=-1.0)
    with pytest.raises(ValueError, match="unknown request option"):
        SubmitSpec.make(0, tempature=0.5)
    # wire SubmitMsg carries exactly the spec shape
    back = wire.unpack(wire.pack(SubmitMsg.from_spec(spec))).to_spec()
    assert back == spec
