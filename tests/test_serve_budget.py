"""Budgeted session API: chunked prefill, streaming, deadlines, SLO admission.

Coverage for the `StepBudget`/`StepReport` session contract and the
lifecycle built on it:

* chunked prefill is bit-identical to solo prefill for chunk sizes
  {1, 7, exact-length, > length} — chunking regroups the same masked
  per-token launches, so it must never change a logit;
* cancellation mid-prefill reclaims the slot without perturbing neighbours
  (bit-identity vs a trace that never contained the request) and the slot
  serves its next occupant exactly like a fresh one;
* deadline expiry surfaces ``Result.status == 'expired'`` for queued and
  resident requests, on a deterministic step-counting engine clock;
* `poll_partial` streams LM tokens incrementally and per-timestep SNN
  sparsity stats;
* the `SLOScheduler` orders admission by deadline/priority, splits the
  step budget toward slots racing a deadline, composes over the sparsity
  scheduler via ``make_scheduler('slo:sparsity')``, and prices deadlines
  with a chunk-invariant sec-per-*unit* model per workload kind (the step
  model alone mispriced decode work under mixed chunk widths).
"""
import jax
import numpy as np
import pytest

from repro.configs import vgg9_snn
from repro.configs.base import ArchConfig
from repro.models import transformer as tf
from repro.models.vgg9 import init_vgg9
from repro.serve.api import (EngineConfig, Request, SlotProgress, StepBudget,
                             StepReport)
from repro.serve.core import EngineCore, StepClock
from repro.serve.runners.lm import LMRunner
from repro.serve.runners.snn import SNNRunner
from repro.serve.scheduler import (SLOScheduler, SparsityAwareScheduler,
                                   make_scheduler)

LM_CFG = ArchConfig(name="t-budget", family="dense", n_layers=2, d_model=32,
                    n_heads=4, n_kv_heads=2, head_dim=8, d_ff=64, vocab=61,
                    dtype="float32", remat="none", q_chunk=8, kv_chunk=8)
SNN_CFG = vgg9_snn.TINY


@pytest.fixture(scope="module")
def lm_runner():
    params = tf.init_params(jax.random.PRNGKey(0), LM_CFG)
    return LMRunner(LM_CFG, params, max_seq=64)


def _solo(runner, prompt, tokens):
    return runner.run([Request(0, prompt, {"max_new_tokens": tokens})])[0].outputs


def _step_core(runner, **cfg):
    """Engine on the deterministic step-counting clock (`StepClock`)."""
    clock = StepClock()
    core = EngineCore(runner, EngineConfig(**cfg), clock=clock)
    clock.attach(core)
    return core


# ---------------------------------------------------------------------------
# Chunked prefill
# ---------------------------------------------------------------------------

def test_chunked_prefill_bit_identical_all_chunk_sizes(lm_runner):
    """{1, 7, exact-length, > length} x {mid-stream join}: every chunk size
    must reproduce the solo tokens exactly, while a resident decodes."""
    prompt = [int(t) for t in
              np.random.default_rng(0).integers(1, LM_CFG.vocab, size=13)]
    solo = _solo(lm_runner, prompt, 5)
    resident_solo = _solo(lm_runner, [4, 2], 9)
    for chunk in (1, 7, len(prompt), len(prompt) + 8):
        core = EngineCore(lm_runner,
                          EngineConfig(slots=2, prefill_chunk=chunk))
        a = core.submit([4, 2], max_new_tokens=9)
        core.step()
        core.step()                    # a is mid-decode when b joins
        b = core.submit(prompt, max_new_tokens=5)
        results = core.run_until_complete()
        assert results[b].outputs == solo, chunk
        assert results[a].outputs == resident_solo, chunk
        # one chunk per ceil(prompt/chunk) prefill steps, ttft matches
        expect_chunks = -(-len(prompt) // chunk)
        assert results[b].stats["prefill_chunks"] == expect_chunks
        assert results[b].stats["ttft_steps"] == expect_chunks


def test_chunked_prefill_reduces_steps_and_raises_goodput(lm_runner):
    stats = {}
    for chunk in (1, 8):
        core = EngineCore(lm_runner, EngineConfig(slots=2, prefill_chunk=chunk))
        a = core.submit([1, 2], max_new_tokens=12)
        core.step()
        b = core.submit(list(range(1, 25)), max_new_tokens=3)
        core.run_until_complete()
        stats[chunk] = core.stats()
    assert stats[8]["steps_run"] < stats[1]["steps_run"]
    assert (stats[8]["goodput_decode_tok_per_step"]
            > stats[1]["goodput_decode_tok_per_step"])
    # same decode work in both runs
    assert stats[8]["decode_tokens"] == stats[1]["decode_tokens"]


def test_padded_len_equals_prompt_len_under_continuous(lm_runner):
    core = EngineCore(lm_runner, EngineConfig(slots=2, prefill_chunk=4))
    rid = core.submit([9, 9, 4], max_new_tokens=2)
    res = core.run_until_complete()[rid]
    assert res.stats["padded_len"] == res.stats["prompt_len"] == 3


def test_step_budget_units_cap_trims_prefill_extras():
    """A total-units cap trims prefill allowances (never below one token
    per occupied slot), so the scheduler can bound per-step latency."""
    budget = StepBudget(units=5, chunk=4)
    assert budget.for_slot(0) == 4
    boosted = StepBudget(chunk=2, per_slot={1: 6})
    assert boosted.for_slot(0) == 2 and boosted.for_slot(1) == 6


def test_lm_session_honors_units_cap(lm_runner):
    session = lm_runner.open_session(2)
    session.admit(0, Request(0, list(range(1, 20)), {"max_new_tokens": 2}))
    session.admit(1, Request(1, list(range(1, 20)), {"max_new_tokens": 2}))
    report = session.step(StepBudget(units=6, chunk=8))
    assert report.cost["units"] == 6          # 8 + 8 trimmed to the cap
    report = session.step(StepBudget(units=1, chunk=8))
    assert report.cost["units"] == 2          # floor: one token per slot


# ---------------------------------------------------------------------------
# Cancellation
# ---------------------------------------------------------------------------

def test_cancel_mid_prefill_reclaims_slot_without_perturbing(lm_runner):
    """Cancel a joiner mid-prefill: the resident's tokens must be identical
    to a trace that never contained the cancelled request, and the freed
    slot must serve its next occupant exactly like a solo run."""
    reference = EngineCore(lm_runner, EngineConfig(slots=2, prefill_chunk=4))
    ra = reference.submit([4, 2], max_new_tokens=10)
    ref_out = reference.run_until_complete()[ra].outputs

    core = EngineCore(lm_runner, EngineConfig(slots=2, prefill_chunk=4))
    a = core.submit([4, 2], max_new_tokens=10)
    core.step()
    b = core.submit(list(range(1, 30)), max_new_tokens=4)
    core.step()                                # b mid-prefill (chunk 4 of 29)
    assert core.in_flight() == 2
    assert core.cancel(b)
    res_b = core.poll(b)
    assert res_b.status == "cancelled"
    assert res_b.stats["prefill_chunks"] >= 1  # partial progress surfaced
    c = core.submit([7, 7, 7], max_new_tokens=4)   # reuses b's slot
    results = core.run_until_complete()
    assert results[a].outputs == ref_out
    assert results[c].outputs == _solo(lm_runner, [7, 7, 7], 4)


def test_cancel_queued_and_unknown(lm_runner):
    core = EngineCore(lm_runner, EngineConfig(slots=1))
    a = core.submit([1], max_new_tokens=2)
    b = core.submit([2], max_new_tokens=2)     # still queued
    assert core.cancel(b)
    assert core.poll(b).status == "cancelled"
    assert not core.cancel(12345)
    results = core.run_until_complete()
    assert results[a].status == "ok"
    assert core.stats()["cancelled"] == 1


# ---------------------------------------------------------------------------
# Deadlines
# ---------------------------------------------------------------------------

def test_deadline_expiry_queued_and_resident(lm_runner):
    core = _step_core(lm_runner, slots=1)
    # resident: budget far beyond its deadline -> expires mid-decode with
    # partial outputs
    x = core.submit([1, 2, 3], max_new_tokens=30, deadline_s=6)
    # queued: never gets the slot before its deadline
    y = core.submit([5], max_new_tokens=2, deadline_s=3)
    results = core.run_until_complete()
    assert results[x].status == "expired"
    assert results[y].status == "expired"
    assert 3 < len(results[x].outputs) < 33    # partial decode surfaced
    assert results[y].outputs is None
    assert core.stats()["expired"] == 2


def test_no_deadline_means_no_expiry(lm_runner):
    core = _step_core(lm_runner, slots=1)
    rid = core.submit([1, 2], max_new_tokens=4)
    assert core.run_until_complete()[rid].status == "ok"


# ---------------------------------------------------------------------------
# Streaming partials
# ---------------------------------------------------------------------------

def test_poll_partial_streams_lm_tokens(lm_runner):
    core = EngineCore(lm_runner, EngineConfig(slots=1, prefill_chunk=2))
    rid = core.submit([3, 1, 4, 1], max_new_tokens=5)
    streamed = []
    while core.in_flight() or core.pending():
        core.step()
        streamed.extend(core.poll_partial(rid))
    final = core.poll(rid)
    assert final.outputs == [3, 1, 4, 1] + streamed
    assert core.poll_partial(rid) == []        # drained with the result


def test_poll_partial_streams_snn_timestep_stats():
    params = init_vgg9(jax.random.PRNGKey(0), SNN_CFG)
    runner = SNNRunner(SNN_CFG, params)
    core = EngineCore(runner, EngineConfig(slots=2))
    img = jax.random.uniform(jax.random.PRNGKey(2),
                             (SNN_CFG.img_hw, SNN_CFG.img_hw, 3))
    rid = core.submit(img)
    core.step()
    parts = core.poll_partial(rid)
    assert len(parts) == SNN_CFG.timesteps     # one entry per timestep
    for entry in parts:
        assert entry and all(0.0 <= v <= 1.0 for v in entry.values())
    res = core.poll(rid)
    # the streamed trace is the per-request ts_occupancy stat, timestep-major
    for layer, vals in res.stats["ts_occupancy"].items():
        assert [p[layer] for p in parts] == vals


# ---------------------------------------------------------------------------
# SLO scheduler
# ---------------------------------------------------------------------------

def _req(rid, payload=(), deadline_s=None, priority=0, arrival_s=0.0, **opts):
    return Request(rid, list(payload), opts, deadline_s=deadline_s,
                   priority=priority, arrival_s=arrival_s)


def test_slo_select_orders_by_priority_then_deadline():
    sched = SLOScheduler()
    key_fn = lambda r: "k"
    queue = [_req(0, max_new_tokens=4),
             _req(1, deadline_s=50.0, max_new_tokens=4),
             _req(2, deadline_s=10.0, max_new_tokens=4),
             _req(3, deadline_s=90.0, priority=5, max_new_tokens=4)]
    picks = sched.select(queue, 3, key_fn=key_fn, active_key=None)
    # priority 5 first, then tightest deadline, then the next deadline
    assert [r.request_id for r in picks] == [3, 2, 1]
    # remaining slots go to the inner (FIFO) scheduler's picks
    picks = sched.select(queue, 4, key_fn=key_fn, active_key=None)
    assert [r.request_id for r in picks] == [3, 2, 1, 0]


def test_slo_scheduler_meets_deadline_fifo_misses(lm_runner):
    """Two bulk requests ahead of an interactive one with a tight deadline:
    FIFO expires it in the queue; the SLO scheduler admits it first."""
    outcomes = {}
    for scheduler in ("fifo", "slo"):
        core = _step_core(lm_runner, slots=1, scheduler=scheduler)
        bulk = [core.submit([9, 9], max_new_tokens=12) for _ in range(2)]
        inter = core.submit([5], max_new_tokens=2, deadline_s=6.0, priority=1)
        results = core.run_until_complete()
        outcomes[scheduler] = results[inter].status
        assert all(results[b].status == "ok" for b in bulk)
    assert outcomes == {"fifo": "expired", "slo": "ok"}


def test_slo_plan_step_boosts_prefill_chunk_toward_deadline():
    sched = SLOScheduler(boost_cap=32)
    sched.on_report(StepReport(), seconds=1.0, now=1.0)    # learn 1 s/step
    residents = {0: _req(0, payload=[0] * 40, deadline_s=12.0,
                         max_new_tokens=4)}
    progress = {0: SlotProgress(0, "prefill", units_done=0, units_total=44)}
    budget = sched.plan_step(residents, progress, now=2.0,
                             default=StepBudget(chunk=1))
    # 40 prefill tokens, ~6 step slack after decode: chunk must be boosted
    assert budget.for_slot(0) >= 6
    # decode-phase residents keep the default
    progress = {0: SlotProgress(0, "decode", units_done=42, units_total=44)}
    budget = sched.plan_step(residents, progress, now=2.0,
                             default=StepBudget(chunk=1))
    assert budget.for_slot(0) == 1


def test_slo_expire_evicts_only_provably_late():
    sched = SLOScheduler(boost_cap=8)
    sched.on_report(StepReport(), seconds=1.0, now=1.0)
    residents = {0: _req(0, payload=[0] * 8, deadline_s=100.0,
                         max_new_tokens=4),
                 1: _req(1, payload=[0] * 8, deadline_s=3.0,
                         max_new_tokens=40)}
    progress = {
        0: SlotProgress(0, "prefill", units_done=0, units_total=12),
        1: SlotProgress(1, "prefill", units_done=0, units_total=48),
    }
    # slot 0 has plenty of slack; slot 1 needs >= 41 steps for 3 s of slack
    assert sched.expire(residents, progress, now=2.0) == [1]


def test_slo_sec_per_unit_fixes_mixed_chunk_mispricing():
    """Regression: with only the *step*-time model, a 1 s chunk-64 prefill
    step teaches the scheduler 1 s/step, so a decode-only resident (one
    token per step) is priced ~64x slower than reality and gets evicted
    despite having plenty of slack. Learning seconds-per-*unit* from the
    same report prices the decode correctly — the estimate is invariant to
    how the engine chunked the observed work."""
    residents = {0: _req(0, payload=[0] * 8, deadline_s=10.0,
                         max_new_tokens=40)}
    progress = {0: SlotProgress(0, "decode", units_done=12, units_total=48)}

    naive = SLOScheduler()
    naive.on_report(StepReport(), seconds=1.0, now=1.0)    # step model only
    assert naive.expire(residents, progress, now=2.0) == [0]   # mispriced

    sched = SLOScheduler()
    # the same observation, but costed the way LMSession reports it: the
    # 1 s step covered 64 prompt tokens -> 1/64 s per token
    sched.on_report(StepReport(cost={"units": 64, "prompt_tokens": 64}),
                    seconds=1.0, now=1.0)
    assert sched._sec_per_unit["lm"] == pytest.approx(1 / 64)
    # 36 remaining tokens ~ 0.56 s of slack needed, deadline 8 s out: kept
    assert sched.expire(residents, progress, now=2.0) == []
    # per-kind isolation: a slower SNN observation must not reprice LM work
    sched.on_report(StepReport(cost={"units": 4, "timesteps": 4}),
                    seconds=2.0, now=3.0)
    assert sched._sec_per_unit["snn"] == pytest.approx(0.5)
    assert sched._sec_per_unit["lm"] == pytest.approx(1 / 64)
    assert sched.expire(residents, progress, now=2.0) == []


def test_make_scheduler_composes_slo_over_sparsity():
    sched = make_scheduler("slo:sparsity")
    assert isinstance(sched, SLOScheduler)
    assert isinstance(sched.inner, SparsityAwareScheduler)
    assert sched.name == "slo:sparsity"
    with pytest.raises(ValueError):
        make_scheduler("slo:nope")
