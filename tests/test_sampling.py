"""Differential tests for the serving sampling layer (`serve.sampling`).

Three layers of coverage:

* filter semantics on fixed logits against pure-numpy references written
  inline (independent of the implementation's own helpers): temperature
  scaling, top-k with stable tie-breaks, nucleus top-p keeping the
  crossing token, and the composed pipeline;
* determinism: the token at generation index i is a pure function of
  (seed, i, logits) — identical across repeated calls, engine restarts,
  and the router's drain/re-route replay;
* integration: `Result.stats['logprobs']` equals log_softmax of the raw
  per-position logits at the chosen tokens (checked against a manual
  `decode_step` teacher-forcing loop), and temperature -> 0 degenerates to
  the greedy path bit-identically.
"""
import jax
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.models import transformer as tf
from repro.serve import sampling
from repro.serve.api import EngineConfig, Request, StepBudget
from repro.serve.core import EngineCore, StepClock
from repro.serve.runners.lm import LMRunner
from repro.serve.sampling import SamplingParams

CFG = ArchConfig(name="t-sampling", family="dense", n_layers=1, d_model=32,
                 n_heads=4, n_kv_heads=2, head_dim=8, d_ff=64, vocab=31,
                 dtype="float32", remat="none", q_chunk=8, kv_chunk=8)


@pytest.fixture(scope="module")
def params():
    return tf.init_params(jax.random.PRNGKey(0), CFG)


@pytest.fixture(scope="module")
def runner(params):
    return LMRunner(CFG, params, max_seq=32)


def _logits(seed=0, n=16):
    return np.random.default_rng(seed).normal(size=n)


# ---------------------------------------------------------------------------
# Filter semantics vs inline numpy references
# ---------------------------------------------------------------------------

def test_log_softmax_reference():
    x = _logits(1)
    ref = np.log(np.exp(x) / np.exp(x).sum())
    np.testing.assert_allclose(sampling.log_softmax(x), ref, atol=1e-12)
    # stability: a huge offset changes nothing
    np.testing.assert_allclose(sampling.log_softmax(x + 1e4),
                               sampling.log_softmax(x), atol=1e-9)


@pytest.mark.parametrize("k", [1, 3, 7, 15, 16, 0])
def test_top_k_keeps_exactly_k(k):
    x = _logits(2)
    out = sampling.apply_top_k(x, k)
    kept = np.isfinite(out)
    if k == 0 or k >= x.size:
        assert kept.all()
        np.testing.assert_array_equal(out, x)
    else:
        assert kept.sum() == k
        # the kept set is the k largest by value
        ref_kept = set(np.argsort(-x, kind="stable")[:k])
        assert set(np.flatnonzero(kept)) == ref_kept
        np.testing.assert_array_equal(out[kept], x[kept])


def test_top_k_tie_break_is_stable():
    # four-way tie at the top, k=2: the two lowest token ids survive
    x = np.array([-1.0, 5.0, 5.0, 5.0, 5.0, 0.0])
    out = sampling.apply_top_k(x, 2)
    assert set(np.flatnonzero(np.isfinite(out))) == {1, 2}


def test_top_p_reference():
    x = _logits(3)
    p = 0.7
    out = sampling.apply_top_p(x, p)
    # inline reference: sort probs descending, keep the smallest prefix
    # whose cumulative mass reaches p (crossing token kept)
    probs = np.exp(x - x.max())
    probs = probs / probs.sum()
    order = np.argsort(-x, kind="stable")
    cum = np.cumsum(probs[order])
    n_keep = int(np.searchsorted(cum, p, side="left")) + 1
    ref_kept = set(order[:n_keep])
    assert set(np.flatnonzero(np.isfinite(out))) == ref_kept
    assert cum[n_keep - 1] >= p                   # kept mass reaches p
    if n_keep > 1:
        assert cum[n_keep - 2] < p                # smallest such prefix


def test_top_p_always_keeps_top_token():
    x = np.array([0.0, 10.0, 0.0])
    out = sampling.apply_top_p(x, 1e-9)
    assert np.isfinite(out[1])
    assert np.isfinite(out).sum() == 1


def test_top_p_after_top_k_respects_masks():
    x = _logits(4)
    masked = sampling.apply_top_k(x, 5)
    out = sampling.apply_top_p(masked, 0.5)
    # nothing masked by top-k ever comes back
    assert not np.isfinite(out[~np.isfinite(masked)]).any()
    assert np.isfinite(out).sum() >= 1


def test_sample_matches_inline_reference():
    x = _logits(5)
    params = SamplingParams(temperature=0.7, top_k=8, top_p=0.9, seed=123)
    for index in range(6):
        # reference pipeline, written out independently
        y = x / 0.7
        order = np.argsort(-y, kind="stable")
        y_k = np.full_like(y, -np.inf)
        y_k[order[:8]] = y[order[:8]]
        probs = np.exp(y_k - y_k[np.isfinite(y_k)].max())
        probs[~np.isfinite(y_k)] = 0.0
        probs = probs / probs.sum()
        cum = np.cumsum(probs[order])
        n_keep = int(np.searchsorted(cum, 0.9, side="left")) + 1
        y_p = np.full_like(y, -np.inf)
        y_p[order[:n_keep]] = y_k[order[:n_keep]]
        probs = np.exp(y_p - y_p[np.isfinite(y_p)].max())
        probs[~np.isfinite(y_p)] = 0.0
        probs = probs / probs.sum()
        rng = np.random.default_rng(
            np.random.SeedSequence((123, index)))
        ref_tok = int(rng.choice(probs.size, p=probs))
        tok, lp = sampling.sample(x, params, index)
        assert tok == ref_tok
        # logprob comes from the RAW distribution, pre-filter
        np.testing.assert_allclose(lp, sampling.log_softmax(x)[tok],
                                   atol=1e-12)


def test_temperature_zero_is_exact_argmax():
    x = _logits(6)
    x[3] = x.max() + 1.0
    tok, lp = sampling.sample(x, SamplingParams(temperature=0.0), index=0)
    assert tok == 3
    np.testing.assert_allclose(lp, sampling.log_softmax(x)[3], atol=1e-12)
    # tie-break: first maximum, same as np.argmax / the device greedy path
    x2 = np.array([1.0, 7.0, 7.0, 0.0])
    tok2, _ = sampling.sample(x2, SamplingParams(temperature=0.0), index=0)
    assert tok2 == int(np.argmax(x2)) == 1


def test_token_rng_pure_function_of_seed_and_index():
    draws = [sampling.token_rng(9, i).integers(1 << 30) for i in range(4)]
    again = [sampling.token_rng(9, i).integers(1 << 30) for i in range(4)]
    assert draws == again
    assert len(set(draws)) > 1                    # indices are independent
    other = [sampling.token_rng(10, i).integers(1 << 30) for i in range(4)]
    assert draws != other                         # seeds are independent


def test_params_validation_and_opt_in():
    with pytest.raises(AssertionError):
        SamplingParams(temperature=-0.1)
    with pytest.raises(AssertionError):
        SamplingParams(top_p=0.0)
    with pytest.raises(AssertionError):
        SamplingParams(top_k=-1)
    assert SamplingParams.from_options({"max_new_tokens": 4}) is None
    sp = SamplingParams.from_options({"temperature": 0.5, "seed": 3})
    assert sp is not None and not sp.greedy and sp.track_logprobs
    greedy = SamplingParams.from_options({"seed": 3})
    assert greedy.greedy and not greedy.track_logprobs
    assert SamplingParams.from_options({"logprobs": True}).track_logprobs


# ---------------------------------------------------------------------------
# Engine integration: determinism, logprobs, greedy degeneration
# ---------------------------------------------------------------------------

def _serve(runner, prompts, options, slots=2):
    core = EngineCore(runner, EngineConfig(slots=slots))
    ids = [core.submit(p, **o) for p, o in zip(prompts, options)]
    results = core.run_until_complete()
    return [results[i] for i in ids]


PROMPTS = [[1, 2, 3, 4], [7, 5, 3]]


def test_same_seed_identical_across_engine_restarts(runner):
    options = [{"max_new_tokens": 8, "temperature": 0.9, "top_p": 0.9,
                "seed": 40 + i} for i in range(len(PROMPTS))]
    first = _serve(runner, PROMPTS, options)
    second = _serve(runner, PROMPTS, options)    # fresh engine + session
    assert [r.outputs for r in first] == [r.outputs for r in second]
    assert [r.stats["logprobs"] for r in first] == \
        [r.stats["logprobs"] for r in second]
    # a different seed diverges (the distribution is not degenerate)
    other = _serve(runner, PROMPTS,
                   [dict(o, seed=o["seed"] + 100) for o in options])
    assert [r.outputs for r in other] != [r.outputs for r in first]


def test_sampled_replay_bit_identical_under_router_reroute(params):
    """The router re-routes a wedged replica's in-flight sampled request by
    resubmitting the frozen Request — the per-(seed, index) contract makes
    the replayed stream bit-identical to a fault-free run."""
    from repro.serve.faults import parse_fleet_plan
    from repro.serve.router import make_router
    runner = LMRunner(CFG, params, max_seq=32)
    opts = {"max_new_tokens": 6, "temperature": 0.8, "top_k": 12, "seed": 5}

    ref_core = EngineCore(runner, EngineConfig(slots=2), clock=StepClock())
    ref_id = ref_core.submit(PROMPTS[0], **opts)
    ref = ref_core.run_until_complete()[ref_id]

    plans = parse_fleet_plan("0=wedge@4")
    router = make_router(runner, 2, EngineConfig(slots=2), plans=plans,
                         wedge_patience=3)
    rid = router.submit(PROMPTS[0], affinity="a", **opts)
    for _ in range(200):
        router.step()
        if not router._outstanding:
            break
    res = router.poll(rid)
    assert res.status == "ok"
    assert router.stats()["rerouted"] >= 1
    assert res.outputs == ref.outputs
    assert res.stats["logprobs"] == ref.stats["logprobs"]


def test_logprobs_equal_log_softmax_of_chosen_tokens(runner, params):
    """Teacher-force the served stream through a manual `decode_step` loop
    and check every surfaced logprob is log_softmax(raw logits)[token]."""
    opts = {"max_new_tokens": 6, "temperature": 0.7, "top_p": 0.95, "seed": 2}
    res = _serve(runner, [PROMPTS[0]], [opts], slots=1)[0]
    out = res.outputs
    plen = len(PROMPTS[0])
    gen = out[plen:]
    lps = res.stats["logprobs"]
    assert len(lps) == len(gen) == opts["max_new_tokens"]

    cache = tf.init_cache(CFG, 1, 32)
    ref_lps = []
    for pos, tok in enumerate(out[:-1]):
        logits, cache = tf.decode_step(
            params, cache, {"tokens": np.array([[tok]], np.int32)},
            np.array([pos], np.int32), CFG)
        if pos >= plen - 1:           # this distribution selected out[pos+1]
            lsm = sampling.log_softmax(np.asarray(logits[0, -1]))
            ref_lps.append(float(lsm[out[pos + 1]]))
    np.testing.assert_allclose(lps, ref_lps, atol=1e-6)


def test_temperature_zero_request_is_bit_identical_to_greedy(runner):
    plain = _serve(runner, PROMPTS,
                   [{"max_new_tokens": 8}] * len(PROMPTS))
    t0 = _serve(runner, PROMPTS,
                [{"max_new_tokens": 8, "temperature": 0.0, "seed": 77,
                  "logprobs": True} for _ in PROMPTS])
    assert [r.outputs for r in plain] == [r.outputs for r in t0]
    # the greedy path only surfaces logprobs when asked
    assert all("logprobs" not in r.stats for r in plain)
    assert all(len(r.stats["logprobs"]) == 8 for r in t0)


def test_batch_admission_rejects_sampling_options(runner):
    core = EngineCore(runner, EngineConfig(slots=2, admission="batch"))
    core.submit(PROMPTS[0], max_new_tokens=4, temperature=0.5, seed=1)
    with pytest.raises(ValueError, match="greedy-only"):
        core.run_until_complete()
