"""Data-mesh sharded SNN serving: param-tree sharding rules + multi-device
engine equivalence.

The SNN serves data-parallel: conv kernels / LIF parameters replicate while
the folded ``[T*B·H·W, K]`` batch axis shards over ``'data'``. The spec
rules are pure logic (no devices needed); the 2-device engine run executes
in a subprocess with ``XLA_FLAGS`` so the main test process keeps its
single-device view, and must be bit-identical — logits, per-request spike
counts and per-request skip rates — to the 1-device run.
"""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "repro.dist.compression",
    reason="distributed repro.dist package not implemented yet (ROADMAP open item)")

from repro.configs import vgg9_snn
from repro.dist import sharding as shd
from repro.models.vgg9 import init_vgg9


def _run_subprocess(code: str, n_dev: int = 2) -> str:
    env = {"XLA_FLAGS": f"--xla_force_host_platform_device_count={n_dev}",
           "PYTHONPATH": "src", "PATH": "/usr/bin:/bin"}
    import os
    env.update({k: v for k, v in os.environ.items()
                if k not in env and k != "XLA_FLAGS"})
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, cwd=".",
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


class _DataMesh:
    """Spec-rule stand-in for a serving data mesh (no devices needed)."""
    axis_names = ("data",)
    shape = {"data": 2}


def test_snn_param_tree_replicates():
    """Conv kernels, biases and LIF thresholds replicate on a data mesh:
    the weights ride along on every device while the batch shards."""
    mesh = _DataMesh()
    params = jax.eval_shape(lambda: init_vgg9(jax.random.PRNGKey(0), vgg9_snn.TINY))
    specs = shd.param_specs(params, mesh)
    import jax.sharding as js
    for path, spec in jax.tree_util.tree_flatten_with_path(
            specs, is_leaf=lambda x: isinstance(x, js.PartitionSpec))[0]:
        assert tuple(spec) in ((), (None,) * len(tuple(spec))), (path, spec)
    # conv kernel [3,3,cin,cout] replicates even on a model-capable mesh
    class _TP:
        axis_names = ("data", "model")
        shape = {"data": 2, "model": 2}
    spec = shd.param_spec((jax.tree_util.DictKey("conv1"), jax.tree_util.DictKey("w")),
                          jax.ShapeDtypeStruct((3, 3, 8, 12), jnp.float32), _TP())
    assert spec == js.PartitionSpec()
    # per-layer LIF threshold vector: 1-D -> replicated, mesh never consulted
    spec = shd.param_spec((jax.tree_util.DictKey("lif"), jax.tree_util.DictKey("theta")),
                          jax.ShapeDtypeStruct((12,), jnp.float32), None)
    assert spec == js.PartitionSpec()


def test_folded_batch_shards_on_data():
    """The slot batch (leading axis of images and of the folded activations)
    takes the data axis when it divides; odd batches degrade to replicated."""
    from jax.sharding import PartitionSpec as P
    mesh = _DataMesh()
    specs = shd.batch_spec(
        {"images": jax.ShapeDtypeStruct((4, 16, 16, 3), jnp.float32)}, mesh)
    assert specs["images"] == P(("data",), None, None, None)
    odd = shd.batch_spec(
        {"images": jax.ShapeDtypeStruct((3, 16, 16, 3), jnp.float32)}, mesh)
    assert odd["images"] == P()


def test_two_device_engine_bit_identical():
    """EngineCore + SNNRunner under a 2-device data mesh: logits, per-request
    spike counts and skip rates identical to the 1-device run."""
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import vgg9_snn
        from repro.dist.context import compute_mesh
        from repro.launch.mesh import make_data_mesh
        from repro.models.vgg9 import init_vgg9
        from repro.serve.api import EngineConfig
        from repro.serve.core import EngineCore
        from repro.serve.runners.snn import SNNRunner

        cfg = vgg9_snn.TINY
        params = init_vgg9(jax.random.PRNGKey(0), cfg)
        keys = jax.random.split(jax.random.PRNGKey(1), 6)
        imgs = [jax.random.uniform(k, (cfg.img_hw, cfg.img_hw, cfg.in_ch))
                for k in keys]
        imgs[1] = imgs[1] * 0.01     # a near-silent request: sparsity signal

        def serve(mesh):
            runner = SNNRunner(cfg, params, interpret=True)
            core = EngineCore(runner, EngineConfig(slots=4))
            ids = [core.submit(im) for im in imgs]
            if mesh is not None:
                with compute_mesh(mesh):
                    results = core.run_until_complete()
            else:
                results = core.run_until_complete()
            return [results[i] for i in ids]

        solo = serve(None)
        sharded = serve(make_data_mesh(2))
        for a, b in zip(solo, sharded):
            np.testing.assert_array_equal(np.asarray(a.outputs),
                                          np.asarray(b.outputs))
            assert a.stats["spike_total"] == b.stats["spike_total"]
            assert a.stats["out_spikes"] == b.stats["out_spikes"]
            assert a.stats["in_spikes"] == b.stats["in_spikes"]
            assert a.stats["skip_rate"] == b.stats["skip_rate"]
            assert a.stats["energy_j"] == b.stats["energy_j"]
        # the silent request's own-rows sparsity signal survives sharding
        silent = np.mean(list(sharded[1].stats["skip_rate"].values()))
        dense = np.mean(list(sharded[0].stats["skip_rate"].values()))
        assert silent > dense, (silent, dense)
        print("OK")
    """)
    assert "OK" in out


def test_compressed_train_step_threads_residual():
    """A compress_axis train step under shard_map on 4 devices: finite loss,
    residual state becomes non-zero (error feedback is live) and params
    come back replicated-identical across shards."""
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import ArchConfig
        from repro.models import transformer as tf
        from repro.train.optim import adamw
        from repro.train.schedule import constant
        from repro.train.train_step import (init_train_state, make_train_step,
                                            shard_map_compressed_step,
                                            stack_error_state)

        cfg = ArchConfig(name="t", family="dense", n_layers=2, d_model=32,
                         n_heads=4, n_kv_heads=2, head_dim=8, d_ff=64,
                         vocab=64, dtype="float32", remat="none",
                         q_chunk=8, kv_chunk=8)
        mesh = jax.make_mesh((4,), ("data",))
        opt = adamw(weight_decay=0.0)
        inner = make_train_step(lambda p, b: tf.train_loss(p, b, cfg), opt,
                                constant(1e-2), compress_axis="data")
        step = jax.jit(shard_map_compressed_step(inner, mesh))
        params = tf.init_params(jax.random.PRNGKey(0), cfg)
        state = stack_error_state(init_train_state(params, opt, compress=True), 4)
        batch = {"tokens": jnp.zeros((8, 16), jnp.int32),
                 "labels": jnp.ones((8, 16), jnp.int32)}
        state2, metrics = step(state, batch)
        assert np.isfinite(float(metrics["loss"]))
        err_mag = sum(float(jnp.abs(e).sum())
                      for e in jax.tree.leaves(state2["grad_err"]))
        assert err_mag > 0.0, "error feedback residual never populated"
        state3, metrics3 = step(state2, batch)
        assert np.isfinite(float(metrics3["loss"]))
        print("OK")
    """, n_dev=4)
    assert "OK" in out
