"""FPGA energy model calibration (paper Tables I-III, Fig. 4) + TPU roofline."""
import numpy as np

from repro.core.energy import (FP32_POWER, INT4_POWER, energy_per_image,
                               power_model, roofline)
from repro.core.workload import balance_allocation, conv_workload, dense_input_workload, fc_workload


def _vgg9_workloads(spike_scale=1.0):
    """Layer workloads roughly shaped like the paper's CIFAR10 profile."""
    convs = [(112, 40_000), (192, 30_000), (216, 25_000), (480, 15_000),
             (504, 12_000), (560, 8_000)]
    ls = [dense_input_workload("conv0", 32, 32, 64, 2)]
    ls += [conv_workload(f"conv{i+1}", c, 9, s * spike_scale) for i, (c, s) in enumerate(convs)]
    ls += [fc_workload("fc0", 1064, 2_000 * spike_scale), fc_workload("fc1", 1000, 500 * spike_scale)]
    return ls


def test_int4_lower_power_than_fp32():
    assert INT4_POWER.p_per_nc < FP32_POWER.p_per_nc
    assert INT4_POWER.p_mem_per_byte * 1.6e6 < FP32_POWER.p_mem_per_byte * 12.9e6


def test_int4_vs_fp32_energy_ratio_in_paper_band():
    """Paper §V-C: int4 cuts energy 1.7x-3.4x (power + sparsity combined)."""
    ls = _vgg9_workloads()
    alloc = balance_allocation(ls, 60)
    wb_int4 = [1000] + [9 * 100 * 0.5] * 6 + [5e5, 5e5]
    wb_fp32 = [8000] + [9 * 100 * 4.0] * 6 + [4e6, 4e6]
    e4 = energy_per_image(ls, alloc, wb_int4, "int4")
    # fp32 nets also spike ~1.1x more (paper Fig. 1)
    e32 = energy_per_image(_vgg9_workloads(1.1), alloc, wb_fp32, "fp32")
    ratio = e32["energy_j"] / e4["energy_j"]
    assert 1.5 < ratio < 5.0, ratio


def test_direct_vs_rate_energy_gap():
    """Paper Table II: direct T=2 vs rate T=25 -> >10x energy gap.

    Rate coding at T=25 carries ~2.6x the spikes and ~29x the latency-scale
    workload of direct T=2 in the paper's measurement."""
    alloc = [1, 8, 4, 18, 6, 6, 20, 2, 1]   # paper CIFAR10 LW
    wb = [1000] + [9 * 100 * 0.5] * 6 + [5e5, 5e5]
    direct = energy_per_image(_vgg9_workloads(1.0), alloc, wb, "int4")
    rate = energy_per_image(_vgg9_workloads(2.6 * 25 / 2), alloc, wb, "int4")
    assert rate["energy_j"] / direct["energy_j"] > 10


def test_latency_scales_with_clock_and_cores():
    ls = _vgg9_workloads()
    a1 = balance_allocation(ls, 30)
    e1 = energy_per_image(ls, a1, [1e4] * 9, "int4")
    e2 = energy_per_image(ls, [2 * a for a in a1], [1e4] * 9, "int4")
    np.testing.assert_allclose(e2["latency_s"], e1["latency_s"] / 2, rtol=1e-9)


def test_roofline_terms_and_dominance():
    r = roofline(flops=1e15, bytes_hbm=1e12, coll_bytes=0, chips=256)
    assert r.dominant in ("compute", "memory")
    assert r.bound == max(r.t_comp, r.t_mem)
    r2 = roofline(flops=1e12, bytes_hbm=1e9, coll_bytes=1e12, chips=256)
    assert r2.dominant == "collective"
