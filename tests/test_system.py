"""End-to-end behaviour tests for the paper's system.

The paper's central claims, reproduced at CPU scale on synthetic data:
  1. the hybrid SNN trains (surrogate-gradient BPTT) to above-chance accuracy;
  2. int4 QAT holds accuracy near fp32 while changing total spikes (Fig. 1);
  3. direct coding beats rate coding in accuracy and spikes-per-inference at
     far fewer timesteps (Table II);
  4. the hybrid kernel path and the energy model connect: fewer spikes ->
     less event-driven work -> less energy (Eq. 3 + §V-C).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import vgg9_snn
from repro.core.energy import energy_per_image
from repro.core.hybrid import plan_hybrid
from repro.data.synthetic import image_batch
from repro.models.vgg9 import init_vgg9, vgg9_forward, vgg9_loss
from repro.train.optim import adamw
from repro.train.schedule import constant
from repro.train.train_step import init_train_state, make_train_step

CFG = dataclasses.replace(vgg9_snn.TINY, num_classes=4)


def _train(cfg, steps=60, seed=0, rate_rng=False):
    opt = adamw(weight_decay=0.0)

    def loss_fn(params, batch):
        rng = batch.get("rng")
        return vgg9_loss(params, batch, cfg, rng=rng)

    step = jax.jit(make_train_step(loss_fn, opt, constant(2e-3)))
    params = init_vgg9(jax.random.PRNGKey(seed), cfg)
    state = init_train_state(params, opt)
    for i in range(steps):
        b = image_batch(seed, i, 32, num_classes=cfg.num_classes, hw=cfg.img_hw)
        if rate_rng:
            b["rng"] = jax.random.fold_in(jax.random.PRNGKey(7), i)
        state, metrics = step(state, b)
    return state["params"], float(metrics["loss"])


def _accuracy_and_spikes(params, cfg, seed=99, n=4):
    correct = total = 0
    spikes = 0.0
    for i in range(n):
        b = image_batch(seed, i, 32, num_classes=cfg.num_classes, hw=cfg.img_hw)
        rng = jax.random.fold_in(jax.random.PRNGKey(11), i) if cfg.coding == "rate" else None
        logits, counts = vgg9_forward(params, b["images"], cfg, rng=rng)
        correct += int((jnp.argmax(logits, -1) == b["labels"]).sum())
        total += 32
        spikes += float(sum(counts.values()))
    return correct / total, spikes / total


@pytest.fixture(scope="module")
def trained():
    params, loss = _train(CFG)
    return params, loss


def test_snn_trains_above_chance(trained):
    params, _ = trained
    acc, _ = _accuracy_and_spikes(params, CFG)
    assert acc > 0.4, acc  # 4-class chance = 0.25


def test_quantization_sparsity_interplay(trained):
    """Fig. 1: int4 sparsifies with small accuracy delta (tiny-scale analogue)."""
    params, _ = trained
    cfg_q = dataclasses.replace(CFG, quant_bits=4)
    acc_f, spk_f = _accuracy_and_spikes(params, CFG)
    acc_q, spk_q = _accuracy_and_spikes(params, cfg_q)
    # accuracy within a few points (paper: <=3.1%); allow tiny-model noise
    assert acc_q > acc_f - 0.15, (acc_q, acc_f)
    # spike count moves; at paper scale int4 has FEWER spikes — at this toy
    # scale we assert the effect is present and bounded rather than its sign
    assert abs(spk_q - spk_f) / spk_f < 0.5


def test_direct_beats_rate_coding():
    """Table II: direct T=2 vs rate T=8 — higher accuracy, fewer spikes."""
    params_d, _ = _train(CFG, steps=60)
    cfg_r = dataclasses.replace(CFG, coding="rate", timesteps=8)
    params_r, _ = _train(cfg_r, steps=60, rate_rng=True)
    acc_d, spk_d = _accuracy_and_spikes(params_d, CFG)
    acc_r, spk_r = _accuracy_and_spikes(params_r, cfg_r)
    assert acc_d >= acc_r - 0.05, (acc_d, acc_r)
    assert spk_d < spk_r, (spk_d, spk_r)  # 2 vs 8 timesteps -> fewer events


def test_spikes_drive_workload_and_energy(trained):
    """Eq. 3 + §V-C: measured spikes -> plan -> energy; fewer spikes ->
    strictly less energy under the same allocation."""
    params, _ = trained
    b = image_batch(5, 0, 16, num_classes=CFG.num_classes, hw=CFG.img_hw)
    _, counts = vgg9_forward(params, b["images"], CFG)
    convs = [c for c in counts if c.startswith("conv")]
    specs = [{"name": "conv0", "kind": "dense_input", "h_out": CFG.img_hw,
              "w_out": CFG.img_hw, "c_out": 8, "timesteps": CFG.timesteps}]
    for c in convs[1:]:
        specs.append({"name": c, "kind": "conv", "c_out": 16, "filter_coeffs": 9})
    specs.append({"name": "fc0", "kind": "fc", "n_out": CFG.fc_dim})
    spike_counts = {k: float(v) for k, v in counts.items()}
    plan = plan_hybrid(specs, spike_counts, budget=24)
    assert plan.layers[0].path == "dense" and all(
        l.path == "sparse" for l in plan.layers[1:])
    assert abs(sum(plan.overheads) - 1.0) < 1e-6

    # energy monotone in spikes
    from repro.core.workload import conv_workload
    ls_lo = [conv_workload("c", 16, 9, spike_counts[convs[1]])]
    ls_hi = [conv_workload("c", 16, 9, spike_counts[convs[1]] * 2)]
    e_lo = energy_per_image(ls_lo, [4], [1e4], "int4")
    e_hi = energy_per_image(ls_hi, [4], [1e4], "int4")
    assert e_hi["energy_j"] > e_lo["energy_j"]
