"""Supervised multi-replica router: balancing, supervision, replay re-route.

Stub-runner coverage (no jax) of every router behavior — load balancing,
session affinity, QueueFull backoff + priority shedding, wedge/raise/NaN
detection, drain + deterministic-replay re-route with partial dedup, retry
budgets, deadline preservation — plus a router-level slot-invariant sweep
and, at the bottom, the ISSUE-6 chaos acceptance test on the real LM
runner: a 3-replica fleet with one replica wedged mid-stream and another
NaN-poisoned completes every in-flight request, re-routed outputs
bit-identical to a fault-free single-replica run.
"""
import jax

from repro.configs.base import ArchConfig
from repro.models import transformer as tf
from repro.serve.api import EngineConfig
from repro.serve.core import EngineCore, StepClock, all_finite
from repro.serve.faults import FaultPlan, flood_queue, parse_fleet_plan
from repro.serve.router import make_router

from test_serve_continuous import StubRunner

CFG = EngineConfig(slots=2, max_queue=4)


def _router(n=3, plans=None, config=CFG, **kw):
    return make_router(StubRunner(), n, config, plans=plans, **kw)


def _payload(steps=2, key="a"):
    return {"key": key, "steps": steps}


def _drive(router, rids, max_steps=400):
    """Step the fleet to completion, draining each request's partial stream
    as a live client would; returns (results, streams)."""
    streams = {rid: [] for rid in rids}
    for _ in range(max_steps):
        router.step()
        for rid in rids:
            streams[rid].extend(router.poll_partial(rid))
        if not router._outstanding:
            break
    assert not router._outstanding, "fleet did not converge"
    return {rid: router.poll(rid) for rid in rids}, streams


# ---------------------------------------------------------------------------
# Placement
# ---------------------------------------------------------------------------

def test_submit_balances_across_replicas():
    router = _router(3)
    for _ in range(6):
        router.submit(_payload())
    placed = [router._placement[rid] for rid in range(6)]
    assert sorted(placed.count(i) for i in range(3)) == [2, 2, 2]
    results = router.run_until_complete()
    assert len(results) == 6
    assert all(r.status == "ok" for r in results.values())


def test_affinity_pins_stream_to_one_replica():
    router = _router(3)
    rids = [router.submit(_payload(), affinity="stream-7") for _ in range(4)]
    assert len({router._placement[r] for r in rids}) == 1
    other = router.submit(_payload())        # un-pinned: balances elsewhere
    assert router._placement[other] != router._placement[rids[0]]
    router.run_until_complete()


def test_queue_full_backs_off_then_places():
    """A full replica queue parks the request router-side; it is placed on
    a later step once capacity frees — submit() never raises."""
    router = _router(1, config=EngineConfig(slots=1, max_queue=1))
    rids = [router.submit(_payload(1)) for _ in range(5)]
    assert len(router._waiting) > 0          # overflow parked, not raised
    results = router.run_until_complete()
    assert sorted(results) == rids
    assert all(r.status == "ok" for r in results.values())


def test_overload_sheds_lowest_priority_as_rejected():
    router = _router(1, config=EngineConfig(slots=1, max_queue=1),
                     max_waiting=3)
    high = [router.submit(_payload(1), priority=5) for _ in range(4)]
    low = [router.submit(_payload(1), priority=0) for _ in range(4)]
    results = router.run_until_complete()
    assert all(results[r].status == "ok" for r in high)
    shed = [r for r in low if results[r].status == "rejected"]
    assert shed and all(results[r].outputs is None for r in shed)
    assert router.stats()["rejected"] == len(shed)


# ---------------------------------------------------------------------------
# Supervision + re-route
# ---------------------------------------------------------------------------

def test_wedged_replica_is_drained_and_rerouted():
    """The heartbeat condemns a busy no-progress replica after
    ``wedge_patience`` steps; its in-flight request replays on a healthy
    replica and completes — partials deduplicated, none lost."""
    router = _router(2, plans={0: FaultPlan.parse("wedge@2")},
                     wedge_patience=3)
    rid = router.submit(_payload(steps=6))
    assert router._placement[rid] == 0
    results, streams = _drive(router, [rid])
    assert results[rid].status == "ok"
    states = {r.idx: r.state for r in router.replicas}
    assert states[0] == "drained" and states[1] == "healthy"
    assert router.replicas[0].condition == "wedged"
    assert router.stats()["rerouted"] == 1
    # replay dedup: the caller sees each emitted item exactly once
    assert streams[rid] == [1, 2, 3, 4, 5, 6]


def test_raise_fault_condemns_replica_and_reroutes():
    router = _router(2, plans={0: FaultPlan.parse("raise@1:message=kaboom")})
    rid = router.submit(_payload(steps=4))
    results = router.run_until_complete()
    assert results[rid].status == "ok"
    assert router.replicas[0].condition == "wedged"
    assert "kaboom" in router.replicas[0].reason


def test_nan_poisoned_request_fails_with_partials_intact():
    """The numerics probe marks the replica POISONED; the poisoned request
    retires ``'failed'`` keeping its clean pre-poison partials, and the
    replica's *other* in-flight request re-routes and completes."""
    router = _router(3, plans={0: FaultPlan.parse("nan@2:slot=0")})
    a = router.submit(_payload(steps=6))                # replica 0, slot 0
    f1 = router.submit(_payload(steps=1))               # load replicas 1, 2
    f2 = router.submit(_payload(steps=1))               # so b lands on 0 too
    b = router.submit(_payload(steps=6))
    assert router._placement[a] == router._placement[b] == 0
    results, streams = _drive(router, [a, f1, f2, b])
    assert results[a].status == "failed"
    assert results[b].status == "ok"
    assert router.replicas[0].condition == "poisoned"
    assert streams[a] == [1, 2] and all_finite(streams[a])   # clean prefix
    assert streams[b] == [1, 2, 3, 4, 5, 6]                  # re-routed, dedup'd


def test_retry_budget_exhaustion_fails_request():
    """Every replica wedges: the request burns its re-route budget and
    retires ``'failed'`` instead of bouncing forever."""
    plans = {i: FaultPlan.parse("wedge@1") for i in range(3)}
    router = _router(3, plans=plans, max_retries=2, wedge_patience=2)
    rid = router.submit(_payload(steps=5))
    results = router.run_until_complete()
    assert results[rid].status == "failed"
    assert all(r.state == "drained" for r in router.replicas)
    assert router.stats()["rerouted"] == 2              # budget, then fail


def test_deadline_preserved_across_reroute():
    """Re-routing recomputes the *remaining* deadline on the shared clock:
    a request whose deadline passes during the wedge expires instead of
    getting a fresh budget on the new replica."""
    router = _router(2, plans={0: FaultPlan.parse("wedge@1")},
                     wedge_patience=8)
    rid = router.submit(_payload(steps=4), deadline_s=6.0)
    results = router.run_until_complete()
    assert results[rid].status == "expired"             # wedge ate the budget


def test_flood_queue_helper_on_router():
    router = _router(2)
    rids = flood_queue(router, _payload(1), count=10)
    assert len(rids) == 10                              # router never raises
    results = router.run_until_complete()
    assert len(results) == 10


def test_router_slot_invariants_under_faults():
    """Fleet-wide leak check: after every supervision round, each replica's
    slot occupancy matches its resident map exactly."""
    plans = parse_fleet_plan("0=wedge@3,1=nan@4:slot=0")
    router = _router(3, plans=plans, wedge_patience=2)
    rids = [router.submit(_payload(steps=4)) for _ in range(9)]
    for _ in range(60):
        router.step()
        for rep in router.replicas:
            occupied = [s.request_id for s in rep.core.slots
                        if s.request_id is not None]
            assert len(occupied) == len(set(occupied))
            assert set(occupied) == set(rep.core._resident)
        if not router._outstanding:
            break
    assert not router._outstanding
    for rid in rids:
        assert router.poll(rid) is not None


def test_stats_surface():
    router = _router(2, plans={0: FaultPlan.parse("wedge@1")},
                     wedge_patience=2)
    router.submit(_payload(steps=3))
    router.run_until_complete()
    stats = router.stats()
    assert stats["healthy"] == 1 and stats["drains"] == 1
    assert [r["state"] for r in stats["replicas"]] == ["drained", "healthy"]
    assert stats["ok"] == 1 and stats["rerouted"] == 1
    assert stats["replicas"][0]["condition"] == "wedged"


# ---------------------------------------------------------------------------
# ISSUE-6 chaos acceptance: real LM runner, 3 replicas, 2 faults
# ---------------------------------------------------------------------------

LM_CFG = ArchConfig(name="t-router", family="dense", n_layers=2, d_model=32,
                    n_heads=4, n_kv_heads=2, head_dim=8, d_ff=64, vocab=61,
                    dtype="float32", remat="none", q_chunk=8, kv_chunk=8)


def test_chaos_lm_wedge_and_poison_bit_identical():
    """3-replica LM fleet; replica 0 wedges mid-stream, replica 1
    NaN-poisons slot 0. Every in-flight request completes: the wedged
    replica's request re-routes and its outputs are bit-identical to a
    fault-free single-replica run; the poisoned request retires 'failed'
    with its clean partial tokens intact."""
    from repro.serve.runners.lm import LMRunner
    params = tf.init_params(jax.random.PRNGKey(0), LM_CFG)
    runner = LMRunner(LM_CFG, params, max_seq=32)
    prompts = [[1, 2, 3, 4], [7, 5, 3], [9, 9]]

    # fault-free single-replica reference
    ref_core = EngineCore(runner, EngineConfig(slots=2), clock=StepClock())
    ref_ids = [ref_core.submit(p, max_new_tokens=6) for p in prompts]
    ref = ref_core.run_until_complete()

    plans = parse_fleet_plan("0=wedge@4,1=nan@4:slot=0")
    router = make_router(runner, 3, EngineConfig(slots=2), plans=plans,
                         wedge_patience=3)
    a = router.submit(prompts[0], max_new_tokens=6, affinity="a")   # replica 0
    b = router.submit(prompts[1], max_new_tokens=6, affinity="b")   # replica 1
    c = router.submit(prompts[2], max_new_tokens=6, affinity="c")   # replica 2
    assert [router._placement[r] for r in (a, b, c)] == [0, 1, 2]

    results, streams = _drive(router, [a, b, c])
    assert set(results) == {a, b, c}

    # wedged replica's request: re-routed, bit-identical to fault-free run
    assert results[a].status == "ok"
    assert results[a].outputs == ref[ref_ids[0]].outputs
    assert router.replicas[0].condition == "wedged"
    assert router.stats()["rerouted"] >= 1

    # poisoned replica's request: retired 'failed', clean partials intact
    assert results[b].status == "failed"
    assert router.replicas[1].condition == "poisoned"
    partials_b = streams[b]
    assert partials_b and all_finite(partials_b)
    ref_b_tokens = ref[ref_ids[1]].outputs[len(prompts[1]):]
    assert partials_b == ref_b_tokens[:len(partials_b)]
    assert len(partials_b) < len(ref_b_tokens)          # genuinely partial

    # untouched replica: business as usual, and A's dedup'd partial stream
    # reassembles the full fault-free decode
    assert results[c].status == "ok"
    assert results[c].outputs == ref[ref_ids[2]].outputs
    assert streams[a] == ref[ref_ids[0]].outputs[len(prompts[0]):]
    assert {r.state for r in router.replicas} == {"drained", "healthy"}
