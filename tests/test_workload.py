"""Eq. 3 workload model + balanced core allocation (paper §V-A)."""
import itertools

import numpy as np

from repro.core.workload import (balance_allocation, conv_workload,
                                 dense_input_workload, fc_workload,
                                 latency_overheads, layer_latencies,
                                 scale_allocation)


def _layers():
    return [
        dense_input_workload("conv0", 32, 32, 64, 2),
        conv_workload("conv1", 112, 9, 50_000),
        conv_workload("conv2", 192, 9, 20_000),
        fc_workload("fc", 1064, 3_000),
    ]


def test_eq3_values():
    w = conv_workload("c", 64, 9, 1000)
    assert w.work == 9 * 64 * 1000
    f = fc_workload("f", 256, 500)
    assert f.work == 256 * 500


def test_balance_is_optimal_vs_bruteforce():
    """Greedy water-filling matches exhaustive min-max search (small case)."""
    layers = _layers()[:3]
    budget = 9
    best = None
    for alloc in itertools.product(range(1, budget), repeat=3):
        if sum(alloc) != budget:
            continue
        t = layer_latencies(layers, alloc).max()
        if best is None or t < best:
            best = t
    greedy = balance_allocation(layers, budget)
    assert sum(greedy) == budget
    np.testing.assert_allclose(layer_latencies(layers, greedy).max(), best, rtol=1e-9)


def test_overheads_sum_to_one():
    layers = _layers()
    alloc = balance_allocation(layers, 20)
    assert abs(latency_overheads(layers, alloc).sum() - 1.0) < 1e-9


def test_perf_scaling_halves_latency():
    layers = _layers()
    lw = balance_allocation(layers, 12)
    perf2 = scale_allocation(lw, 2)
    t1 = layer_latencies(layers, lw).sum()
    t2 = layer_latencies(layers, perf2).sum()
    np.testing.assert_allclose(t2, t1 / 2, rtol=1e-9)


def test_more_spikes_more_cores():
    """The allocator gives more cores to spikier layers (Eq. 3 driven)."""
    layers = [conv_workload("a", 64, 9, 1_000), conv_workload("b", 64, 9, 100_000)]
    alloc = balance_allocation(layers, 20)
    assert alloc[1] > alloc[0]
