"""Per-architecture smoke tests (reduced configs of the same family).

Every assigned architecture instantiates a small same-family config and runs
one forward + one train-grad step + one decode step on CPU, asserting output
shapes and finiteness. Full configs are exercised only via the dry-run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_archs
from repro.models import transformer as tf
from repro.models.frontends import synth_frontend

ARCHS = sorted(all_archs())
B, S = 2, 24


def _reduce(cfg):
    kw = dict(dtype="float32", remat="none", d_model=48, head_dim=12,
              q_chunk=8, kv_chunk=8, mlstm_chunk=8, vocab=101,
              fsdp_experts=False)
    if cfg.d_ff:
        kw["d_ff"] = 96
    if cfg.moe_d_ff:
        kw["moe_d_ff"] = 32
    if cfg.d_rnn:
        kw["d_rnn"] = 48
    if cfg.n_experts:
        kw["n_experts"] = 8
        kw["top_k"] = min(cfg.top_k, 2)
        kw["n_experts_padded"] = 0
    if cfg.window:
        kw["window"] = 8
    if cfg.frontend:
        kw["n_frontend_tokens"] = 4
        kw["d_frontend"] = 16
    period = len(cfg.pattern)
    kw["n_layers"] = 2 * period + len(cfg.tail)
    # head counts stay faithful to the family (GQA ratios preserved)
    return cfg.with_(**kw)


def _batch(cfg, key):
    s_tok = S - (cfg.n_frontend_tokens if cfg.frontend else 0)
    batch = {"tokens": jax.random.randint(key, (B, s_tok), 0, cfg.vocab),
             "labels": jax.random.randint(key, (B, s_tok), 0, cfg.vocab)}
    if cfg.frontend:
        batch["frontend_embeds"] = synth_frontend(key, cfg, B)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_grad(arch):
    cfg = _reduce(all_archs()[arch])
    key = jax.random.PRNGKey(0)
    params = tf.init_params(key, cfg)
    batch = _batch(cfg, key)

    logits, aux = tf.forward(params, batch, cfg)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"

    loss, grads = jax.value_and_grad(tf.train_loss)(params, batch, cfg)
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
    gnorm = sum(jnp.sum(jnp.square(g)) for g in jax.tree.leaves(grads)) ** 0.5
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0, f"{arch}: bad grads"


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch):
    cfg = _reduce(all_archs()[arch])
    key = jax.random.PRNGKey(1)
    params = tf.init_params(key, cfg)
    cache = tf.init_cache(cfg, B, S)
    tok = jax.random.randint(key, (B, 1), 0, cfg.vocab)
    logits, cache2 = tf.decode_step(params, cache, {"tokens": tok}, jnp.int32(2), cfg)
    assert logits.shape == (B, 1, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", ARCHS)
def test_param_count_full_config_band(arch):
    """Full config parameter counts stay within +-40% of the advertised
    size (sanity on the faithfulness of the architecture configs)."""
    from repro.launch.specs import count_params
    cfg = all_archs()[arch]
    expected = {
        "granite-34b": 34e9, "starcoder2-15b": 15e9, "qwen1.5-4b": 4e9,
        "minitron-8b": 8e9, "recurrentgemma-2b": 2.7e9, "musicgen-large": 3.3e9,
        "phi-3-vision-4.2b": 4.2e9, "llama4-maverick-400b-a17b": 400e9,
        "granite-moe-3b-a800m": 3.3e9, "xlstm-125m": 125e6,
    }[arch]
    total, active = count_params(cfg)
    assert 0.6 * expected < total < 1.4 * expected, (arch, total, expected)
    if arch == "llama4-maverick-400b-a17b":
        assert 10e9 < active < 25e9, active   # a17b
    if arch == "granite-moe-3b-a800m":
        assert 0.4e9 < active < 1.4e9, active  # a800m


def test_decode_matches_forward_last_position():
    """Teacher-forced decode over a short prompt reproduces forward logits
    (KV-cache correctness end-to-end)."""
    cfg = _reduce(all_archs()["starcoder2-15b"])
    key = jax.random.PRNGKey(2)
    params = tf.init_params(key, cfg)
    toks = jax.random.randint(key, (B, 6), 0, cfg.vocab)
    logits_full, _ = tf.forward(params, {"tokens": toks}, cfg)
    cache = tf.init_cache(cfg, B, 8)
    outs = []
    for t in range(6):
        lg, cache = tf.decode_step(params, cache, {"tokens": toks[:, t:t + 1]},
                                   jnp.int32(t), cfg)
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(logits_full),
                               rtol=2e-2, atol=2e-3)
