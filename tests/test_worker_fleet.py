"""Multi-process worker fleet tests.

Three layers:

* `serve_connection` driven over in-memory byte streams — the exact
  protocol exchange shape (pushes before the terminal reply, heartbeat
  echoing the step seq) with no subprocess in the loop.
* `SubprocessTransport` against real stub workers — submit/step/poll over
  a pipe, queue-full and option rejection crossing the wire, handshake
  version-mismatch refusal, kill -9 surfacing as `WorkerDied`.
* The supervised router over a worker fleet — a killed worker's in-flight
  requests replay on the survivor; for the LM workload the replayed
  outputs are bit-identical to a fault-free in-process run, the
  acceptance property of the whole process-isolation design.
"""
import dataclasses
import io

import pytest

from repro.configs.base import ArchConfig
from repro.serve.api import EngineConfig, QueueFull, SubmitSpec
from repro.serve.core import EngineCore
from repro.serve.router import make_worker_fleet
from repro.serve.wire import (AckMsg, HeartbeatMsg, HelloMsg, PartialMsg,
                              ProtocolError, ReadyMsg, ResultMsg,
                              ShutdownMsg, StepMsg, SubmitMsg, read_frame,
                              write_frame)
from repro.serve.worker import (RunnerSpec, SubprocessTransport, WorkerDied,
                                build_runner, lm_spec, serve_connection)

STUB = RunnerSpec(kind="stub")
CONFIG = EngineConfig(slots=2, max_queue=4, max_idle_steps=50)


# ---------------------------------------------------------------------------
# serve_connection over in-memory streams: exact protocol shape
# ---------------------------------------------------------------------------

def drive_worker(messages, config=CONFIG):
    inbuf = io.BytesIO()
    write_frame(inbuf, HelloMsg(runner=STUB.to_wire(),
                                config=dataclasses.asdict(config)))
    for msg in messages:
        write_frame(inbuf, msg)
    inbuf.seek(0)
    out = io.BytesIO()
    code = serve_connection(inbuf, out)
    out.seek(0)
    frames = []
    while True:
        frame = read_frame(out)
        if frame is None:
            break
        frames.append(frame)
    return code, frames


def test_protocol_exchange_shape():
    code, frames = drive_worker([SubmitMsg(payload={"steps": 2}),
                                 StepMsg(seq=1), StepMsg(seq=2),
                                 ShutdownMsg()])
    assert code == 0
    ready, ack, *rest = frames
    assert isinstance(ready, ReadyMsg) and ready.workload == "stub"
    assert ack == AckMsg(ok=True, rid=0)
    # step 1: a partial push then the heartbeat echoing seq=1
    assert rest[0] == PartialMsg(rid=0, items=(("tick", 1),))
    assert isinstance(rest[1], HeartbeatMsg) and rest[1].seq == 1
    assert rest[1].in_flight == 1 and rest[1].cost_finite
    # step 2 finishes: partial + result pushes *before* the heartbeat
    assert rest[2] == PartialMsg(rid=0, items=(("tick", 2),))
    assert isinstance(rest[3], ResultMsg)
    assert rest[3].rid == 0 and rest[3].outputs == ("done", 2)
    assert rest[3].status == "ok"
    assert isinstance(rest[4], HeartbeatMsg) and rest[4].seq == 2
    assert rest[4].in_flight == 0
    # shutdown ack is the final frame
    assert rest[5] == AckMsg(ok=True)


def test_worker_eof_is_clean_exit():
    code, frames = drive_worker([SubmitMsg(payload={"steps": 1})])
    assert code == 0                       # parent closing the pipe is fine
    assert isinstance(frames[0], ReadyMsg)


def test_worker_rejects_bad_handshake():
    inbuf = io.BytesIO()
    write_frame(inbuf, StepMsg(seq=1))     # step before hello
    inbuf.seek(0)
    out = io.BytesIO()
    assert serve_connection(inbuf, out) == 2
    out.seek(0)
    reply = read_frame(out)
    assert "expected hello" in reply.error


# ---------------------------------------------------------------------------
# SubprocessTransport against real stub workers
# ---------------------------------------------------------------------------

def test_subprocess_stub_round_trip():
    t = SubprocessTransport(STUB, CONFIG)
    try:
        assert t.stats()["worker_pid"] == t.pid and t.pid > 0
        rid = t.submit_spec(SubmitSpec.make({"steps": 2}))
        assert t.in_flight() == 1          # visible before the first step
        t.step()
        assert t.poll(rid) is None
        t.step()
        res = t.poll(rid)
        assert res.outputs == ("done", 2) and res.status == "ok"
        assert t.poll_partial(rid) == [("tick", 1), ("tick", 2)]
        assert t.in_flight() == 0
        marker = t.progress_marker()
        assert len(marker) == 4 and marker[0] >= 1
        assert t.cost_finite() and t.failed_count() == 0
    finally:
        t.close()
    assert t.proc.returncode == 0          # clean shutdown exchange


def test_queue_full_and_option_rejection_cross_the_wire():
    t = SubprocessTransport(STUB, EngineConfig(slots=1, max_queue=1))
    try:
        t.submit_spec(SubmitSpec.make({"steps": 5}))
        t.step()                           # occupy the slot
        t.submit_spec(SubmitSpec.make({"steps": 5}))
        with pytest.raises(QueueFull):
            t.submit_spec(SubmitSpec.make({"steps": 5}))
        # a raw (client-unvalidated) SubmitSpec still gets rejected by the
        # worker's own submit boundary — validation crosses the wire
        with pytest.raises(ValueError, match="unknown request option"):
            t.submit_spec(SubmitSpec(payload={"steps": 1},
                                     options={"bogus": 1}))
    finally:
        t.close()


def test_handshake_version_mismatch_refused():
    with pytest.raises(ProtocolError, match="rejected handshake.*version"):
        SubprocessTransport(STUB, CONFIG, _hello_version=999)


def test_kill_surfaces_as_workerdied():
    t = SubprocessTransport(STUB, CONFIG, step_timeout_s=10.0)
    rid = t.submit_spec(SubmitSpec.make({"steps": 10}))
    t.step()
    t.kill()
    with pytest.raises(WorkerDied):
        t.step()
    # a dead transport degrades, it does not raise from the read surface
    assert t.cancel(rid) is False
    assert t.poll(rid) is None
    assert t.stats()["worker_dead"] is not None
    with pytest.raises(WorkerDied):
        t.submit_spec(SubmitSpec.make({"steps": 1}))
    t.close()


# ---------------------------------------------------------------------------
# supervised router over worker fleets + chaos
# ---------------------------------------------------------------------------

def test_stub_fleet_reroutes_after_kill():
    router = make_worker_fleet(STUB, 2, CONFIG)
    try:
        rids = [router.submit({"steps": 4}) for _ in range(6)]
        router.step()
        victim = router.replicas[0].transport
        assert victim.in_flight() > 0
        victim.kill()
        results = router.run_until_complete()
        assert [r for r in router.replicas if r.state == "healthy"]
        assert len(router.drain_log) == 1
        for rid in rids:
            assert results[rid].status == "ok"
            assert results[rid].outputs == ("done", 4)
    finally:
        router.close()


LM_CFG = ArchConfig(name="t-fleet", family="dense", n_layers=1, d_model=32,
                    n_heads=4, n_kv_heads=2, head_dim=8, d_ff=64, vocab=31,
                    dtype="float32", remat="none", q_chunk=8, kv_chunk=8)
PROMPTS = [[1, 2, 3], [7, 5, 3, 9], [11, 4], [8, 8, 8]]
TOKENS = 4


def test_lm_fleet_kill_replays_bit_identical():
    """The acceptance property: kill -9 a worker mid-stream and every
    request still completes, bit-identical to a fault-free in-process run
    of the same `RunnerSpec`."""
    spec = lm_spec(LM_CFG, seed=0, max_seq=16)
    config = EngineConfig(slots=2, max_queue=8, max_idle_steps=50)

    reference = EngineCore(build_runner(spec), config)
    ref_ids = [reference.submit(p, max_new_tokens=TOKENS) for p in PROMPTS]
    ref_results = reference.run_until_complete()
    expected = [ref_results[rid].outputs for rid in ref_ids]

    router = make_worker_fleet(spec, 2, config, step_timeout_s=300.0)
    try:
        rids = [router.submit(p, max_new_tokens=TOKENS) for p in PROMPTS]
        for _ in range(2):
            router.step()
        victim = router.replicas[0].transport
        assert victim.in_flight() > 0      # killing a worker with work
        victim.kill()
        results = router.run_until_complete()
    finally:
        router.close()
    assert len(router.drain_log) == 1
    assert router.stats()["rerouted"] >= 1
    for rid, want, prompt in zip(rids, expected, PROMPTS):
        assert results[rid].status == "ok"
        assert list(results[rid].outputs) == list(want), prompt
