"""Unit tests for the declarative CLI flag-compatibility table.

`launch.serve.FLAG_RULES` is the compatibility policy as data; these tests
iterate it directly: every rule has a minimal violating namespace that
fires it (and only it), the table is exhaustively covered by name so a new
rule without a test fails loudly, and known-good combinations pass clean.
"""
import argparse
import sys

import pytest

from repro.launch.serve import FLAG_RULES, check_flags


def ns(**over):
    """A namespace matching the parser's defaults."""
    base = dict(workload="lm", arch="qwen1.5-4b", tokens=16, requests=4,
                slots=4, d_model=64, n_layers=4, vocab=512, seq=64,
                img_hw=0, int4=False, precision="", scheduler="fifo",
                admission="continuous", prefill_chunk=1, slo_ms=0.0,
                replicas=1, fault_plan="", workers=0, speculate=0,
                temperature=0.0, top_k=0, top_p=1.0, mixed_trace=False,
                data_shard=0, seed=0)
    base.update(over)
    return argparse.Namespace(**base)


#: rule name -> a minimal namespace override that violates exactly it
VIOLATIONS = {
    "replicas-range": dict(replicas=0),
    "workers-range": dict(workers=-1),
    "slo-needs-continuous": dict(slo_ms=100.0, admission="batch"),
    "slo-vs-fleet": dict(slo_ms=100.0, replicas=2),
    "precision-vs-int4": dict(precision="adaptive", int4=True),
    "precision-vs-fleet": dict(precision="adaptive", fault_plan="0=wedge@4"),
    "lm-only-knobs": dict(workload="snn", temperature=0.5),
    "sampling-needs-continuous": dict(admission="batch", speculate=2),
    "speculate-vs-precision": dict(speculate=2, precision="fp32"),
    "workers-vs-replicas": dict(workers=2, replicas=2),
    "workers-vs-fault-plan": dict(workers=2, fault_plan="0=wedge@4"),
    "workers-vs-precision": dict(workers=2, precision="adaptive"),
    "workers-vs-slo": dict(workers=2, slo_ms=100.0),
    "workers-vs-data-shard": dict(workers=2, data_shard=2),
}


def test_table_is_well_formed_and_fully_covered():
    names = [rule.name for rule in FLAG_RULES]
    assert len(names) == len(set(names)), "duplicate rule names"
    assert all(rule.error for rule in FLAG_RULES), "rule without a message"
    # exhaustive: a rule added to the table without a violation case (or
    # vice versa) fails here by name
    assert set(names) == set(VIOLATIONS)


def test_defaults_are_accepted():
    assert check_flags(ns()) == []


@pytest.mark.parametrize("name", sorted(VIOLATIONS))
def test_each_rule_fires_exactly_once_on_its_violation(name):
    fired = check_flags(ns(**VIOLATIONS[name]))
    assert [rule.name for rule in fired] == [name]


@pytest.mark.parametrize("over", [
    dict(workers=2),
    dict(workers=2, workload="snn"),
    dict(workers=2, int4=True, speculate=3, temperature=0.7, top_p=0.9),
    dict(workers=2, scheduler="sparsity", mixed_trace=True, workload="snn"),
    dict(replicas=3, fault_plan="0=wedge@4,1=nan@6:slot=0"),
    dict(precision="adaptive", scheduler="sparsity", workload="snn"),
    dict(slo_ms=3000.0, scheduler="slo"),
    dict(speculate=4, temperature=0.8, top_p=0.95),
    dict(data_shard=2, workload="snn"),
])
def test_known_good_combinations_pass(over):
    assert check_flags(ns(**over)) == []


def test_cli_rejects_conflict_with_table_message(monkeypatch, capsys):
    from repro.launch import serve as launch_serve
    monkeypatch.setattr(sys, "argv",
                        ["serve.py", "--workers", "2", "--replicas", "3"])
    with pytest.raises(SystemExit) as exc:
        launch_serve.main()
    assert exc.value.code == 2
    assert "pick one" in capsys.readouterr().err
