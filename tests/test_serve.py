"""Serving engine: batched greedy generation + int4-weight numerics.

Ported off the seed-era `ServeEngine` shim onto `EngineCore` + `LMRunner`
directly; the shim's one-release deprecation alias is now fully removed
(asserted at the bottom).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.models import transformer as tf
from repro.serve.api import EngineConfig
from repro.serve.core import EngineCore
from repro.serve.runners.lm import LMRunner

CFG = ArchConfig(name="t-serve", family="dense", n_layers=2, d_model=32,
                 n_heads=4, n_kv_heads=2, head_dim=8, d_ff=64, vocab=61,
                 dtype="float32", remat="none", q_chunk=8, kv_chunk=8)


def _params():
    return tf.init_params(jax.random.PRNGKey(0), CFG)


def _generate(runner, prompts, num_tokens, slots=4):
    core = EngineCore(runner, EngineConfig(slots=slots))
    ids = [core.submit(p, max_new_tokens=num_tokens) for p in prompts]
    results = core.run_until_complete()
    return [results[i].outputs for i in ids]


def test_generate_shapes_and_determinism():
    runner = LMRunner(CFG, _params(), max_seq=32)
    prompts = [[1, 2, 3], [5], [9, 9], [4]]
    out1 = _generate(runner, prompts, 6)
    out2 = _generate(runner, prompts, 6)
    assert out1 == out2  # greedy decode is deterministic
    for p, o in zip(prompts, out1):
        assert len(o) == len(p) + 6
        assert all(0 <= t < CFG.vocab for t in o)


def test_generate_matches_manual_decode():
    """Engine output == manual decode_step loop (same greedy choices)."""
    params = _params()
    runner = LMRunner(CFG, params, max_seq=32)
    prompt = [3, 7, 1]
    out = _generate(runner, [prompt], 4, slots=1)[0]

    cache = tf.init_cache(CFG, 1, 32)
    toks = jnp.asarray([prompt], jnp.int32)
    nxt = None
    for t in range(3):
        logits, cache = tf.decode_step(params, cache, {"tokens": toks[:, t:t + 1]},
                                       jnp.int32(t), CFG)
        nxt = int(jnp.argmax(logits[0, -1]))
    manual = list(prompt)
    cur = nxt
    for k in range(4):
        manual.append(cur)
        logits, cache = tf.decode_step(params, cache,
                                       {"tokens": jnp.asarray([[cur]], jnp.int32)},
                                       jnp.int32(3 + k), CFG)
        cur = int(jnp.argmax(logits[0, -1]))
    assert out == manual


def test_ragged_prompts_match_solo_decode():
    """Regression: shorter prompts in a ragged batch must decode exactly as
    if served alone. The seed engine teacher-forced them on pad zeros up to
    the batch max prompt length, corrupting their decode state."""
    runner = LMRunner(CFG, _params(), max_seq=32)
    prompts = [[1, 2, 3, 4, 5], [7], [9, 9], [3, 1]]   # unequal lengths
    batched = _generate(runner, prompts, 6)
    for p, got in zip(prompts, batched):
        solo = _generate(runner, [p], 6)[0]
        assert got == solo, (p, got, solo)


def test_int4_serving_quantizes_weights():
    params = _params()
    r16 = LMRunner(CFG, params, max_seq=16)
    r4 = LMRunner(CFG, params, max_seq=16, quant_bits=4)
    # int4 view has coarse weights somewhere in the tree
    quantized_any = False
    for a, b in zip(jax.tree.leaves(r16.params), jax.tree.leaves(r4.params)):
        if a.ndim >= 2 and not np.array_equal(np.asarray(a), np.asarray(b)):
            quantized_any = True
    assert quantized_any
    out = _generate(r4, [[1, 2]], 3, slots=1)[0]
    assert len(out) == 5


def test_serve_engine_alias_removed():
    """PR 5 marked `ServeEngine` one-release; this release removes it: the
    module is gone and the package exports no trace of the name."""
    import repro.serve
    assert not hasattr(repro.serve, "ServeEngine")
    assert "ServeEngine" not in repro.serve.__all__
    with pytest.raises(ModuleNotFoundError):
        import repro.serve.engine  # noqa: F401
