"""Serving engine: batched greedy generation + int4-weight numerics."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import transformer as tf
from repro.serve.engine import ServeEngine

CFG = ArchConfig(name="t-serve", family="dense", n_layers=2, d_model=32,
                 n_heads=4, n_kv_heads=2, head_dim=8, d_ff=64, vocab=61,
                 dtype="float32", remat="none", q_chunk=8, kv_chunk=8)


def _params():
    return tf.init_params(jax.random.PRNGKey(0), CFG)


def test_generate_shapes_and_determinism():
    params = _params()
    engine = ServeEngine(CFG, params, batch_slots=4, max_seq=32)
    prompts = [[1, 2, 3], [5], [9, 9], [4]]
    out1 = engine.generate(prompts, 6)
    out2 = engine.generate(prompts, 6)
    assert out1 == out2  # greedy decode is deterministic
    for p, o in zip(prompts, out1):
        assert len(o) == len(p) + 6
        assert all(0 <= t < CFG.vocab for t in o)


def test_generate_matches_manual_decode():
    """Engine output == manual decode_step loop (same greedy choices)."""
    params = _params()
    engine = ServeEngine(CFG, params, batch_slots=1, max_seq=32)
    prompt = [3, 7, 1]
    out = engine.generate([prompt], 4)[0]

    cache = tf.init_cache(CFG, 1, 32)
    toks = jnp.asarray([prompt], jnp.int32)
    nxt = None
    for t in range(3):
        logits, cache = tf.decode_step(params, cache, {"tokens": toks[:, t:t + 1]},
                                       jnp.int32(t), CFG)
        nxt = int(jnp.argmax(logits[0, -1]))
    manual = list(prompt)
    cur = nxt
    for k in range(4):
        manual.append(cur)
        logits, cache = tf.decode_step(params, cache,
                                       {"tokens": jnp.asarray([[cur]], jnp.int32)},
                                       jnp.int32(3 + k), CFG)
        cur = int(jnp.argmax(logits[0, -1]))
    assert out == manual


def test_ragged_prompts_match_solo_decode():
    """Regression: shorter prompts in a ragged batch must decode exactly as
    if served alone. The seed engine teacher-forced them on pad zeros up to
    the batch max prompt length, corrupting their decode state."""
    params = _params()
    engine = ServeEngine(CFG, params, batch_slots=4, max_seq=32)
    prompts = [[1, 2, 3, 4, 5], [7], [9, 9], [3, 1]]   # unequal lengths
    batched = engine.generate(prompts, 6)
    for p, got in zip(prompts, batched):
        solo = ServeEngine(CFG, params, batch_slots=4, max_seq=32).generate([p], 6)[0]
        assert got == solo, (p, got, solo)


def test_int4_serving_quantizes_weights():
    params = _params()
    e16 = ServeEngine(CFG, params, batch_slots=1, max_seq=16)
    e4 = ServeEngine(CFG, params, batch_slots=1, max_seq=16, quant_bits=4)
    w16 = np.asarray(jax.tree.leaves(e16.params)[0])
    # int4 view has coarse weights somewhere in the tree
    quantized_any = False
    for a, b in zip(jax.tree.leaves(e16.params), jax.tree.leaves(e4.params)):
        if a.ndim >= 2 and not np.array_equal(np.asarray(a), np.asarray(b)):
            quantized_any = True
    assert quantized_any
    out = e4.generate([[1, 2]], 3)[0]
    assert len(out) == 5
