"""Spiking VGG9 (the paper's model): semantics, hybrid kernels, quantization."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import vgg9_snn
from repro.models.vgg9 import (VGG9Config, conv_names, init_vgg9, vgg9_forward,
                               vgg9_infer_hybrid, vgg9_loss, _maxpool_spikes)

CFG = vgg9_snn.TINY


@pytest.fixture(scope="module")
def setup():
    params = init_vgg9(jax.random.PRNGKey(0), CFG)
    imgs = jax.random.uniform(jax.random.PRNGKey(1), (4, CFG.img_hw, CFG.img_hw, 3))
    labels = jnp.array([0, 1, 2, 3])
    return params, imgs, labels


def test_forward_shapes_and_finite(setup):
    params, imgs, _ = setup
    logits, counts = vgg9_forward(params, imgs, CFG)
    assert logits.shape == (4, CFG.num_classes)
    assert bool(jnp.isfinite(logits).all())
    assert set(counts) == set(conv_names(CFG) + ["fc0", "fc1"])
    assert all(float(v) >= 0 for v in counts.values())


def test_grad_flows_through_bptt(setup):
    params, imgs, labels = setup
    loss, grads = jax.value_and_grad(vgg9_loss)(params, {"images": imgs, "labels": labels}, CFG)
    assert bool(jnp.isfinite(loss))
    g0 = float(jnp.abs(grads["conv0"]["w"]).sum())
    assert g0 > 0, "surrogate gradient must reach the input layer"


def test_hybrid_kernels_bitexact_vs_training_path(setup):
    """Dense-core + sparse-core kernel inference == pure-JAX reference."""
    params, imgs, _ = setup
    ref_logits, ref_counts = vgg9_forward(params, imgs, CFG)
    hyb_logits, hyb_counts = vgg9_infer_hybrid(params, imgs, CFG, interpret=True)
    np.testing.assert_array_equal(np.asarray(hyb_logits), np.asarray(ref_logits))
    for k in ref_counts:
        assert int(hyb_counts[k]) == int(ref_counts[k]), k


def test_hoisting_input_conv_is_exact(setup):
    """Direct coding: hoisted input conv == per-timestep recompute."""
    params, imgs, _ = setup
    cfg_hoist = dataclasses.replace(CFG, hoist_input_conv=True)
    cfg_slow = dataclasses.replace(CFG, hoist_input_conv=False)
    a, ca = vgg9_forward(params, imgs, cfg_hoist)
    b, cb = vgg9_forward(params, imgs, cfg_slow)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for k in ca:
        assert int(ca[k]) == int(cb[k])


def test_int4_qat_view_changes_spikes_not_shapes(setup):
    params, imgs, _ = setup
    lq, cq = vgg9_forward(params, imgs, vgg9_snn.TINY_INT4)
    lf, cf = vgg9_forward(params, imgs, CFG)
    assert lq.shape == lf.shape
    assert int(sum(cq.values())) != int(sum(cf.values()))  # quantization moves spikes


def test_rate_coding_runs_and_spikes_scale_with_T(setup):
    params, imgs, _ = setup
    c5 = vgg9_forward(params, imgs, dataclasses.replace(CFG, coding="rate", timesteps=5),
                      rng=jax.random.PRNGKey(2))[1]
    c10 = vgg9_forward(params, imgs, dataclasses.replace(CFG, coding="rate", timesteps=10),
                       rng=jax.random.PRNGKey(2))[1]
    assert sum(float(v) for v in c10.values()) > sum(float(v) for v in c5.values())


def test_maxpool_on_spikes_is_or_gate():
    s = jnp.zeros((1, 4, 4, 1)).at[0, 0, 1, 0].set(1.0)
    out = _maxpool_spikes(s)
    assert out.shape == (1, 2, 2, 1)
    assert float(out[0, 0, 0, 0]) == 1.0     # any spike in window -> 1
    assert float(out[0, 1, 1, 0]) == 0.0
    assert set(np.unique(np.asarray(out))) <= {0.0, 1.0}


def test_population_decoding_shape():
    cfg = dataclasses.replace(CFG, population=64, num_classes=4)
    params = init_vgg9(jax.random.PRNGKey(3), cfg)
    imgs = jax.random.uniform(jax.random.PRNGKey(4), (2, cfg.img_hw, cfg.img_hw, 3))
    logits, _ = vgg9_forward(params, imgs, cfg)
    assert logits.shape == (2, 4)
