"""Property battery for speculative decode (`serve.speculative` + the
`_LMSession` verify/accept/rollback machinery).

The invariant under test everywhere: drafts may only change how many
positions one launch advances — never which tokens come out. Concretely:

* speculative greedy output is bit-identical to plain greedy decode, for
  the real n-gram proposer across >= 4 model seeds AND for adversarial
  stub proposers (all-right / all-wrong / partially-right / empty);
* the acceptance ledger closes exactly: accepted + rejected == drafted,
  per request and in aggregate;
* after rollback, the KV cache and positions match a never-speculated
  session bit-for-bit (the all-wrong proposer rejects every draft, so
  every step exercises the rollback launch);
* speculation composes with the other session invariants: chunked-prefill
  joiners in the same launch, cancel mid-speculation with slot reuse,
  per-step units caps trimming draft tails, and sampled requests.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.models import transformer as tf
from repro.serve.api import EngineConfig, Request, StepBudget
from repro.serve.core import EngineCore
from repro.serve.runners.lm import LMRunner
from repro.serve.speculative import NGramProposer, Proposer

CFG = ArchConfig(name="t-spec", family="dense", n_layers=1, d_model=32,
                 n_heads=4, n_kv_heads=2, head_dim=8, d_ff=64, vocab=31,
                 dtype="float32", remat="none", q_chunk=8, kv_chunk=8)

PROMPTS = [[1, 2, 3, 4], [7, 5, 3], [9, 9]]
TOKENS = 12


@pytest.fixture(scope="module")
def params():
    return tf.init_params(jax.random.PRNGKey(0), CFG)


@pytest.fixture(scope="module")
def plain_runner(params):
    return LMRunner(CFG, params, max_seq=32)


def _serve(runner, prompts, options=None, slots=2, **cfg_kw):
    core = EngineCore(runner, EngineConfig(slots=slots, **cfg_kw))
    options = options or [{"max_new_tokens": TOKENS}] * len(prompts)
    ids = [core.submit(p, **o) for p, o in zip(prompts, options)]
    results = core.run_until_complete()
    return [results[i] for i in ids]


def _assert_ledger(results):
    for r in results:
        s = r.stats
        assert s["accepted_tokens"] + s["rejected_tokens"] \
            == s["drafted_tokens"], s


# ---------------------------------------------------------------------------
# NGramProposer units
# ---------------------------------------------------------------------------

def test_ngram_finds_repeated_continuation():
    p = NGramProposer(max_ngram=3, min_ngram=1, max_k=4)
    #             match ...........v          v trailing 2-gram
    history = [5, 1, 2, 8, 9, 3, 0, 1, 2]
    assert p.propose(history, 4) == [8, 9, 3, 0]


def test_ngram_prefers_longer_ngram_and_most_recent_match():
    p = NGramProposer(max_ngram=2, min_ngram=1, max_k=2)
    # trailing [4, 2]: the 2-gram match at index 2 wins over any 1-gram
    # match on [2] alone
    assert p.propose([9, 9, 4, 2, 7, 7, 4, 2], 2) == [7, 7]
    # two 1-gram matches on [3]: the most recent one (followed by 6) wins
    assert p.propose([3, 5, 3, 6, 1, 3], 2) == [6, 1]


def test_ngram_empty_when_no_match_or_no_room():
    p = NGramProposer()
    assert p.propose([1, 2, 3, 4], 4) == []        # no repeated suffix
    assert p.propose([7], 4) == []                 # history too short
    assert p.propose([1, 2, 1], 0) == []           # k == 0


def test_ngram_respects_max_k():
    p = NGramProposer(max_ngram=1, min_ngram=1, max_k=2)
    assert p.propose([4, 5, 6, 7, 8, 4], 8) == [5, 6]


def test_proposer_protocol():
    assert isinstance(NGramProposer(), Proposer)


def test_speculation_gated_to_kv_architectures(params):
    recurrent = dataclasses.replace(CFG, pattern=("rglru",))
    with pytest.raises(AssertionError, match="rollback"):
        LMRunner(recurrent, params, max_seq=32, speculate_k=2)
    LMRunner(recurrent, params, max_seq=32)        # fine without speculation


# ---------------------------------------------------------------------------
# Bit-identity: real proposer, >= 4 model seeds
# ---------------------------------------------------------------------------

def test_ngram_speculative_bit_identical_across_seeds():
    total_drafted = 0
    for seed in range(4):
        params = tf.init_params(jax.random.PRNGKey(seed), CFG)
        plain = _serve(LMRunner(CFG, params, max_seq=32), PROMPTS)
        spec_results = _serve(
            LMRunner(CFG, params, max_seq=32, speculate_k=4), PROMPTS)
        assert [r.outputs for r in plain] == \
            [r.outputs for r in spec_results], f"seed {seed}"
        _assert_ledger(spec_results)
        total_drafted += sum(r.stats["drafted_tokens"] for r in spec_results)
    # tiny models cycle, so prompt lookup genuinely drafts across the sweep
    assert total_drafted > 0


# ---------------------------------------------------------------------------
# Adversarial proposers: all-right / all-wrong / partially-right / empty
# ---------------------------------------------------------------------------

class OracleProposer:
    """Draft from the precomputed plain-greedy streams, corrupted per mode.

    Greedy emission always follows the plain stream (that is the invariant
    under test), so the history of any slot is a prefix of its stream and
    the true continuation is known exactly."""

    def __init__(self, streams, mode, n_wrong=2):
        self.by_prompt = {tuple(s[:len(p)]): s
                          for p, s in zip(PROMPTS, streams)}
        self.mode = mode
        self.n_wrong = n_wrong

    def propose(self, history, k):
        full = next(s for pfx, s in self.by_prompt.items()
                    if tuple(history[:len(pfx)]) == pfx)
        assert list(history) == full[:len(history)], (
            "emitted stream diverged from plain greedy")
        right = full[len(history):len(history) + k]
        if self.mode == "empty" or not right:
            return []
        wrong = [(t + 1) % CFG.vocab for t in right]
        if self.mode == "all_right":
            return right
        if self.mode == "all_wrong":
            return wrong
        split = max(0, len(right) - self.n_wrong)   # partially right
        return right[:split] + wrong[split:]


@pytest.fixture(scope="module")
def plain_streams(plain_runner):
    return [r.outputs for r in _serve(plain_runner, PROMPTS)]


@pytest.mark.parametrize("mode", ["all_right", "all_wrong",
                                  "partially_right", "empty"])
def test_adversarial_drafts_bit_identical(params, plain_streams, mode):
    runner = LMRunner(CFG, params, max_seq=32, speculate_k=4,
                      proposer=OracleProposer(plain_streams, mode))
    results = _serve(runner, PROMPTS)
    assert [r.outputs for r in results] == plain_streams
    _assert_ledger(results)
    drafted = sum(r.stats["drafted_tokens"] for r in results)
    accepted = sum(r.stats["accepted_tokens"] for r in results)
    if mode == "empty":
        assert drafted == 0
    elif mode == "all_right":
        assert drafted > 0 and accepted == drafted
    elif mode == "all_wrong":
        assert drafted > 0 and accepted == 0
    else:
        assert 0 < accepted < drafted


def test_random_drafts_bit_identical(params, plain_streams):
    """Random token drafts across >= 4 draft seeds: whatever junk the
    proposer offers, the emitted stream never moves."""
    class RandomProposer:
        def __init__(self, seed):
            self.rng = np.random.default_rng(seed)

        def propose(self, history, k):
            n = int(self.rng.integers(0, k + 1))
            return [int(t) for t in self.rng.integers(0, CFG.vocab, size=n)]

    for seed in range(4):
        runner = LMRunner(CFG, params, max_seq=32, speculate_k=4,
                          proposer=RandomProposer(seed))
        results = _serve(runner, PROMPTS)
        assert [r.outputs for r in results] == plain_streams, f"seed {seed}"
        _assert_ledger(results)


# ---------------------------------------------------------------------------
# Rollback: KV cache / positions match a never-speculated session
# ---------------------------------------------------------------------------

def _assert_caches_equal(a, b):
    la, ta = jax.tree.flatten(a)
    lb, tb = jax.tree.flatten(b)
    assert ta == tb
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_rollback_cache_and_positions_match_plain_session(
        params, plain_streams):
    """All-wrong drafts force a rollback on every verify step; the session's
    KV cache and position vector must end bit-identical to a session that
    never speculated."""
    spec_runner = LMRunner(CFG, params, max_seq=32, speculate_k=4,
                           proposer=OracleProposer(plain_streams, "all_wrong"))
    plain_sess = LMRunner(CFG, params, max_seq=32).open_session(slots=2)
    spec_sess = spec_runner.open_session(slots=2)
    for sess in (plain_sess, spec_sess):
        sess.admit(0, Request(0, PROMPTS[0], {"max_new_tokens": TOKENS}))
        sess.admit(1, Request(1, PROMPTS[1], {"max_new_tokens": TOKENS}))
        done = 0
        for _ in range(100):
            done += len(sess.step(StepBudget()).finished)
            if done == 2:
                break
        assert done == 2
    assert spec_sess.out == plain_sess.out
    assert spec_sess.pos == plain_sess.pos
    assert sum(spec_sess.rejected) == sum(spec_sess.drafted) > 0
    _assert_caches_equal(spec_sess.cache, plain_sess.cache)


def test_accepted_prefix_cache_matches_plain_session(params, plain_streams):
    """The accept path too: partially-right drafts leave accepted KV
    entries in place and zero only the rejected suffix."""
    spec_runner = LMRunner(
        CFG, params, max_seq=32, speculate_k=4,
        proposer=OracleProposer(plain_streams, "partially_right"))
    plain_sess = LMRunner(CFG, params, max_seq=32).open_session(slots=1)
    spec_sess = spec_runner.open_session(slots=1)
    for sess in (plain_sess, spec_sess):
        sess.admit(0, Request(0, PROMPTS[0], {"max_new_tokens": TOKENS}))
        for _ in range(100):
            if sess.step(StepBudget()).finished:
                break
    assert spec_sess.out == plain_sess.out
    assert spec_sess.accepted[0] > 0 and spec_sess.rejected[0] > 0
    _assert_caches_equal(spec_sess.cache, plain_sess.cache)


# ---------------------------------------------------------------------------
# Composition: chunked prefill, cancel, budget caps, sampling
# ---------------------------------------------------------------------------

def test_speculative_rows_coexist_with_chunked_prefill_joiner(params):
    """A long prompt prefills in chunks inside the same launches whose
    other rows are speculatively verifying — outputs bit-identical to the
    plain engine on the same trace."""
    long_prompt = [int(t) for t in
                   np.random.default_rng(0).integers(1, CFG.vocab, size=14)]
    prompts = [PROMPTS[0], PROMPTS[1], long_prompt]
    opts = [{"max_new_tokens": TOKENS}] * 3
    plain = _serve(LMRunner(CFG, params, max_seq=32), prompts, opts,
                   slots=2, prefill_chunk=4)
    spec = _serve(LMRunner(CFG, params, max_seq=32, speculate_k=4), prompts,
                  opts, slots=2, prefill_chunk=4)
    assert [r.outputs for r in plain] == [r.outputs for r in spec]
    _assert_ledger(spec)


def test_cancel_mid_speculation_reclaims_slot_cleanly(params, plain_streams):
    """Cancel a slot while its drafts are mid-flight; the next occupant of
    that slot decodes bit-identically to a solo run (no speculative KV
    leakage through the stale-reset / position-masking path)."""
    runner = LMRunner(CFG, params, max_seq=32, speculate_k=4,
                      proposer=OracleProposer(plain_streams, "all_wrong"))
    sess = runner.open_session(slots=2)
    sess.admit(0, Request(0, PROMPTS[0], {"max_new_tokens": TOKENS}))
    sess.admit(1, Request(1, PROMPTS[1], {"max_new_tokens": TOKENS}))
    # step until slot 0 has speculated (and had drafts rejected) at least once
    for _ in range(20):
        sess.step(StepBudget())
        if sess.drafted[0] > 0:
            break
    assert sess.drafted[0] > 0
    res = sess.cancel(0)
    assert res.status == "cancelled"
    assert res.stats["rejected_tokens"] == res.stats["drafted_tokens"] > 0

    # reuse the slot: new occupant must match its plain solo stream
    sess.admit(0, Request(2, PROMPTS[2], {"max_new_tokens": TOKENS}))
    outs = {}
    for _ in range(100):
        outs.update(sess.step(StepBudget()).finished)
        if len(outs) == 2:
            break
    assert outs[0].outputs == plain_streams[2]
    assert outs[1].outputs == plain_streams[1]


def test_units_cap_trims_draft_tails(params):
    """A tight per-step units budget trims speculative drafts (never below
    one token per slot) exactly like it trims prefill chunks."""
    class ConstantProposer:
        def propose(self, history, k):
            return [0] * k

    runner = LMRunner(CFG, params, max_seq=32, speculate_k=4,
                      proposer=ConstantProposer())
    sess = runner.open_session(slots=2)
    sess.admit(0, Request(0, [3], {"max_new_tokens": TOKENS}))
    sess.admit(1, Request(1, [5], {"max_new_tokens": TOKENS}))
    sess.step(StepBudget())                 # consume the 1-token prompts

    rep = sess.step(StepBudget(units=2))    # cap == slots: no room to draft
    assert rep.cost["units"] == 2
    assert rep.cost["drafted_tokens"] == 0

    rep = sess.step(StepBudget(units=4))    # room for a trimmed draft only
    assert rep.cost["units"] == 4
    assert 0 < rep.cost["drafted_tokens"] <= 2

    rep = sess.step(StepBudget())           # uncapped: full drafts
    assert rep.cost["drafted_tokens"] == 8


def test_sampled_requests_speculate_bit_identically(params):
    opts = [{"max_new_tokens": TOKENS, "temperature": 0.8, "top_p": 0.9,
             "seed": 11 + i} for i in range(len(PROMPTS))]
    plain = _serve(LMRunner(CFG, params, max_seq=32), PROMPTS, opts)
    spec = _serve(LMRunner(CFG, params, max_seq=32, speculate_k=4),
                  PROMPTS, opts)
    assert [r.outputs for r in plain] == [r.outputs for r in spec]
    assert [r.stats["logprobs"] for r in plain] == \
        [r.stats["logprobs"] for r in spec]
    _assert_ledger(spec)


def test_engine_stats_aggregate_speculation(params):
    core = EngineCore(LMRunner(CFG, params, max_seq=32, speculate_k=4),
                      EngineConfig(slots=2))
    ids = [core.submit(p, max_new_tokens=TOKENS) for p in PROMPTS]
    results = core.run_until_complete()
    stats = core.stats()
    assert stats["drafted_tokens"] == sum(
        results[i].stats["drafted_tokens"] for i in ids)
    assert stats["accepted_tokens"] == sum(
        results[i].stats["accepted_tokens"] for i in ids)
    assert 0.0 <= stats["accept_rate"] <= 1.0
    assert stats["goodput_accepted_tok_per_step"] >= 0.0
