"""Direct vs rate coding (paper §I, §V-D)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.coding import direct_code, rate_code, sparsity, spike_count


def test_direct_code_repeats_input():
    x = jax.random.uniform(jax.random.PRNGKey(0), (2, 4, 4, 3))
    coded = direct_code(x, 3)
    assert coded.shape == (3, 2, 4, 4, 3)
    for t in range(3):
        np.testing.assert_array_equal(np.asarray(coded[t]), np.asarray(x))


def test_rate_code_is_binary_with_matching_rate():
    x = jnp.full((1, 32, 32, 3), 0.3)
    spikes = rate_code(jax.random.PRNGKey(0), x, 200)
    assert set(np.unique(np.asarray(spikes))) <= {0.0, 1.0}
    rate = float(spikes.mean())
    assert abs(rate - 0.3) < 0.02


def test_rate_code_extremes():
    x = jnp.stack([jnp.zeros((4, 4)), jnp.ones((4, 4))])
    spikes = rate_code(jax.random.PRNGKey(1), x, 10)
    assert float(spikes[:, 0].sum()) == 0.0
    assert float(spikes[:, 1].mean()) == 1.0


def test_spike_count_and_sparsity():
    s = jnp.array([[1.0, 0, 0, 0], [0, 1.0, 0, 0]])
    assert int(spike_count(s)) == 2
    np.testing.assert_allclose(float(sparsity(s)), 0.75)
