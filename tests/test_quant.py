"""Quantization: QAT fake-quant, int4 packing, QTensor."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quant import (QTensor, dequantize, fake_quant, pack_int4,
                              qat_params, quantize_int4, unpack_int4)


def test_pack_unpack_roundtrip():
    rng = np.random.default_rng(0)
    q = rng.integers(-8, 8, size=(6, 10)).astype(np.int8)
    packed = pack_int4(jnp.asarray(q))
    assert packed.shape == (6, 5)
    out = unpack_int4(packed, (6, 10))
    np.testing.assert_array_equal(np.asarray(out), q)


def test_quantize_int4_error_bound():
    """|w - dequant(quant(w))| <= scale/2 per channel."""
    rng = np.random.default_rng(1)
    w = rng.normal(size=(32, 16)).astype(np.float32)
    qt = quantize_int4(jnp.asarray(w), axis=-1)
    back = np.asarray(dequantize(qt))
    scale = np.asarray(qt.scale).reshape(1, -1)
    assert np.all(np.abs(w - back) <= scale / 2 + 1e-7)


def test_qtensor_storage_is_4bit():
    w = jnp.ones((64, 128))
    qt = quantize_int4(w)
    assert qt.packed.size == 64 * 128 // 2
    assert qt.nbytes_logical == 64 * 128 // 2


def test_fake_quant_levels():
    """int4 symmetric -> at most 15 distinct levels."""
    w = jnp.linspace(-1, 1, 1000)
    out = fake_quant(w, 4, None)
    assert len(np.unique(np.asarray(out))) <= 15


def test_fake_quant_ste_gradient():
    w = jnp.array([0.1, -0.5, 0.9])
    g = jax.grad(lambda w: fake_quant(w, 4, None).sum())(w)
    np.testing.assert_allclose(np.asarray(g), 1.0, atol=1e-6)  # in-range: identity


def test_fake_quant_int8_tighter_than_int4():
    rng = np.random.default_rng(2)
    w = jnp.asarray(rng.normal(size=(100,)).astype(np.float32))
    e4 = jnp.abs(fake_quant(w, 4, None) - w).mean()
    e8 = jnp.abs(fake_quant(w, 8, None) - w).mean()
    assert e8 < e4


def test_qat_params_targets_weights_only():
    params = {"layer": {"w": jnp.linspace(-1, 1, 16), "b": jnp.linspace(-1, 1, 16),
                        "beta": jnp.asarray(0.15)}}
    out = qat_params(params, bits_w=4, bits_b=8)
    assert len(np.unique(np.asarray(out["layer"]["w"]))) <= 15
    assert len(np.unique(np.asarray(out["layer"]["b"]))) > 15  # int8: finer
    np.testing.assert_allclose(float(out["layer"]["beta"]), 0.15, rtol=1e-6)  # untouched


def test_qtensor_is_pytree():
    qt = quantize_int4(jnp.ones((8, 8)))
    leaves = jax.tree.leaves(qt)
    assert len(leaves) == 2
    out = jax.jit(lambda q: dequantize(q))(qt)
    assert out.shape == (8, 8)
