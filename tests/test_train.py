"""Training substrate: optimizers, schedules, checkpointing, fault tolerance."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ckpt
from repro.train.loop import TrainLoop
from repro.train.optim import (adafactor, adamw, apply_updates,
                               clip_by_global_norm, make_optimizer, sgd)
from repro.train.schedule import constant, warmup_cosine
from repro.train.train_step import init_train_state, make_train_step


# ---------------------------------------------------------------------------
# Optimizers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["sgd", "adamw", "adafactor"])
def test_optimizer_descends_quadratic(name):
    opt = make_optimizer(name, weight_decay=0.0) if name != "sgd" else sgd(0.9, 0.0)
    params = {"w": jnp.array([3.0, -2.0]), "m": jnp.ones((4, 4)) * 2}
    loss_fn = lambda p: jnp.sum(p["w"] ** 2) + jnp.sum(p["m"] ** 2)
    state = opt.init(params)
    for _ in range(150):
        g = jax.grad(loss_fn)(params)
        upd, state = opt.update(g, state, params, jnp.asarray(0.05))
        params = apply_updates(params, upd)
    assert float(loss_fn(params)) < 0.2


def test_adafactor_memory_is_factored():
    opt = adafactor()
    params = {"w": jnp.zeros((128, 256))}
    state = opt.init(params)
    n_state = sum(x.size for x in jax.tree.leaves(state["s"]))
    assert n_state == 128 + 256  # vr + vc, not 128*256


def test_clip_by_global_norm():
    grads = {"a": jnp.full((10,), 10.0)}
    clipped, norm = clip_by_global_norm(grads, 1.0)
    np.testing.assert_allclose(float(jnp.linalg.norm(clipped["a"])), 1.0, rtol=1e-5)
    assert float(norm) > 1.0


def test_schedules():
    lr = warmup_cosine(1.0, 10, 100, min_ratio=0.1)
    assert float(lr(0)) < float(lr(9))
    np.testing.assert_allclose(float(lr(10)), 1.0, rtol=0.1)
    assert float(lr(99)) < 0.2
    assert float(constant(0.5)(123)) == 0.5


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------

def _tree():
    return {"params": {"w": jnp.arange(12.0).reshape(3, 4)},
            "step": jnp.asarray(7, jnp.int32)}


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 7, t)
    template = jax.eval_shape(lambda: t)
    out = ckpt.restore(str(tmp_path), 7, template)
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                  np.asarray(t["params"]["w"]))
    assert int(out["step"]) == 7


def test_checkpoint_keep_k(tmp_path):
    for s in range(5):
        ckpt.save(str(tmp_path), s, _tree(), keep=2)
    assert ckpt.all_steps(str(tmp_path)) == [3, 4]
    assert ckpt.latest_step(str(tmp_path)) == 4


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    ckpt.save(str(tmp_path), 1, _tree())
    bad = jax.eval_shape(lambda: {"params": {"w": jnp.zeros((2, 2))},
                                  "step": jnp.asarray(0, jnp.int32)})
    with pytest.raises(ValueError):
        ckpt.restore(str(tmp_path), 1, bad)


def test_checkpoint_atomic_no_partial_visible(tmp_path):
    """A stale .tmp dir from a crashed writer is never listed as a step."""
    ckpt.save(str(tmp_path), 3, _tree())
    os.makedirs(os.path.join(str(tmp_path), "step_00000009.tmp.123"))
    assert ckpt.all_steps(str(tmp_path)) == [3]


# ---------------------------------------------------------------------------
# Fault-tolerant loop: crash -> resume -> bit-identical result
# ---------------------------------------------------------------------------

def _make_training(tmp_path):
    opt = adamw(weight_decay=0.0)
    target = jnp.asarray(np.random.default_rng(3).normal(size=(8,)).astype(np.float32))

    def loss_fn(params, batch):
        return jnp.sum((params["w"] * batch["x"] - batch["y"]) ** 2)

    step = jax.jit(make_train_step(loss_fn, opt, constant(0.05)))

    def make_batch(i):
        k = jax.random.fold_in(jax.random.PRNGKey(0), i)
        x = jax.random.normal(k, (8,))
        return {"x": x, "y": x * target}

    params = {"w": jnp.zeros(8)}
    state = init_train_state(params, opt)
    loop = TrainLoop(step, make_batch, ckpt_dir=str(tmp_path), ckpt_every=5,
                     log_every=100, log_fn=lambda *a: None)
    return loop, state


def test_loss_decreases(tmp_path):
    loop, state = _make_training(tmp_path / "a")
    state = loop.run(state, 120)
    first = loop.history[0][1]["loss"]
    last = loop.history[-1][1]["loss"]
    assert last < first * 0.3, (first, last)


def test_crash_resume_bit_identical(tmp_path):
    # uninterrupted run
    loop1, s1 = _make_training(tmp_path / "clean")
    final1 = loop1.run(s1, 20)

    # crashed-at-12 run, resumed from the step-10 checkpoint
    loop2, s2 = _make_training(tmp_path / "crash")
    with pytest.raises(RuntimeError):
        loop2.run(s2, 20, fail_at_step=12)
    template = jax.eval_shape(lambda: s2)
    restored, start = loop2.maybe_restore(template)
    assert start == 10
    final2 = loop2.run(restored, 20, start_step=start)

    for a, b in zip(jax.tree.leaves(final1), jax.tree.leaves(final2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_grad_accumulation_matches_full_batch():
    """accum_steps microbatching == one big batch (linear loss in batch)."""
    opt = sgd(momentum=0.0, weight_decay=0.0)

    def loss_fn(params, batch):
        return jnp.mean((params["w"] * batch["x"] - batch["y"]) ** 2)

    batch = {"x": jnp.arange(8.0) + 1, "y": jnp.ones(8)}
    s0 = init_train_state({"w": jnp.asarray(2.0)}, opt)
    s_full, m_full = make_train_step(loss_fn, opt, constant(0.1))(s0, batch)
    s_acc, m_acc = make_train_step(loss_fn, opt, constant(0.1), accum_steps=4)(s0, batch)
    np.testing.assert_allclose(float(m_full["loss"]), float(m_acc["loss"]), rtol=1e-6)
    np.testing.assert_allclose(float(s_full["params"]["w"]), float(s_acc["params"]["w"]),
                               rtol=1e-5)
