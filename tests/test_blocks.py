"""Block-level equivalence tests: attention, RG-LRU, xLSTM, MoE."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (attn_init, attention_block, attention_decode,
                                    chunked_causal_attention, init_kv_cache)
from repro.models.moe import moe_init, moe_apply
from repro.models.rglru import (rglru_block, rglru_block_decode, rglru_init,
                                rglru_init_state)
from repro.models.xlstm import (mlstm_block, mlstm_block_decode, mlstm_init,
                                mlstm_init_state, slstm_block, slstm_block_decode,
                                slstm_init, slstm_init_state)

KEY = jax.random.PRNGKey(0)


def _naive_attention(q, k, v, window=0):
    b, s, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    qh = q.reshape(b, s, kv, g, hd).astype(jnp.float32) / np.sqrt(hd)
    sc = jnp.einsum("bqkgh,bskh->bkgqs", qh, k.astype(jnp.float32))
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    sc = jnp.where(mask[None, None, None], sc, -1e30)
    w = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bkgqh", w, v.astype(jnp.float32))
    return jnp.moveaxis(out, 3, 1).reshape(b, s, h, hd)


@pytest.mark.parametrize("window", [0, 8])
@pytest.mark.parametrize("q_chunk,kv_chunk", [(4, 8), (8, 4), (32, 32)])
def test_chunked_attention_matches_naive(window, q_chunk, kv_chunk):
    b, s, h, kv, hd = 2, 32, 4, 2, 16
    q = jax.random.normal(jax.random.fold_in(KEY, 1), (b, s, h, hd))
    k = jax.random.normal(jax.random.fold_in(KEY, 2), (b, s, kv, hd))
    v = jax.random.normal(jax.random.fold_in(KEY, 3), (b, s, kv, hd))
    out = chunked_causal_attention(q, k, v, window=window,
                                   q_chunk=q_chunk, kv_chunk=kv_chunk)
    ref = _naive_attention(q, k, v, window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_chunked_attention_unroll_identical():
    b, s, h, kv, hd = 1, 16, 4, 4, 8
    q = jax.random.normal(jax.random.fold_in(KEY, 4), (b, s, h, hd))
    k = jax.random.normal(jax.random.fold_in(KEY, 5), (b, s, kv, hd))
    v = jax.random.normal(jax.random.fold_in(KEY, 6), (b, s, kv, hd))
    a = chunked_causal_attention(q, k, v, q_chunk=4, kv_chunk=4, unroll=False)
    b_ = chunked_causal_attention(q, k, v, q_chunk=4, kv_chunk=4, unroll=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=1e-6)


@pytest.mark.parametrize("window", [0, 6])
def test_attention_decode_matches_block(window):
    """Per-token decode with ring-buffer cache == full attention."""
    d, h, kv, hd, s, b = 32, 4, 1, 8, 12, 2
    p = attn_init(jax.random.fold_in(KEY, 7), d, h, kv, hd, False, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(KEY, 8), (b, s, d)) * 0.3
    full = attention_block(p, x, n_heads=h, n_kv_heads=kv, head_dim=hd,
                           rope_theta=1e4, window=window, q_chunk=4, kv_chunk=4)
    cache = init_kv_cache(b, window if window else s, kv, hd, jnp.float32)
    outs = []
    for t in range(s):
        o, cache = attention_decode(p, x[:, t:t + 1], cache, jnp.int32(t),
                                    n_heads=h, n_kv_heads=kv, head_dim=hd,
                                    rope_theta=1e4, window=window)
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), atol=2e-4)


def test_rglru_decode_matches_scan():
    d, d_rnn, b, s = 24, 24, 2, 10
    p = rglru_init(jax.random.fold_in(KEY, 9), d, d_rnn, 4, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(KEY, 10), (b, s, d)) * 0.5
    full = rglru_block(p, x)
    state = rglru_init_state(b, d_rnn, 4, jnp.float32)
    outs = []
    for t in range(s):
        o, state = rglru_block_decode(p, x[:, t:t + 1], state)
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), atol=1e-4)


def test_rglru_state_decays():
    """RG-LRU is a leaky integrator: zero input decays the state."""
    d = 8
    p = rglru_init(jax.random.fold_in(KEY, 11), d, d, 4, jnp.float32)
    state = rglru_init_state(2, d, 4, jnp.float32)
    state = dict(state, h=jnp.ones((2, d)))
    _, s2 = rglru_block_decode(p, jnp.zeros((2, 1, d)), state)
    assert float(jnp.abs(s2["h"]).max()) < 1.0


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_mlstm_chunked_matches_sequential(chunk):
    b, s, d, h = 2, 16, 32, 4
    p = mlstm_init(jax.random.fold_in(KEY, 12), d, h, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(KEY, 13), (b, s, d)) * 0.5
    blk = mlstm_block(p, x, h, chunk=chunk)
    st = mlstm_init_state(b, d, h)
    outs = []
    for t in range(s):
        o, st = mlstm_block_decode(p, x[:, t:t + 1], st, h)
        outs.append(o)
    np.testing.assert_allclose(np.asarray(blk), np.asarray(jnp.concatenate(outs, 1)),
                               atol=1e-4)


def test_slstm_block_matches_decode():
    b, s, d, h = 2, 12, 16, 4
    p = slstm_init(jax.random.fold_in(KEY, 14), d, h, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(KEY, 15), (b, s, d)) * 0.5
    blk = slstm_block(p, x, h)
    st = slstm_init_state(b, d)
    outs = []
    for t in range(s):
        o, st = slstm_block_decode(p, x[:, t:t + 1], st, h)
        outs.append(o)
    np.testing.assert_allclose(np.asarray(blk), np.asarray(jnp.concatenate(outs, 1)),
                               atol=1e-5)


def test_moe_matches_dense_expert_reference():
    """With ample capacity, capacity-grouped MoE == explicit per-token experts."""
    b, s, d, e, k, ff = 2, 8, 16, 4, 2, 32
    p = moe_init(jax.random.fold_in(KEY, 16), d, e, ff, "swiglu", jnp.float32)
    x = jax.random.normal(jax.random.fold_in(KEY, 17), (b, s, d)) * 0.5
    y, aux = moe_apply(p, x, top_k=k, act="swiglu", n_experts=e, capacity_factor=8.0)

    # reference: run every expert densely, combine with the same gates
    xt = x.reshape(-1, d)
    logits = xt @ p["w_router"]
    gv, idx = jax.lax.top_k(logits, k)
    w = jax.nn.softmax(gv, axis=-1)
    dense = []
    for ei in range(e):
        h = xt @ p["experts"]["w_in"][ei]
        hg = jax.nn.silu(xt @ p["experts"]["w_gate"][ei])
        dense.append((hg * h) @ p["experts"]["w_out"][ei])
    dense = jnp.stack(dense, 1)                       # [T, E, d]
    ref = jnp.zeros_like(xt)
    for kk in range(k):
        ref += w[:, kk:kk + 1] * jnp.take_along_axis(
            dense, idx[:, kk][:, None, None], axis=1)[:, 0]
    np.testing.assert_allclose(np.asarray(y.reshape(-1, d)), np.asarray(ref), atol=1e-4)
    assert float(aux) > 0


def test_moe_capacity_drops_tokens():
    """Tiny capacity forces drops; output stays finite and bounded."""
    b, s, d, e = 1, 16, 8, 2
    p = moe_init(jax.random.fold_in(KEY, 18), d, e, 16, "gelu", jnp.float32)
    x = jax.random.normal(jax.random.fold_in(KEY, 19), (b, s, d))
    y, _ = moe_apply(p, x, top_k=1, act="gelu", n_experts=e, capacity_factor=0.25)
    assert bool(jnp.isfinite(y).all())


def test_moe_padded_experts_never_routed():
    b, s, d, e = 2, 8, 8, 3
    p = moe_init(jax.random.fold_in(KEY, 20), d, e, 16, "gelu", jnp.float32,
                 n_experts_padded=4)
    assert p["experts"]["w_in"].shape[0] == 4
    x = jax.random.normal(jax.random.fold_in(KEY, 21), (b, s, d))
    y, _ = moe_apply(p, x, top_k=2, act="gelu", n_experts=e, n_experts_padded=4,
                     capacity_factor=4.0)
    # zeroing the padded expert's weights must not change the output
    p2 = jax.tree.map(lambda a: a, p)
    p2["experts"] = {kk: vv.at[3].set(0.0) for kk, vv in p["experts"].items()}
    y2, _ = moe_apply(p2, x, top_k=2, act="gelu", n_experts=e, n_experts_padded=4,
                      capacity_factor=4.0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2), atol=1e-6)
