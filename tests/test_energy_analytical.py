"""Analytical (per-op) energy model: `core.energy.analytical_energy_per_image`.

The model prices every membrane update (Horowitz-style per-op constants)
instead of FPGA power x latency (Eq. 3). The load-bearing property is the
deliberate disagreement between the two: Eq. 3 bills weight *storage* for
the whole layer latency, the analytical model bills weight *traffic* that
scales with spikes — so near-silent inputs look relatively cheaper under
the analytical model, and the precision controller consults both.
"""
import pytest

from repro.core.energy import (ANALYTICAL_FP32, ANALYTICAL_INT4,
                               AnalyticalEnergyModel, analytical_energy_per_image,
                               analytical_model, energy_per_image)
from repro.core.workload import (balance_allocation, conv_workload,
                                 dense_input_workload, fc_workload)


def _workloads(spikes):
    return [
        dense_input_workload("conv0", 8, 8, 4, 2),
        conv_workload("conv1", 8, 9, spikes),
        fc_workload("fc0", 16, spikes / 2),
    ]


def test_precision_mapping():
    assert analytical_model("fp32") is ANALYTICAL_FP32
    assert analytical_model("int4") is ANALYTICAL_INT4
    with pytest.raises(KeyError):
        analytical_model("int8")
    # int4 is cheaper on every axis the precision flips: op energy and
    # weight traffic; SRAM cost per byte and state word are shared
    assert ANALYTICAL_INT4.e_acc_j < ANALYTICAL_FP32.e_acc_j
    assert ANALYTICAL_INT4.e_mac_j < ANALYTICAL_FP32.e_mac_j
    assert ANALYTICAL_INT4.wbytes < ANALYTICAL_FP32.wbytes
    assert ANALYTICAL_INT4.e_sram_j_per_byte == ANALYTICAL_FP32.e_sram_j_per_byte
    assert ANALYTICAL_INT4.state_bytes == ANALYTICAL_FP32.state_bytes


def test_split_sums_to_total_and_int4_beats_fp32():
    for spikes in (0.0, 37.0, 512.0):
        for precision in ("fp32", "int4"):
            e = analytical_energy_per_image(_workloads(spikes), precision)
            assert e["energy_j"] == pytest.approx(
                e["energy_compute_j"] + e["energy_memory_j"])
            assert e["energy_compute_j"] >= 0 and e["energy_memory_j"] > 0
        fp32 = analytical_energy_per_image(_workloads(spikes), "fp32")
        int4 = analytical_energy_per_image(_workloads(spikes), "int4")
        assert int4["energy_j"] < fp32["energy_j"]


def test_monotone_in_spikes():
    prev = -1.0
    for spikes in (0.0, 1.0, 10.0, 100.0, 1000.0):
        e = analytical_energy_per_image(_workloads(spikes), "int4")["energy_j"]
        assert e > prev
        prev = e


def test_silent_spiking_layers_cost_only_the_dense_input():
    """Zero spikes -> conv/fc trigger zero updates; all remaining energy is
    the dense-coded input layer paying full MACs + its weight/state traffic."""
    silent = analytical_energy_per_image(_workloads(0.0), "fp32")
    dense_only = analytical_energy_per_image(
        [dense_input_workload("conv0", 8, 8, 4, 2)], "fp32")
    assert silent["energy_j"] == pytest.approx(dense_only["energy_j"])
    m = ANALYTICAL_FP32
    fan = 8 * 8 * 4 * 2
    assert silent["energy_compute_j"] == pytest.approx(fan * m.e_mac_j)
    assert silent["energy_memory_j"] == pytest.approx(
        fan * (m.wbytes + m.state_bytes) * m.e_sram_j_per_byte)


def test_dense_input_pays_macs_spiking_layers_accumulates():
    """A conv layer's compute is priced at e_acc, the dense input at e_mac —
    same update count must yield e_mac/e_acc compute ratio."""
    fan = 1000
    as_dense = analytical_energy_per_image(
        [dense_input_workload("x", 10, 10, 10, 1)], "fp32")
    as_conv = analytical_energy_per_image(
        [conv_workload("x", 100, 10, 1.0)], "fp32")   # fan 1000, spikes 1
    m = ANALYTICAL_FP32
    assert as_dense["energy_compute_j"] == pytest.approx(fan * m.e_mac_j)
    assert as_conv["energy_compute_j"] == pytest.approx(fan * m.e_acc_j)
    assert as_dense["energy_memory_j"] == pytest.approx(
        as_conv["energy_memory_j"])


def test_custom_model_overrides_precision():
    m = AnalyticalEnergyModel(e_acc_j=1.0, e_mac_j=2.0,
                              e_sram_j_per_byte=0.0, wbytes=0.0,
                              state_bytes=0.0)
    e = analytical_energy_per_image(_workloads(10.0), "int4", model=m)
    # 128 dense MACs @2 + (72*10 + 16*5) accumulates @1, no memory term
    assert e["energy_memory_j"] == 0.0
    assert e["energy_j"] == pytest.approx(8 * 8 * 4 * 2 * 2.0 + 720 + 80)


def test_storage_vs_traffic_disagreement_with_eq3():
    """The documented model split, made falsifiable: under Eq. 3 the int4
    payoff is a fixed power ratio — at a given allocation the int4/fp32
    energy ratio does not move with sparsity at all.  Under the analytical
    model the payoff *couples to sparsity*: weight traffic scales with
    spikes, so denser inputs shift energy toward the (cheaper-per-op but
    shared-SRAM) terms and the int4/fp32 ratio drifts.  The two models also
    disagree on the ratio's magnitude by a wide margin — which is why the
    precision controller prices decisions under both rather than trusting
    one."""
    def ratios(spikes):
        w = _workloads(spikes)
        alloc = balance_allocation(w, 12)
        eq3 = (energy_per_image(w, alloc, [0.5] * 3, "int4")["energy_j"]
               / energy_per_image(w, alloc, [4.0] * 3, "fp32")["energy_j"])
        ana = (analytical_energy_per_image(w, "int4")["energy_j"]
               / analytical_energy_per_image(w, "fp32")["energy_j"])
        return eq3, ana

    eq3_quiet, ana_quiet = ratios(1.0)
    eq3_dense, ana_dense = ratios(1000.0)
    # Eq. 3: storage-power ratio, sparsity-invariant at fixed allocation
    assert eq3_quiet == pytest.approx(eq3_dense, rel=1e-6)
    # analytical: quantization payoff couples to sparsity
    assert abs(ana_dense - ana_quiet) > 0.02
    # and the models disagree on the payoff magnitude itself
    assert abs(eq3_dense - ana_dense) > 0.1
    assert ana_dense > eq3_dense        # Eq. 3 overstates the int4 win


def test_empty_workloads_cost_nothing():
    e = analytical_energy_per_image([], "int4")
    assert e == {"energy_j": 0.0, "energy_compute_j": 0.0,
                 "energy_memory_j": 0.0}
