"""Edge-case regression battery for `transformer.decode_chunk` — the ragged
multi-token launch that serves as both the chunked-prefill and the
speculative-verify primitive.

The contract under test: `decode_chunk` IS C sequential `decode_step` calls
with per-column active masks, fused — so every edge (take=0 rows, C=1,
full-chunk rows, ragged pos0) must be bit-identical to the sequential
reference, picks and logits and cache alike. `rollback_cache_rows` must
restore the exact never-consumed state for the rejected suffix. The
empty-prompt argmax-placeholder seam (`runners/lm.py` admit()) must
survive speculation being enabled.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.models import transformer as tf
from repro.serve.api import EngineConfig, Request, StepBudget
from repro.serve.core import EngineCore
from repro.serve.runners.lm import LMRunner

CFG = ArchConfig(name="t-chunk", family="dense", n_layers=1, d_model=32,
                 n_heads=4, n_kv_heads=2, head_dim=8, d_ff=64, vocab=31,
                 dtype="float32", remat="none", q_chunk=8, kv_chunk=8)
SEQ = 16


@pytest.fixture(scope="module")
def params():
    return tf.init_params(jax.random.PRNGKey(0), CFG)


def _caches_equal(a, b, rows=None):
    """Compare caches exactly; with ``rows``, only those batch rows."""
    la, ta = jax.tree.flatten(a)
    lb, tb = jax.tree.flatten(b)
    assert ta == tb
    for x, y in zip(la, lb):
        x, y = np.asarray(x), np.asarray(y)
        if rows is not None:
            axis = 1 if x.ndim >= 4 and x.shape[0] != len(rows) else 0
            x = np.take(x, np.flatnonzero(rows), axis=axis)
            y = np.take(y, np.flatnonzero(rows), axis=axis)
        np.testing.assert_array_equal(x, y)


def _sequential_reference(params, cache, tokens, pos0, take):
    """C decode_step calls with per-column active masks — the semantics
    decode_chunk fuses."""
    b, c = tokens.shape
    picks = np.zeros((b, c), np.int32)
    logits = np.zeros((b, c, CFG.vocab), np.float32)
    for t in range(c):
        act = np.arange(c)[t] < take
        lg, cache = tf.decode_step(
            params, cache, {"tokens": tokens[:, t][:, None]},
            jnp.asarray(pos0 + t, jnp.int32), CFG,
            active=jnp.asarray(act))
        last = np.asarray(lg[:, -1])
        picks[:, t] = last.argmax(axis=-1)
        logits[:, t] = last
    return picks, logits, cache


def _rand_tokens(b, c, seed=0):
    return np.random.default_rng(seed).integers(
        1, CFG.vocab, size=(b, c)).astype(np.int32)


def test_c1_equals_decode_step_exactly(params):
    """A width-1 chunk is one decode_step: picks, logits and cache all
    bit-identical (the seam the session's pow2 bucketing relies on)."""
    b = 3
    tokens = _rand_tokens(b, 1)
    pos0 = np.array([0, 2, 5], np.int32)
    cache = tf.init_cache(CFG, b, SEQ)
    # seed the caches identically with a couple of positions of history
    for t in range(2):
        _, cache = tf.decode_step(params, cache,
                                  {"tokens": _rand_tokens(b, 1, 9 + t)},
                                  jnp.asarray(pos0 - 2 + t), CFG)

    step_logits, step_cache = tf.decode_step(
        params, cache, {"tokens": tokens}, jnp.asarray(pos0), CFG)
    picks, logits, chunk_cache = tf.decode_chunk(
        params, cache, jnp.asarray(tokens), pos0,
        jnp.ones(b, np.int32), CFG)

    np.testing.assert_array_equal(
        np.asarray(picks)[:, 0], np.asarray(step_logits[:, -1]).argmax(-1))
    np.testing.assert_array_equal(np.asarray(logits)[:, 0],
                                  np.asarray(step_logits[:, -1]))
    _caches_equal(chunk_cache, step_cache)


def test_take_zero_rows_freeze(params):
    """take=0 rows advance no cache and their outputs are garbage to be
    ignored — the inactive-slot contract free slots ride along on."""
    b, c = 3, 4
    tokens = _rand_tokens(b, c)
    pos0 = np.zeros(b, np.int32)
    take = np.array([c, 0, 2], np.int32)
    cache = tf.init_cache(CFG, b, SEQ)
    _, _, new_cache = tf.decode_chunk(params, cache, jnp.asarray(tokens),
                                      jnp.asarray(pos0),
                                      jnp.asarray(take), CFG)
    frozen = np.array([False, True, False])
    _caches_equal(new_cache, cache, rows=frozen)
    # active rows did write: their KV entries moved off the zero init
    changed = np.array([True, False, True])
    with pytest.raises(AssertionError):
        _caches_equal(new_cache, cache, rows=changed)


def test_ragged_chunk_matches_sequential_decode_steps(params):
    """Full-chunk, partial, and single-token rows at ragged pos0, against
    the C-sequential-decode_steps reference: bit-identical picks, logits
    at every consumed column, and cache."""
    b, c = 4, 5
    tokens = _rand_tokens(b, c, 3)
    pos0 = np.array([0, 3, 1, 6], np.int32)
    take = np.array([c, 1, 3, 2], np.int32)   # full / one / partial / partial
    cache0 = tf.init_cache(CFG, b, SEQ)

    ref_picks, ref_logits, ref_cache = _sequential_reference(
        params, cache0, tokens, pos0, take)
    picks, logits, cache = tf.decode_chunk(
        params, cache0, jnp.asarray(tokens), jnp.asarray(pos0),
        jnp.asarray(take), CFG)

    picks, logits = np.asarray(picks), np.asarray(logits)
    for i in range(b):
        cols = np.arange(take[i])             # masked columns carry garbage
        np.testing.assert_array_equal(picks[i, cols], ref_picks[i, cols])
        np.testing.assert_array_equal(logits[i, cols], ref_logits[i, cols])
    _caches_equal(cache, ref_cache)


def test_rollback_restores_never_consumed_state(params):
    """Consume a verify-shaped chunk, roll the suffix back: the cache must
    equal one that only ever consumed the accepted prefix."""
    b, c = 2, 4
    tokens = _rand_tokens(b, c, 4)
    pos0 = np.array([2, 5], np.int32)
    cache0 = tf.init_cache(CFG, b, SEQ)
    # seed history up to pos0 so the rollback boundary is interior
    for t in range(2):
        _, cache0 = tf.decode_step(params, cache0,
                                   {"tokens": _rand_tokens(b, 1, 7 + t)},
                                   jnp.asarray(pos0 - 2 + t), CFG)

    keep = np.array([1, 3], np.int32)          # accepted columns per row
    _, _, full = tf.decode_chunk(params, cache0, jnp.asarray(tokens),
                                 jnp.asarray(pos0),
                                 jnp.full(b, c, np.int32), CFG)
    _, _, prefix = tf.decode_chunk(params, cache0, jnp.asarray(tokens),
                                   jnp.asarray(pos0),
                                   jnp.asarray(keep), CFG)
    rolled = tf.rollback_cache_rows(full, jnp.asarray(pos0 + keep),
                                    jnp.ones(b, bool))
    _caches_equal(rolled, prefix)
    # and a False row mask leaves a row untouched
    half = tf.rollback_cache_rows(full, jnp.asarray(pos0 + keep),
                                  jnp.asarray([True, False]))
    _caches_equal(half, prefix, rows=np.array([True, False]))
    _caches_equal(half, full, rows=np.array([False, True]))


def test_empty_prompt_placeholder_seam_with_speculation(params):
    """The empty-prompt argmax-placeholder 0 (batch-path parity seam in
    `runners/lm.py` admit()) survives speculation: same stream as the
    plain session, placeholder logprob recorded as 0.0."""
    outs = {}
    for label, k in (("plain", 0), ("spec", 4)):
        runner = LMRunner(CFG, params, max_seq=SEQ, speculate_k=k)
        core = EngineCore(runner, EngineConfig(slots=2))
        rid = core.submit([], max_new_tokens=8, logprobs=True)
        full = core.submit([5, 4, 3], max_new_tokens=8)
        results = core.run_until_complete()
        assert results[rid].outputs[0] == 0      # forced placeholder
        assert results[rid].stats["logprobs"][0] == 0.0
        assert len(results[rid].stats["logprobs"]) == 8
        outs[label] = (results[rid].outputs, results[full].outputs)
    assert outs["plain"] == outs["spec"]


def test_session_chunk_c1_bucket_equals_budget_chunk1(params):
    """Session-level seam: a budget that produces width-1 launches and one
    that produces wider (bucketed) launches emit the same stream."""
    runner = LMRunner(CFG, params, max_seq=SEQ)
    streams = {}
    for chunk in (1, 4):
        sess = runner.open_session(slots=2)
        sess.admit(0, Request(0, [1, 2, 3, 4, 5, 6], {"max_new_tokens": 6}))
        sess.admit(1, Request(1, [9, 8], {"max_new_tokens": 6}))
        done = {}
        for _ in range(50):
            done.update(sess.step(StepBudget(chunk=chunk)).finished)
            if len(done) == 2:
                break
        streams[chunk] = [done[i].outputs for i in (0, 1)]
    assert streams[1] == streams[4]
